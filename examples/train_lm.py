"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU
with the full production substrate (pipeline, AdamW, checkpoint/restart,
straggler watchdog).

    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch qwen3-0.6b
"""
import argparse
import dataclasses
import logging

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def hundred_m(base: ModelConfig) -> ModelConfig:
    """~100M-param variant of the chosen arch family (CPU-trainable)."""
    return dataclasses.replace(
        base, name=base.name + "-100m", n_layers=max(4, base.n_layers // 7),
        d_model=512, n_heads=8, n_kv=4, d_ff=1536, d_head=64, vocab=32000)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-lm")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = hundred_m(get_config(args.arch))
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params")
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        DataConfig(batch=args.batch, seq_len=args.seq),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=10),
    )
    report = trainer.run()
    print("final:", report)


if __name__ == "__main__":
    main()

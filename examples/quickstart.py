"""Quickstart: partition a graph with DFEP and run ETSCH algorithms on it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import algorithms as alg
from repro.core import dfep, etsch, graph, metrics


def main() -> None:
    # 1. a graph (synthetic stand-in for the paper's ASTROPH dataset)
    g = graph.load_dataset("astroph", scale=0.1, seed=0)
    print(f"graph: |V|={g.n_vertices} |E|={g.n_edges}")

    # 2. DFEP edge partitioning (paper §IV), K=8 partitions
    owner, info = dfep.partition(g, k=8, key=0)
    print(f"DFEP: rounds={info['rounds']} unsold={info['unsold_at_stop']}")

    # 3. quality metrics (paper §V-A)
    m = metrics.evaluate(g, owner, 8)
    print(f"balance: largest={m.largest_norm:.3f} nstdev={m.nstdev:.3f}")
    print(f"comm:    messages={m.messages} frontier={m.frontier_total}")
    print(f"connected partitions: {m.connected_frac:.0%}  gain={m.gain:.3f}")

    # 4. ETSCH (paper §III): SSSP / CC / PageRank / MIS on the partitions
    part = etsch.compile_partitioning(g, owner, 8)
    sssp = alg.etsch_sssp(part, source=0)
    print(f"SSSP: {int(sssp.supersteps)} supersteps "
          f"(vertex-centric baseline: {int(alg.reference_sssp(g, 0)[1])})")
    cc = alg.etsch_cc(part, key=1)
    print(f"CC:   {int(cc.supersteps)} supersteps")
    pr = alg.etsch_pagerank(part, g.degrees(), iters=20)
    print(f"PageRank: mass={float(pr.rank.sum()):.4f} (→1.0)")
    mis = alg.etsch_mis(part, jax.random.key(2))
    print(f"MIS:  |S|={int(mis.in_set.sum())} valid="
          f"{bool(alg.is_maximal_independent_set(g, mis.in_set))}")


if __name__ == "__main__":
    main()

"""Serve a small model with batched requests: prefill + greedy decode via
the production Engine (KV caches, batched decode steps).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b --n-new 16
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.serve_step import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--n-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params, _ = lm.init_params(cfg, jax.random.key(0))
    engine = Engine(cfg, params, s_max=args.prompt_len + args.n_new + 8)

    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    out = engine.generate(prompts, n_new=args.n_new)
    print(f"{args.batch} requests x {args.n_new} new tokens:")
    for i in range(args.batch):
        print(f"  req {i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()

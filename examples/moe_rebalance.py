"""Beyond-paper demo: DFEP-balanced MoE expert placement (DESIGN.md §4).

Simulates Zipf-skewed routing, runs DFEP on the expert co-activation graph,
and reports the shard-load imbalance before/after re-placement.

    PYTHONPATH=src python examples/moe_rebalance.py
"""
import numpy as np

from repro.core import moe_dfep


def main() -> None:
    rng = np.random.default_rng(0)
    e, k, t = 64, 8, 20000
    p = 1.0 / (np.arange(e) + 1.0) ** 1.1
    p /= p.sum()
    first = rng.choice(e, size=t, p=p)
    second = (first + rng.choice([1, 2, 3, 5], size=t)) % e
    eidx = np.stack([first, second], 1)
    loads = np.bincount(eidx.reshape(-1), minlength=e).astype(float)

    naive = moe_dfep.naive_imbalance(loads, k)
    placement = moe_dfep.place_experts(eidx, n_experts=e, k=k, seed=0)
    print(f"experts={e} shards={k} tokens={t}")
    print(f"naive contiguous placement: max/mean load = {naive:.3f}")
    print(f"DFEP-balanced placement:    max/mean load = "
          f"{placement.imbalance:.3f}")
    print(f"per-shard load: {placement.shard_load.astype(int).tolist()}")


if __name__ == "__main__":
    main()

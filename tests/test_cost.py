"""Cost attribution: the hlo_parse analyzer on real engine executables,
CostModel memoization and its never-raise contract, CostLedger accounting
invariants on a served mixed-tenant workload, cost-weighted admission and
flush ordering, and the usage renderer."""
import json

import numpy as np
import pytest

from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import obs
from repro.engine.registry import get_program
from repro.gserve.request import AdmissionError
from repro.gserve.scheduler import MicroBatcher
from repro.obs import profile, usage
from repro.obs.ledger import CostLedger, CostSample
from repro.roofline.hlo_parse import analyze_hlo


@pytest.fixture(autouse=True)
def _clean_profile_cache():
    """The model cache and recorder are process-global; leave both clean
    for whichever test runs next."""
    profile.reset_models()
    rec = obs.get()
    rec.disable()
    rec.reset()
    yield
    profile.reset_models()
    rec.disable()
    rec.reset()


def _engine(n=120, k=4, seed=3):
    g = graph.watts_strogatz(n, 4, 0.2, seed=seed)
    owner, _ = dfep.partition(g, k=k, key=0)
    return g, E.Engine(E.compile_plan(g, np.asarray(owner), k))


def _lower(g, eng, kind, params, batched=None):
    """Lower exactly the executable the serving path would dispatch."""
    entry = get_program(kind)
    params = G.QueryRequest(kind, params=params).params
    kw = {name: fn(g) for name, fn in entry.resources}
    kw.update(entry.ctx_args(params))
    return eng.lower_hlo(entry.program, batched_kw=batched,
                         max_supersteps=entry.supersteps_of(params), **kw)


# ---------------------------------------------------------------------------
# hlo_parse robustness + engine-executable coverage (ISSUE 8 satellites)
# ---------------------------------------------------------------------------

def test_unknown_opcode_degrades_to_unmodeled_count():
    hlo = """HloModule m

ENTRY %main (p0: f32[64], p1: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %p1 = f32[64]{0} parameter(1)
  %a = f32[64]{0} add(%p0, %p1)
  %b = f32[64]{0} frobnicate(%a, %p1)
  ROOT %r = f32[64]{0} multiply(%a, %b)
}
"""
    c = analyze_hlo(hlo)
    # the unknown op is counted, not raised, and does not poison the
    # modeled instructions around it (add + multiply = 2 * 64 flops);
    # its byte traffic is still charged (bytes need only shapes)
    assert c.unmodeled_ops == 1
    assert c.flops == 128.0
    assert c.bytes_traffic > 0
    assert np.isfinite(c.arithmetic_intensity)


def test_engine_hlo_costs_positive_and_monotone_in_graph_size():
    """Parse the compiled SSSP (batched) and PageRank superstep HLO at two
    graph sizes: flops/bytes positive, finite, and monotone."""
    bkw = {"source": np.zeros(4, np.int32)}
    costs = {}
    for n in (120, 240):
        g, eng = _engine(n=n)
        sssp = analyze_hlo(_lower(g, eng, "sssp", {"source": 0},
                                  batched=bkw), trip_clamp=1)
        pr = analyze_hlo(_lower(g, eng, "pagerank", {"iters": 5}),
                         trip_clamp=1)
        for c in (sssp, pr):
            assert c.flops > 0 and np.isfinite(c.flops)
            assert c.bytes_traffic > 0 and np.isfinite(c.bytes_traffic)
        costs[n] = (sssp, pr)
    s_small, p_small = costs[120]
    s_big, p_big = costs[240]
    assert s_big.flops > s_small.flops
    assert s_big.bytes_traffic > s_small.bytes_traffic
    assert p_big.flops > p_small.flops
    assert p_big.bytes_traffic > p_small.bytes_traffic


# ---------------------------------------------------------------------------
# obs.profile: memoized CostModel
# ---------------------------------------------------------------------------

def test_cost_model_memoized_per_shape():
    g, eng = _engine()
    entry = get_program("sssp")
    bkw = {"source": np.zeros(4, np.int32)}
    m1 = profile.cost_model(eng, entry.program, bucket=4, batched_kw=bkw)
    assert m1.error is None
    assert m1.flops_per_sweep > 0 and m1.hbm_bytes_per_sweep > 0
    assert m1.compile_s > 0
    m2 = profile.cost_model(eng, entry.program, bucket=4, batched_kw=bkw)
    assert m2 is m1                                  # cache hit
    st = profile.profile_stats()
    assert st["hits"] == 1 and st["misses"] == 1 and st["errors"] == 0
    # a different bucket is a different executable -> a fresh model
    bkw8 = {"source": np.zeros(8, np.int32)}
    m3 = profile.cost_model(eng, entry.program, bucket=8, batched_kw=bkw8)
    assert m3 is not m1 and profile.profile_stats()["misses"] == 2
    # cost() scales linearly in sweeps; attainable_s is a positive bound
    fl1, by1, _ = m1.cost(1)
    fl3, by3, _ = m1.cost(3)
    assert fl3 == pytest.approx(3 * fl1) and by3 == pytest.approx(3 * by1)
    assert m1.attainable_s(3) > 0


def test_cost_model_never_raises():
    g, eng = _engine()

    class Boom:
        plan = eng.plan
        mesh = None

        def lower_hlo(self, *a, **kw):
            raise RuntimeError("lowering exploded")

    m = profile.cost_model(Boom(), get_program("sssp").program, bucket=4)
    assert m.error is not None and "lowering exploded" in m.error
    assert m.cost(10) == (0.0, 0.0, 0.0)
    # the error model is cached too: a persistently broken lowering is
    # paid for once, not per dispatch
    m2 = profile.cost_model(Boom(), get_program("sssp").program, bucket=4)
    assert m2 is m
    st = profile.profile_stats()
    assert st["errors"] == 1 and st["hits"] == 1


# ---------------------------------------------------------------------------
# CostLedger accounting
# ---------------------------------------------------------------------------

def _sample(tenant, device_s, program="sssp", graph_fp="g1", epoch=0, **kw):
    return CostSample(tenant=tenant, program=program, graph=graph_fp,
                      epoch=epoch, device_s=device_s, **kw)


def test_ledger_totals_shares_and_snapshot():
    led = CostLedger(window_s=30.0)
    led.post(_sample("a", 0.3, flops=3e6, utilization=0.5))
    led.post(_sample("a", 0.3, program="pagerank", flops=6e6))
    led.post(_sample("b", 0.2, flops=2e6, utilization=1.0))
    led.post(_sample("b", 0.0, from_cache=True))
    tot = led.totals()
    assert tot["series"] == 3
    assert tot["device_s"] == pytest.approx(0.8)
    assert tot["flops"] == pytest.approx(11e6)
    assert tot["requests"] == 4
    assert tot["dispatched"] == 3 and tot["cached"] == 1
    # lifetime shares sum to 1 and split by device time
    shares = led.tenant_shares(None)
    assert sum(shares.values()) == pytest.approx(1.0)
    assert shares["a"] == pytest.approx(0.75)
    # windowed shares (all samples just posted) agree
    win = led.tenant_shares(30.0)
    assert win["a"] == pytest.approx(0.75, rel=1e-6)
    snap = led.snapshot()
    assert snap["kind"] == "cost_ledger"
    assert set(snap["tenants"]) == {"a", "b"}
    assert snap["tenants"]["b"]["cached"] == 1
    # utilization aggregates device-time-weighted: b's 0.2s at 1.0 plus
    # a 0s cache hit -> 1.0
    assert snap["tenants"]["b"]["utilization"] == pytest.approx(1.0)
    assert len(snap["series"]) == 3


def test_ledger_merge_is_additive():
    a, b = CostLedger(), CostLedger()
    a.post(_sample("a", 0.5, flops=1e6))
    b.post(_sample("a", 0.25, flops=2e6))
    b.post(_sample("c", 0.25))
    a.merge(b)
    tot = a.totals()
    assert tot["device_s"] == pytest.approx(1.0)
    assert tot["flops"] == pytest.approx(3e6)
    assert tot["series"] == 2                  # same-key series folded
    assert a.tenant_shares(None)["a"] == pytest.approx(0.75)


def test_served_workload_reconciles_with_execute_spans():
    """The ISSUE 8 acceptance invariant, at test scale: ledger device
    seconds == the server's measured execute-span total (±1%), and every
    completed request lands in exactly one series (cache hits included)."""
    g, eng = _engine(n=150)
    led = CostLedger(window_s=30.0)
    srv = G.GraphServer(eng, g, buckets=(1, 4), ledger=led)
    reqs = [G.QueryRequest("sssp", tenant="a", params={"source": s})
            for s in (0, 1, 2)]
    reqs += [G.QueryRequest("pagerank", tenant="b", params={"iters": 5}),
             G.QueryRequest("wcc", tenant="b")]
    srv.serve(reqs)
    # repeat query -> result-cache hit -> zero-cost sample, same series key
    rep = srv.serve([G.QueryRequest("sssp", tenant="a",
                                    params={"source": 0})])[0]
    assert rep.from_cache
    tot = led.totals()
    dev = srv.metrics.device_time_s
    assert dev > 0
    assert abs(tot["device_s"] - dev) <= 0.01 * dev
    assert tot["requests"] == srv.metrics.n_completed == 6
    assert tot["dispatched"] == 5 and tot["cached"] == 1
    snap = led.snapshot()
    for agg in snap["tenants"].values():
        assert 0.0 <= agg["utilization"]
    # per-request flop attribution flowed through the models
    assert tot["flops"] > 0
    srv.close()


# ---------------------------------------------------------------------------
# cost-weighted serving behaviour
# ---------------------------------------------------------------------------

def test_cost_weighted_admission_shrinks_overdrawn_quota():
    """With the ledger showing one tenant holding ~90% of the windowed
    device time, its count-based pending quota (max_pending//n_active)
    shrinks by fair/used; the under-budget tenant keeps the full quota."""
    g, eng = _engine()

    def fill(srv):
        srv.submit(G.QueryRequest("sssp", tenant="cheap",
                                  params={"source": 0}))
        n = 0
        try:
            for it in range(20):
                srv.submit(G.QueryRequest("pagerank", tenant="heavy",
                                          params={"iters": 10 + it}))
                n += 1
        except AdmissionError:
            pass
        return n

    plain = G.GraphServer(eng, g, max_pending=8, cache_entries=0)
    count_quota = fill(plain)
    plain.close()
    assert count_quota == 4                    # 8 max_pending / 2 active

    led = CostLedger(window_s=30.0)
    led.post(_sample("heavy", 0.9, program="pagerank"))
    led.post(_sample("cheap", 0.1))
    srv = G.GraphServer(eng, g, max_pending=8, cache_entries=0, ledger=led)
    cost_quota = fill(srv)
    # fair=0.5, used=0.9 -> quota floor(4 * 0.5/0.9) = 2
    assert cost_quota == 2
    # the cheap tenant (share 0.1 < fair) keeps its count-based quota
    for s in range(1, 4):
        srv.submit(G.QueryRequest("sssp", tenant="cheap",
                                  params={"source": s}))
    srv.close()


def test_cost_weighted_flush_order_drains_cheap_tenant_first():
    b = MicroBatcher(buckets=(1, 4))
    heavy = G.QueryRequest("pagerank", tenant="heavy", params={"iters": 7})
    cheap = G.QueryRequest("sssp", tenant="cheap", params={"source": 0})
    b.add(heavy)
    b.add(cheap)
    # FIFO (no ledger): arrival order -> heavy's key first
    assert b.next_batch().requests[0].tenant == "heavy"

    b2 = MicroBatcher(buckets=(1, 4))
    b2.cost_of = {"heavy": 0.9, "cheap": 0.1}.get
    b2.add(heavy)
    b2.add(cheap)
    # cost-weighted: the cheap head tenant flushes first despite arriving
    # second; the heavy backlog drains after
    first, second = b2.next_batch(), b2.next_batch()
    assert first.requests[0].tenant == "cheap"
    assert second.requests[0].tenant == "heavy"


# ---------------------------------------------------------------------------
# renderer + snapshot plumbing
# ---------------------------------------------------------------------------

def test_usage_renderer_loads_dump_and_obs_snapshot(tmp_path):
    led = CostLedger(window_s=30.0)
    led.post(_sample("alice", 0.6, flops=5e7, utilization=0.4))
    led.post(_sample("bob", 0.2, program="pagerank"))
    p = tmp_path / "usage_ledger.json"
    led.dump(str(p))
    text = usage.render(usage.load(str(p)))
    assert "alice" in text and "bob" in text and "pagerank" in text
    assert "USAGE LEDGER" in text
    # the ledger rides inside a full obs snapshot too (provider nesting)
    unregister = __import__("repro.obs.ledger", fromlist=["register"]) \
        .register(led, name="ledger_under_test")
    try:
        snap_path = tmp_path / "snap.json"
        snap_path.write_text(json.dumps(obs.snapshot(), default=str))
        doc = json.loads(snap_path.read_text())
        # the named provider carries a full ledger snapshot the renderer
        # accepts as-is (load()'s recursive search would surface the
        # process-global "ledger" provider first, which is empty here)
        assert doc["ledger_under_test"]["kind"] == "cost_ledger"
        assert "alice" in usage.render(doc["ledger_under_test"])
    finally:
        unregister()


def test_ledger_rides_in_obs_snapshot_by_default():
    """The process-global ledger is a registered provider: posting to it
    shows up in obs.snapshot() with no extra wiring."""
    from repro.obs.ledger import get_ledger
    led = get_ledger()
    led.reset()
    led.post(_sample("snapshot-tenant", 0.1))
    try:
        found = usage._find_ledger(obs.snapshot())
        assert found is not None
        assert "snapshot-tenant" in found["tenants"]
    finally:
        led.reset()

"""Beyond-paper extensions: multi-source SSSP, k-core, gradient compression."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import dfep, graph
from repro.core.etsch import compile_partitioning
from repro.train import compress as C


@pytest.fixture(scope="module")
def setup():
    g = graph.barabasi_albert(400, 3, seed=7)
    owner, _ = dfep.partition(g, k=4, key=0)
    part = compile_partitioning(g, owner, 4)
    return g, part


def test_multi_sssp_matches_single(setup):
    g, part = setup
    sources = jnp.array([0, 5, 17], jnp.int32)
    multi = alg.etsch_multi_sssp(part, sources)
    for i, s in enumerate([0, 5, 17]):
        ref, _ = alg.reference_sssp(g, s)
        got, want = np.asarray(multi.dist[i]), np.asarray(ref)
        finite = np.isfinite(want)
        assert (got[finite] == want[finite]).all()


@pytest.mark.parametrize("k_core", [2, 3, 5])
def test_kcore_matches_reference(setup, k_core):
    g, part = setup
    res = alg.etsch_kcore(part, k_core)
    want = alg.reference_kcore(g, k_core)
    assert np.array_equal(np.asarray(res.in_core), np.asarray(want))
    # k-core property: every member has >= k neighbours inside the core
    u, v = g.as_numpy()
    core = np.asarray(res.in_core)
    if core.any():
        deg = np.zeros(g.n_vertices, int)
        live = core[u] & core[v]
        np.add.at(deg, u[live], 1)
        np.add.at(deg, v[live], 1)
        assert (deg[core] >= k_core).all()


def test_compress_roundtrip_accuracy():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    c = C.compress(x)
    y = C.decompress(c, x.shape)
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


@given(seed=st.integers(0, 50), n=st.integers(1, 2000))
@settings(max_examples=15, deadline=None)
def test_compress_roundtrip_property(seed, n):
    x = jax.random.normal(jax.random.key(seed), (n,))
    y = C.decompress(C.compress(x), x.shape)
    # per-block bound: |err| <= blockmax/127
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_error_feedback_mean_converges():
    """With error feedback, the time-average of the decompressed signal
    converges to the true (constant) gradient despite quantisation."""
    g = {"w": jnp.full((300,), 0.003)}   # tiny values vs block scale
    err = C.init_error_state(g)
    acc = jnp.zeros((300,))
    steps = 50
    for _ in range(steps):
        d, err, _ = C.ef_compress_tree(g, err)
        acc = acc + d["w"]
    np.testing.assert_allclose(np.asarray(acc / steps),
                               np.asarray(g["w"]), rtol=0.05)


def test_compression_ratio():
    g = {"a": jnp.zeros((4096, 128)), "b": jnp.zeros((999,))}
    assert C.compression_ratio(g) > 3.5

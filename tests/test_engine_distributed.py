"""Engine shard_map path: partitions sharded over an 8-device host mesh in a
subprocess (same pattern as test_distributed.py), checked against the
whole-graph oracles and the single-device fallback."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.core import algorithms as alg
    from repro.core import baselines, dfep, graph, metrics
    from repro import engine as E

    assert len(jax.devices()) == 8
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    owner, _ = dfep.partition(g, k=8, key=0, max_rounds=400, stall_rounds=16)
    plan = E.compile_plan(g, np.asarray(owner), 8)
    mesh = jax.make_mesh((8,), ("parts",))
    eng = E.Engine(plan, mesh=mesh)

    r = E.engine_sssp(eng, 0)
    ref, ref_rounds = alg.reference_sssp(g, 0)
    assert np.array_equal(np.asarray(r.state), np.asarray(ref)), "sssp"
    assert int(r.supersteps) <= int(ref_rounds)

    rw = E.engine_wcc(eng)
    refc, _ = alg.reference_cc(g)
    assert np.array_equal(np.asarray(rw.state), np.asarray(refc)), "wcc"

    rp = E.engine_pagerank(eng, g.degrees(), iters=20)
    refp = alg.reference_pagerank(g, iters=20)
    np.testing.assert_allclose(np.asarray(rp.state), np.asarray(refp),
                               atol=1e-5)

    # sharded == single-device fallback, superstep for superstep
    r1 = E.engine_sssp(E.Engine(plan), 0)
    assert int(r1.supersteps) == int(r.supersteps)
    assert np.array_equal(np.asarray(r1.state), np.asarray(r.state))

    # measured replica-exchange volume == combinatorial MESSAGES
    m = metrics.evaluate(g, np.asarray(owner), 8, compute_gain=False)
    assert plan.exchange_per_superstep() == m.messages, \
        (plan.exchange_per_superstep(), m.messages)
    print("exchange/superstep:", m.messages,
          "total:", r.total_exchanged, "supersteps:", int(r.supersteps))

    # K=8 partitions on a 4-device mesh (2 partition blocks per device)
    mesh4 = jax.make_mesh((4,), ("parts",))
    r4 = E.engine_sssp(E.Engine(plan, mesh=mesh4), 3)
    ref4, _ = alg.reference_sssp(g, 3)
    assert np.array_equal(np.asarray(r4.state), np.asarray(ref4)), "k8d4"
    print("ENGINE_DIST_OK")
""")


BATCHED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.core import algorithms as alg
    from repro.core import dfep, graph
    from repro import engine as E

    assert len(jax.devices()) == 8
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    owner, _ = dfep.partition(g, k=8, key=0, max_rounds=400, stall_rounds=16)
    plan = E.compile_plan(g, np.asarray(owner), 8)
    mesh = jax.make_mesh((8,), ("parts",))
    eng = E.Engine(plan, mesh=mesh)

    # batched multi-source SSSP through the shard_map superstep: partitions
    # stay sharded over the mesh, the batch axis is vmapped inside the body
    sources = [0, 3, 7, 11, 42, 111]
    res = E.multi_source_sssp(eng, sources)
    assert res.state.shape == (len(sources), g.n_vertices)
    for i, s in enumerate(sources):
        ref, _ = alg.reference_sssp(g, s)
        assert np.array_equal(np.asarray(res.state[i]), np.asarray(ref)), s

    # identical to the single-device batched fallback, lane for lane
    r1 = E.multi_source_sssp(E.Engine(plan), sources)
    assert np.array_equal(np.asarray(r1.state), np.asarray(res.state))
    assert np.array_equal(np.asarray(r1.supersteps), np.asarray(res.supersteps))

    # non-blocking dispatch on the mesh path settles to the same answer
    pend = eng.dispatch_batched(E.SSSP, {"source": jnp.asarray([5, 9], jnp.int32)})
    out = pend.result()
    for i, s in enumerate((5, 9)):
        ref, _ = alg.reference_sssp(g, s)
        assert np.array_equal(np.asarray(out.state[i]), np.asarray(ref)), s

    # K=8 partitions on a 4-device mesh (2 partition blocks per device)
    mesh4 = jax.make_mesh((4,), ("parts",))
    r4 = E.multi_source_sssp(E.Engine(plan, mesh=mesh4), [1, 2])
    for i, s in enumerate((1, 2)):
        ref, _ = alg.reference_sssp(g, s)
        assert np.array_equal(np.asarray(r4.state[i]), np.asarray(ref)), s
    print("ENGINE_DIST_BATCHED_OK")
""")


CHANNEL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.core import algorithms as alg
    from repro.core import dfep, graph
    from repro.core.graph import edge_weights
    from repro import engine as E

    assert len(jax.devices()) == 8
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    owner, _ = dfep.partition(g, k=8, key=0, max_rounds=400, stall_rounds=16)
    plan = E.compile_plan(g, np.asarray(owner), 8)
    mesh = jax.make_mesh((8,), ("parts",))
    eng = E.Engine(plan, mesh=mesh)

    # vertex property channels on the sharded superstep: the replicated
    # [V, F] plane is gathered partition-locally INSIDE the shard_map body
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 40, size=g.n_vertices).astype(np.float32)
    r = E.engine_label_propagation(eng, labels)
    assert np.array_equal(np.asarray(r.state),
                          alg.reference_label_propagation(g, labels)), "lp"

    p = rng.random(g.n_vertices).astype(np.float32); p /= p.sum()
    rp = E.engine_personalized_pagerank(eng, g.degrees(), p, iters=12)
    np.testing.assert_allclose(
        np.asarray(rp.state),
        alg.reference_personalized_pagerank(g, p, iters=12), atol=1e-5)

    # K=8 on a 4-device mesh (2 partition blocks per device)
    mesh4 = jax.make_mesh((4,), ("parts",))
    r4 = E.engine_label_propagation(E.Engine(plan, mesh=mesh4), labels)
    assert np.array_equal(np.asarray(r4.state), np.asarray(r.state)), "k8d4"

    # edge property channel on the BATCHED shard_map path: the [E_pad, F]
    # plane rides the replicated kwargs, sources ride the vmapped batch
    INF = jnp.float32(jnp.inf)
    def prepare(plan, kw):
        return {"source": kw["source"],
                "w": E.gather_edge_channel(plan, kw["weights"])[:, :, 0]}
    def init(plan, ctx):
        hit = plan.vmask & (plan.local2global == ctx["source"])
        return jnp.where(hit, 0.0, INF)
    def fin(glob, present, plan, ctx):
        iota = jnp.arange(plan.n_vertices)
        return jnp.where(present, glob,
                         jnp.where(iota == ctx["source"], 0.0, INF))
    CW = E.EdgeProgram(name="cwsssp", mode="replica", combine="min",
        prepare=prepare, init=init, pre=lambda s, c: s,
        apply=lambda o, a, c: jnp.minimum(o, a), finalize=fin,
        local_fixpoint=True, edge=lambda m, plan, ctx: m + ctx["w"])
    u, v = g.as_numpy()
    w = np.zeros(g.e_pad, np.float32)
    w[:len(u)] = edge_weights(u, v)
    rb = eng.run_batched(CW, {"source": np.array([1, 7], np.int32)},
                         weights=w)
    for i, s in enumerate((1, 7)):
        assert np.array_equal(np.asarray(rb.state[i]),
                              alg.reference_weighted_sssp(g, s)), s
    print("ENGINE_DIST_CHANNELS_OK")
""")


def _run_subprocess(script: str, marker: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=1200,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert marker in res.stdout, \
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"


@pytest.mark.slow
def test_engine_shard_map():
    _run_subprocess(SCRIPT, "ENGINE_DIST_OK")


@pytest.mark.slow
def test_engine_shard_map_batched():
    """run_batched on a mesh: the lifted single-device restriction."""
    _run_subprocess(BATCHED_SCRIPT, "ENGINE_DIST_BATCHED_OK")


@pytest.mark.slow
def test_engine_shard_map_channels():
    """Property channels on both shard_map paths: vertex planes through
    dispatch, an edge plane through dispatch_batched."""
    _run_subprocess(CHANNEL_SCRIPT, "ENGINE_DIST_CHANNELS_OK")

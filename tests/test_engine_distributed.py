"""Engine shard_map path: partitions sharded over an 8-device host mesh in a
subprocess (same pattern as test_distributed.py), checked against the
whole-graph oracles and the single-device fallback."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.core import algorithms as alg
    from repro.core import baselines, dfep, graph, metrics
    from repro import engine as E

    assert len(jax.devices()) == 8
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    owner, _ = dfep.partition(g, k=8, key=0, max_rounds=400, stall_rounds=16)
    plan = E.compile_plan(g, np.asarray(owner), 8)
    mesh = jax.make_mesh((8,), ("parts",))
    eng = E.Engine(plan, mesh=mesh)

    r = E.engine_sssp(eng, 0)
    ref, ref_rounds = alg.reference_sssp(g, 0)
    assert np.array_equal(np.asarray(r.state), np.asarray(ref)), "sssp"
    assert int(r.supersteps) <= int(ref_rounds)

    rw = E.engine_wcc(eng)
    refc, _ = alg.reference_cc(g)
    assert np.array_equal(np.asarray(rw.state), np.asarray(refc)), "wcc"

    rp = E.engine_pagerank(eng, g.degrees(), iters=20)
    refp = alg.reference_pagerank(g, iters=20)
    np.testing.assert_allclose(np.asarray(rp.state), np.asarray(refp),
                               atol=1e-5)

    # sharded == single-device fallback, superstep for superstep
    r1 = E.engine_sssp(E.Engine(plan), 0)
    assert int(r1.supersteps) == int(r.supersteps)
    assert np.array_equal(np.asarray(r1.state), np.asarray(r.state))

    # measured replica-exchange volume == combinatorial MESSAGES
    m = metrics.evaluate(g, np.asarray(owner), 8, compute_gain=False)
    assert plan.exchange_per_superstep() == m.messages, \
        (plan.exchange_per_superstep(), m.messages)
    print("exchange/superstep:", m.messages,
          "total:", r.total_exchanged, "supersteps:", int(r.supersteps))

    # K=8 partitions on a 4-device mesh (2 partition blocks per device)
    mesh4 = jax.make_mesh((4,), ("parts",))
    r4 = E.engine_sssp(E.Engine(plan, mesh=mesh4), 3)
    ref4, _ = alg.reference_sssp(g, 3)
    assert np.array_equal(np.asarray(r4.state), np.asarray(ref4)), "k8d4"
    print("ENGINE_DIST_OK")
""")


@pytest.mark.slow
def test_engine_shard_map():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "ENGINE_DIST_OK" in res.stdout, \
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"

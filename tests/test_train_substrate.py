"""Optimizer / checkpoint / pipeline / serving substrate tests."""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import lm
from repro.serve import serve_step
from repro.train.optimizer import (AdamWConfig, apply_updates, global_norm,
                                   init_opt_state, schedule)


def test_adamw_matches_reference_step():
    """Single-param AdamW vs hand-computed update."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.5])}
    st_ = init_opt_state(p)
    new_p, st2, m = apply_updates(cfg, p, g, st_)
    # step 1: mh = g, vh = g^2  ->  delta = g/(|g|+eps) = 1
    np.testing.assert_allclose(np.asarray(new_p["w"]), [2.0 - 0.1], rtol=1e-5)


def test_grad_clip_limits_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=0.001, weight_decay=0.0,
                      warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st_ = init_opt_state(p)
    _, st2, m = apply_updates(cfg, p, g, st_)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


@given(seed=st.integers(0, 100), step=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_pipeline_deterministic_skip_ahead(seed, step):
    cfg = get_config("qwen3-0.6b", smoke=True)
    pipe = SyntheticPipeline(cfg, DataConfig(batch=2, seq_len=16, seed=seed))
    a = pipe.batch_at(step)
    b = pipe.batch_at(step)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = pipe.batch_at(step + 1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.full((3,), 7))}
    mgr.save(10, tree)
    mgr.save(20, tree)
    mgr.save(30, tree)
    assert mgr.all_steps() == [20, 30]       # keep=2 GC'd step 10
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = mgr.restore(template)
    for ka, kb in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(ka, np.float32),
                                      np.asarray(kb, np.float32))


def test_checkpoint_resume_trainer(tmp_path):
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = get_config("qwen3-0.6b", smoke=True)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    dcfg = DataConfig(batch=2, seq_len=16)
    tcfg = TrainerConfig(steps=4, ckpt_dir=str(tmp_path), ckpt_every=2,
                         log_every=100)
    t1 = Trainer(cfg, ocfg, dcfg, tcfg)
    t1.run()
    assert t1.ckpt.latest_step() == 4
    # new trainer resumes from step 4, runs to 6
    tcfg2 = TrainerConfig(steps=6, ckpt_dir=str(tmp_path), ckpt_every=2,
                          log_every=100)
    t2 = Trainer(cfg, ocfg, dcfg, tcfg2)
    assert t2.step == 4
    t2.run()
    assert int(t2.opt_state.step) == 6


def test_greedy_decode_matches_forward_argmax():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params, _ = lm.init_params(cfg, jax.random.key(3))
    toks = jax.random.randint(jax.random.key(4), (2, 12), 0, cfg.vocab)
    full, _, _ = lm.forward_lm(cfg, params, toks, remat=False)
    logits_p, caches = serve_step.prefill(cfg, params, toks[:, :11])

    def grow(x):
        if x.ndim >= 3 and x.shape[2] == 11:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 5)
            return jnp.pad(x, pad)
        return x

    caches = jax.tree.map(grow, caches)
    logits_d, _ = serve_step.decode(cfg, params, toks[:, 11:12], caches,
                                    jnp.int32(11))
    a = serve_step.greedy_token(full[:, -1:, :], cfg.vocab)
    b = serve_step.greedy_token(logits_d, cfg.vocab)
    assert np.array_equal(np.asarray(a), np.asarray(b))

"""Active observability correctness: log-histogram percentile accuracy
against ``np.percentile`` on adversarial distributions + merge
associativity, multi-window burn-rate alerts firing and clearing on
synthetic latency streams (fake clock), flight-bundle round-trip +
bounded retention + report rendering that names tenant/program/window,
``ServeMetrics`` histogram percentiles matching the old sorted-list
values within one log-bucket width, and the adaptive compaction policy
triggering on a scripted idle-after-burst sequence with oracle-exact,
retrace-free patched plans."""
import json

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import obs
from repro import stream as S
from repro.engine import runtime
from repro.gserve.metrics import ServeMetrics, percentile
from repro.obs import report
from repro.obs.flight import FlightRecorder
from repro.obs.histogram import LogHistogram, WindowedHistogram
from repro.obs.monitor import GaugeWatch, Monitor, SLOPolicy


@pytest.fixture(autouse=True)
def _clean_recorder():
    rec = obs.get()
    rec.disable()
    rec.reset()
    yield
    rec.disable()
    rec.reset()


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------

ADVERSARIAL = {
    # one bucket holds everything: every percentile must be exact
    "point_mass": np.full(500, 3.7e-3),
    # dense low mode + tiny far tail: tail percentiles must not collapse
    "bimodal_heavy_tail": np.concatenate([np.full(990, 1e-4),
                                          np.full(10, 50.0)]),
    # samples exactly on decade edges (bucket-boundary rounding)
    "decade_edges": np.array([1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0,
                              10.0] * 40),
    # 5 orders of magnitude, log-uniform
    "log_uniform": 10.0 ** np.random.default_rng(0).uniform(-5, 0, 2000),
    # realistic latency shape
    "lognormal": np.random.default_rng(1).lognormal(-6.0, 1.0, 2000),
}


@pytest.mark.parametrize("name", sorted(ADVERSARIAL))
def test_percentile_within_one_bucket_of_exact(name):
    xs = ADVERSARIAL[name]
    h = LogHistogram()
    h.record_many(xs)
    w = h.width_factor
    for q in (1, 25, 50, 75, 90, 95, 99, 99.9, 100):
        # the histogram implements the inverted-CDF (nearest-rank)
        # percentile; compare against numpy's same definition
        exact = float(np.percentile(xs, q, method="inverted_cdf"))
        got = h.percentile(q)
        assert exact / w <= got <= exact * w, (name, q, exact, got)
    assert h.n == len(xs)
    assert h.vmin == xs.min() and h.vmax == xs.max()
    assert h.mean == pytest.approx(xs.mean())


def test_percentile_tails_clamped_to_observed_range():
    h = LogHistogram()
    h.record_many([2.5e-3] * 99 + [7.0])
    assert h.percentile(100) == 7.0          # exact max, not bucket midpoint
    assert h.percentile(1) >= 2.5e-3 / h.width_factor
    assert h.percentile(0) >= h.vmin


def test_merge_is_associative_and_matches_bulk():
    rng = np.random.default_rng(2)
    parts = [rng.lognormal(-5, 1.5, n) for n in (17, 400, 3, 81)]
    whole = LogHistogram()
    whole.record_many(np.concatenate(parts))

    def hist(xs):
        h = LogHistogram()
        h.record_many(xs)
        return h

    a, b, c, d = map(hist, parts)
    left = hist([]).merge(a).merge(b).merge(c).merge(d)
    right = hist([]).merge(a.copy().merge(b)).merge(c.copy().merge(d))
    for m in (left, right):
        assert np.array_equal(m.counts, whole.counts)
        assert m.n == whole.n
        assert m.vmin == whole.vmin and m.vmax == whole.vmax
        assert m.total == pytest.approx(whole.total)
    with pytest.raises(ValueError):
        left.merge(LogHistogram(buckets_per_decade=16))


def test_windowed_histogram_rotation_and_expiry():
    wh = WindowedHistogram(slot_s=1.0, slots=4)
    wh.record(1e-3, now=0.5)
    wh.record(1e-2, now=1.5, ok=False)
    hist, n_fail = wh.window(2.0, now=1.9)
    assert hist.n == 2 and n_fail == 1
    # jump far ahead: every old slice must expire, even with no recording
    hist, n_fail = wh.window(4.0, now=100.0)
    assert hist.n == 0 and n_fail == 0
    assert wh.lifetime_n == 2 and wh.lifetime_fail == 1
    wh.record(5e-3, now=101.0)
    hist, _ = wh.window(4.0, now=101.0)
    assert hist.n == 1
    assert wh.rate(4.0, now=101.0) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# burn-rate monitor (fake clock)
# ---------------------------------------------------------------------------

def _fake_clock():
    t = [0.0]

    def clock():
        return t[0]
    clock.advance = lambda dt: t.__setitem__(0, t[0] + dt)
    return clock


def test_burn_rate_fires_and_clears_on_synthetic_stream():
    clock = _fake_clock()
    mon = Monitor(policies=[SLOPolicy(
        name="p99-lat", tenant="*", program="sssp",
        latency_objective_s=1e-3, availability_target=0.99,
        fast_window_s=5.0, slow_window_s=30.0, burn_threshold=2.0,
        min_samples=5)], clock=clock)
    rec = obs.get()
    rec.enable()

    for _ in range(60):                      # healthy: all under objective
        clock.advance(0.5)
        mon.observe("tA", "sssp", 1e-4)
    assert mon.evaluate() == [] and mon.active_alerts() == []

    for _ in range(60):                      # breach: all over objective
        clock.advance(0.5)
        mon.observe("tA", "sssp", 5e-2)
    fired = mon.evaluate()
    assert len(fired) == 1
    alert = fired[0]
    assert alert["kind"] == "burn_rate" and alert["tenant"] == "tA"
    assert alert["program"] == "sssp"
    assert alert["burn_fast"] >= 2.0 and alert["burn_slow"] >= 2.0
    assert alert["window"]["fast"]["bad"] > 0
    assert mon.active_alerts() == [alert]
    # still breached next tick: edge-triggered, no duplicate event
    clock.advance(0.5)
    assert mon.evaluate() == []
    assert len([e for e in rec.events() if e["name"] == "obs.alert"]) == 1

    for _ in range(120):                     # recovery: fast window drains
        clock.advance(0.5)
        mon.observe("tA", "sssp", 1e-4)
    assert mon.evaluate() == []
    assert mon.active_alerts() == []
    assert any(e["name"] == "obs.alert_clear" for e in rec.events())
    mon.close()


def test_rejections_count_as_bad_and_wildcards_name_offender():
    clock = _fake_clock()
    mon = Monitor(policies=[SLOPolicy(
        name="avail", latency_objective_s=10.0,   # latency never "bad"
        availability_target=0.9, fast_window_s=4.0, slow_window_s=8.0,
        burn_threshold=1.5, min_samples=4)], clock=clock)
    for _ in range(20):
        clock.advance(0.3)
        mon.observe("noisy", "wcc", 0.0, ok=False)   # shed at admission
        mon.observe("quiet", "wcc", 1e-4)
    fired = mon.evaluate()
    assert [a["tenant"] for a in fired] == ["noisy"]
    assert fired[0]["window"]["fast"]["n_fail"] > 0
    mon.close()


def test_gauge_watch_ceiling_and_drift():
    clock = _fake_clock()
    mon = Monitor(clock=clock)
    mon.watch_gauge(GaugeWatch(gauge="stream.replication_factor",
                               ceiling=4.0, max_rel_increase=0.10))
    rec = obs.get()
    rec.enable()
    rec.gauge("stream.replication_factor", 2.0)   # baseline
    assert mon.evaluate() == []
    rec.gauge("stream.replication_factor", 2.5)   # +25% drift, under ceiling
    fired = mon.evaluate()
    assert len(fired) == 1 and fired[0]["kind"] == "gauge_drift"
    assert "drifted" in fired[0]["reasons"][0]
    rec.gauge("stream.replication_factor", 2.05)  # back within drift bound
    assert mon.evaluate() == [] and mon.active_alerts() == []
    mon.close()


def test_retrace_rate_watcher():
    clock = _fake_clock()
    mon = Monitor(clock=clock)
    mon.watch_retrace_rate(max_per_s=0.5, window_s=10.0)
    rec = obs.get()
    rec.enable()
    assert mon.evaluate() == []
    for _ in range(5):
        clock.advance(1.0)
        rec.counter("engine.retraces", 2)          # 2/s: a retrace storm
        mon.evaluate()
    active = mon.active_alerts()
    assert len(active) == 1 and active[0]["kind"] == "retrace_rate"
    assert active[0]["rate_per_s"] > 0.5
    mon.close()


# ---------------------------------------------------------------------------
# flight recorder + report
# ---------------------------------------------------------------------------

def test_flight_bundle_roundtrip_and_bounded_retention(tmp_path):
    rec = obs.get()
    rec.enable()
    rec.event("stream.plan_swap", version=3)
    rec.gauge("stream.replication_factor", 2.5)
    fr = FlightRecorder(str(tmp_path), max_bundles=3)
    paths = [fr.dump(f"reason-{i}", context={"i": i}) for i in range(5)]
    kept = fr.bundles()
    assert len(kept) == 3                      # retention bound holds
    assert [p.name for p in kept] == [p.name for p in paths[2:]]
    doc = json.loads(kept[-1].read_text())
    assert doc["flight_bundle"] == 1
    assert doc["reason"] == "reason-4" and doc["context"] == {"i": 4}
    assert doc["stats"]["recorded"] >= 1
    assert doc["snapshot"]["gauges"]["stream.replication_factor"] == 2.5
    assert any(e["name"] == "stream.plan_swap" for e in doc["events"])
    # the dump itself is on the record (so the NEXT bundle shows this one)
    assert any(e["name"] == "obs.flight_dump" for e in rec.events())


def test_report_names_tenant_program_and_window(tmp_path):
    clock = _fake_clock()
    rec = obs.get()
    rec.enable()
    mon = Monitor(policies=[SLOPolicy(
        name="slo-sssp", tenant="tenant-slow", program="sssp",
        latency_objective_s=1e-3, fast_window_s=5.0, slow_window_s=20.0,
        min_samples=3)], clock=clock)
    fr = FlightRecorder(str(tmp_path))
    disarm = fr.arm(mon)
    for _ in range(30):
        clock.advance(0.5)
        mon.observe("tenant-slow", "sssp", 0.2)
    mon.evaluate()
    assert len(fr.bundles()) == 1              # armed dump at fire time
    text = report.render(report.load(str(fr.bundles()[0])))
    assert "tenant-slow" in text and "sssp" in text
    assert "slo-sssp" in text
    assert "window" in text and "fast 5.0s" in text
    assert "burn rate" in text
    disarm()
    mon.close()


def test_report_renders_jsonl_trace(tmp_path):
    rec = obs.get()
    rec.enable()
    with rec.span("serve.batch", program="wcc"):
        rec.event("engine.dispatch", bucket=8)
    path = tmp_path / "trace.jsonl"
    obs.export_jsonl(str(path))
    text = report.render(report.load(str(path)))
    assert "serve.batch" in text and "engine.dispatch" in text
    assert "SPAN LATENCY" in text


# ---------------------------------------------------------------------------
# ServeMetrics on histograms
# ---------------------------------------------------------------------------

def test_serve_metrics_percentiles_match_list_within_bucket_width():
    m = ServeMetrics()
    rng = np.random.default_rng(3)
    lats = rng.lognormal(-6.5, 0.8, 800)       # realistic latency spread
    for v in lats:
        m.record_result(float(v), from_cache=False)
    snap = m.snapshot()
    w = m.latency_hist.width_factor
    xs = list(lats)
    for key, q in (("latency_p50_s", 50), ("latency_p95_s", 95),
                   ("latency_p99_s", 99)):
        old = percentile(xs, q)                # the old sorted-list answer
        assert old / w <= snap[key] <= old * w, (key, old, snap[key])
    assert snap["latency_mean_s"] == pytest.approx(lats.mean(), rel=1e-4)
    assert snap["completed"] == len(lats)
    assert snap["windowed"]["n"] == len(lats)
    assert snap["windowed"]["p99_s"] > 0
    # fixed memory: the histogram state does not grow with request count
    assert not hasattr(m, "latencies")


def test_served_slow_tenant_fires_alert_and_bundle_names_it(tmp_path):
    """End-to-end acceptance: a served workload with one injected-slow
    tenant raises an ``obs.alert`` burn-rate event naming that tenant, and
    the armed flight recorder's bundle renders to a report naming
    tenant/program/window."""
    g = graph.watts_strogatz(150, 4, 0.2, seed=3)
    owner, _ = dfep.partition(g, k=4, key=0)
    plan = E.compile_plan(g, np.asarray(owner), 4)
    # per-tenant objectives: impossible for the slow tenant (every request
    # is over budget), unmissable for the fast one
    mon = Monitor(policies=[
        SLOPolicy(name="slo-slow", tenant="t-slow", latency_objective_s=1e-9,
                  fast_window_s=5.0, slow_window_s=20.0, min_samples=3),
        SLOPolicy(name="slo-fast", tenant="t-fast", latency_objective_s=60.0,
                  fast_window_s=5.0, slow_window_s=20.0, min_samples=3),
    ], eval_interval_s=0.0)
    fr = FlightRecorder(str(tmp_path))
    disarm = fr.arm(mon)
    srv = G.GraphServer(E.Engine(plan), g, cache_entries=0, monitor=mon)
    rec = obs.get()
    rec.enable()
    srv.serve([G.QueryRequest("sssp", tenant=t, params={"source": i})
               for i, t in enumerate(["t-slow", "t-fast"] * 6)])
    alerts = mon.active_alerts()
    assert [a["tenant"] for a in alerts] == ["t-slow"]
    assert alerts[0]["policy"] == "slo-slow"
    assert any(e["name"] == "obs.alert" for e in rec.events())
    assert len(fr.bundles()) == 1
    text = report.render(report.load(str(fr.bundles()[0])))
    assert "t-slow" in text and "sssp" in text and "slo-slow" in text
    assert "t-fast" not in text.split("ALERTS")[1].split("HEALTH")[0]
    disarm()
    srv.close()
    mon.close()


def test_monitor_not_fed_when_recorder_disabled():
    g = graph.watts_strogatz(150, 4, 0.2, seed=3)
    owner, _ = dfep.partition(g, k=4, key=0)
    plan = E.compile_plan(g, np.asarray(owner), 4)
    mon = Monitor()
    srv = G.GraphServer(E.Engine(plan), g, cache_entries=0, monitor=mon)
    srv.serve([G.QueryRequest("sssp", tenant="a", params={"source": 1})])
    assert mon._series == {}        # master switch off: no monitor cost
    srv.close()
    mon.close()


# ---------------------------------------------------------------------------
# adaptive compaction policy
# ---------------------------------------------------------------------------

def _burst(n_v, n, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n_v, size=(n, 2))
    return e[e[:, 0] != e[:, 1]]


def test_adaptive_policy_compacts_in_idle_gap_not_mid_burst():
    """Scripted idle-after-burst: after a warmup burst the adaptive policy
    must (a) compact during idle_tick, not mid-apply, (b) leave patched
    plans oracle-exact, and (c) keep the bursts retrace-free (queries
    between bursts hit the warm jit cache)."""
    g = graph.watts_strogatz(220, 4, 0.2, seed=5)
    clock = _fake_clock()
    policy = S.AdaptiveCompactionPolicy(
        Monitor(clock=clock), headroom_batches=3.0)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=64,
                                             drift_threshold=1e9),
                           key=0, policy=policy)
    reactive = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=64,
                                                 drift_threshold=1e9), key=0)
    # warmup burst: telemetry for the policy, jit warmth for the engine
    # (the policy has no telemetry before its first apply, so this burst
    # may itself be forced — fig_stream's timed phase starts after warmup
    # for the same reason, and so does the assertion window here)
    sess.apply(inserts=_burst(g.n_vertices, 150, 90))
    clock.advance(1.0)
    assert sess.idle_tick()                     # proactive: telemetry says
    assert sess.n_idle_compactions == 1         #   headroom can't absorb 3x
    E.engine_sssp(sess.engine, 0)               # absorb the idle retrace
    forced0 = sess.n_forced_recompiles

    traces0 = runtime.TRACE_COUNTER["run_loop"]
    for wave in range(4):                       # timed phase equivalent
        sess.apply(inserts=_burst(g.n_vertices, 150, 91 + wave))
        reactive.apply(inserts=_burst(g.n_vertices, 150, 91 + wave))
        r = E.engine_sssp(sess.engine, 0)
        ref, _ = alg.reference_sssp(sess.graph(), 0)
        assert np.array_equal(np.asarray(r.state), np.asarray(ref))
        clock.advance(1.0)
        if sess.idle_tick():
            E.engine_sssp(sess.engine, 0)       # retrace paid in the gap
            traces0 = runtime.TRACE_COUNTER["run_loop"]
        else:
            assert runtime.TRACE_COUNTER["run_loop"] == traces0
    assert sess.n_forced_recompiles == forced0
    # the reactive twin on the identical workload was forced mid-burst
    assert reactive.n_forced_recompiles >= 1
    policy.close()


def test_adaptive_policy_sizes_slack_from_observed_peak():
    mon = Monitor(clock=_fake_clock())
    policy = S.AdaptiveCompactionPolicy(mon, headroom_batches=2.0)
    g = graph.watts_strogatz(150, 4, 0.2, seed=1)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9),
                           key=0, policy=policy)
    assert policy.recommend_slack(sess) == (None, None)   # no telemetry yet
    policy.on_apply(sess, 500, 500, 0.1)
    edge_rec, vertex_rec = policy.recommend_slack(sess)
    assert edge_rec == 1000 and vertex_rec is None
    # the recommendation only ever RAISES the session default: a recompile
    # sized by it leaves >= 2*edge_slack free half-edge slots everywhere
    from repro.obs.health import plan_health
    sess._recompile(reason="idle")
    assert plan_health(sess.plan)["min_free_edge_slots"] >= 2 * 1000
    assert sess.n_forced_recompiles == 0        # idle recompile not "forced"
    mon.close()


def test_reactive_policy_is_default_and_inert():
    g = graph.watts_strogatz(120, 4, 0.2, seed=2)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    assert isinstance(sess.policy, S.ReactiveCompactionPolicy)
    sess.apply(inserts=_burst(g.n_vertices, 40, 1))
    assert sess.idle_tick() is False            # never proactive
    assert sess.n_idle_compactions == 0

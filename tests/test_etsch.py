"""ETSCH framework tests: SSSP/CC/PageRank/MIS vs whole-graph references,
for DFEP and baseline partitionings."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import baselines, dfep, graph
from repro.core.etsch import compile_partitioning


@pytest.fixture(scope="module", params=["dfep", "random", "hash"])
def setup(request):
    g = graph.barabasi_albert(500, 3, seed=2)
    k = 5
    if request.param == "dfep":
        owner, _ = dfep.partition(g, k=k, key=0)
    elif request.param == "random":
        owner = baselines.random_partition(g, k, seed=0)
    else:
        owner = baselines.hash_partition(g, k)
    part = compile_partitioning(g, owner, k)
    return g, part


def test_sssp_matches_reference(setup):
    g, part = setup
    res = alg.etsch_sssp(part, 0)
    ref, ref_rounds = alg.reference_sssp(g, 0)
    got, want = np.asarray(res.state), np.asarray(ref)
    finite = np.isfinite(want)
    assert (got[finite] == want[finite]).all()
    assert np.isinf(got[~finite]).all()
    # ETSCH must not need more supersteps than one-hop-per-round Pregel
    assert int(res.supersteps) <= int(ref_rounds)


def test_cc_matches_reference(setup):
    g, part = setup
    res = alg.etsch_cc(part, key=1)
    ref, _ = alg.reference_cc(g)
    got, want = np.asarray(res.state), np.asarray(ref)
    # same partition structure: group vertices by label, compare partitions
    touched = np.zeros(g.n_vertices, bool)
    u, v = g.as_numpy()
    touched[u] = touched[v] = True
    def canon(lab):
        _, inv = np.unique(lab[touched], return_inverse=True)
        return inv
    assert (canon(got) == canon(want)).all()


def test_pagerank_matches_reference(setup):
    g, part = setup
    got = alg.etsch_pagerank(part, g.degrees(), iters=25).rank
    want = alg.reference_pagerank(g, iters=25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_mis_valid_and_maximal(setup):
    g, part = setup
    res = alg.etsch_mis(part, jax.random.key(4))
    assert bool(alg.is_independent_set(g, res.in_set))
    assert bool(alg.is_maximal_independent_set(g, res.in_set))


def test_sssp_gain_positive_for_dfep():
    """Paper fig 5d: DFEP partitions compress paths (gain > 0)."""
    g = graph.watts_strogatz(800, 6, 0.05, seed=5)
    owner, _ = dfep.partition(g, k=4, key=0)
    part = compile_partitioning(g, owner, 4)
    res = alg.etsch_sssp(part, 0)
    _, ref_rounds = alg.reference_sssp(g, 0)
    gain = 1.0 - int(res.supersteps) / int(ref_rounds)
    assert gain > 0.0


def test_disconnected_graph_cc():
    # two components: ring + ring
    n = 60
    u = np.arange(30); v = (u + 1) % 30
    u2 = 30 + np.arange(30); v2 = 30 + ((u2 - 30 + 1) % 30)
    g = graph.from_edge_array(n, np.stack([np.concatenate([u, u2]),
                                           np.concatenate([v, v2])], 1))
    owner = baselines.hash_partition(g, 3)
    part = compile_partitioning(g, owner, 3)
    res = alg.etsch_cc(part, key=0)
    got = np.asarray(res.state)
    assert len(np.unique(got[:30])) == 1
    assert len(np.unique(got[30:])) == 1
    assert got[0] != got[30]

"""Shared test config.

The container has no ``hypothesis`` wheel; rather than losing the property
tests we install a minimal, deterministic stand-in exposing the subset the
suite uses (``given`` / ``settings`` / ``strategies.integers``). When the
real package is available it is used untouched.

With ``REPRO_FLIGHT_DIR`` set (CI exports it), every test failure also
dumps a flight-recorder bundle — the obs ring, counters, gauges, and
provider snapshot at the moment of the assertion — into that directory,
which the workflow uploads as an artifact.  Locally the variable is unset
and the hook is inert.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types
import zlib

import pytest


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    rep = outcome.get_result()
    if rep.when != "call" or not rep.failed:
        return
    if not os.environ.get("REPRO_FLIGHT_DIR"):
        return
    try:  # postmortem capture must never mask the real failure
        from repro.obs import flight
        fr = flight.from_env()
        if fr is not None:
            fr.dump(f"test.{item.nodeid}",
                    context={"outcome": rep.outcome,
                             "duration_s": round(rep.duration, 3)})
    except Exception:
        pass

try:  # pragma: no cover - exercised only where hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    class _Integers:
        def __init__(self, min_value: int, max_value: int):
            self.min_value = int(min_value)
            self.max_value = int(max_value)

        def sample(self, rng: random.Random) -> int:
            # always exercise the endpoints, then sample uniformly
            return rng.randint(self.min_value, self.max_value)

        def endpoints(self):
            return (self.min_value, self.max_value)

    def _settings(max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 20))
                # deterministic per-test stream (process-hash is salted)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                names = list(strategies)
                # first examples pin the strategy endpoints (min, then max)
                for bound in range(2):
                    draw = {k: s.endpoints()[bound]
                            for k, s in strategies.items()}
                    fn(*args, **kwargs, **draw)
                for _ in range(max(n - 2, 0)):
                    draw = {k: strategies[k].sample(rng) for k in names}
                    fn(*args, **kwargs, **draw)

            # hide strategy params from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _Integers
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

"""repro.obs correctness: ring-buffer wraparound, the disabled no-op
contract, span-tree connectivity across a served micro-batch (admission ->
batch -> dispatch -> execute -> materialize), retrace events on a forced
bucket-shape change, export round-trips (JSONL + Chrome trace schema), and
partition-health gauges matching the core metrics after a stream patch."""
import json
import time

import numpy as np
import pytest

from repro.core import dfep, graph, metrics
from repro import engine as E
from repro import gserve as G
from repro import obs
from repro import stream as S
from repro.engine import runtime
from repro.obs.recorder import Recorder


@pytest.fixture(autouse=True)
def _clean_recorder():
    """The recorder is process-global: leave it disabled and empty for
    whichever test (in any file) runs next."""
    rec = obs.get()
    rec.disable()
    rec.reset()
    yield
    rec.disable()
    rec.reset()


def _served_server(n=150, k=4, seed=3, **kw):
    g = graph.watts_strogatz(n, 4, 0.2, seed=seed)
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    return g, G.GraphServer(E.Engine(plan), g, **kw)


# ---------------------------------------------------------------------------
# recorder core
# ---------------------------------------------------------------------------

def test_ring_wraparound():
    r = Recorder(capacity=16)
    r.enable()
    for i in range(2 * 16 + 3):
        r.event("tick", i=i)
    evs = r.events()
    assert len(evs) == 16
    # oldest-first unwrap: the surviving events are exactly the last 16
    assert [e["args"]["i"] for e in evs] == list(range(19, 35))
    st = r.stats()
    assert st["since_reset"] == 35 and st["dropped"] == 35 - 16
    assert st["recorded"] == 35


def test_lifetime_survives_reset():
    r = Recorder(capacity=8)
    r.enable()
    for i in range(5):
        r.event("tick")
    r.reset()
    assert r.stats()["recorded"] == 5 and r.stats()["since_reset"] == 0
    r.enable()
    r.event("tock")
    assert r.stats()["recorded"] == 6
    assert [e["name"] for e in r.events()] == ["tock"]


def test_disabled_is_noop_and_cheap():
    r = Recorder(capacity=64)
    assert not r.enabled
    r.event("never", x=1)
    r.counter("never")
    r.gauge("never", 1.0)
    sid = r.begin("never")
    assert sid is None
    r.end(sid)                       # end(None) needs no caller branch
    with r.span("never") as s:
        assert s is None
    with r.tags(program="x"):
        r.event("never")
    assert r.events() == [] and r.stats()["recorded"] == 0
    assert r.stats()["open_spans"] == 0
    # near-zero overhead: one enabled-check branch per call — generously
    # bounded here (loaded CI boxes) but orders of magnitude under what
    # any allocating/recording path would cost
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        r.event("never", a=1, b=2)
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 20e-6
    assert r.stats()["recorded"] == 0


def test_enable_with_new_capacity_reallocates():
    r = Recorder(capacity=4)
    r.enable()
    r.event("a")
    r.enable(capacity=8)             # capacity change drops the old ring
    assert r.stats()["capacity"] == 8 and r.events() == []
    r.event("b")
    assert [e["name"] for e in r.events()] == ["b"]


def test_span_stack_nesting_and_explicit_parent():
    r = Recorder()
    r.enable()
    with r.span("outer") as oid:
        with r.span("inner"):
            pass
        sid = r.begin("sibling", parent=oid)
        r.end(sid, extra="yes")
    by = {e["name"]: e for e in r.events()}
    assert by["inner"]["args"]["parent_id"] == oid
    assert by["sibling"]["args"]["parent_id"] == oid
    assert by["sibling"]["args"]["extra"] == "yes"
    assert "parent_id" not in by["outer"]["args"]
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in by.values())


def test_ambient_tags_merge():
    r = Recorder()
    r.enable()
    with r.tags(program="sssp", bucket=8):
        r.event("engine.retrace", epoch=3)
        r.event("engine.retrace", program="explicit-wins")
    e1, e2 = r.events()
    assert e1["args"] == {"program": "sssp", "bucket": 8, "epoch": 3}
    assert e2["args"]["program"] == "explicit-wins"


def test_provider_snapshot_and_weakref_drop():
    r = Recorder()

    class Src:
        def stats(self):
            return {"x": 1}

    s = Src()
    unreg = r.register_provider("src", s.stats)
    r.register_provider("fn", lambda: {"y": 2})
    snap = r.snapshot()
    assert snap["src"] == {"x": 1} and snap["fn"] == {"y": 2}
    del s                            # collected owner drops out silently
    assert "src" not in r.snapshot()
    unreg()
    r.register_provider("fn2", lambda: {"z": 3})
    assert "fn2" in r.snapshot()


# ---------------------------------------------------------------------------
# serve-path span tree
# ---------------------------------------------------------------------------

def test_served_batch_span_tree_connected():
    g, srv = _served_server()
    rec = obs.get()
    rec.enable()
    reqs = [G.QueryRequest("sssp", tenant="a", params={"source": 1}),
            G.QueryRequest("sssp", tenant="b", params={"source": 5}),
            G.QueryRequest("wcc", tenant="a")]
    out = srv.serve(reqs)
    assert all(r.value is not None for r in out)

    by_name = {}
    for e in rec.events():
        by_name.setdefault(e["name"], []).append(e)
    # one admission span per submitted request, tagged with its tenant
    adm = by_name["serve.admission"]
    assert len(adm) == 3
    assert {e["args"]["tenant"] for e in adm} == {"a", "b"}
    assert all(e["args"]["admitted"] for e in adm)
    # two micro-batches (sssp x2 coalesced, wcc), each a span that names
    # every rider request and tenant
    batches = by_name["serve.batch"]
    assert len(batches) == 2
    ids = {e["args"]["span_id"]: e for e in batches}
    sssp_batch = next(e for e in batches if e["args"]["program"] == "sssp")
    assert sssp_batch["args"]["n_requests"] == 2
    assert sssp_batch["args"]["tenants"] == ["a", "b"]
    assert {r.request.id for r in out[:2]} == \
        set(sssp_batch["args"]["requests"])
    # dispatch/execute/materialize all attach to a batch span explicitly
    # (the pipelined drain interleaves batches, so nesting can't carry it)
    for stage in ("serve.dispatch", "serve.execute", "serve.materialize"):
        stage_evs = by_name[stage]
        assert len(stage_evs) == 2, stage
        for e in stage_evs:
            assert e["args"]["parent_id"] in ids, stage
    # engine-level dispatch events rode along underneath
    assert len(by_name["engine.dispatch"]) == 2
    assert len(by_name["engine.result"]) == 2
    assert rec.stats()["open_spans"] == 0
    srv.close()


def test_admission_rejection_closes_span():
    _, srv = _served_server(max_pending=2)
    rec = obs.get()
    rec.enable()
    srv.submit(G.QueryRequest("sssp", tenant="a", params={"source": 1}))
    srv.submit(G.QueryRequest("sssp", tenant="a", params={"source": 2}))
    with pytest.raises(G.AdmissionError):
        srv.submit(G.QueryRequest("sssp", tenant="a", params={"source": 3}))
    adm = [e for e in rec.events() if e["name"] == "serve.admission"]
    assert [e["args"]["admitted"] for e in adm] == [True, True, False]
    assert "reason" in adm[-1]["args"]
    assert rec.stats()["open_spans"] == 0
    srv.drain()
    srv.close()


def test_retrace_events_attributed_and_counted():
    # a graph size nothing else traces: the process-wide jit cache must be
    # cold for these avals or no retrace happens at all
    g, srv = _served_server(n=173, k=5, buckets=(1, 2))
    rec = obs.get()
    rec.enable()
    before = runtime.TRACE_COUNTER["run_loop"]
    srv.serve([G.QueryRequest("sssp", params={"source": 1})])
    srv.serve([G.QueryRequest("sssp", params={"source": 2}),
               G.QueryRequest("sssp", params={"source": 5})])
    delta = runtime.TRACE_COUNTER["run_loop"] - before
    retraces = [e for e in rec.events() if e["name"] == "engine.retrace"]
    # the accounting invariant: every TRACE_COUNTER bump is now an
    # attributable event carrying the program (explicit arg) and the
    # dispatch's bucket shape (ambient tag set at the dispatch site)
    assert len(retraces) == delta >= 1
    assert all(e["args"]["program"] == "sssp" for e in retraces)
    assert all(e["args"]["bucket"] in (1, 2) for e in retraces)
    assert all(e["args"]["epoch"] == 0 for e in retraces)
    snap = rec.snapshot()
    assert snap["counters"]["engine.retraces"] == delta
    assert snap["jit"]["run_loop_traces"] == runtime.TRACE_COUNTER["run_loop"]
    srv.close()


def test_retrace_event_on_forced_compaction_epoch():
    # zero slack: any insert forces a compaction, whose epoch bump is a new
    # static aux -> the one legitimate retrace on the streaming path, and
    # the event must carry the NEW epoch so a trace shows what triggered it
    g = graph.watts_strogatz(166, 4, 0.2, seed=2)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32,
                                             edge_slack=0, vertex_slack=0,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, buckets=(1,), cache_entries=0)
    srv.serve([G.QueryRequest("sssp", params={"source": 1})])  # trace cold
    rec = obs.get()
    rec.enable()
    rng = np.random.default_rng(1)
    sess.apply(inserts=rng.integers(0, g.n_vertices, size=(90, 2)))
    assert sess.epoch > 0
    before = runtime.TRACE_COUNTER["run_loop"]
    srv.serve([G.QueryRequest("sssp", params={"source": 3})])
    delta = runtime.TRACE_COUNTER["run_loop"] - before
    retraces = [e for e in rec.events() if e["name"] == "engine.retrace"]
    assert len(retraces) == delta >= 1
    assert retraces[-1]["args"]["epoch"] == sess.epoch
    assert retraces[-1]["args"]["program"] == "sssp"
    srv.close()


def test_patched_plan_keeps_warm_cache_no_retrace_events():
    g = graph.watts_strogatz(150, 4, 0.2, seed=3)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=64,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, buckets=(2,), cache_entries=0)
    rec = obs.get()
    srv.serve([G.QueryRequest("sssp", params={"source": 1}),
               G.QueryRequest("sssp", params={"source": 5})])  # trace cold
    rec.enable()
    sess.apply(inserts=np.array([[0, 90], [3, 77]]))
    srv.serve([G.QueryRequest("sssp", params={"source": 2}),
               G.QueryRequest("sssp", params={"source": 7})])
    evs = [e["name"] for e in rec.events()]
    # patched plan: same treedef/avals -> warm jit cache, zero retraces —
    # but the swap itself and the dispatches are all on the record
    assert "engine.retrace" not in evs
    assert "stream.plan_swap" in evs and "serve.plan_swap" in evs
    assert "engine.dispatch" in evs
    srv.close()


# ---------------------------------------------------------------------------
# stream health gauges
# ---------------------------------------------------------------------------

def test_health_gauges_match_plan_metrics_after_patch():
    g = graph.watts_strogatz(150, 4, 0.2, seed=1)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=64,
                                             drift_threshold=1e9), key=0)
    rec = obs.get()
    rec.enable()
    rng = np.random.default_rng(0)
    u, v = g.as_numpy()
    sess.apply(inserts=rng.integers(0, g.n_vertices, size=(20, 2)),
               deletes=np.stack([u[:10], v[:10]], 1))

    plan = sess.plan
    snap = rec.snapshot()
    gauges = snap["gauges"]
    # the paper's axes, recomputed from the installed plan by core/metrics
    # formulas — the gauge stamped at the swap must agree exactly
    assert gauges["stream.replication_factor"] == \
        pytest.approx(plan.replication_factor())
    sizes = np.asarray(plan.n_edges_local)
    assert gauges["stream.balance_nstdev"] == \
        pytest.approx(metrics.nstdev(sizes, int(sizes.sum())))
    assert gauges["stream.exchange_per_superstep"] == plan.exchange_volume
    assert 0 < gauges["stream.edge_lane_occupancy_max"] <= 1.0
    assert gauges["stream.min_free_edge_slots"] >= 0

    swaps = [e for e in rec.events() if e["name"] == "stream.plan_swap"]
    assert swaps, "plan mutation must emit a swap event"
    last = swaps[-1]["args"]
    assert last["replication_factor"] == \
        pytest.approx(plan.replication_factor())
    assert last["inserts"] == 20 and last["deletes"] == 10
    assert last["version"] == sess.version
    # the apply itself was a span
    assert any(e["name"] == "stream.apply" for e in rec.events())


def test_compaction_event_carries_new_epoch():
    g = graph.watts_strogatz(120, 4, 0.2, seed=3)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32,
                                             edge_slack=0, vertex_slack=0,
                                             drift_threshold=1e9), key=0)
    rec = obs.get()
    rec.enable()
    epoch0 = sess.epoch
    rng = np.random.default_rng(1)
    sess.apply(inserts=rng.integers(0, g.n_vertices, size=(40, 2)))
    assert sess.epoch > epoch0          # zero slack forces compaction
    comps = [e for e in rec.events() if e["name"] == "stream.compaction"]
    assert comps and comps[-1]["args"]["epoch"] == sess.epoch


# ---------------------------------------------------------------------------
# export round-trip
# ---------------------------------------------------------------------------

def test_export_roundtrip(tmp_path):
    g, srv = _served_server()
    rec = obs.get()
    rec.enable()
    srv.serve([G.QueryRequest("sssp", tenant="a", params={"source": 1}),
               G.QueryRequest("wcc", tenant="b")])
    srv.close()
    evs = rec.events()

    jl = tmp_path / "trace.jsonl"
    n = obs.export_jsonl(str(jl))
    lines = [json.loads(x) for x in jl.read_text().splitlines()]
    assert n == len(lines) == len(evs)
    assert [x["name"] for x in lines] == [e["name"] for e in evs]

    ct = tmp_path / "trace_chrome.json"
    n2 = obs.export_chrome_trace(str(ct))
    doc = json.loads(ct.read_text())
    tes = doc["traceEvents"]
    assert n2 == len(tes) == len(evs)
    for te in tes:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(te)
        assert te["ph"] in ("X", "i")
        if te["ph"] == "X":
            assert te["dur"] >= 0
        else:
            assert te["s"] == "t"
    # the span tree survives the export: parent ids resolve in-file
    sids = {te["args"]["span_id"] for te in tes if "span_id" in te["args"]}
    for te in tes:
        if "parent_id" in te.get("args", {}):
            assert te["args"]["parent_id"] in sids


def test_overwritten_counter_monotone_across_reset():
    r = Recorder(capacity=8)
    r.enable()
    for i in range(12):
        r.event("tick", i=i)
    assert r.stats()["overwritten"] == 4
    r.reset()                        # ring cleared, lifetime loss is not
    assert r.stats()["overwritten"] == 4
    r.enable()
    for i in range(10):
        r.event("tock", i=i)
    st = r.stats()
    assert st["overwritten"] == 6
    assert st["dropped"] == 2        # per-reset loss restarts, lifetime grows


def test_chrome_export_tolerates_overwritten_parent(tmp_path):
    r = Recorder(capacity=4)
    r.enable()
    with r.span("parent") as pid:
        pass                         # parent's X event lands first...
    sid = r.begin("orphan-child", parent=pid)
    r.end(sid)
    for i in range(3):               # ...and the flood overwrites it
        r.event("filler", i=i)
    assert all(e["name"] != "parent" for e in r.events())
    path = tmp_path / "trace.json"
    n = obs.export_chrome_trace(str(path), recorder=r)
    doc = json.loads(path.read_text())
    assert n == len(doc["traceEvents"]) == 4
    # the unresolvable reference is renamed, not emitted: Perfetto would
    # otherwise try to parent the slice onto a nonexistent span
    (child,) = [te for te in doc["traceEvents"]
                if te["name"] == "orphan-child"]
    assert "parent_id" not in child["args"]
    assert child["args"]["dangling_parent_id"] == pid
    assert doc["otherData"]["dangling_parents"] == 1


def test_raising_provider_reported_not_fatal():
    r = Recorder()
    boom_calls = []

    def boom():
        boom_calls.append(1)
        raise RuntimeError("gauge backend gone")

    r.register_provider("boom", boom)
    r.register_provider("fine", lambda: {"ok": 1})
    snap = r.snapshot()              # must not raise
    assert snap["fine"] == {"ok": 1}
    assert snap["boom"] == {"error": "RuntimeError: gauge backend gone"}
    assert boom_calls == [1]


# Clock discipline (no wall-clock time.time() in measured paths) is
# enforced repo-wide by the LP002 AST rule (repro.analysis) via
# tests/test_analysis.py::test_repo_scans_clean — alias-aware, unlike the
# grep-mirroring test that used to live here.

# analysis-virtual-path: engine/dispatch.py
"""RH002 bad: mutable defaults shared across calls / unhashable as static."""


def dispatch(prog, resources={}):  # FLAG: RH002
    return prog, resources


def submit(reqs=[], *, opts=dict()):  # FLAG: RH002  (and the kw-only one)
    return reqs, opts

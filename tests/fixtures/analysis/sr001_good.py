# analysis-virtual-path: gserve/warm.py
"""Good twin of incident_scalar_state.py: the cold rows come from the
program entry's declared ``StateSpec``, so scalar and vector-state
programs share one allocation path — and explicit rank-2 numpy shapes
(a deliberate ``(V, F)`` tuple) are not the analyzer's business."""
import numpy as np


def warm_block(entry, rows, buffer):
    cold = entry.state.cold(buffer.graph.n_vertices)
    return np.stack([r if r is not None else cold for r in rows])


def scratch_plane(buffer, features):
    # explicit rank choice: fine
    return np.zeros((buffer.graph.n_vertices, features), np.float32)

# analysis-virtual-path: gserve/router.py
"""LP001 bad: per-kind string branching in the serving layer — including
the reversed-operand form the old grep guard could not see."""


def route(req):
    if req.kind == "sssp":  # FLAG: LP001
        return "shortest"
    if "pagerank" == req.kind:  # FLAG: LP001
        return "rank"
    if req.channel != "vertex":  # FLAG: LP001
        return "edgeplane"
    return "generic"

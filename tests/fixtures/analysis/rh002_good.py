# analysis-virtual-path: engine/dispatch.py
"""RH002 good: None defaults, constructed inside."""


def dispatch(prog, resources=None):
    return prog, dict(resources or {})


def submit(reqs=None, *, opts=None):
    return list(reqs or ()), dict(opts or {})

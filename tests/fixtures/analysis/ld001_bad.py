# analysis-virtual-path: gserve/widget.py
"""LD001 bad: an attribute written both under and outside self._lock."""
import threading


class Widget:
    def __init__(self):
        self._lock = threading.Lock()
        self._cache = {}
        self._epoch = 0

    def swap(self, items):
        with self._lock:
            self._cache = dict(items)
            self._epoch += 1

    def refresh(self, items):
        self._cache = dict(items)  # FLAG: LD001
        self._cache.update(items)  # FLAG: LD001

    def bump(self):
        self._epoch += 1  # FLAG: LD001

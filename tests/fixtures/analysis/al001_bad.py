# analysis-virtual-path: stream/owner.py
"""AL001 bad: a possibly read-only return value assigned to a field the
class mutates in place."""
import numpy as np


class OwnerTable:
    def __init__(self, owner):
        self.owner = np.array(owner)

    def reauction(self, region):
        # jax-backed, read-only view assigned to an in-place-mutated field
        self.owner = region.local_reauction()  # FLAG: AL001

    def apply(self, idx, p):
        self.owner[idx] = p

# analysis-virtual-path: engine/registry.py
"""RH003 good: key functions index declared params totally (KeyError on
a missing param beats silently aliasing two requests onto one cache
entry)."""


def batch_key_of(prog, params):
    return (prog, params["iters"])


def admit(params):
    # .get() outside *key*-named functions is unrestricted
    return params.get("priority", 0)

# analysis-virtual-path: engine/converge.py
"""TS003 bad: Python control flow on traced values inside a jit body."""
import jax
import jax.numpy as jnp


@jax.jit
def converge(state, prev):
    if jnp.all(state == prev):  # FLAG: TS003
        return state
    while jnp.max(jnp.abs(state - prev)) > 1e-6:  # FLAG: TS003
        prev, state = state, state * 0.5
    return state

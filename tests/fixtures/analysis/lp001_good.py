# analysis-virtual-path: gserve/router.py
"""LP001 good: dispatch through the registry, no string special-casing."""


def route(req, registry):
    spec = registry.lookup(req.kind)   # using .kind as a lookup key is fine
    return spec.dispatch(req)

# analysis-virtual-path: engine/registry.py
"""RH003 bad: key function defaults a missing param instead of raising."""


def batch_key_of(prog, params):
    return (prog, params.get("iters", 30))  # FLAG: RH003


def lane_cache_key(prog, epoch, kw):
    return (prog, epoch, kw.get("damping"))  # FLAG: RH003

# analysis-virtual-path: engine/registry.py
"""RH001 bad: dict iteration order baked into a cache key."""


def cache_key_of(params, resources):
    base = tuple(params.items())  # FLAG: RH001
    res = tuple((resources or {}).keys())  # FLAG: RH001
    return base + res

# analysis-virtual-path: core/partition.py
"""LP003 good: core depends only on core (and the outside world)."""
import numpy as np

from . import graph
from .metrics import evaluate


def partition(g):
    return evaluate(graph.validate(g), np.zeros(1))

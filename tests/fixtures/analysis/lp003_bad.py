# analysis-virtual-path: core/partition.py
"""LP003 bad: the core layer reaching up into engine/serving — absolute
and relative forms both resolve."""
import repro.engine.runtime  # FLAG: LP003
from repro.gserve import server  # FLAG: LP003
from ..obs import recorder  # FLAG: LP003


def partition(g):
    return repro.engine.runtime, server, recorder, g

# analysis-virtual-path: engine/runtime.py
"""Incident fixture — PR 6 observability-overhead regression.

The first cut of the engine instrumentation computed the convergence
gauge with ``jnp.max`` while building the recorder event.  Every recorded
superstep dispatched a fresh single-op XLA computation and
``benchmarks/fig_obs.py`` blew its 3% overhead budget.  The fix reduced
with numpy on the already-synced host copy; TS001 must flag the original
forever."""
import jax.numpy as jnp

from repro import obs as _obs


def materialize(result):
    host = result.block_until_ready()
    _obs.get().event(
        "engine.superstep",
        residual=float(jnp.max(jnp.abs(result.delta))),  # FLAG: TS001
    )
    return host

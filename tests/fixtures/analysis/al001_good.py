# analysis-virtual-path: stream/owner.py
"""AL001 good: every assignment to the mutated field is provably fresh."""
import numpy as np


class OwnerTable:
    def __init__(self, owner):
        self.owner = np.asarray(owner).copy()

    def reauction(self, region):
        new_owner = region.local_reauction()
        self.owner = np.array(new_owner)   # writable copy

    def apply(self, idx, p):
        self.owner[idx] = p

# analysis-virtual-path: engine/converge.py
"""TS003 good: static-Python branches and lax control flow are fine."""
import jax
import jax.numpy as jnp


@jax.jit
def converge(state, prev, axis=None):
    if prev is None:              # static trace-time branch: legitimate
        prev = jnp.zeros_like(state)
    if axis is not None:          # static trace-time branch: legitimate
        state = state.sum(axis)
    return jnp.where(state == prev, state, state * 0.5)

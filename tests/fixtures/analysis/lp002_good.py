# analysis-virtual-path: gserve/timing.py
"""LP002 good: monotonic clock for intervals."""
import time


def measure(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0

# analysis-virtual-path: engine/registry.py
"""Incident fixture — the pagerank ``iters=None`` cache-identity bug.

A cache key built with ``params.get("iters")`` mapped the
omitted-parameter default and an explicit ``iters=None`` onto the same
compiled program even though validation treated them differently — two
semantically distinct requests shared one cache entry.  Key functions now
index declared params totally (``params[name]`` raises on a miss); RH003
must flag the original forever."""


def cache_key_of(prog, epoch, params):
    return (prog, epoch, params.get("iters"))  # FLAG: RH003

# analysis-virtual-path: engine/sweep.py
"""TS002 good: traced body stays in jnp; syncs happen in the host driver."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("n",))
def sweep(state, n):
    return state * jnp.sum(state)


def driver(state):
    # the driver is NOT traced: it may sync freely after dispatch
    out = sweep(state, 4)
    return np.asarray(out), float(out[0])

# analysis-virtual-path: gserve/widget.py
"""LD001 good: guarded state only mutated under the lock; private helpers
whose every call site holds the lock inherit the locked context; unguarded
attributes stay free."""
import threading


class Widget:
    def __init__(self):
        self._lock = threading.Lock()
        self._cache = {}
        self._stats = 0       # never written under the lock: unguarded

    def swap(self, items):
        with self._lock:
            self._store(items)

    def clear(self):
        with self._lock:
            self._store(())

    def _store(self, items):
        # locked context: both call sites above hold self._lock
        self._cache = dict(items)

    def note(self):
        self._stats += 1      # unguarded attr, no lock needed

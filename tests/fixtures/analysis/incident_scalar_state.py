# analysis-virtual-path: gserve/warm.py
"""Incident fixture — the implicit scalar-state-rank hazard.

Before the ``StateSpec`` API, the serving warm store cold-filled missing
warm-start lanes with ``np.full(buffer.graph.n_vertices, np.inf)`` —
hard-coding one float per vertex.  The first vector-state program
(``gcn_layer``, ``[V, F]`` per-vertex planes) would have warm-started from
a rank-1 block and crashed in a reshape deep inside jit, lanes already
batched, long after admission.  The fix allocates through the program
entry's declared spec (``entry.state.cold(V)``); SR001 must flag the
original forever."""
import numpy as np


def warm_block(entry, rows, buffer):
    cold = np.full(buffer.graph.n_vertices, np.inf, np.float32)  # FLAG: SR001
    return np.stack([r if r is not None else cold for r in rows])

# analysis-virtual-path: engine/sweep.py
"""TS002 bad: host syncs inside a jit-traced function."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("n",))
def sweep(state, n):
    host = np.asarray(state)  # FLAG: TS002
    total = float(jnp.sum(state))  # FLAG: TS002
    flat = state.tolist()  # FLAG: TS002
    return state * total, host, flat


def driver(state):
    return jax.jit(_inner)(state)  # _inner becomes a trace root


def _inner(state):
    return state.item()  # FLAG: TS002

# analysis-virtual-path: engine/registry.py
"""RH001 good: keys sorted before they become cache identity."""


def cache_key_of(params, resources):
    base = tuple(sorted(params.items()))
    res = tuple(sorted((resources or {}).keys()))
    return base + res

# analysis-virtual-path: engine/instr.py
"""TS001 bad: jnp reduction computed inside recorder event arguments."""
import jax.numpy as jnp

from repro import obs as _obs


def after_sweep(state):
    rec = _obs.get()
    rec.event("engine.sweep", max_state=float(jnp.max(state)))  # FLAG: TS001
    _obs.get().gauge("engine.norm", jnp.linalg.norm(state))  # FLAG: TS001

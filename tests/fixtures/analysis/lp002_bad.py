# analysis-virtual-path: gserve/timing.py
"""LP002 bad: wall-clock intervals, including both aliased forms the old
grep (`grep -F 'time.time()'`) could never catch."""
import time as t
from time import time as now


def measure(fn):
    t0 = now()  # FLAG: LP002
    fn()
    t1 = t.time()  # FLAG: LP002
    return t1 - t0

# analysis-virtual-path: stream/session.py
"""Incident fixture — PR 7 ``_reauction`` read-only-view bug.

``local_reauction`` returns a jax-backed, read-only array.  Assigning it
straight to ``self.owner`` armed a time bomb: the next slot-level
in-place write (``self.owner[idx] = p``) raised ``ValueError: assignment
destination is read-only`` — but only on the first streamed update after
a re-auction, a path no unit test exercised.  The shipped fix wraps the
return in ``np.array(...)``; AL001 must flag the original forever."""


class StreamSession:
    def __init__(self, owner):
        self.owner = list(owner)

    def _reauction(self, g, region):
        new_owner = local_reauction(g, self.owner, region)
        self.owner = new_owner  # FLAG: AL001

    def apply_update(self, idx, p):
        self.owner[idx] = p


def local_reauction(g, owner, region):
    raise NotImplementedError  # stand-in for the real kernel-backed call

# analysis-virtual-path: engine/instr.py
"""TS001 good: reductions done with numpy on already-synced host arrays."""
import numpy as np

from repro import obs as _obs


def after_sweep(state_np):
    rec = _obs.get()
    rec.event("engine.sweep", max_state=float(np.max(state_np)))
    _obs.get().gauge("engine.norm", float(np.linalg.norm(state_np)))

"""DFEP behaviour tests: validity, balance, connectedness, money conservation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dfep, graph, metrics
from repro.core.etsch import compile_partitioning


@pytest.fixture(scope="module")
def small_graph():
    return graph.watts_strogatz(600, 6, 0.1, seed=3)


@pytest.fixture(scope="module")
def small_slots(small_graph):
    return dfep.build_slots(small_graph)


@pytest.fixture(scope="module")
def small_partition(small_graph, small_slots):
    owner, info = dfep.partition(small_graph, k=6, key=0, slots=small_slots)
    return owner, info


def test_partition_is_total_and_disjoint(small_graph, small_partition):
    owner, info = small_partition
    own = np.asarray(owner)
    em = np.asarray(small_graph.edge_mask)
    # every real edge owned by exactly one valid partition
    assert (own[em] >= 0).all() and (own[em] < 6).all()
    # padding slots are never assigned
    assert (own[~em] == -2).all()


def test_partition_covers_all_edges(small_graph, small_partition):
    owner, _ = small_partition
    own = np.asarray(owner)[np.asarray(small_graph.edge_mask)]
    assert np.bincount(own, minlength=6).sum() == small_graph.n_edges


def test_balance(small_graph, small_partition):
    owner, info = small_partition
    m = metrics.evaluate(small_graph, owner, 6, compute_gain=False)
    # paper-quality balance on a small-world graph
    assert m.largest_norm < 1.5, m.largest_norm
    assert m.nstdev < 0.35, m.nstdev


def test_connectedness(small_graph, small_partition):
    """DFEP (non-C) partitions are connected subgraphs (paper §IV)."""
    owner, info = small_partition
    if info["finalized"]:
        pytest.skip("stall fallback used; connectedness not guaranteed")
    m = metrics.evaluate(small_graph, owner, 6, compute_gain=False)
    assert m.connected_frac == 1.0


def test_money_conservation_per_round(small_graph, small_slots):
    """Units only enter via init+grants and leave 1 per purchase."""
    g, slots = small_graph, small_slots
    cfg = dfep.DfepConfig(k=4)
    st = dfep.init_state(g, cfg, jax.random.key(1))
    rnd = jax.jit(lambda s: dfep._round(g, slots, cfg, s))
    for _ in range(30):
        before_money = int(jnp.sum(st.mv))
        before_owned = int(jnp.sum(st.owner >= 0))
        st2 = rnd(st)
        after_money = int(jnp.sum(st2.mv))
        after_owned = int(jnp.sum(st2.owner >= 0))
        bought = after_owned - before_owned
        sizes = dfep._sizes(st2.owner, 4)
        grant = jnp.minimum(cfg.cap, -(-jnp.int32(g.n_edges) // jnp.maximum(sizes, 1)))
        remaining = int(jnp.sum(st2.owner == dfep.FREE))
        granted = int(jnp.sum(grant)) if remaining > 0 else 0
        assert after_money == before_money - bought + granted
        st = st2


def test_owner_never_unassigned(small_graph, small_slots):
    """Once sold, an edge stays sold (plain DFEP; DFEP-C may only transfer)."""
    g, slots = small_graph, small_slots
    cfg = dfep.DfepConfig(k=4)
    st = dfep.init_state(g, cfg, jax.random.key(2))
    rnd = jax.jit(lambda s: dfep._round(g, slots, cfg, s))
    prev = np.asarray(st.owner)
    for _ in range(40):
        st = rnd(st)
        cur = np.asarray(st.owner)
        sold_before = prev >= 0
        assert (cur[sold_before] == prev[sold_before]).all()
        prev = cur


def test_variant_c_transfers_only_to_poor(small_graph, small_slots):
    g, slots = small_graph, small_slots
    cfg = dfep.DfepConfig(k=4, variant_c=True)
    st = dfep.init_state(g, cfg, jax.random.key(3))
    rnd = jax.jit(lambda s: dfep._round(g, slots, cfg, s))
    for _ in range(60):
        prev = np.asarray(st.owner)
        st = rnd(st)
        cur = np.asarray(st.owner)
        moved = (prev >= 0) & (cur != prev)
        if moved.any():
            sizes = np.bincount(prev[prev >= 0], minlength=4)
            mean = sizes.sum() / 4
            # recipients were poor at the time of the steal
            assert (sizes[cur[moved]] < mean / cfg.poor_p + 1).all()


def test_determinism(small_graph, small_slots):
    a, _ = dfep.partition(small_graph, k=4, key=7, slots=small_slots)
    b, _ = dfep.partition(small_graph, k=4, key=7, slots=small_slots)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_road_graph_variant_c_beats_plain_on_balance():
    """Paper fig 6/7: on large-diameter graphs DFEP-C balances better."""
    g = graph.road_network(28, 28, 0.25, seed=0)
    slots = dfep.build_slots(g)
    _, info_a = dfep.partition(g, k=8, key=1, slots=slots)
    owner_a, _ = dfep.partition(g, k=8, key=1, slots=slots)
    owner_c, _ = dfep.partition(g, k=8, key=1, variant_c=True, slots=slots)
    ma = metrics.evaluate(g, owner_a, 8, compute_gain=False)
    mc = metrics.evaluate(g, owner_c, 8, compute_gain=False)
    # DFEP-C should not be (much) worse balanced on a road network
    assert mc.nstdev <= ma.nstdev * 1.25 + 0.05


def test_compile_partitioning_roundtrip(small_graph, small_partition):
    owner, _ = small_partition
    part = compile_partitioning(small_graph, owner, 6)
    sizes = np.asarray(part.sizes)
    own = np.asarray(owner)[np.asarray(small_graph.edge_mask)]
    assert (sizes == np.bincount(own, minlength=6)).all()
    # members: every edge endpoint of partition k is a member
    member = np.asarray(part.member)
    ps, pd, pm = np.asarray(part.src), np.asarray(part.dst), np.asarray(part.mask)
    for k in range(6):
        assert member[k, ps[k][pm[k]]].all()
        assert member[k, pd[k][pm[k]]].all()

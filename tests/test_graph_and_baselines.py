"""Graph container/generator + baseline partitioner tests (incl. hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import baselines, graph, metrics


def test_from_edge_array_dedup_and_selfloops():
    edges = np.array([[0, 1], [1, 0], [2, 2], [1, 2], [1, 2]])
    g = graph.from_edge_array(3, edges)
    assert g.n_edges == 2  # (0,1) and (1,2)
    u, v = g.as_numpy()
    assert set(zip(u.tolist(), v.tolist())) == {(0, 1), (1, 2)}


def test_degrees():
    edges = np.array([[0, 1], [0, 2], [0, 3]])
    g = graph.from_edge_array(4, edges)
    assert np.asarray(g.degrees()).tolist() == [3, 1, 1, 1]


@pytest.mark.parametrize("name", ["astroph", "usroads", "wordnet"])
def test_dataset_profiles(name):
    """Synthetic stand-ins land in the published |V|/|E| ballpark at scale."""
    spec = graph.DATASETS[name]
    g = graph.load_dataset(name, scale=0.05, seed=0)
    assert g.n_vertices > 0.5 * spec.v_published * 0.05
    # |E|/|V| ratio within 2x of published
    pub_ratio = spec.e_published / spec.v_published
    got_ratio = g.n_edges / g.n_vertices
    assert 0.4 * pub_ratio < got_ratio < 2.5 * pub_ratio


def test_road_network_has_large_diameter():
    g = graph.road_network(20, 20, 0.2, seed=0)
    from repro.core.algorithms import reference_sssp
    _, rounds = reference_sssp(g, 0)
    assert int(rounds) > 15  # diameter-class >> small-world


def test_remap_edges_preserves_counts():
    g = graph.watts_strogatz(300, 4, 0.0, seed=0)
    g2 = graph.remap_edges(g, 0.3, seed=1)
    assert g2.n_vertices == g.n_vertices
    assert abs(g2.n_edges - g.n_edges) < 0.1 * g.n_edges  # dedup may drop a few


@given(k=st.integers(2, 12), seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_random_partition_balance(k, seed):
    g = graph.watts_strogatz(400, 4, 0.1, seed=0)
    owner = baselines.random_partition(g, k, seed=seed)
    own = np.asarray(owner)[np.asarray(g.edge_mask)]
    assert own.min() >= 0 and own.max() < k
    sizes = np.bincount(own, minlength=k)
    assert sizes.max() < 2.0 * g.n_edges / k  # random is well balanced


@given(k=st.integers(2, 12))
@settings(max_examples=8, deadline=None)
def test_hash_partition_deterministic_and_total(k):
    g = graph.barabasi_albert(200, 3, seed=1)
    a = baselines.hash_partition(g, k)
    b = baselines.hash_partition(g, k)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    own = np.asarray(a)[np.asarray(g.edge_mask)]
    assert own.min() >= 0 and own.max() < k


def test_greedy_partition_valid_and_balanced():
    g = graph.barabasi_albert(300, 3, seed=0)
    owner = baselines.greedy_partition(g, 6, seed=0)
    own = np.asarray(owner)[np.asarray(g.edge_mask)]
    assert len(own) == g.n_edges and own.min() >= 0 and own.max() < 6
    m = metrics.evaluate(g, owner, 6, compute_gain=False)
    assert m.largest_norm < 1.6


def test_jabeja_valid():
    g = graph.watts_strogatz(400, 6, 0.1, seed=0)
    owner, info = baselines.jabeja_partition(g, 5, seed=0, rounds=60)
    own = np.asarray(owner)[np.asarray(g.edge_mask)]
    assert own.min() >= 0 and own.max() < 5
    assert info["rounds"] == 60


def test_metrics_nstdev_zero_for_perfect():
    sizes = np.array([10, 10, 10, 10])
    assert metrics.nstdev(sizes, 40) == 0.0


def test_messages_counts_frontier_replicas():
    # path 0-1-2 split into 2 partitions at vertex 1: F_0={1}, F_1={1} → 2
    g = graph.from_edge_array(3, np.array([[0, 1], [1, 2]]))
    owner = jnp.where(g.edge_mask, jnp.asarray(
        np.array([0, 1] + [0] * (g.e_pad - 2), np.int32)), -2)
    m = metrics.evaluate(g, owner, 2, compute_gain=False)
    assert m.messages == 2
    assert m.frontier_total == 1

"""Program-registry contract: typed misuse errors with actionable messages,
param normalization, warm-state validation, the no-per-kind-branching
invariant of the serving layer, and the acceptance flow — a program
registered through the PUBLIC API only runs partition → engine → stream
patch → serve with zero edits under src/repro/gserve/."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import baselines, dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S
from repro.engine import registry


# ---------------------------------------------------------------------------
# typed misuse errors
# ---------------------------------------------------------------------------

def test_duplicate_registration_raises():
    with pytest.raises(E.DuplicateProgramError, match="already registered"):
        E.register("sssp", E.SSSP,
                   params=[E.ParamSpec("source", int, batchable=True)])


def test_unknown_program_raises():
    with pytest.raises(E.UnknownProgramError, match="registered:"):
        E.get_program("nope")
    with pytest.raises(E.UnknownProgramError):
        G.QueryRequest("nope")


def test_unknown_param_raises():
    with pytest.raises(E.UnknownParamError, match="declared: source"):
        G.QueryRequest("sssp", params={"source": 0, "radius": 3})


def test_missing_required_param_raises():
    with pytest.raises(E.ParamTypeError, match="requires parameter"):
        G.QueryRequest("sssp")


def test_wrong_dtype_raises():
    with pytest.raises(E.ParamTypeError, match="expects int"):
        G.QueryRequest("sssp", params={"source": 1.5})
    with pytest.raises(E.ParamTypeError, match="expects int"):
        G.QueryRequest("sssp", params={"source": "zero"})
    with pytest.raises(E.ParamTypeError):
        G.QueryRequest("sssp", params={"source": True})   # bool is not int
    # numpy integer scalars coerce cleanly
    r = G.QueryRequest("sssp", params={"source": np.int64(4)})
    assert r.params["source"] == 4 and type(r.params["source"]) is int


def test_batch_axis_on_scalar_param_raises():
    # non-batchable param passed a batch axis
    with pytest.raises(E.BatchAxisError, match="not batchable"):
        G.QueryRequest("pagerank", params={"iters": [10, 20]})
    # a batchable param still takes one scalar per request — the scheduler
    # forms the batch axis by coalescing requests
    with pytest.raises(E.BatchAxisError, match="one request"):
        G.QueryRequest("sssp", params={"source": np.arange(4)})


def test_param_validate_hook_runs():
    with pytest.raises(ValueError, match=">= 0"):
        G.QueryRequest("pagerank", params={"iters": -1})


def test_warm_state_shape_mismatch_raises():
    g = graph.watts_strogatz(80, 4, 0.1, seed=0)
    eng = E.Engine(E.compile_plan(g, baselines.hash_partition(g, 2), 2))
    with pytest.raises(E.WarmStateError, match="80 vertices"):
        eng.run(E.SSSP, source=jnp.int32(0), warm_state=np.zeros(7))
    with pytest.raises(E.WarmStateError, match="no warm_init hook"):
        eng.run(E.WCC, warm_state=np.zeros(80))
    # batched: one [V] row per lane required
    with pytest.raises(E.WarmStateError):
        eng.run_batched(E.SSSP, {"source": np.array([0, 1], np.int32)},
                        warm_state=np.zeros(80))


def test_registration_schema_validation():
    with pytest.raises(E.RegistryError, match="at most one batchable"):
        registry.ProgramRegistry().register(
            "two-axes", E.SSSP,
            params=[E.ParamSpec("a", int, batchable=True),
                    E.ParamSpec("b", int, batchable=True)])
    with pytest.raises(E.RegistryError, match="duplicate parameter"):
        registry.ProgramRegistry().register(
            "dup", E.SSSP, params=[E.ParamSpec("a"), E.ParamSpec("a")])
    with pytest.raises(E.RegistryError, match="role"):
        registry.ProgramRegistry().register(
            "badrole", E.SSSP, params=[E.ParamSpec("a", int, role="wat")])
    # defaults run the same dtype/validate gauntlet as caller values —
    # a bad default fails at REGISTRATION, not deep inside a dispatch
    with pytest.raises(E.RegistryError, match="default .* is invalid"):
        registry.ProgramRegistry().register(
            "baddefault", E.SSSP, params=[E.ParamSpec("iters", int,
                                                      default=None)])
    def _pos(v):
        if v <= 0:
            raise ValueError("must be > 0")
    with pytest.raises(ValueError, match="> 0"):
        registry.ProgramRegistry().register(
            "badvalidated", E.SSSP,
            params=[E.ParamSpec("n", int, default=0, validate=_pos)])


# ---------------------------------------------------------------------------
# derived keys
# ---------------------------------------------------------------------------

def test_keys_derive_from_normalized_params():
    a = G.QueryRequest("sssp", tenant="a", params={"source": 3})
    b = G.QueryRequest("sssp", tenant="b", params={"source": 3})
    assert a.batch_key() == b.batch_key() == ("sssp",)
    assert a.cache_key() == b.cache_key() == ("sssp", ("source", 3))
    entry = E.get_program("sssp")
    assert entry.lane_cache_key(a.params, 9) == ("sssp", ("source", 9))


# The no-kind/no-channel-branching invariant is enforced by the LP001
# AST rule (repro.analysis) via tests/test_analysis.py::test_repo_scans_clean
# — the grep-mirroring test that lived here is gone with the CI greps.


# ---------------------------------------------------------------------------
# acceptance: a user program, public API only, partition → engine →
# stream patch → serve
# ---------------------------------------------------------------------------

def _hops2_oracle(g, source):
    """Vertices within 2 hops of source (1.0/0.0), via the BFS oracle."""
    from repro.core import algorithms as alg
    lvl = alg.reference_bfs(g, source)
    return ((lvl >= 0) & (lvl <= 2)).astype(np.float32)


def _make_hops2():
    """A genuinely new EdgeProgram built from public pieces: 2-hop
    reachability (min-hop relaxation capped at 2, finalized to 1/0)."""
    INF = jnp.float32(jnp.inf)

    def init(plan, ctx):
        hit = plan.vmask & (plan.local2global == ctx["source"])
        return jnp.where(hit, 0.0, INF)

    def finalize(glob, present, plan, ctx):
        iota = jnp.arange(plan.n_vertices)
        isolated = jnp.where(iota == ctx["source"], 0.0, INF)
        d = jnp.where(present, glob, isolated)
        return (d <= 2.0).astype(jnp.float32)

    return E.EdgeProgram(
        name="hops2", mode="replica", combine="min",
        prepare=lambda plan, kw: {"source": kw["source"]},
        init=init, pre=lambda s, ctx: s, apply=lambda o, a, ctx:
        jnp.minimum(o, jnp.minimum(a, 3.0)),    # cap: hops beyond 2 are 3
        finalize=finalize, local_fixpoint=True,
        edge=lambda m, plan, ctx: m + 1.0)


@pytest.fixture
def hops2_registered():
    E.register("hops2", _make_hops2(),
               params=[E.ParamSpec("source", int, batchable=True)],
               oracle=_hops2_oracle)
    yield
    E.unregister("hops2")


def test_custom_program_end_to_end(hops2_registered):
    """Register through the public API, then flow partition → engine →
    stream patch → serve without touching a single gserve module."""
    g = graph.watts_strogatz(160, 4, 0.15, seed=2)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, buckets=(1, 2, 4))
    out = srv.serve([G.QueryRequest("hops2", tenant=f"t{i}",
                                    params={"source": s})
                     for i, s in enumerate((0, 17, 45))])
    for r in out:
        assert np.array_equal(r.value, _hops2_oracle(sess.graph(),
                                                     r.request.params["source"]))
    # live update: the patched plan serves the registered program too
    sess.apply(inserts=np.array([[0, 80], [17, 120]]),
               deletes=None)
    r = srv.serve([G.QueryRequest("hops2", params={"source": 0})])[0]
    assert not r.from_cache
    assert np.array_equal(r.value, _hops2_oracle(sess.graph(), 0))


def test_new_programs_flow_through_stream_patch():
    """Weighted SSSP and BFS (registered via the public registry API) stay
    bit-identical to their oracles across live patches — the plan's
    per-half-edge weights are maintained by the patch path."""
    from repro.core import algorithms as alg
    g = graph.watts_strogatz(150, 4, 0.2, seed=4)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    rng = np.random.default_rng(3)
    for _ in range(2):
        gu, gv = sess.graph().as_numpy()
        kill = rng.choice(len(gu), size=3, replace=False)
        sess.apply(inserts=rng.integers(0, 150, size=(5, 2)),
                   deletes=np.stack([gu[kill], gv[kill]], 1))
        g_now = sess.graph()
        rw = sess.engine.run(E.WEIGHTED_SSSP, source=jnp.int32(1))
        assert np.array_equal(np.asarray(rw.state),
                              alg.reference_weighted_sssp(g_now, 1))
        rb = sess.engine.run(E.BFS, source=jnp.int32(1))
        assert np.array_equal(np.asarray(rb.state),
                              alg.reference_bfs(g_now, 1))


def test_patched_plan_weights_match_recompiled():
    """plan.edge_w after in-place patching equals a from-scratch compile of
    the same content (the content-hash weight function is the contract)."""
    g = graph.watts_strogatz(100, 4, 0.1, seed=6)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=16,
                                             drift_threshold=1e9), key=0)
    sess.apply(inserts=np.array([[0, 50], [1, 60], [2, 70]]))
    assert sess.n_patches >= 1, "update should patch, not recompile"
    fresh = E.compile_plan(sess.graph(), sess.owner, 3)
    # compare weights per (partition, global-target, global-nbr) half-edge
    def wmap(plan):
        l2g = np.asarray(plan.local2global)
        tgt = np.asarray(plan.edge_tgt)
        nbr = np.asarray(plan.edge_nbr)
        em = np.asarray(plan.emask)
        ew = np.asarray(plan.edge_w)
        return {(p, int(l2g[p, tgt[p, s]]), int(l2g[p, nbr[p, s]])):
                float(ew[p, s])
                for p in range(plan.k) for s in np.flatnonzero(em[p])}
    assert wmap(sess.plan) == wmap(fresh)


# ---------------------------------------------------------------------------
# property channels: misuse matrix, key identity, layout, e2e, maintenance
# ---------------------------------------------------------------------------

import jax.numpy as _jnp

from hypothesis import given, settings, strategies as st

from repro.core import algorithms as alg
from repro.core.graph import edge_weights
from repro.engine import kernels as K


def _small_graph(seed=0, n=120):
    return graph.watts_strogatz(n, 4, 0.15, seed=seed)


def _labels(n, seed=0):
    return np.random.default_rng(seed).integers(0, 30, size=n).astype(
        np.float32)


def _make_cwsssp():
    """Channel-weighted SSSP: weights arrive as an EDGE property plane in
    graph slot order (instead of being baked into plan.edge_w) — built
    from public pieces only, mirroring the wsssp worked example."""
    INF = _jnp.float32(_jnp.inf)

    def prepare(plan, kw):
        return {"source": kw["source"],
                "w": E.gather_edge_channel(plan, kw["weights"])[:, :, 0]}

    def init(plan, ctx):
        hit = plan.vmask & (plan.local2global == ctx["source"])
        return _jnp.where(hit, 0.0, INF)

    def fin(glob, present, plan, ctx):
        iota = _jnp.arange(plan.n_vertices)
        iso = _jnp.where(iota == ctx["source"], 0.0, INF)
        return _jnp.where(present, glob, iso)

    return E.EdgeProgram(
        name="cwsssp", mode="replica", combine="min",
        prepare=prepare, init=init, pre=lambda s, c: s,
        apply=lambda o, a, c: _jnp.minimum(o, a), finalize=fin,
        local_fixpoint=True, edge=lambda m, plan, ctx: m + ctx["w"])


def _slot_weights(sg) -> np.ndarray:
    """Content-hash weights laid out in graph slot order, [e_pad]."""
    w = np.zeros(sg.e_pad, np.float32)
    m = sg._mask
    w[m] = edge_weights(sg._u[m], sg._v[m])
    return w


@pytest.fixture
def cwsssp_registered():
    E.register("cwsssp", _make_cwsssp(), params=[
        E.ParamSpec("source", int, batchable=True),
        E.ParamSpec("weights", float, role="channel", channel="edge")],
        oracle=lambda g, source, weights: alg.reference_weighted_sssp(
            g, source))
    yield
    E.unregister("cwsssp")


def test_channel_misuse_matrix(cwsssp_registered):
    g = _small_graph()
    lab = _labels(g.n_vertices)
    # unknown channel name
    with pytest.raises(E.UnknownParamError, match="declared: labels"):
        G.QueryRequest("labelprop", params={"labels": lab, "labelz": lab})
    # scalar where a plane is expected
    with pytest.raises(E.ChannelError, match="takes an array plane"):
        G.QueryRequest("labelprop", params={"labels": 3.0})
    # wrong rank
    with pytest.raises(E.ChannelError, match=r"\[N\] or \[N, F\]"):
        G.QueryRequest("labelprop", params={"labels": lab.reshape(2, -1, 1)})
    # wrong dtype (not coercible to float32)
    with pytest.raises(E.ParamTypeError, match="float32"):
        G.QueryRequest("labelprop", params={"labels": np.array(["a", "b"])})
    # feature-width mismatch against the declared F
    with pytest.raises(E.ChannelError, match="declares 1 feature"):
        G.QueryRequest("labelprop",
                       params={"labels": np.zeros((g.n_vertices, 2))})
    # [V, F] vs [E_pad, F] mix-up — both directions, typed + actionable
    owner = baselines.hash_partition(g, 3)
    plan = E.compile_plan(g, owner, 3)
    lp = E.get_program("labelprop")
    cw = E.get_program("cwsssp")
    with pytest.raises(E.ChannelError, match="VERTEX channel"):
        lp.channel_args(lp.normalize({"labels": np.zeros(g.e_pad)}), plan)
    with pytest.raises(E.ChannelError, match="EDGE channel"):
        cw.channel_args(
            cw.normalize({"source": 0,
                          "weights": np.zeros(g.n_vertices)}), plan)
    # the same mix-up is shed at the server door (typed, at submit)
    srv = G.GraphServer(E.Engine(plan), g)
    with pytest.raises(E.ChannelError, match="VERTEX channel"):
        srv.submit(G.QueryRequest("labelprop",
                                  params={"labels": np.zeros(g.e_pad)}))


def test_channel_registration_schema():
    r = registry.ProgramRegistry()
    with pytest.raises(E.RegistryError, match="channel="):
        r.register("c1", E.LABELPROP,
                   params=[E.ParamSpec("x", float, role="channel")])
    with pytest.raises(E.RegistryError, match="dtype=float"):
        r.register("c2", E.LABELPROP,
                   params=[E.ParamSpec("x", int, role="channel",
                                       channel="vertex")])
    with pytest.raises(E.RegistryError, match="cannot be batchable"):
        r.register("c3", E.LABELPROP,
                   params=[E.ParamSpec("x", float, role="channel",
                                       channel="vertex", batchable=True)])
    with pytest.raises(E.RegistryError, match="role='channel'"):
        r.register("c4", E.LABELPROP,
                   params=[E.ParamSpec("x", float, channel="vertex")])


def test_channel_value_never_aliases_caller_memory():
    """Content-addressing contract: the frozen plane is a private copy —
    a caller mutating its own array after construction can neither change
    hashed content nor hit a read-only flag on its own buffer."""
    lab = np.arange(8, dtype=np.float32)            # 1-D, already float32
    cv = E.ChannelValue(lab)
    assert not np.shares_memory(lab, cv.values)
    lab[0] = 999.0                                  # caller's array stays
    assert cv.values[0, 0] == 0.0                   # writable; plane fixed
    assert cv == E.ChannelValue(np.arange(8))
    plane = np.zeros((8, 2), np.float32)            # contiguous 2-D f32
    cv2 = E.ChannelValue(plane)
    plane[0, 0] = 1.0                               # must NOT raise
    assert cv2.values[0, 0] == 0.0


def test_short_edge_plane_reads_fill_not_last_row(cwsssp_registered):
    """gather_edge_channel: a plane with fewer rows than a live slot index
    must read the fill value, never silently clamp to the last row."""
    import jax.numpy as jnp2
    g = _small_graph(seed=9)
    plan = E.compile_plan(g, baselines.hash_partition(g, 3), 3)
    full = _slot_weights_from_graph(g)
    short = full[: plan.edge_slot_hwm // 2]         # covers half the slots
    got = np.asarray(E.gather_edge_channel(plan, jnp2.asarray(short)))
    em = np.asarray(plan.emask)
    es = np.asarray(plan.edge_slot)
    covered = em & (es >= 0) & (es < len(short))
    assert np.array_equal(got[covered, 0], short[es[covered]])
    assert not got[~covered].any(), "uncovered slots must read fill (0)"


def _slot_weights_from_graph(g) -> np.ndarray:
    u, v = g.as_numpy()
    w = np.zeros(g.e_pad, np.float32)
    w[np.asarray(g.edge_mask)] = edge_weights(u, v)
    return w


def test_channel_content_identity_keys():
    g = _small_graph()
    lab = _labels(g.n_vertices, seed=1)
    a = G.QueryRequest("labelprop", tenant="a", params={"labels": lab})
    b = G.QueryRequest("labelprop", tenant="b",
                       params={"labels": lab.copy()})
    c = G.QueryRequest("labelprop", params={"labels": lab + 1.0})
    # byte-identical planes: same digest -> shared batch/cache identity
    assert a.batch_key() == b.batch_key()
    assert a.cache_key() == b.cache_key()
    # different features: two tenants NEVER share keys (hence never a
    # cached result or a coalesced dispatch)
    assert a.batch_key() != c.batch_key()
    assert a.cache_key() != c.cache_key()
    # pre-built ChannelValue ("bound once per epoch" client-side) is the
    # same identity as the raw array
    cv = E.ChannelValue(lab)
    d = G.QueryRequest("labelprop", params={"labels": cv})
    assert d.cache_key() == a.cache_key()


def test_channel_tenants_never_share_cache():
    g = _small_graph(seed=3)
    owner = baselines.hash_partition(g, 3)
    plan = E.compile_plan(g, owner, 3)
    srv = G.GraphServer(E.Engine(plan), g)
    la = _labels(g.n_vertices, seed=4)
    lb = la + 100.0
    ra = srv.serve([G.QueryRequest("labelprop", tenant="a",
                                   params={"labels": la})])[0]
    rb = srv.serve([G.QueryRequest("labelprop", tenant="b",
                                   params={"labels": lb})])[0]
    assert not rb.from_cache, "different planes must never share a result"
    assert np.array_equal(ra.value, alg.reference_label_propagation(g, la))
    assert np.array_equal(rb.value, alg.reference_label_propagation(g, lb))
    # same plane, third tenant: cache hit
    rc = srv.serve([G.QueryRequest("labelprop", tenant="c",
                                   params={"labels": la.copy()})])[0]
    assert rc.from_cache
    assert np.array_equal(rc.value, ra.value)


def test_labelprop_and_ppr_end_to_end():
    """The acceptance flow: both flagship channel programs served through
    partition -> engine -> stream patch -> serve, oracle-exact, with zero
    gserve edits beyond the generic channel_args call."""
    g = _small_graph(seed=5, n=160)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess)
    rng = np.random.default_rng(6)
    lab = _labels(g.n_vertices, seed=6)
    pers = rng.random(g.n_vertices).astype(np.float32)
    pers /= pers.sum()
    for step in range(3):
        g_now = sess.graph()
        rl = srv.serve([G.QueryRequest("labelprop",
                                       params={"labels": lab})])[0]
        assert np.array_equal(
            rl.value, alg.reference_label_propagation(g_now, lab)), step
        rp = srv.serve([G.QueryRequest("ppr", params={
            "personalization": pers, "iters": 10})])[0]
        np.testing.assert_allclose(
            rp.value,
            alg.reference_personalized_pagerank(g_now, pers, iters=10),
            atol=1e-5)
        sess.apply(inserts=rng.integers(0, g.n_vertices, size=(6, 2)))
    srv.close()


def test_stale_channel_hash_after_patch(cwsssp_registered):
    """A stream patch rebinding a maintained edge plane bumps its content
    digest: post-patch requests carry the NEW identity, so neither the
    result cache nor the batch former can alias them with pre-patch
    answers computed from the old plane."""
    g = _small_graph(seed=7)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=16,
                                            drift_threshold=1e9), key=0)
    sess.bind_channel("cwsssp", "weights", _slot_weights(sess.sg),
                      fill=lambda u, v: edge_weights(np.asarray([u]),
                                                     np.asarray([v]))[0])
    entry = E.get_program("cwsssp")
    srv = G.GraphServer.from_session(sess)
    try:
        r0 = srv.serve([G.QueryRequest("cwsssp", params={"source": 0})])[0]
        key0 = r0.request.cache_key()
        digest0 = entry.bindings["weights"].digest
        assert np.array_equal(
            r0.value, alg.reference_weighted_sssp(sess.graph(), 0))
        sess.apply(inserts=np.array([[0, 60], [1, 70], [2, 80]]))
        assert sess.n_patches >= 1
        assert entry.bindings["weights"].digest != digest0, \
            "maintained plane must re-bind with a fresh content hash"
        r1 = srv.serve([G.QueryRequest("cwsssp", params={"source": 0})])[0]
        assert r1.request.cache_key() != key0
        assert not r1.from_cache
        assert np.array_equal(
            r1.value, alg.reference_weighted_sssp(sess.graph(), 0))
    finally:
        srv.close()
        sess.unbind_channel("cwsssp", "weights")


def test_bound_edge_channel_survives_compaction(cwsssp_registered):
    """Compaction remaps bound edge planes by the same slot gather as the
    owner array: results stay oracle-exact across the epoch bump."""
    g = _small_graph(seed=8, n=100)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=16,
                                            drift_threshold=1e9), key=0)
    sess.bind_channel("cwsssp", "weights", _slot_weights(sess.sg),
                      fill=lambda u, v: edge_weights(np.asarray([u]),
                                                     np.asarray([v]))[0])
    try:
        rng = np.random.default_rng(9)
        n = 0
        while sess.sg.epoch == 0 and n < 80:
            sess.apply(inserts=rng.integers(0, g.n_vertices, size=(16, 2)))
            n += 1
        assert sess.sg.epoch >= 1, "compaction never triggered"
        eng = sess.engine
        r = eng.run(E.get_program("cwsssp").program, source=_jnp.int32(3),
                    weights=np.asarray(
                        E.get_program("cwsssp").bindings["weights"]))
        assert np.array_equal(
            np.asarray(r.state),
            alg.reference_weighted_sssp(sess.graph(), 3))
    finally:
        sess.unbind_channel("cwsssp", "weights")


def test_bind_channel_validation_and_ownership(cwsssp_registered):
    """A failed bind leaves nothing installed on the registry entry, and a
    second live session cannot clobber a maintained binding."""
    g = _small_graph(seed=10, n=80)
    cfg = S.StreamConfig(k=2, chunk_size=16, drift_threshold=1e9)
    sess = S.StreamSession(g, cfg, key=0)
    entry = E.get_program("cwsssp")
    with pytest.raises(E.ChannelError, match="edge slots"):
        sess.bind_channel("cwsssp", "weights",
                          np.zeros(sess.sg.e_pad + 64, np.float32))
    assert "weights" not in entry.bindings, \
        "failed bind must not leave a plane live for normalize()"
    sess.bind_channel("cwsssp", "weights", _slot_weights(sess.sg))
    sess2 = S.StreamSession(g, cfg, key=0)
    try:
        with pytest.raises(E.ChannelError, match="another live"):
            sess2.bind_channel("cwsssp", "weights",
                               _slot_weights(sess2.sg))
        # ...nor may a non-owner RELEASE the owner's binding
        with pytest.raises(E.ChannelError, match="only its owner"):
            sess2.unbind_channel("cwsssp", "weights")
        assert "weights" in entry.bindings
        sess.unbind_channel("cwsssp", "weights")
        sess2.bind_channel("cwsssp", "weights", _slot_weights(sess2.sg))
    finally:
        sess2.unbind_channel("cwsssp", "weights")


def test_channel_plane_invalidated_by_swap_fails_soft(cwsssp_registered):
    """A plane validated at submit can be invalidated by a plan swap that
    lands before its batch is popped (live-slot high-water mark grows past
    it). That must fail the REQUEST (typed error result), not the drain
    pipeline — the server keeps serving."""
    g = _small_graph(seed=12, n=100)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=16,
                                            drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess)
    plane = _slot_weights(sess.sg)[: sess.plan.edge_slot_hwm]  # valid NOW
    rid = srv.submit(G.QueryRequest("cwsssp",
                                    params={"source": 0, "weights": plane}))
    sess.apply(inserts=np.array([[0, 50], [1, 60]]))   # hwm grows past it
    srv.drain()
    r = srv.result(rid)
    assert r is not None and r.value is None
    assert r.error and "EDGE channel" in r.error
    ok = srv.serve([G.QueryRequest("cwsssp", params={
        "source": 0, "weights": _slot_weights(sess.sg)})])[0]
    assert ok.error is None
    assert np.array_equal(ok.value,
                          alg.reference_weighted_sssp(sess.graph(), 0))
    srv.close()


def test_gc_session_releases_binding(cwsssp_registered):
    """A session dropped without unbind_channel must not leave its stale
    plane live on the process-global registry entry."""
    import gc
    g = _small_graph(seed=13, n=80)
    sess = S.StreamSession(g, S.StreamConfig(k=2, chunk_size=16,
                                            drift_threshold=1e9), key=0)
    sess.bind_channel("cwsssp", "weights", _slot_weights(sess.sg))
    entry = E.get_program("cwsssp")
    assert "weights" in entry.bindings
    del sess
    gc.collect()
    assert "weights" not in entry.bindings, \
        "a dead maintainer's plane must not resolve for new requests"


@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_channel_gather_padding_invariant(seed):
    """Padding-identity property for the channel gathers: the laid-out
    planes (and the program results through them) are invariant to how
    much slack/padding the plan reserves and how far the external plane
    is zero-padded beyond the live rows."""
    rng = np.random.default_rng(seed)
    g = graph.watts_strogatz(80 + seed % 17, 4, 0.2, seed=seed % 5)
    owner = baselines.hash_partition(g, 3)
    lean = E.compile_plan(g, owner, 3)
    fat = E.compile_plan(g, owner, 3,
                         edge_slack=1 + seed % 40,
                         vertex_slack=1 + (seed // 7) % 30)

    # vertex plane, F=3
    vf = rng.random((g.n_vertices, 3)).astype(np.float32)
    for plan in (lean, fat):
        got = np.asarray(K.gather_vertex_channel(plan, _jnp.asarray(vf)))
        l2g = np.asarray(plan.local2global)
        vm = np.asarray(plan.vmask)
        assert np.array_equal(got[vm], vf[l2g[vm]])
        assert not got[~vm].any(), "slack/pad slots must be pinned to 0"

    # edge plane in slot order, padded two different amounts
    u, v = g.as_numpy()
    ew = np.zeros(g.e_pad, np.float32)
    ew[np.asarray(g.edge_mask)] = edge_weights(u, v)
    ew_long = np.concatenate([ew, np.zeros(64, np.float32)])
    ref = None
    for plan in (lean, fat):
        for plane in (ew, ew_long):
            got = np.asarray(K.gather_edge_channel(plan,
                                                   _jnp.asarray(plane)))
            em = np.asarray(plan.emask)
            # live half-edges read their undirected edge's weight
            assert np.allclose(got[em, 0],
                               np.asarray(plan.edge_w)[em])
            assert not got[~em].any()
    # end-to-end: the engine result through either plan is identical
    r_lean = E.engine_label_propagation(E.Engine(lean), vf[:, 0])
    r_fat = E.engine_label_propagation(E.Engine(fat), vf[:, 0])
    assert np.array_equal(np.asarray(r_lean.state), np.asarray(r_fat.state))

"""Program-registry contract: typed misuse errors with actionable messages,
param normalization, warm-state validation, the no-per-kind-branching
invariant of the serving layer, and the acceptance flow — a program
registered through the PUBLIC API only runs partition → engine → stream
patch → serve with zero edits under src/repro/gserve/."""
import pathlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import baselines, dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S
from repro.engine import registry


# ---------------------------------------------------------------------------
# typed misuse errors
# ---------------------------------------------------------------------------

def test_duplicate_registration_raises():
    with pytest.raises(E.DuplicateProgramError, match="already registered"):
        E.register("sssp", E.SSSP,
                   params=[E.ParamSpec("source", int, batchable=True)])


def test_unknown_program_raises():
    with pytest.raises(E.UnknownProgramError, match="registered:"):
        E.get_program("nope")
    with pytest.raises(E.UnknownProgramError):
        G.QueryRequest("nope")


def test_unknown_param_raises():
    with pytest.raises(E.UnknownParamError, match="declared: source"):
        G.QueryRequest("sssp", params={"source": 0, "radius": 3})


def test_missing_required_param_raises():
    with pytest.raises(E.ParamTypeError, match="requires parameter"):
        G.QueryRequest("sssp")


def test_wrong_dtype_raises():
    with pytest.raises(E.ParamTypeError, match="expects int"):
        G.QueryRequest("sssp", params={"source": 1.5})
    with pytest.raises(E.ParamTypeError, match="expects int"):
        G.QueryRequest("sssp", params={"source": "zero"})
    with pytest.raises(E.ParamTypeError):
        G.QueryRequest("sssp", params={"source": True})   # bool is not int
    # numpy integer scalars coerce cleanly
    r = G.QueryRequest("sssp", params={"source": np.int64(4)})
    assert r.params["source"] == 4 and type(r.params["source"]) is int


def test_batch_axis_on_scalar_param_raises():
    # non-batchable param passed a batch axis
    with pytest.raises(E.BatchAxisError, match="not batchable"):
        G.QueryRequest("pagerank", params={"iters": [10, 20]})
    # a batchable param still takes one scalar per request — the scheduler
    # forms the batch axis by coalescing requests
    with pytest.raises(E.BatchAxisError, match="one request"):
        G.QueryRequest("sssp", params={"source": np.arange(4)})


def test_param_validate_hook_runs():
    with pytest.raises(ValueError, match=">= 0"):
        G.QueryRequest("pagerank", params={"iters": -1})


def test_warm_state_shape_mismatch_raises():
    g = graph.watts_strogatz(80, 4, 0.1, seed=0)
    eng = E.Engine(E.compile_plan(g, baselines.hash_partition(g, 2), 2))
    with pytest.raises(E.WarmStateError, match="80 vertices"):
        eng.run(E.SSSP, source=jnp.int32(0), warm_state=np.zeros(7))
    with pytest.raises(E.WarmStateError, match="no warm_init hook"):
        eng.run(E.WCC, warm_state=np.zeros(80))
    # batched: one [V] row per lane required
    with pytest.raises(E.WarmStateError):
        eng.run_batched(E.SSSP, {"source": np.array([0, 1], np.int32)},
                        warm_state=np.zeros(80))


def test_registration_schema_validation():
    with pytest.raises(E.RegistryError, match="at most one batchable"):
        registry.ProgramRegistry().register(
            "two-axes", E.SSSP,
            params=[E.ParamSpec("a", int, batchable=True),
                    E.ParamSpec("b", int, batchable=True)])
    with pytest.raises(E.RegistryError, match="duplicate parameter"):
        registry.ProgramRegistry().register(
            "dup", E.SSSP, params=[E.ParamSpec("a"), E.ParamSpec("a")])
    with pytest.raises(E.RegistryError, match="role"):
        registry.ProgramRegistry().register(
            "badrole", E.SSSP, params=[E.ParamSpec("a", int, role="wat")])
    # defaults run the same dtype/validate gauntlet as caller values —
    # a bad default fails at REGISTRATION, not deep inside a dispatch
    with pytest.raises(E.RegistryError, match="default .* is invalid"):
        registry.ProgramRegistry().register(
            "baddefault", E.SSSP, params=[E.ParamSpec("iters", int,
                                                      default=None)])
    def _pos(v):
        if v <= 0:
            raise ValueError("must be > 0")
    with pytest.raises(ValueError, match="> 0"):
        registry.ProgramRegistry().register(
            "badvalidated", E.SSSP,
            params=[E.ParamSpec("n", int, default=0, validate=_pos)])


# ---------------------------------------------------------------------------
# derived keys
# ---------------------------------------------------------------------------

def test_keys_derive_from_normalized_params():
    a = G.QueryRequest("sssp", tenant="a", params={"source": 3})
    b = G.QueryRequest("sssp", tenant="b", params={"source": 3})
    assert a.batch_key() == b.batch_key() == ("sssp",)
    assert a.cache_key() == b.cache_key() == ("sssp", ("source", 3))
    entry = E.get_program("sssp")
    assert entry.lane_cache_key(a.params, 9) == ("sssp", ("source", 9))


def test_no_kind_string_branching_in_gserve():
    """CI-guarded invariant, enforced in tier-1 too: the serving layer
    derives everything from the registry and never branches on program-kind
    strings."""
    root = pathlib.Path(__file__).resolve().parents[1] / "src/repro/gserve"
    offenders = [p.name for p in sorted(root.glob("*.py"))
                 if 'kind == "' in p.read_text()]
    assert not offenders, f"per-kind branching found in: {offenders}"


# ---------------------------------------------------------------------------
# acceptance: a user program, public API only, partition → engine →
# stream patch → serve
# ---------------------------------------------------------------------------

def _hops2_oracle(g, source):
    """Vertices within 2 hops of source (1.0/0.0), via the BFS oracle."""
    from repro.core import algorithms as alg
    lvl = alg.reference_bfs(g, source)
    return ((lvl >= 0) & (lvl <= 2)).astype(np.float32)


def _make_hops2():
    """A genuinely new EdgeProgram built from public pieces: 2-hop
    reachability (min-hop relaxation capped at 2, finalized to 1/0)."""
    INF = jnp.float32(jnp.inf)

    def init(plan, ctx):
        hit = plan.vmask & (plan.local2global == ctx["source"])
        return jnp.where(hit, 0.0, INF)

    def finalize(glob, present, plan, ctx):
        iota = jnp.arange(plan.n_vertices)
        isolated = jnp.where(iota == ctx["source"], 0.0, INF)
        d = jnp.where(present, glob, isolated)
        return (d <= 2.0).astype(jnp.float32)

    return E.EdgeProgram(
        name="hops2", mode="replica", combine="min",
        prepare=lambda plan, kw: {"source": kw["source"]},
        init=init, pre=lambda s, ctx: s, apply=lambda o, a, ctx:
        jnp.minimum(o, jnp.minimum(a, 3.0)),    # cap: hops beyond 2 are 3
        finalize=finalize, local_fixpoint=True,
        edge=lambda m, plan, ctx: m + 1.0)


@pytest.fixture
def hops2_registered():
    E.register("hops2", _make_hops2(),
               params=[E.ParamSpec("source", int, batchable=True)],
               oracle=_hops2_oracle)
    yield
    E.unregister("hops2")


def test_custom_program_end_to_end(hops2_registered):
    """Register through the public API, then flow partition → engine →
    stream patch → serve without touching a single gserve module."""
    g = graph.watts_strogatz(160, 4, 0.15, seed=2)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, buckets=(1, 2, 4))
    out = srv.serve([G.QueryRequest("hops2", tenant=f"t{i}",
                                    params={"source": s})
                     for i, s in enumerate((0, 17, 45))])
    for r in out:
        assert np.array_equal(r.value, _hops2_oracle(sess.graph(),
                                                     r.request.params["source"]))
    # live update: the patched plan serves the registered program too
    sess.apply(inserts=np.array([[0, 80], [17, 120]]),
               deletes=None)
    r = srv.serve([G.QueryRequest("hops2", params={"source": 0})])[0]
    assert not r.from_cache
    assert np.array_equal(r.value, _hops2_oracle(sess.graph(), 0))


def test_new_programs_flow_through_stream_patch():
    """Weighted SSSP and BFS (registered via the public registry API) stay
    bit-identical to their oracles across live patches — the plan's
    per-half-edge weights are maintained by the patch path."""
    from repro.core import algorithms as alg
    g = graph.watts_strogatz(150, 4, 0.2, seed=4)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    rng = np.random.default_rng(3)
    for _ in range(2):
        gu, gv = sess.graph().as_numpy()
        kill = rng.choice(len(gu), size=3, replace=False)
        sess.apply(inserts=rng.integers(0, 150, size=(5, 2)),
                   deletes=np.stack([gu[kill], gv[kill]], 1))
        g_now = sess.graph()
        rw = sess.engine.run(E.WEIGHTED_SSSP, source=jnp.int32(1))
        assert np.array_equal(np.asarray(rw.state),
                              alg.reference_weighted_sssp(g_now, 1))
        rb = sess.engine.run(E.BFS, source=jnp.int32(1))
        assert np.array_equal(np.asarray(rb.state),
                              alg.reference_bfs(g_now, 1))


def test_patched_plan_weights_match_recompiled():
    """plan.edge_w after in-place patching equals a from-scratch compile of
    the same content (the content-hash weight function is the contract)."""
    g = graph.watts_strogatz(100, 4, 0.1, seed=6)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=16,
                                             drift_threshold=1e9), key=0)
    sess.apply(inserts=np.array([[0, 50], [1, 60], [2, 70]]))
    assert sess.n_patches >= 1, "update should patch, not recompile"
    fresh = E.compile_plan(sess.graph(), sess.owner, 3)
    # compare weights per (partition, global-target, global-nbr) half-edge
    def wmap(plan):
        l2g = np.asarray(plan.local2global)
        tgt = np.asarray(plan.edge_tgt)
        nbr = np.asarray(plan.edge_nbr)
        em = np.asarray(plan.emask)
        ew = np.asarray(plan.edge_w)
        return {(p, int(l2g[p, tgt[p, s]]), int(l2g[p, nbr[p, s]])):
                float(ew[p, s])
                for p in range(plan.k) for s in np.flatnonzero(em[p])}
    assert wmap(sess.plan) == wmap(fresh)

"""Vector-state supersteps + fused gSpMM: the GNN inference service.

Covers the PR 10 surface end to end:

  * ``StateSpec`` — the declarative per-vertex rank (shape/cold/key),
    typed ``StateError``/``WarmStateError`` on rank mismatches at the
    engine door instead of reshape crashes inside jit;
  * fused Pallas ``gspmm`` vs the XLA ``gspmm_ref`` across combines,
    feature widths and slack (hypothesis padding-invariance property);
  * the F=1 contract: a program lifted to [K, Vmax, 1] hooks finalizes
    BIT-identically to its legacy scalar twin (sssp replica-min path,
    pagerank partial-add path) — vector state is one code path, not a
    parallel implementation;
  * ``gcn_layer`` / ``kge_score`` served oracle-exact through
    StreamSession -> GraphServer across an insert-only stream patch,
    with zero gserve edits (the registry entry carries everything);
  * dense-channel validation at the request door, device-resident plane
    reuse, and both shard_map paths in a forced-8-device subprocess.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import baselines, dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S
from repro.engine import kernels
from repro.engine.programs import GCN_F_IN, GCN_F_OUT, KGE_F
from repro.engine.registry import DEFAULT_REGISTRY


def _plan(g, k=4, **kw):
    return E.compile_plan(g, baselines.hash_partition(g, k), k, **kw)


# ---------------------------------------------------------------------------
# StateSpec — the declarative rank
# ---------------------------------------------------------------------------

def test_state_spec_shapes_and_cold():
    s = E.StateSpec()
    assert s.shape(7) == (7,) and s.batch_shape(3, 7) == (3, 7)
    assert s is not E.SCALAR and s == E.SCALAR and s.key() == E.SCALAR.key()
    cold = s.cold(5)
    assert cold.shape == (5,) and np.all(np.isinf(cold))
    v = E.StateSpec(features=4, fill=0.0)
    assert v.shape(7) == (7, 4) and v.batch_shape(3, 7) == (3, 7, 4)
    assert v.cold(5).shape == (5, 4) and not np.any(v.cold(5))
    assert v.key() != s.key()
    assert "[V, 4]" in v.describe() and "scalar" in s.describe()


def test_state_spec_rejects_nonsense():
    with pytest.raises(ValueError, match="positive int"):
        E.StateSpec(features=0)
    with pytest.raises(ValueError, match="positive int"):
        E.StateSpec(features=2.5)
    with pytest.raises(TypeError):
        E.StateSpec(dtype="not-a-dtype")


def test_error_hierarchy():
    # state violations are registry errors, so one except clause at the
    # server door catches the whole family
    assert issubclass(E.StateError, E.RegistryError)
    assert issubclass(E.WarmStateError, E.StateError)
    assert issubclass(E.ChannelError, E.StateError)


def test_warm_state_rank_mismatch_is_typed():
    g = graph.watts_strogatz(80, 4, 0.1, seed=0)
    eng = E.Engine(_plan(g, 2))
    # wrong rank (a [V, 2] block for a scalar program) — same typed error
    # as a wrong vertex count, never a reshape crash inside jit
    with pytest.raises(E.WarmStateError, match="scalar"):
        eng.run(E.WEIGHTED_SSSP, source=jnp.int32(0),
                warm_state=np.zeros((80, 2), np.float32))
    with pytest.raises(E.WarmStateError, match="80 vertices"):
        eng.run(E.WEIGHTED_SSSP, source=jnp.int32(0),
                warm_state=np.zeros(79, np.float32))


# ---------------------------------------------------------------------------
# fused gSpMM kernel vs XLA reference
# ---------------------------------------------------------------------------

def _gspmm_fixture(seed=0, f=8):
    g = graph.watts_strogatz(120, 4, 0.2, seed=seed)
    plan = _plan(g, 4, edge_slack=16, vertex_slack=8)
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.normal(size=(g.n_vertices, f))
                        .astype(np.float32))
    return g, plan, kernels.gather_vertex_channel(plan, feats)


@pytest.mark.parametrize("combine", ["add", "sum", "max", "mean"])
def test_gspmm_matches_ref(combine):
    g, plan, local = _gspmm_fixture()
    got = np.asarray(kernels.gspmm(plan, local, plan.edge_w, combine))
    ref = np.asarray(kernels.gspmm_ref(plan, local, plan.edge_w, combine))
    finite = np.isfinite(ref)
    assert np.allclose(got[finite], ref[finite], atol=1e-5)
    assert np.array_equal(finite, np.isfinite(got))


def test_gspmm_wide_edge_weights():
    """Per-feature edge weights ([K, Emax, F], the kge relation plane
    shape) flow through the same fused kernel as scalar weights."""
    g, plan, local = _gspmm_fixture(seed=3, f=4)
    rng = np.random.default_rng(9)
    w3 = jnp.asarray(rng.normal(size=plan.emask.shape + (4,))
                     .astype(np.float32))
    got = np.asarray(kernels.gspmm(plan, local, w3, "add"))
    ref = np.asarray(kernels.gspmm_ref(plan, local, w3, "add"))
    assert np.allclose(got, ref, atol=1e-5)


def test_gspmm_scalar_feats_rank():
    """Rank-2 features still come back rank-3 with F=1 — one contract."""
    g, plan, local = _gspmm_fixture(f=1)
    got = kernels.gspmm(plan, local[:, :, 0], plan.edge_w, "add")
    assert got.ndim == 3 and got.shape[2] == 1


@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_gspmm_padding_invariance(seed):
    """Slack slots (the streaming growth region) must be inert: a plan
    compiled with reserved edge/vertex slack aggregates [V, F] planes
    identically to the slack-free plan, and both match the dense numpy
    contraction on live vertices."""
    rng = np.random.default_rng(seed)
    g = graph.watts_strogatz(90 + seed % 17, 4, 0.2, seed=seed % 5)
    owner = baselines.hash_partition(g, 3)
    tight = E.compile_plan(g, owner, 3)
    slacked = E.compile_plan(g, owner, 3, edge_slack=32, vertex_slack=16)
    f = 2 + seed % 7
    feats = rng.normal(size=(g.n_vertices, f)).astype(np.float32)

    outs = []
    for plan in (tight, slacked):
        local = kernels.gather_vertex_channel(plan, jnp.asarray(feats))
        agg = kernels.gspmm(plan, local, plan.edge_w, "add")
        glob = np.zeros((g.n_vertices, f), np.float32)
        k_idx = np.asarray(plan.vmask)
        l2g = np.asarray(plan.local2global)
        a = np.asarray(agg)
        for p in range(a.shape[0]):
            glob[l2g[p][k_idx[p]]] += a[p][k_idx[p]]
        outs.append(glob)
    assert np.allclose(outs[0], outs[1], atol=1e-5)

    u, v = g.as_numpy()
    ew = graph.edge_weights(u, v)
    dense = np.zeros((g.n_vertices, f), np.float32)
    np.add.at(dense, v, feats[u] * ew[:, None])
    np.add.at(dense, u, feats[v] * ew[:, None])
    assert np.allclose(outs[0], dense, atol=1e-4)


# ---------------------------------------------------------------------------
# F=1 lifted hooks == legacy scalar path, bit for bit
# ---------------------------------------------------------------------------

def _lift(base):
    """Clone a scalar program with hooks carrying [K, Vmax, 1] planes."""
    def init(plan, ctx):
        return base.init(plan, ctx)[:, :, None]

    def pre(state, ctx):
        return base.pre(state[:, :, 0], ctx)[:, :, None]

    def apply(old, agg, ctx):
        return base.apply(old[:, :, 0], agg[:, :, 0], ctx)[:, :, None]

    def finalize(glob, present, plan, ctx):
        return base.finalize(glob[:, 0], present, plan, ctx)

    return base._replace(name=f"vec_{base.name}", init=init, pre=pre,
                         apply=apply, finalize=finalize, warm_init=None)


def test_f1_vector_sssp_bit_identical():
    g = graph.watts_strogatz(150, 4, 0.15, seed=1)
    eng = E.Engine(_plan(g, 4))
    scalar = eng.run(E.SSSP, source=jnp.int32(0))
    vec = eng.run(_lift(E.SSSP), source=jnp.int32(0))
    assert np.array_equal(np.asarray(scalar.state), np.asarray(vec.state))
    assert int(scalar.supersteps) == int(vec.supersteps)


def test_f1_vector_pagerank_bit_identical():
    g = graph.watts_strogatz(150, 4, 0.15, seed=1)
    eng = E.Engine(_plan(g, 4))
    scalar = eng.run(E.PAGERANK, max_supersteps=15, degrees=g.degrees())
    vec = eng.run(_lift(E.PAGERANK), max_supersteps=15,
                  degrees=g.degrees())
    assert np.array_equal(np.asarray(scalar.state), np.asarray(vec.state))


# ---------------------------------------------------------------------------
# the served GNN programs
# ---------------------------------------------------------------------------

def test_gcn_layer_oracle():
    g = graph.watts_strogatz(130, 4, 0.2, seed=2)
    eng = E.Engine(_plan(g, 4))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(g.n_vertices, GCN_F_IN)).astype(np.float32)
    w = rng.normal(size=(GCN_F_IN, GCN_F_OUT)).astype(np.float32)
    res = E.engine_gcn_layer(eng, g.degrees(), x, w)
    assert res.state.shape == (g.n_vertices, GCN_F_OUT)
    np.testing.assert_allclose(np.asarray(res.state),
                               alg.reference_gcn_layer(g, x, w), atol=1e-5)


def test_kge_score_oracle():
    g = graph.watts_strogatz(130, 4, 0.2, seed=2)
    eng = E.Engine(_plan(g, 4))
    rng = np.random.default_rng(1)
    ent = rng.normal(size=(g.n_vertices, KGE_F)).astype(np.float32)
    rel = rng.normal(size=(g.e_pad, KGE_F)).astype(np.float32)
    res = E.engine_kge_score(eng, ent, rel)
    assert res.state.shape == (g.n_vertices,)
    np.testing.assert_allclose(np.asarray(res.state),
                               alg.reference_kge_score(g, ent, rel),
                               atol=1e-5)


def test_dense_channel_validated_at_door():
    rng = np.random.default_rng(0)
    x = rng.random((50, GCN_F_IN)).astype(np.float32)
    # wrong rows on the dense weight matrix: rejected at request
    # construction, not deep inside the finalize matmul under jit
    with pytest.raises(ValueError, match="gcn_layer.weight"):
        G.QueryRequest("gcn_layer", params={
            "x": x, "weight": np.zeros((3, GCN_F_OUT), np.float32)})
    # wrong feature width rides the generic channel validation
    with pytest.raises(E.ChannelError, match="feature"):
        G.QueryRequest("gcn_layer", params={
            "x": np.zeros((50, 2), np.float32),
            "weight": np.zeros((GCN_F_IN, GCN_F_OUT), np.float32)})


def test_served_gnn_across_stream_patch():
    """The acceptance path: partition -> engine -> stream patch -> serve,
    oracle-exact on the exact snapshot each answer was served from, with
    the generic registry dispatch (zero gserve branching)."""
    sess = S.StreamSession(graph.watts_strogatz(140, 4, 0.1, seed=3),
                           S.StreamConfig(k=4, chunk_size=32,
                                          drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, cache_entries=0)
    rng = np.random.default_rng(5)
    try:
        for phase in range(2):
            if phase:
                n_v = sess.graph().n_vertices
                a = rng.integers(0, n_v, size=6)
                sess.apply(inserts=np.stack([a, (a + 7) % n_v], 1))
            g = sess.graph()
            for name in ("gcn_layer", "kge_score"):
                entry = DEFAULT_REGISTRY.get(name)
                params = {}
                for spec in entry.channel_params:
                    n = {"vertex": g.n_vertices, "edge": g.e_pad,
                         "dense": GCN_F_IN}[spec.channel]
                    params[spec.name] = rng.random((n, spec.features)) \
                        .astype(np.float32)
                out = srv.serve([G.QueryRequest(name, tenant=f"t{i}",
                                                params=params)
                                 for i in range(3)])
                want = entry.oracle(g, **params)
                for r in out:
                    np.testing.assert_allclose(r.value, want,
                                               atol=entry.oracle_atol)
    finally:
        srv.close()


def test_channel_planes_stay_device_resident():
    g = graph.watts_strogatz(100, 4, 0.1, seed=4)
    plan = _plan(g, 4)
    entry = DEFAULT_REGISTRY.get("gcn_layer")
    rng = np.random.default_rng(2)
    params = entry.normalize({
        "x": rng.random((g.n_vertices, GCN_F_IN)).astype(np.float32),
        "weight": rng.random((GCN_F_IN, GCN_F_OUT)).astype(np.float32)})
    before = E.resident_stats()
    first = entry.channel_args(params, plan)
    mid = E.resident_stats()
    second = entry.channel_args(params, plan)
    after = E.resident_stats()
    # same digests: the second dispatch reuses the resident buffers
    assert mid["misses"] - before["misses"] == 2
    assert after["hits"] - mid["hits"] == 2
    assert after["resident_bytes"] > 0
    for k in first:
        assert first[k] is second[k]


# ---------------------------------------------------------------------------
# shard_map paths (forced 8-device host mesh, subprocess)
# ---------------------------------------------------------------------------

MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.core import algorithms as alg
    from repro.core import dfep, graph
    from repro import engine as E

    assert len(jax.devices()) == 8
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    owner, _ = dfep.partition(g, k=8, key=0, max_rounds=400,
                              stall_rounds=16)
    plan = E.compile_plan(g, np.asarray(owner), 8)
    mesh = jax.make_mesh((8,), ("parts",))
    eng = E.Engine(plan, mesh=mesh)
    rng = np.random.default_rng(0)

    from repro.engine.programs import GCN_F_IN, GCN_F_OUT, KGE_F
    x = rng.normal(size=(g.n_vertices, GCN_F_IN)).astype(np.float32)
    w = rng.normal(size=(GCN_F_IN, GCN_F_OUT)).astype(np.float32)
    r = E.engine_gcn_layer(eng, g.degrees(), x, w)
    np.testing.assert_allclose(np.asarray(r.state),
                               alg.reference_gcn_layer(g, x, w), atol=1e-5)

    ent = rng.normal(size=(g.n_vertices, KGE_F)).astype(np.float32)
    rel = rng.normal(size=(g.e_pad, KGE_F)).astype(np.float32)
    rk = E.engine_kge_score(eng, ent, rel)
    np.testing.assert_allclose(np.asarray(rk.state),
                               alg.reference_kge_score(g, ent, rel),
                               atol=1e-5)

    # sharded == single-device, element for element
    r1 = E.engine_gcn_layer(E.Engine(plan), g.degrees(), x, w)
    np.testing.assert_allclose(np.asarray(r1.state), np.asarray(r.state),
                               atol=1e-6)

    # batched shard_map path with rank-3 state: an F=1 lifted SSSP must
    # match the scalar batched result lane for lane
    base = E.SSSP
    def init(plan, ctx): return base.init(plan, ctx)[:, :, None]
    def pre(state, ctx): return base.pre(state[:, :, 0], ctx)[:, :, None]
    def apply(old, agg, ctx):
        return base.apply(old[:, :, 0], agg[:, :, 0], ctx)[:, :, None]
    def fin(glob, present, plan, ctx):
        return base.finalize(glob[:, 0], present, plan, ctx)
    VEC = base._replace(name="vec_sssp", init=init, pre=pre, apply=apply,
                        finalize=fin, warm_init=None)
    sources = {"source": np.array([0, 7, 42], np.int32)}
    rv = eng.run_batched(VEC, dict(sources))
    rs = eng.run_batched(base, dict(sources))
    assert np.array_equal(np.asarray(rv.state), np.asarray(rs.state))

    # K=8 partitions on a 4-device mesh (2 partition blocks per device)
    mesh4 = jax.make_mesh((4,), ("parts",))
    r4 = E.engine_gcn_layer(E.Engine(plan, mesh=mesh4), g.degrees(), x, w)
    np.testing.assert_allclose(np.asarray(r4.state), np.asarray(r.state),
                               atol=1e-6)
    print("GNN_DIST_OK")
""")


@pytest.mark.slow
def test_gnn_shard_map():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "GNN_DIST_OK" in res.stdout, \
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"

"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py
oracles, plus hypothesis property tests. Kernels run in interpret mode
(Python execution of the TPU kernel body) on CPU."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# lane_cumsum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,k", [(64, 4), (1000, 20), (2048, 128), (777, 33)])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_lane_cumsum_shapes(s, k, dtype):
    x = jax.random.randint(jax.random.key(0), (s, k), -5, 10).astype(dtype)
    got = ops.lane_cumsum(x, block_s=256)
    want = ref.cumsum_lanes(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(s=st.integers(1, 300), k=st.integers(1, 40), seed=st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_lane_cumsum_property(s, k, seed):
    x = jax.random.randint(jax.random.key(seed), (s, k), 0, 7, dtype=jnp.int32)
    got = ops.lane_cumsum(x, block_s=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.cumsum(x, 0)))


# ---------------------------------------------------------------------------
# frontier_min
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,v", [(4, 100), (20, 5000), (7, 333), (128, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_frontier_min_shapes(k, v, dtype):
    key = jax.random.key(1)
    k1, k2 = jax.random.split(key)
    state = jax.random.uniform(k1, (k, v), jnp.float32, 0, 100).astype(dtype)
    member = jax.random.bernoulli(k2, 0.4, (k, v))
    got = ops.frontier_min(state, member, block_v=512)
    want = ref.kreduce_min(state, member)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_frontier_min_all_masked_is_inf():
    state = jnp.ones((3, 50), jnp.float32)
    member = jnp.zeros((3, 50), jnp.bool_)
    got = ops.frontier_min(state, member, block_v=128)
    assert np.isinf(np.asarray(got)).all()


# ---------------------------------------------------------------------------
# minplus_sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,e", [(100, 300), (513, 1000), (2048, 4096)])
def test_minplus_sweep_shapes(v, e):
    key = jax.random.key(2)
    ks, kd, km, kx = jax.random.split(key, 4)
    src = jax.random.randint(ks, (e,), 0, v, dtype=jnp.int32)
    dst = jax.random.randint(kd, (e,), 0, v, dtype=jnp.int32)
    mask = jax.random.bernoulli(km, 0.9, (e,))
    dist = jnp.where(jax.random.bernoulli(kx, 0.3, (v,)),
                     jax.random.uniform(kx, (v,), jnp.float32, 0, 10),
                     jnp.inf)
    got = ops.minplus_sweep(dist, src, dst, mask, block_v=256, block_e=256)
    want = ref.minplus_relax(dist, src, dst, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@given(v=st.integers(2, 200), e=st.integers(1, 400), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_minplus_sweep_property(v, e, seed):
    key = jax.random.key(seed)
    ks, kd, kx = jax.random.split(key, 3)
    src = jax.random.randint(ks, (e,), 0, v, dtype=jnp.int32)
    dst = jax.random.randint(kd, (e,), 0, v, dtype=jnp.int32)
    mask = jnp.ones((e,), jnp.bool_)
    dist = jnp.where(jnp.arange(v) == 0, 0.0, jnp.inf).astype(jnp.float32)
    got = ops.minplus_sweep(dist, src, dst, mask, block_v=128, block_e=128)
    want = ref.minplus_relax(dist, src, dst, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    # a sweep never increases any distance (monotone relaxation)
    assert (np.asarray(got) <= np.asarray(dist)).all()


def test_minplus_iterated_equals_bfs():
    """Iterating the kernel's sweep reaches the BFS fixed point."""
    from repro.core import graph
    from repro.core.algorithms import reference_sssp
    g = graph.watts_strogatz(300, 4, 0.1, seed=0)
    dist = jnp.where(jnp.arange(g.n_vertices) == 0, 0.0, jnp.inf).astype(jnp.float32)
    for _ in range(200):
        nd = ops.minplus_sweep(dist, g.src, g.dst, g.edge_mask,
                               block_v=256, block_e=512)
        if bool(jnp.all(nd == dist)):
            break
        dist = nd
    ref_d, _ = reference_sssp(g, 0)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(ref_d))


# ---------------------------------------------------------------------------
# selective_scan (Mamba-1 recurrence)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d,n,blk,chunk", [
    (2, 64, 32, 8, 16, 16),
    (1, 100, 48, 16, 32, 32),   # non-divisible S -> padding path
    (2, 128, 128, 16, 128, 64),
])
def test_selective_scan_matches_ref(b, s, d, n, blk, chunk):
    ks = jax.random.split(jax.random.key(5), 6)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bb = jax.random.normal(ks[2], (b, s, n)) * 0.5
    cc = jax.random.normal(ks[3], (b, s, n)) * 0.5
    a = jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    d_skip = jax.random.normal(ks[5], (d,))
    got = ops.selective_scan(x, dt, bb, cc, a, d_skip,
                             block_d=blk, chunk=chunk)
    want = ref.selective_scan_ref(x, dt, bb, cc, a, d_skip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_selective_scan_matches_ssm_module():
    """Kernel == the model's chunked associative scan (train path)."""
    from repro.models.ssm import _selective_scan_chunked
    b, s, d, n = 2, 64, 32, 8
    ks = jax.random.split(jax.random.key(6), 6)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bb = jax.random.normal(ks[2], (b, s, n)) * 0.5
    cc = jax.random.normal(ks[3], (b, s, n)) * 0.5
    a = jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    d_skip = jax.random.normal(ks[5], (d,))
    got = ops.selective_scan(x, dt, bb, cc, a, d_skip, block_d=16, chunk=16)
    want, _ = _selective_scan_chunked(
        x, dt, bb, cc, a, d_skip,
        jnp.zeros((b, d, n), jnp.float32), chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)

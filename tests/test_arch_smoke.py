"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED config of the same family, runs one forward + one train step on CPU
asserting output shapes and no NaNs. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import all_archs, get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import train_step

B, S = 2, 32


def _batch(cfg):
    pipe = SyntheticPipeline(cfg, DataConfig(batch=B, seq_len=S, seed=0))
    return pipe.batch_at(0)


@pytest.mark.parametrize("arch", all_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params, specs = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    kw = {}
    if cfg.family == "vlm":
        kw["img_embeds"] = batch["img_embeds"]
    if cfg.family == "encdec":
        kw["enc_frames"] = batch["enc_frames"]
    logits, aux, _ = jax.jit(
        lambda p, t: lm.forward_lm(cfg, p, t, remat=False, **kw)
    )(params, batch["tokens"])
    s_total = S + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_total, lm.vocab_pad(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # spec tree mirrors param tree
    assert jax.tree.structure(jax.tree.map(lambda x: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, specs,
                                        is_leaf=lambda x: isinstance(x, tuple)))


@pytest.mark.parametrize("arch", all_archs())
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = lm.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = _batch(cfg)
    new_params, new_opt, metrics = jax.jit(
        lambda p, o, b: train_step(cfg, ocfg, p, o, b))(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     params, new_params))
    assert moved > 0.0

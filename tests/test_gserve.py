"""repro.gserve correctness: micro-batch scheduling (pad-to-bucket, FIFO
coalescing), registry-derived request validation, result-cache sharing
across tenants with exact content-keyed invalidation, fair-share admission
control, timer-based partial-bucket flush, warm-started repair across
insert-only stream patches, warm jit caches across bursts, and the
serving-under-mutation contract — every result bit-identical to the
whole-graph oracle for the snapshot (version) it was served from, with no
stale cache entry surviving a plan swap."""
import time

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S
from repro.engine import runtime


def _static_server(n=150, k=4, seed=3, **kw):
    g = graph.watts_strogatz(n, 4, 0.2, seed=seed)
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    return g, G.GraphServer(E.Engine(plan), g, **kw)


def _check(result, g):
    """Generic oracle check — derived from the registry entry, so it covers
    every registered program without naming one."""
    entry = result.request.entry
    ref = entry.oracle(g, **result.request.params)
    if entry.oracle_atol:
        np.testing.assert_allclose(result.value, np.asarray(ref),
                                   atol=entry.oracle_atol)
    else:
        assert np.array_equal(result.value, np.asarray(ref)), result.request


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_bucket_for():
    assert G.bucket_for(1, (1, 2, 4)) == 1
    assert G.bucket_for(3, (1, 2, 4)) == 4
    assert G.bucket_for(9, (1, 2, 4)) == 4      # clamped to largest


def test_microbatcher_coalescing_and_fifo():
    b = G.MicroBatcher(buckets=(1, 2, 4))
    reqs = [G.QueryRequest("sssp", tenant="a", params={"source": 1}),
            G.QueryRequest("wcc", tenant="b"),
            G.QueryRequest("sssp", tenant="b", params={"source": 2}),
            G.QueryRequest("sssp", tenant="c", params={"source": 1}),  # dup
            G.QueryRequest("wcc", tenant="c"),
            G.QueryRequest("pagerank", tenant="a", params={"iters": 5})]
    for r in reqs:
        b.add(r)
    assert len(b) == 6
    m1 = b.next_batch()                 # sssp queue arrived first
    assert m1.key == ("sssp",) and len(m1.requests) == 3
    assert m1.params == (1, 2)          # dedup within the batch
    assert m1.lane == (0, 1, 0)
    assert m1.bucket == 2
    assert m1.padded_params == (1, 2)
    m2 = b.next_batch()                 # both wcc requests share one run
    assert m2.key == ("wcc",) and len(m2.requests) == 2 and m2.params is None
    m3 = b.next_batch()
    assert m3.key == ("pagerank", ("iters", 5))
    assert b.next_batch() is None and len(b) == 0


def test_padded_params_repeat_last():
    b = G.MicroBatcher(buckets=(4,))
    for s in (5, 9, 13):
        b.add(G.QueryRequest("sssp", params={"source": s}))
    m = b.next_batch()
    assert m.bucket == 4 and m.padded_params == (5, 9, 13, 13)


def test_request_validation():
    with pytest.raises(ValueError):
        G.QueryRequest("sssp")                   # missing source
    with pytest.raises(ValueError):
        G.QueryRequest("betweenness")            # unknown kind


def test_param_normalization_pagerank_iters_default():
    """Regression for the iters=None vs default identity bug: omitting a
    defaulted param and passing its default spell the SAME query, so they
    coalesce into one dispatch and share one cache entry."""
    a = G.QueryRequest("pagerank")
    b = G.QueryRequest("pagerank", params={"iters": 30})
    assert a.params == b.params == {"iters": 30}
    assert a.batch_key() == b.batch_key()
    assert a.cache_key() == b.cache_key()
    c = G.QueryRequest("pagerank", params={"iters": 10})
    assert c.batch_key() != a.batch_key()
    # and end-to-end: the default-spelled request hits the explicit one's
    # cache entry (one engine run total)
    _, srv = _static_server()
    r1 = srv.serve([G.QueryRequest("pagerank")])[0]
    r2 = srv.serve([G.QueryRequest("pagerank", params={"iters": 30})])[0]
    assert not r1.from_cache and r2.from_cache


# ---------------------------------------------------------------------------
# static serving
# ---------------------------------------------------------------------------

def test_serve_matches_oracles_mixed_tenants():
    g, srv = _static_server(buckets=(1, 2, 4, 8))
    reqs = [G.QueryRequest("sssp", tenant=f"t{i % 3}",
                           params={"source": (i * 7) % 150})
            for i in range(10)]
    reqs += [G.QueryRequest("wcc", tenant="t3"),
             G.QueryRequest("wcc", tenant="t4"),
             G.QueryRequest("pagerank", tenant="t5", params={"iters": 10})]
    out = srv.serve(reqs)
    assert [r.request.id for r in out] == [r.id for r in reqs]
    for r in out:
        _check(r, g)
    st = srv.stats()
    # 13 requests but far fewer dispatches: sssp coalesced, wcc shared
    assert st["completed"] == 13 and st["batches"] <= 4
    assert st["mean_batch_occupancy"] > 1.0


def test_serve_new_programs_registered_via_registry():
    """Weighted SSSP and BFS were registered through the public registry
    API only — the serving stack derives their dispatch entirely from the
    entry (zero gserve edits), and results are bit-identical to the
    core/algorithms.py oracles."""
    g, srv = _static_server(buckets=(1, 2, 4))
    reqs = [G.QueryRequest("wsssp", tenant="a", params={"source": 3}),
            G.QueryRequest("wsssp", tenant="b", params={"source": 11}),
            G.QueryRequest("bfs", tenant="a", params={"source": 3}),
            G.QueryRequest("bfs", tenant="c", params={"source": 40})]
    out = srv.serve(reqs)
    for r in out:
        _check(r, g)
    # cross-tenant cache sharing works for registered programs too
    r2 = srv.serve([G.QueryRequest("wsssp", tenant="z",
                                   params={"source": 3})])[0]
    assert r2.from_cache


def test_result_cache_shared_across_tenants():
    g, srv = _static_server()
    a = srv.serve([G.QueryRequest("sssp", tenant="a",
                                  params={"source": 11})])[0]
    assert not a.from_cache
    b = srv.serve([G.QueryRequest("sssp", tenant="b",
                                  params={"source": 11})])[0]
    assert b.from_cache and np.array_equal(a.value, b.value)
    w1 = srv.serve([G.QueryRequest("wcc", tenant="a")])[0]
    w2 = srv.serve([G.QueryRequest("wcc", tenant="b")])[0]
    assert not w1.from_cache and w2.from_cache
    assert srv.stats()["result_cache"]["hits"] >= 2
    # served values are shared across tenants and with the cache: mutation
    # must fail loudly instead of corrupting other tenants' answers
    for res in (a, b, w1, w2):
        with pytest.raises(ValueError):
            res.value[0] = -1.0


def test_admission_control():
    _, srv = _static_server(max_pending=2)
    srv.submit(G.QueryRequest("sssp", params={"source": 1}))
    srv.submit(G.QueryRequest("sssp", params={"source": 2}))
    with pytest.raises(G.AdmissionError):
        srv.submit(G.QueryRequest("sssp", params={"source": 3}))
    assert srv.stats()["rejected"] == 1
    out = srv.drain()                   # queue drains; door reopens
    assert len(out) == 2
    srv.submit(G.QueryRequest("sssp", params={"source": 3}))
    assert len(srv.drain()) == 1


def test_fair_share_admission_no_starvation():
    """Per-tenant fair share: one tenant saturating the queue cannot lock
    a quiet tenant out. The hog is capped at max_pending//active_tenants
    once contention exists, while the newcomer's first request is admitted
    even at a full queue — and gets served."""
    g, srv = _static_server(max_pending=8)
    admitted = 0
    with pytest.raises(G.AdmissionError):
        for i in range(20):
            srv.submit(G.QueryRequest("sssp", tenant="hog",
                                      params={"source": i}))
            admitted += 1
    assert admitted == 8                   # solo tenant may fill the queue
    # the quiet tenant still gets in at a full queue ...
    qid = srv.submit(G.QueryRequest("sssp", tenant="quiet",
                                    params={"source": 99}))
    # ... and with 2 active tenants the hog is now over its share (8 >= 4)
    with pytest.raises(G.AdmissionError, match="fair share"):
        srv.submit(G.QueryRequest("sssp", tenant="hog",
                                  params={"source": 50}))
    assert srv.stats()["rejected_fair_share"] >= 1
    out = srv.drain()
    served = {r.request.id: r for r in out}
    assert qid in served                   # the quiet tenant was served
    _check(served[qid], g)
    # queue drained: the hog's door reopens
    srv.submit(G.QueryRequest("sssp", tenant="hog", params={"source": 50}))
    assert len(srv.drain()) == 1
    # the first-request exemption is bounded: a flood of fresh tenant ids
    # hits the 2*max_pending hard wall instead of growing without bound
    n_in = 0
    with pytest.raises(G.AdmissionError, match="hard limit"):
        for i in range(1000):
            srv.submit(G.QueryRequest("sssp", tenant=f"fresh{i}",
                                      params={"source": i % 150}))
            n_in += 1
    assert n_in == 2 * 8
    srv.drain()


def test_timer_flush_bounds_partial_bucket_wait():
    """drain(max_wait_s): a partial bucket waits for the deadline (giving
    concurrent submitters time to fill it), then flushes anyway — while a
    full bucket dispatches immediately, without waiting."""
    g, srv = _static_server(buckets=(4,), max_wait_s=0.15)
    # warm the (bucket=4) jit shape outside the timing
    srv.serve([G.QueryRequest("sssp", params={"source": s})
               for s in (90, 91, 92, 93)])
    for s in (1, 2, 3):
        srv.submit(G.QueryRequest("sssp", params={"source": s}))
    t0 = time.time()
    out = srv.drain()
    waited = time.time() - t0
    assert len(out) == 3 and all(r.bucket == 4 for r in out)
    assert waited >= 0.12, "partial bucket must wait toward the deadline"
    for r in out:
        _check(r, g)
    # a full bucket never waits: with a deadline far beyond the service
    # time, drain returns as soon as the batch completes
    srv.max_wait_s = 30.0
    for s in (20, 21, 22, 23):
        srv.submit(G.QueryRequest("sssp", params={"source": s}))
    t0 = time.time()
    out = srv.drain()
    assert len(out) == 4 and time.time() - t0 < 5.0


def test_pad_to_bucket_keeps_jit_cache_warm():
    """Bursts of any size <= bucket reuse one compiled batched loop: after
    the first burst warms the (bucket=4) shape, later bursts of 2, 3 and 4
    distinct sources must not retrace."""
    g, srv = _static_server(buckets=(4,))
    srv.serve([G.QueryRequest("sssp", params={"source": s})
               for s in (1, 2, 3)])
    traced = runtime.TRACE_COUNTER["run_loop"]
    srv.serve([G.QueryRequest("sssp", params={"source": s})
               for s in (20, 21)])
    srv.serve([G.QueryRequest("sssp", params={"source": s})
               for s in (30, 31, 32, 33)])
    out = srv.serve([G.QueryRequest("sssp", params={"source": s})
                     for s in (40, 41, 42)])
    assert runtime.TRACE_COUNTER["run_loop"] == traced, \
        "padded micro-batches must hit the warm jit cache"
    for r in out:
        _check(r, g)
        assert r.bucket == 4


def test_nonblocking_dispatch_overlap():
    """dispatch_batched returns before results are materialised and several
    in-flight batches can settle out of order."""
    g, srv = _static_server()
    eng = srv.front.engine
    p1 = eng.dispatch_batched(E.SSSP, {"source": np.array([0, 5], np.int32)})
    p2 = eng.dispatch_batched(E.SSSP, {"source": np.array([9, 2], np.int32)})
    r2 = p2.result()
    r1 = p1.result()
    for res, sources in ((r1, (0, 5)), (r2, (9, 2))):
        for i, s in enumerate(sources):
            ref, _ = alg.reference_sssp(g, s)
            assert np.array_equal(np.asarray(res.state[i]), np.asarray(ref))


# ---------------------------------------------------------------------------
# serving under mutation (stream integration)
# ---------------------------------------------------------------------------

def _session_server(n=200, k=4, seed=3, **kw):
    g = graph.watts_strogatz(n, 4, 0.2, seed=seed)
    sess = S.StreamSession(g, S.StreamConfig(k=k, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, **kw)
    return sess, srv


def test_plan_swap_on_stream_update():
    sess, srv = _session_server()
    r0 = srv.serve([G.QueryRequest("sssp", params={"source": 0})])[0]
    assert r0.version == 0 and not r0.from_cache
    sess.apply(inserts=np.array([[1, 150], [2, 160]]))
    r1 = srv.serve([G.QueryRequest("sssp", params={"source": 0})])[0]
    assert r1.version > r0.version and r1.fingerprint != r0.fingerprint
    assert not r1.from_cache, "cache must not serve across a plan swap"
    _check(r1, sess.graph())
    assert srv.stats()["plan_buffer_swaps"] >= 1


def test_warm_start_repair_after_insert_only_patch():
    """ROADMAP item: incremental SSSP result repair. After an insert-only
    patch the server warm-starts the repeated query from the previous
    epoch's distances (valid upper bounds) — the result stays bit-identical
    to the post-patch oracle while converging in no more supersteps than a
    cold recompute; a deletion breaks the lineage and forces cold."""
    sess, srv = _session_server(n=240, seed=5)
    cold = srv.serve([G.QueryRequest("sssp", params={"source": 7}),
                      G.QueryRequest("wsssp", params={"source": 7})])
    assert all(not r.warm_start for r in cold)
    # small insert-only patch (offset-3 pairs: absent from the WS(k=4)
    # lattice): old distances are upper bounds
    sess.apply(inserts=np.array([[3, 6], [10, 13]]))
    warm = srv.serve([G.QueryRequest("sssp", params={"source": 7}),
                      G.QueryRequest("wsssp", params={"source": 7})])
    for r, c in zip(warm, cold):
        assert r.warm_start and not r.from_cache
        assert r.supersteps <= c.supersteps
        _check(r, sess.graph())
    # chained insert-only patches keep the lineage alive — and in a mixed
    # batch only the lane with history is stamped warm: a never-before-seen
    # source coalesced into the same dispatch runs (and reports) cold
    sess.apply(inserts=np.array([[20, 23]]))
    warm2, fresh = srv.serve([
        G.QueryRequest("sssp", params={"source": 7}),
        G.QueryRequest("sssp", params={"source": 101})])
    assert warm2.warm_start and not fresh.warm_start
    assert fresh.bucket == warm2.bucket, "same dispatch"
    _check(warm2, sess.graph())
    _check(fresh, sess.graph())
    # a deletion breaks it: the warm store is dropped, dispatch goes cold
    gu, gv = sess.graph().as_numpy()
    sess.apply(deletes=np.array([[gu[0], gv[0]]]))
    post = srv.serve([G.QueryRequest("sssp", params={"source": 7}),
                      G.QueryRequest("bfs", params={"source": 7})])
    assert all(not r.warm_start for r in post)
    for r in post:
        _check(r, sess.graph())


def test_inflight_queries_drain_against_captured_buffer():
    """Double-buffer semantics: a batch pumped before the swap is labelled
    with (and correct for) the old snapshot; the rest of the queue drains
    against the new one."""
    sess, srv = _session_server(buckets=(2,))
    g_old = sess.graph()
    for s in (0, 3, 9, 12):
        srv.submit(G.QueryRequest("sssp", params={"source": s}))
    first = srv.pump()                         # one bucket=2 batch, old plan
    assert [r.request.params["source"] for r in first] == [0, 3]
    sess.apply(inserts=np.array([[0, 100], [3, 150], [9, 180]]))
    rest = srv.drain()                         # remaining queue, new plan
    g_new = sess.graph()
    assert g_old.fingerprint() != g_new.fingerprint()
    for r in first:
        assert r.version == 0
        _check(r, g_old)
    for r in rest:
        assert r.version > 0
        _check(r, g_new)


def test_serving_under_mutation_stress():
    """Acceptance stress: interleave stream update batches with server
    query bursts. Every returned result must be bit-identical to the oracle
    for the snapshot it was served from, and no stale result-cache entry
    may survive a version bump."""
    sess, srv = _session_server(n=200, buckets=(1, 2, 4))
    snapshots = {sess.version: sess.graph()}
    sess.subscribe(lambda s, event: snapshots.setdefault(s.version,
                                                         s.graph()))
    rng = np.random.default_rng(7)
    n_v = sess.graph().n_vertices
    results = []
    for round_ in range(4):
        # a burst of multi-tenant queries ...
        reqs = [G.QueryRequest("sssp", tenant=f"t{i % 3}",
                               params={"source": int(rng.integers(0, n_v))})
                for i in range(5)]
        reqs.append(G.QueryRequest("wcc", tenant="t0"))
        if round_ % 2:
            reqs.append(G.QueryRequest("pagerank", tenant="t1",
                                       params={"iters": 8}))
        for r in reqs:
            srv.submit(r)
        results.extend(srv.pump())             # partially drain ...
        # ... mutate mid-queue (plan swap while requests are pending) ...
        gu, gv = sess.graph().as_numpy()
        kill = rng.choice(len(gu), size=4, replace=False)
        sess.apply(inserts=rng.integers(0, n_v, size=(6, 2)),
                   deletes=np.stack([gu[kill], gv[kill]], 1))
        # ... then drain the rest against the swapped-in plan
        results.extend(srv.drain())
        # stale cache entries must not survive the bump
        fps = srv.cache.fingerprints()
        assert fps <= {sess.graph().fingerprint()}, \
            "result cache holds entries for a dead fingerprint"
    assert len(results) == 4 * 6 + 2
    served_versions = {r.version for r in results}
    assert len(served_versions) >= 3, "stress never spanned a plan swap"
    for r in results:
        g_at = snapshots[r.version]
        assert r.fingerprint == g_at.fingerprint()
        _check(r, g_at)


def test_epoch_bump_compaction_consistency():
    """Force a compaction epoch (spare slots exhausted) under serving: the
    post-compaction buffer answers correctly and carries the new epoch."""
    g = graph.watts_strogatz(100, 4, 0.1, seed=1)   # small padding
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess)
    r0 = srv.serve([G.QueryRequest("sssp", params={"source": 0})])[0]
    assert r0.epoch == 0
    rng = np.random.default_rng(1)
    stats = sess.apply(inserts=rng.integers(0, 100, size=(400, 2)))
    assert stats["recompiles"] >= 1
    r1 = srv.serve([G.QueryRequest("sssp", params={"source": 0})])[0]
    assert r1.epoch == sess.epoch >= 1
    assert not r1.from_cache
    _check(r1, sess.graph())

"""repro.gserve correctness: micro-batch scheduling (pad-to-bucket, FIFO
coalescing), result-cache sharing across tenants with exact content-keyed
invalidation, admission control, warm jit caches across bursts, and the
serving-under-mutation contract — every result bit-identical to the
whole-graph oracle for the snapshot (version) it was served from, with no
stale cache entry surviving a plan swap."""
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S
from repro.engine import runtime


def _static_server(n=150, k=4, seed=3, **kw):
    g = graph.watts_strogatz(n, 4, 0.2, seed=seed)
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    return g, G.GraphServer(E.Engine(plan), g, **kw)


def _check(result, g):
    req = result.request
    if req.kind == "sssp":
        ref, _ = alg.reference_sssp(g, req.source)
        assert np.array_equal(result.value, np.asarray(ref)), req
    elif req.kind == "wcc":
        ref, _ = alg.reference_cc(g)
        assert np.array_equal(result.value, np.asarray(ref)), req
    else:
        ref = alg.reference_pagerank(g, iters=req.iters)
        np.testing.assert_allclose(result.value, np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_bucket_for():
    assert G.bucket_for(1, (1, 2, 4)) == 1
    assert G.bucket_for(3, (1, 2, 4)) == 4
    assert G.bucket_for(9, (1, 2, 4)) == 4      # clamped to largest


def test_microbatcher_coalescing_and_fifo():
    b = G.MicroBatcher(buckets=(1, 2, 4))
    reqs = [G.QueryRequest("sssp", tenant="a", source=1),
            G.QueryRequest("wcc", tenant="b"),
            G.QueryRequest("sssp", tenant="b", source=2),
            G.QueryRequest("sssp", tenant="c", source=1),   # dup source
            G.QueryRequest("wcc", tenant="c"),
            G.QueryRequest("pagerank", tenant="a", iters=5)]
    for r in reqs:
        b.add(r)
    assert len(b) == 6
    m1 = b.next_batch()                 # sssp queue arrived first
    assert m1.key == ("sssp",) and len(m1.requests) == 3
    assert m1.params == (1, 2)          # dedup within the batch
    assert m1.lane == (0, 1, 0)
    assert m1.bucket == 2
    assert m1.padded_params == (1, 2)
    m2 = b.next_batch()                 # both wcc requests share one run
    assert m2.key == ("wcc",) and len(m2.requests) == 2 and m2.params is None
    m3 = b.next_batch()
    assert m3.key == ("pagerank", 5)
    assert b.next_batch() is None and len(b) == 0


def test_padded_params_repeat_last():
    b = G.MicroBatcher(buckets=(4,))
    for s in (5, 9, 13):
        b.add(G.QueryRequest("sssp", source=s))
    m = b.next_batch()
    assert m.bucket == 4 and m.padded_params == (5, 9, 13, 13)


def test_request_validation():
    with pytest.raises(ValueError):
        G.QueryRequest("sssp")                   # missing source
    with pytest.raises(ValueError):
        G.QueryRequest("betweenness")            # unknown kind


# ---------------------------------------------------------------------------
# static serving
# ---------------------------------------------------------------------------

def test_serve_matches_oracles_mixed_tenants():
    g, srv = _static_server(buckets=(1, 2, 4, 8))
    reqs = [G.QueryRequest("sssp", tenant=f"t{i % 3}", source=(i * 7) % 150)
            for i in range(10)]
    reqs += [G.QueryRequest("wcc", tenant="t3"),
             G.QueryRequest("wcc", tenant="t4"),
             G.QueryRequest("pagerank", tenant="t5", iters=10)]
    out = srv.serve(reqs)
    assert [r.request.id for r in out] == [r.id for r in reqs]
    for r in out:
        _check(r, g)
    st = srv.stats()
    # 13 requests but far fewer dispatches: sssp coalesced, wcc shared
    assert st["completed"] == 13 and st["batches"] <= 4
    assert st["mean_batch_occupancy"] > 1.0


def test_result_cache_shared_across_tenants():
    g, srv = _static_server()
    a = srv.serve([G.QueryRequest("sssp", tenant="a", source=11)])[0]
    assert not a.from_cache
    b = srv.serve([G.QueryRequest("sssp", tenant="b", source=11)])[0]
    assert b.from_cache and np.array_equal(a.value, b.value)
    w1 = srv.serve([G.QueryRequest("wcc", tenant="a")])[0]
    w2 = srv.serve([G.QueryRequest("wcc", tenant="b")])[0]
    assert not w1.from_cache and w2.from_cache
    assert srv.stats()["result_cache"]["hits"] >= 2
    # served values are shared across tenants and with the cache: mutation
    # must fail loudly instead of corrupting other tenants' answers
    for res in (a, b, w1, w2):
        with pytest.raises(ValueError):
            res.value[0] = -1.0


def test_admission_control():
    _, srv = _static_server(max_pending=2)
    srv.submit(G.QueryRequest("sssp", source=1))
    srv.submit(G.QueryRequest("sssp", source=2))
    with pytest.raises(G.AdmissionError):
        srv.submit(G.QueryRequest("sssp", source=3))
    assert srv.stats()["rejected"] == 1
    out = srv.drain()                   # queue drains; door reopens
    assert len(out) == 2
    srv.submit(G.QueryRequest("sssp", source=3))
    assert len(srv.drain()) == 1


def test_pad_to_bucket_keeps_jit_cache_warm():
    """Bursts of any size <= bucket reuse one compiled batched loop: after
    the first burst warms the (bucket=4) shape, later bursts of 2, 3 and 4
    distinct sources must not retrace."""
    g, srv = _static_server(buckets=(4,))
    srv.serve([G.QueryRequest("sssp", source=s) for s in (1, 2, 3)])
    traced = runtime.TRACE_COUNTER["run_loop"]
    srv.serve([G.QueryRequest("sssp", source=s) for s in (20, 21)])
    srv.serve([G.QueryRequest("sssp", source=s) for s in (30, 31, 32, 33)])
    out = srv.serve([G.QueryRequest("sssp", source=s) for s in (40, 41, 42)])
    assert runtime.TRACE_COUNTER["run_loop"] == traced, \
        "padded micro-batches must hit the warm jit cache"
    for r in out:
        _check(r, g)
        assert r.bucket == 4


def test_nonblocking_dispatch_overlap():
    """dispatch_batched returns before results are materialised and several
    in-flight batches can settle out of order."""
    g, srv = _static_server()
    eng = srv.front.engine
    p1 = eng.dispatch_batched(E.SSSP, {"source": np.array([0, 5], np.int32)})
    p2 = eng.dispatch_batched(E.SSSP, {"source": np.array([9, 2], np.int32)})
    r2 = p2.result()
    r1 = p1.result()
    for res, sources in ((r1, (0, 5)), (r2, (9, 2))):
        for i, s in enumerate(sources):
            ref, _ = alg.reference_sssp(g, s)
            assert np.array_equal(np.asarray(res.state[i]), np.asarray(ref))


# ---------------------------------------------------------------------------
# serving under mutation (stream integration)
# ---------------------------------------------------------------------------

def _session_server(n=200, k=4, seed=3, **kw):
    g = graph.watts_strogatz(n, 4, 0.2, seed=seed)
    sess = S.StreamSession(g, S.StreamConfig(k=k, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, **kw)
    return sess, srv


def test_plan_swap_on_stream_update():
    sess, srv = _session_server()
    r0 = srv.serve([G.QueryRequest("sssp", source=0)])[0]
    assert r0.version == 0 and not r0.from_cache
    sess.apply(inserts=np.array([[1, 150], [2, 160]]))
    r1 = srv.serve([G.QueryRequest("sssp", source=0)])[0]
    assert r1.version > r0.version and r1.fingerprint != r0.fingerprint
    assert not r1.from_cache, "cache must not serve across a plan swap"
    _check(r1, sess.graph())
    assert srv.stats()["plan_buffer_swaps"] >= 1


def test_inflight_queries_drain_against_captured_buffer():
    """Double-buffer semantics: a batch pumped before the swap is labelled
    with (and correct for) the old snapshot; the rest of the queue drains
    against the new one."""
    sess, srv = _session_server(buckets=(2,))
    g_old = sess.graph()
    for s in (0, 3, 9, 12):
        srv.submit(G.QueryRequest("sssp", source=s))
    first = srv.pump()                         # one bucket=2 batch, old plan
    assert [r.request.source for r in first] == [0, 3]
    sess.apply(inserts=np.array([[0, 100], [3, 150], [9, 180]]))
    rest = srv.drain()                         # remaining queue, new plan
    g_new = sess.graph()
    assert g_old.fingerprint() != g_new.fingerprint()
    for r in first:
        assert r.version == 0
        _check(r, g_old)
    for r in rest:
        assert r.version > 0
        _check(r, g_new)


def test_serving_under_mutation_stress():
    """Acceptance stress: interleave stream update batches with server
    query bursts. Every returned result must be bit-identical to the oracle
    for the snapshot it was served from, and no stale result-cache entry
    may survive a version bump."""
    sess, srv = _session_server(n=200, buckets=(1, 2, 4))
    snapshots = {sess.version: sess.graph()}
    sess.subscribe(lambda s, event: snapshots.setdefault(s.version,
                                                         s.graph()))
    rng = np.random.default_rng(7)
    n_v = sess.graph().n_vertices
    results = []
    for round_ in range(4):
        # a burst of multi-tenant queries ...
        reqs = [G.QueryRequest("sssp", tenant=f"t{i % 3}",
                               source=int(rng.integers(0, n_v)))
                for i in range(5)]
        reqs.append(G.QueryRequest("wcc", tenant="t0"))
        if round_ % 2:
            reqs.append(G.QueryRequest("pagerank", tenant="t1", iters=8))
        for r in reqs:
            srv.submit(r)
        results.extend(srv.pump())             # partially drain ...
        # ... mutate mid-queue (plan swap while requests are pending) ...
        gu, gv = sess.graph().as_numpy()
        kill = rng.choice(len(gu), size=4, replace=False)
        sess.apply(inserts=rng.integers(0, n_v, size=(6, 2)),
                   deletes=np.stack([gu[kill], gv[kill]], 1))
        # ... then drain the rest against the swapped-in plan
        results.extend(srv.drain())
        # stale cache entries must not survive the bump
        fps = srv.cache.fingerprints()
        assert fps <= {sess.graph().fingerprint()}, \
            "result cache holds entries for a dead fingerprint"
    assert len(results) == 4 * 6 + 2
    served_versions = {r.version for r in results}
    assert len(served_versions) >= 3, "stress never spanned a plan swap"
    for r in results:
        g_at = snapshots[r.version]
        assert r.fingerprint == g_at.fingerprint()
        _check(r, g_at)


def test_epoch_bump_compaction_consistency():
    """Force a compaction epoch (spare slots exhausted) under serving: the
    post-compaction buffer answers correctly and carries the new epoch."""
    g = graph.watts_strogatz(100, 4, 0.1, seed=1)   # small padding
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess)
    r0 = srv.serve([G.QueryRequest("sssp", source=0)])[0]
    assert r0.epoch == 0
    rng = np.random.default_rng(1)
    stats = sess.apply(inserts=rng.integers(0, 100, size=(400, 2)))
    assert stats["recompiles"] >= 1
    r1 = srv.serve([G.QueryRequest("sssp", source=0)])[0]
    assert r1.epoch == sess.epoch >= 1
    assert not r1.from_cache
    _check(r1, sess.graph())

"""repro.engine correctness: plan compaction round-trips the edge set, and
engine SSSP / WCC / PageRank match the whole-graph oracles in
core/algorithms.py across graph profiles × partitioners × K."""
import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import baselines, dfep, graph, metrics
from repro import engine as E

PROFILES = {
    "smallworld": lambda: graph.watts_strogatz(150, 4, 0.1, seed=1),
    "powerlaw": lambda: graph.largest_component(
        graph.barabasi_albert(120, 3, seed=2)),
    "road": lambda: graph.largest_component(
        graph.road_network(10, 12, 0.25, seed=3)),
}

PARTITIONERS = {
    "dfep": lambda g, k: np.asarray(
        dfep.partition(g, k=k, key=0, max_rounds=400, stall_rounds=16)[0]),
    "greedy": lambda g, k: np.asarray(baselines.greedy_partition(g, k, seed=0)),
    "hash": lambda g, k: np.asarray(baselines.hash_partition(g, k)),
}


@pytest.fixture(scope="module")
def graphs():
    return {name: build() for name, build in PROFILES.items()}


@pytest.mark.parametrize("profile", list(PROFILES))
def test_plan_roundtrips_edge_set(graphs, profile):
    """Compacted per-partition CSR blocks contain exactly the owned edges."""
    g = graphs[profile]
    owner = baselines.hash_partition(g, 4)
    plan = E.compile_plan(g, owner, 4)
    u, v = g.as_numpy()
    want = np.unique(np.stack([np.minimum(u, v), np.maximum(u, v)], 1), axis=0)
    per_part = plan.local_edges()
    got = np.unique(np.concatenate(per_part, 0), axis=0)
    assert np.array_equal(want, got)
    # partitions are disjoint: per-partition counts sum to |E|
    assert sum(len(p) for p in per_part) == g.n_edges
    own = np.asarray(owner)[np.asarray(g.edge_mask)]
    for i in range(4):
        assert len(per_part[i]) == int((own == i).sum())


@pytest.mark.parametrize("profile", list(PROFILES))
def test_plan_masters_and_replicas(graphs, profile):
    g = graphs[profile]
    owner = baselines.greedy_partition(g, 4, seed=0)
    plan = E.compile_plan(g, owner, 4)
    l2g = np.asarray(plan.local2global)
    vmask = np.asarray(plan.vmask)
    master = np.asarray(plan.is_master)
    rep = np.asarray(plan.replicated)
    # every present vertex has exactly one master
    masters = np.bincount(l2g[master], minlength=g.n_vertices)
    present = np.zeros(g.n_vertices, bool)
    present[l2g[vmask]] = True
    assert (masters[present] == 1).all() and (masters[~present] == 0).all()
    # replicated <=> copy count >= 2
    copies = np.bincount(l2g[vmask], minlength=g.n_vertices)
    assert ((copies[l2g] >= 2) & vmask == rep).all()


@pytest.mark.parametrize("partitioner", list(PARTITIONERS))
@pytest.mark.parametrize("profile", list(PROFILES))
def test_engine_matches_oracles(graphs, profile, partitioner):
    """SSSP and WCC bit-identical, PageRank within 1e-5, for K in {2,4,8}."""
    g = graphs[profile]
    for k in (2, 4, 8):
        owner = PARTITIONERS[partitioner](g, k)
        plan = E.compile_plan(g, owner, k)
        eng = E.Engine(plan)

        r = E.engine_sssp(eng, 0)
        ref, ref_rounds = alg.reference_sssp(g, 0)
        assert np.array_equal(np.asarray(r.state), np.asarray(ref)), \
            (profile, partitioner, k, "sssp")
        # edge-partitioned execution needs no more rounds than vertex-centric
        assert int(r.supersteps) <= int(ref_rounds)

        rw = E.engine_wcc(eng)
        refc, _ = alg.reference_cc(g)
        assert np.array_equal(np.asarray(rw.state), np.asarray(refc)), \
            (profile, partitioner, k, "wcc")

        rp = E.engine_pagerank(eng, g.degrees(), iters=20)
        refp = alg.reference_pagerank(g, iters=20)
        np.testing.assert_allclose(np.asarray(rp.state), np.asarray(refp),
                                   atol=1e-5)

        # replica-exchange volume agrees with the combinatorial MESSAGES
        m = metrics.evaluate(g, owner, k, compute_gain=False)
        assert plan.exchange_per_superstep() == m.messages
        assert r.total_exchanged == int(r.supersteps) * m.messages


@pytest.mark.parametrize("partitioner", list(PARTITIONERS))
@pytest.mark.parametrize("profile", list(PROFILES))
def test_weighted_sssp_and_bfs_match_oracles(graphs, profile, partitioner):
    """The two registry-registered programs: weighted SSSP (per-half-edge
    content-hash weights via plan.edge_w + the EdgeProgram ``edge`` hook)
    and BFS hop levels — bit-identical to core/algorithms.py oracles."""
    g = graphs[profile]
    for k in (2, 4):
        owner = PARTITIONERS[partitioner](g, k)
        eng = E.Engine(E.compile_plan(g, owner, k))
        rw = E.engine_weighted_sssp(eng, 0)
        refw = alg.reference_weighted_sssp(g, 0)
        assert np.array_equal(np.asarray(rw.state), refw), \
            (profile, partitioner, k, "wsssp")
        rb = E.engine_bfs(eng, 0)
        refb = alg.reference_bfs(g, 0)
        assert np.array_equal(np.asarray(rb.state), refb), \
            (profile, partitioner, k, "bfs")


@pytest.mark.parametrize("partitioner", list(PARTITIONERS))
@pytest.mark.parametrize("profile", list(PROFILES))
def test_channel_programs_match_oracles(graphs, profile, partitioner):
    """The two property-channel programs: label propagation over an
    external [V] label plane (bit-identical — labels flow through min
    only) and personalized PageRank with an external teleport vector
    (1e-5, like plain PageRank: f32 partial sums reassociate)."""
    g = graphs[profile]
    rng = np.random.default_rng(11)
    labels = rng.integers(0, 40, size=g.n_vertices).astype(np.float32)
    pers = rng.random(g.n_vertices).astype(np.float32)
    pers /= pers.sum()
    ref_lp = alg.reference_label_propagation(g, labels)
    ref_pp = alg.reference_personalized_pagerank(g, pers, iters=15)
    for k in (2, 4):
        owner = PARTITIONERS[partitioner](g, k)
        eng = E.Engine(E.compile_plan(g, owner, k))
        rl = E.engine_label_propagation(eng, labels)
        assert np.array_equal(np.asarray(rl.state), ref_lp), \
            (profile, partitioner, k, "labelprop")
        rp = E.engine_personalized_pagerank(eng, g.degrees(), pers, iters=15)
        np.testing.assert_allclose(np.asarray(rp.state), ref_pp, atol=1e-5)


def test_labelprop_warm_init_exact():
    """Insert-only repair contract for labelprop: a previous epoch's labels
    are valid upper bounds (a larger component only lowers the min)."""
    g = graph.watts_strogatz(120, 4, 0.05, seed=4)
    owner = baselines.hash_partition(g, 3)
    eng = E.Engine(E.compile_plan(g, owner, 3))
    labels = np.arange(g.n_vertices, dtype=np.float32)
    cold = eng.run(E.LABELPROP, labels=jnp.asarray(labels))
    warm = eng.run(E.LABELPROP, labels=jnp.asarray(labels),
                   warm_state=cold.state)
    assert np.array_equal(np.asarray(warm.state), np.asarray(cold.state))
    assert int(warm.supersteps) == 1 <= int(cold.supersteps)


def test_warm_init_exact_and_fewer_supersteps(graphs):
    """warm_init: re-running from a previous exact result converges in one
    superstep with an identical answer; warm-starting from upper bounds
    (the insert-only repair scenario) also stays exact. +inf rows of a
    batched warm block cold-start their lane."""
    g = graphs["road"]          # high diameter -> many cold supersteps
    owner = baselines.greedy_partition(g, 4, seed=0)
    eng = E.Engine(E.compile_plan(g, owner, 4))
    cold = eng.run(E.SSSP, source=jnp.int32(0))
    warm = eng.run(E.SSSP, source=jnp.int32(0), warm_state=cold.state)
    assert np.array_equal(np.asarray(warm.state), np.asarray(cold.state))
    assert int(warm.supersteps) == 1 < int(cold.supersteps)
    # upper-bound init (everything shifted up, except the exact source row)
    upper = np.minimum(np.asarray(cold.state) + 2.0, np.inf)
    upper[0] = 0.0
    rep = eng.run(E.SSSP, source=jnp.int32(0), warm_state=upper)
    assert np.array_equal(np.asarray(rep.state), np.asarray(cold.state))
    # batched: lane 0 warm (exact prev), lane 1 "no information" (+inf)
    srcs = np.array([0, 5], np.int32)
    block = np.stack([np.asarray(cold.state),
                      np.full(g.n_vertices, np.inf, np.float32)])
    rb = eng.run_batched(E.SSSP, {"source": srcs}, warm_state=block)
    ref0, _ = alg.reference_sssp(g, 0)
    ref5, _ = alg.reference_sssp(g, 5)
    assert np.array_equal(np.asarray(rb.state[0]), np.asarray(ref0))
    assert np.array_equal(np.asarray(rb.state[1]), np.asarray(ref5))
    ss = np.asarray(rb.supersteps).reshape(-1)
    assert ss[0] <= ss[1], "the warm lane must not converge slower"


def test_multi_source_batched(graphs):
    """Serving path: one vmapped loop answers a batch of sources."""
    g = graphs["smallworld"]
    owner = baselines.greedy_partition(g, 4, seed=0)
    eng = E.Engine(E.compile_plan(g, owner, 4))
    sources = [0, 3, 11, 42]
    res = E.multi_source_sssp(eng, sources)
    assert res.state.shape == (len(sources), g.n_vertices)
    for i, s in enumerate(sources):
        ref, _ = alg.reference_sssp(g, s)
        assert np.array_equal(np.asarray(res.state[i]), np.asarray(ref)), s


def test_plan_cache_counters_and_lru_eviction():
    """compile_plan_cached observability: hits/misses count, and filling the
    cache past _PLAN_CACHE_MAX evicts in LRU order."""
    from repro.engine import plan as P

    E.plan_cache_clear(reset_counters=True)
    base = graph.watts_strogatz(60, 4, 0.1, seed=9)
    owner = np.where(np.asarray(base.edge_mask), 0, -2)

    p1 = E.compile_plan_cached(base, owner, 2)
    assert E.plan_cache_stats()["misses"] == 1
    assert E.compile_plan_cached(base, owner, 2) is p1
    assert E.plan_cache_stats()["hits"] == 1

    # fill with distinct (k) keys: the k=2 entry is oldest EXCEPT that we
    # re-touch it halfway, so LRU must evict the untouched k=3 entry instead
    for k in range(3, 3 + P._PLAN_CACHE_MAX - 1):
        E.compile_plan_cached(base, owner, k)
    assert E.plan_cache_stats()["size"] == P._PLAN_CACHE_MAX
    assert E.plan_cache_stats()["evictions"] == 0
    assert E.compile_plan_cached(base, owner, 2) is p1       # touch (hit)
    E.compile_plan_cached(base, owner, 3 + P._PLAN_CACHE_MAX)  # overflow
    st = E.plan_cache_stats()
    assert st["evictions"] == 1 and st["size"] == P._PLAN_CACHE_MAX
    assert E.compile_plan_cached(base, owner, 2) is p1       # survived (MRU)
    hits = E.plan_cache_stats()["hits"]
    E.compile_plan_cached(base, owner, 3)                    # evicted: miss
    st = E.plan_cache_stats()
    assert st["hits"] == hits and st["misses"] >= 2
    assert st["evictions"] == 2                              # re-add evicted
    E.plan_cache_clear(reset_counters=True)
    st = E.plan_cache_stats()
    assert st["size"] == 0 and st["hits"] == st["misses"] == 0


def test_segment_reduce_matches_reference(graphs):
    """Pallas segmented-scan reduce == XLA scatter reference, min and add."""
    from repro.engine import kernels
    import jax
    g = graphs["powerlaw"]
    plan = E.compile_plan(g, baselines.hash_partition(g, 4), 4)
    key = jax.random.key(0)
    msgs = jax.random.uniform(key, plan.emask.shape, jnp.float32, 0.0, 10.0)
    for combine in ("min", "add"):
        got = kernels.segment_reduce(plan, msgs, combine)
        want = kernels.segment_reduce_ref(plan, msgs, combine)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)


def test_superstep_cap_reports_nonconvergence():
    """Hitting max_supersteps is surfaced instead of silently truncating."""
    n = 60  # path graph with alternating edge ownership: slow cut crossings
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    g = graph.from_edge_array(n, edges)
    owner = jnp.where(g.edge_mask, g.src % 2, -2)
    eng = E.Engine(E.compile_plan(g, owner, 2))
    trunc = eng.run(E.SSSP, max_supersteps=3, source=jnp.int32(0))
    assert not bool(trunc.converged)
    assert not trunc.row()["converged"]
    full = E.engine_sssp(eng, 0)
    assert bool(full.converged)
    ref, _ = alg.reference_sssp(g, 0)
    assert np.array_equal(np.asarray(full.state), np.asarray(ref))


def test_zero_supersteps_is_zero():
    """An explicit 0 is not treated as 'use the default'."""
    g = graph.watts_strogatz(64, 4, 0.1, seed=0)
    eng = E.Engine(E.compile_plan(g, baselines.hash_partition(g, 2), 2))
    r = E.engine_pagerank(eng, g.degrees(), iters=0)
    assert int(r.supersteps) == 0
    np.testing.assert_allclose(np.asarray(r.state),
                               np.full(g.n_vertices, 1.0 / g.n_vertices))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_deleted_slots_are_inert(seed):
    """Padding-identity property: masking half-edge slots out of a plan (the
    streaming deletion path) must make them inert in segment_reduce — for
    both the Pallas segmented-scan path and the scatter reference, for both
    min and add — i.e. equal to a from-scratch plan without those edges.
    masked_update must likewise pin non-vmask slots to the identity."""
    import dataclasses
    import jax
    from repro.engine import kernels

    rng = np.random.default_rng(seed)
    g = graph.watts_strogatz(90, 4, 0.2, seed=seed % 7)
    owner = baselines.hash_partition(g, 3)
    plan = E.compile_plan(g, owner, 3)

    # delete a random subset of undirected edges: clear both half-edge slots
    em = np.asarray(plan.emask).copy()
    l2g = np.asarray(plan.local2global)
    tgt = np.asarray(plan.edge_tgt)
    nbr = np.asarray(plan.edge_nbr)
    u, v = g.as_numpy()
    own = np.asarray(owner)[np.asarray(g.edge_mask)]
    kill = rng.random(g.n_edges) < 0.3
    for a, b, p in zip(u[kill], v[kill], own[kill]):
        ga, gb = l2g[p, tgt[p]], l2g[p, nbr[p]]
        hit = em[p] & (((ga == a) & (gb == b)) | ((ga == b) & (gb == a)))
        assert hit.sum() == 2
        em[p, hit] = False
    deleted = dataclasses.replace(plan, emask=jnp.asarray(em))

    # reference: compile the surviving edge set from scratch
    keep = ~kill
    g2 = graph.from_edge_array(g.n_vertices,
                               np.stack([u[keep], v[keep]], 1))
    own2 = np.full(g2.e_pad, -2, np.int32)
    k2u, k2v = g2.as_numpy()
    lut = {(int(a), int(b)): int(p) for a, b, p in zip(u, v, own)}
    own2[:g2.n_edges] = [lut[(int(a), int(b))] for a, b in zip(k2u, k2v)]
    fresh = E.compile_plan(g2, own2, 3)

    key = jax.random.key(seed)
    msgs = jax.random.uniform(key, em.shape, jnp.float32, 0.5, 10.0)
    for combine in ("min", "add"):
        got_scan = np.asarray(kernels.segment_reduce(deleted, msgs, combine))
        got_ref = np.asarray(kernels.segment_reduce_ref(deleted, msgs,
                                                        combine))
        np.testing.assert_allclose(got_scan, got_ref, rtol=1e-6)
        # per-vertex aggregates equal the fresh plan's (local layouts differ;
        # compare in global-id space over surviving vertices)
        fr_msgs = jnp.zeros(np.asarray(fresh.emask).shape, jnp.float32)
        f_l2g = np.asarray(fresh.local2global)
        f_tgt = np.asarray(fresh.edge_tgt)
        f_nbr = np.asarray(fresh.edge_nbr)
        f_em = np.asarray(fresh.emask)
        # messages are a function of the (target, neighbour) global pair in
        # the original stream; replay them onto the fresh layout
        mlut = {}
        for p in range(3):
            for s in np.flatnonzero(em[p]):
                mlut[(p, int(l2g[p, tgt[p, s]]), int(l2g[p, nbr[p, s]]))] = \
                    float(np.asarray(msgs)[p, s])
        fr = np.zeros(f_em.shape, np.float32)
        for p in range(3):
            for s in np.flatnonzero(f_em[p]):
                fr[p, s] = mlut[(p, int(f_l2g[p, f_tgt[p, s]]),
                                 int(f_l2g[p, f_nbr[p, s]]))]
        want = np.asarray(kernels.segment_reduce_ref(fresh, jnp.asarray(fr),
                                                     combine))
        ident = kernels._IDENTITY[combine]
        agg_got = np.full(g.n_vertices, ident, np.float32)
        agg_want = np.full(g.n_vertices, ident, np.float32)
        vm_d = np.asarray(deleted.vmask)
        vm_f = np.asarray(fresh.vmask)
        scatter = np.minimum.at if combine == "min" else np.add.at
        for p in range(3):
            scatter(agg_got, l2g[p, vm_d[p]], got_scan[p, vm_d[p]])
            scatter(agg_want, f_l2g[p, vm_f[p]], want[p, vm_f[p]])
        np.testing.assert_allclose(agg_got, agg_want, rtol=1e-5)

    # masked_update: non-vmask slots pinned to identity, others combined
    for combine in ("min", "add"):
        state = jax.random.uniform(key, vm_d.shape, jnp.float32, 0.0, 5.0)
        inc = jax.random.uniform(jax.random.key(seed + 1), vm_d.shape,
                                 jnp.float32, 0.0, 5.0)
        outp = np.asarray(kernels.masked_update(
            state, inc, deleted.vmask, deleted.replicated, combine))
        ident = kernels._IDENTITY[combine]
        assert np.all(outp[~vm_d] == ident)


def test_isolated_vertices_finalized():
    """Vertices outside every partition (degree 0) get correct defaults."""
    edges = np.array([[0, 1], [1, 2], [3, 4]])  # vertex 5 isolated
    g = graph.from_edge_array(6, edges)
    plan = E.compile_plan(g, baselines.hash_partition(g, 2), 2)
    eng = E.Engine(plan)
    d = np.asarray(E.engine_sssp(eng, 0).state)
    assert d[5] == np.inf and d[0] == 0.0
    d5 = np.asarray(E.engine_sssp(eng, 5).state)
    assert d5[5] == 0.0 and np.isinf(d5[0])
    labels = np.asarray(E.engine_wcc(eng).state)
    assert labels[5] == 5.0
    pr = np.asarray(E.engine_pagerank(eng, g.degrees(), iters=10).state)
    ref = np.asarray(alg.reference_pagerank(g, iters=10))
    np.testing.assert_allclose(pr, ref, atol=1e-6)

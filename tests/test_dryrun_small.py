"""Dry-run integration: lower+compile cells on a small (2×4) mesh in a
subprocess (8 host devices), exercising the full specs/shardings path the
production 16×16 / 2×16×16 dry-run uses."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.launch import dryrun
    from repro.launch import mesh as M

    # shrink the production mesh for the test
    M.make_production_mesh = lambda multi_pod=False: (
        jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
        else jax.make_mesh((2, 4), ("data", "model")))

    for arch, shape in [("qwen3-0.6b", "train_4k"),
                        ("qwen2-moe-a2.7b", "decode_32k"),
                        ("whisper-small", "decode_32k"),
                        ("falcon-mamba-7b", "long_500k")]:
        rec = dryrun.run_cell(arch, shape, multi_pod=False)
        assert rec["status"] == "ok", (arch, shape, rec.get("error"))
        assert rec["roofline"]["flops"] > 0
    rec = dryrun.run_cell("qwen3-0.6b", "train_4k", multi_pod=True)
    assert rec["status"] == "ok", rec.get("error")
    print("DRYRUN_SMALL_OK")
""")


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "DRYRUN_SMALL_OK" in res.stdout, \
        f"stdout:\n{res.stdout[-2000:]}\nstderr:\n{res.stderr[-3000:]}"

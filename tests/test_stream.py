"""repro.stream correctness: chunked ingest round-trips the edge set,
plan patches keep the engine bit-identical to the whole-graph oracles on
the mutated graph WITHOUT retracing jitted supersteps, and incremental
maintenance (online HDRF + bounded local re-auction) keeps the replication
factor within 10% of a full DFEP re-run."""
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import dfep, graph, metrics
from repro import engine as E
from repro import stream as S
from repro.engine import runtime
from repro.stream.patch import EdgeChange, SlackExhausted, patch_plan


def _mutation(g, frac_del=0.07, frac_ins=0.08, seed=0):
    """>= 10% of |E| worth of deletions + insertions."""
    rng = np.random.default_rng(seed)
    u, v = g.as_numpy()
    n_del = int(frac_del * g.n_edges)
    idx = rng.choice(g.n_edges, size=n_del, replace=False)
    dels = np.stack([u[idx], v[idx]], 1)
    ins = rng.integers(0, g.n_vertices, size=(int(frac_ins * g.n_edges), 2))
    return ins, dels


def _check_oracles(sess):
    g = sess.graph()
    r = E.engine_sssp(sess.engine, 0)
    ref, _ = alg.reference_sssp(g, 0)
    assert np.array_equal(np.asarray(r.state), np.asarray(ref)), "sssp"
    rw = E.engine_wcc(sess.engine)
    refc, _ = alg.reference_cc(g)
    assert np.array_equal(np.asarray(rw.state), np.asarray(refc)), "wcc"
    rp = E.engine_pagerank(sess.engine, g.degrees(), iters=15)
    refp = alg.reference_pagerank(g, iters=15)
    np.testing.assert_allclose(np.asarray(rp.state), np.asarray(refp),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# ingest
# ---------------------------------------------------------------------------

def test_streaming_graph_roundtrip():
    g = graph.watts_strogatz(120, 4, 0.2, seed=3)
    sg = S.StreamingGraph(g, chunk_size=16)
    u, v = g.as_numpy()
    # delete a few, insert a few (dupes + self-loops ignored), compare with
    # a from-scratch build of the same edge set
    sg.delete_chunk(np.stack([u[:10], v[:10]], 1))
    new = np.array([[1, 99], [99, 1], [5, 5], [2, 117], [1, 99]])
    res = sg.insert_chunk(new)
    assert len(res.slots) == 2          # dedup + self-loop drop
    want = {(int(a), int(b)) for a, b in zip(u[10:], v[10:])}
    want |= {(1, 99), (2, 117)}
    want -= {(int(a), int(b)) for a, b in zip(u[:10], v[:10])}
    gu, gv = sg.graph().as_numpy()
    assert {(int(a), int(b)) for a, b in zip(gu, gv)} == want
    # fingerprint matches an independent build of the same edge set
    ref = graph.from_edge_array(g.n_vertices, np.array(sorted(want)))
    assert sg.graph().fingerprint() == ref.fingerprint()


def test_compaction_preserves_edges_and_bumps_epoch():
    g = graph.watts_strogatz(60, 4, 0.1, seed=1)
    sg = S.StreamingGraph(g, chunk_size=8)
    fp = sg.graph().fingerprint()
    keep = sg.compact()
    assert sg.epoch == 1
    assert len(keep) == g.n_edges
    assert sg.graph().fingerprint() == fp
    assert sg.free_slots() >= sg.chunk_size


def test_graph_fingerprint_invariants():
    a = graph.from_edge_array(50, np.array([[1, 2], [2, 3], [4, 5]]))
    b = graph.from_edge_array(50, np.array([[4, 5], [2, 1], [3, 2]]),
                              pad_to=256)
    assert a.fingerprint() == b.fingerprint()     # order/padding invariant
    c = graph.from_edge_array(50, np.array([[1, 2], [2, 3], [4, 6]]))
    assert a.fingerprint() != c.fingerprint()
    # the plan cache keys on content, not identity
    oa = np.where(np.asarray(a.edge_mask), 0, -2)
    ob = np.where(np.asarray(b.edge_mask), 0, -2)
    assert E.compile_plan_cached(a, oa, 2) is E.compile_plan_cached(b, ob, 2)


# ---------------------------------------------------------------------------
# plan patching
# ---------------------------------------------------------------------------

def test_patch_matches_fresh_compile_metrics():
    """Patched replica masks/counters == a from-scratch compile of the same
    (graph, owner) state."""
    g = graph.watts_strogatz(150, 4, 0.1, seed=1)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    ins, dels = _mutation(g, seed=1)
    sess.apply(inserts=ins, deletes=dels)
    assert sess.n_patches >= 1 and sess.n_recompiles == 0

    g2 = sess.graph()
    m = metrics.evaluate(g2, sess.owner, 4, compute_gain=False)
    assert sess.plan.exchange_per_superstep() == m.messages
    assert sess.plan.replication_factor() == m.replication_factor
    fresh = E.compile_plan(g2, sess.owner, 4)
    assert fresh.exchange_volume == sess.plan.exchange_volume
    assert fresh.sum_local_vertices == sess.plan.sum_local_vertices
    np.testing.assert_array_equal(np.asarray(fresh.n_edges_local),
                                  np.asarray(sess.plan.n_edges_local))
    # patched plan holds exactly the mutated edge set
    want = np.unique(np.stack(g2.as_numpy(), 1), axis=0)
    got = np.unique(np.concatenate(sess.plan.local_edges(), 0), axis=0)
    assert np.array_equal(want, got)
    # property-channel index plane: the patched edge_slot mapping per
    # (partition, global endpoints) half-edge equals a fresh compile's —
    # external [E_pad, F] planes read identically through either plan
    def slot_map(plan):
        l2g = np.asarray(plan.local2global)
        tgt = np.asarray(plan.edge_tgt)
        nbr = np.asarray(plan.edge_nbr)
        em = np.asarray(plan.emask)
        es = np.asarray(plan.edge_slot)
        return {(p, int(l2g[p, tgt[p, s]]), int(l2g[p, nbr[p, s]])):
                int(es[p, s])
                for p in range(plan.k) for s in np.flatnonzero(em[p])}
    assert slot_map(sess.plan) == slot_map(fresh)
    assert sess.plan.edge_slot_hwm == fresh.edge_slot_hwm


def test_patch_exhaustion_raises_and_leaves_plan_usable():
    g = graph.watts_strogatz(100, 4, 0.1, seed=1)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32, edge_slack=0,
                                             vertex_slack=0,
                                             drift_threshold=1e9), key=0)
    plan = sess.plan
    free = int(plan.e_max - 1 - int(np.asarray(plan.csr_fill).max()))
    # distinct in-range vertex pairs; 2 slots per edge overruns `free` slots
    import itertools
    pairs = itertools.combinations(range(g.n_vertices), 2)
    too_many = [EdgeChange(a, b, -1, 0)
                for a, b in itertools.islice(pairs, free)]
    with pytest.raises(SlackExhausted):
        patch_plan(plan, too_many)
    # the input plan is untouched and still answers queries
    r = E.engine_sssp(E.Engine(plan), 0)
    ref, _ = alg.reference_sssp(sess.graph(), 0)
    assert np.array_equal(np.asarray(r.state), np.asarray(ref))


# ---------------------------------------------------------------------------
# acceptance: streamed batch >= 10% |E|
# ---------------------------------------------------------------------------

def test_streamed_batch_no_retrace_and_oracle_identical():
    """Plan patches must NOT invalidate the engine's jit cache: the
    superstep loop traces for the warm-up queries and never again."""
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=64,
                                             drift_threshold=1e9), key=0)
    _check_oracles(sess)                       # warm every program's cache
    traced = runtime.TRACE_COUNTER["run_loop"]

    ins, dels = _mutation(g, seed=0)
    assert len(ins) + len(dels) >= 0.10 * g.n_edges
    stats = sess.apply(inserts=ins, deletes=dels)
    assert stats["recompiles"] == 0 and stats["patches"] >= 1

    _check_oracles(sess)                       # bit-identical on mutated graph
    assert runtime.TRACE_COUNTER["run_loop"] == traced, \
        "plan patch caused a jit retrace"


def test_incremental_rf_within_10pct_of_full_rerun():
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=64,
                                             drift_threshold=0.02), key=0)
    ins, dels = _mutation(g, seed=0)
    sess.apply(inserts=ins, deletes=dels)
    _check_oracles(sess)                       # still exact after re-auction

    g2 = sess.graph()
    owner_full, _ = dfep.partition(g2, k=4, key=1)
    rf_full = E.compile_plan(g2, np.asarray(owner_full), 4).replication_factor()
    rf_inc = sess.replication_factor()
    assert rf_inc <= 1.10 * rf_full, (rf_inc, rf_full)


def test_reauction_only_moves_region_edges():
    g = graph.watts_strogatz(200, 4, 0.1, seed=5)
    owner, _ = dfep.partition(g, k=4, key=0)
    owner = np.asarray(owner)
    touched = np.zeros(g.n_vertices, bool)
    touched[:20] = True
    new_owner, info = S.local_reauction(g, owner, touched, 4, hops=1)
    u, v = np.asarray(g.src), np.asarray(g.dst)
    region = S.h_hop_vertices(u, v, np.asarray(g.edge_mask), g.n_vertices,
                              touched, 1)
    changed = (new_owner != owner) & np.asarray(g.edge_mask)
    assert not np.any(changed & ~(region[u] & region[v])), \
        "re-auction moved an edge outside the h-hop region"
    assert info["active_edges"] >= int(changed.sum())
    # every real edge still owned by a valid partition
    m = np.asarray(g.edge_mask)
    assert new_owner[m].min() >= 0 and new_owner[m].max() < 4


def test_compaction_epoch_recompiles_and_stays_correct():
    g = graph.watts_strogatz(100, 4, 0.1, seed=1)   # pad 256: 57 spare slots
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    rng = np.random.default_rng(1)
    stats = sess.apply(inserts=rng.integers(0, 100, size=(400, 2)))
    assert stats["epoch"] >= 1 and stats["recompiles"] >= 1
    assert sess.plan.epoch == sess.epoch
    _check_oracles(sess)


def test_batched_serving_on_patched_plan():
    g = graph.watts_strogatz(120, 4, 0.2, seed=3)
    sess = S.StreamSession(g, S.StreamConfig(k=4, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    ins, dels = _mutation(g, seed=2)
    sess.apply(inserts=ins, deletes=dels)
    sources = [0, 7, 33, 64]
    res = E.multi_source_sssp(sess.engine, sources)
    for i, s in enumerate(sources):
        ref, _ = alg.reference_sssp(sess.graph(), s)
        assert np.array_equal(np.asarray(res.state[i]), np.asarray(ref)), s


def test_vertex_departure_and_return():
    """Deleting a vertex's last edge clears its slot; re-inserting later
    re-registers it (slot reuse) — engine results stay exact throughout."""
    g = graph.watts_strogatz(80, 4, 0.1, seed=4)
    sess = S.StreamSession(g, S.StreamConfig(k=3, chunk_size=32,
                                             drift_threshold=1e9), key=0)
    u, v = g.as_numpy()
    inc = (u == 0) | (v == 0)
    sess.apply(deletes=np.stack([u[inc], v[inc]], 1))
    d = np.asarray(E.engine_sssp(sess.engine, 0).state)
    ref, _ = alg.reference_sssp(sess.graph(), 0)
    assert np.array_equal(d, np.asarray(ref)) and d[0] == 0.0
    _check_oracles(sess)
    sess.apply(inserts=np.array([[0, 40], [0, 41]]))
    _check_oracles(sess)
    d2 = np.asarray(E.engine_sssp(sess.engine, 0).state)
    assert d2[40] == 1.0 and d2[41] == 1.0

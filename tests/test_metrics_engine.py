"""Audit of core/metrics.py against paper §V-A, cross-checked against the
engine's replica-exchange plan (the operational ground truth), with pinned
regression values.

Definitions under test:
  * replication factor = Σ|V_i| / |V|    — engine: vmask count / |V|
  * MESSAGES           = Σ|F_i|          — engine: replicated-slot count,
    which is exactly the number of vertex states crossing the cut per
    superstep (private vertices keep all incident edges local and are
    never exchanged).
"""
import numpy as np

from repro.core import baselines, graph, metrics
from repro import engine as E


def _independent_counts(g, owner, k):
    """Straight-from-the-paper recomputation in plain numpy."""
    u, v = g.as_numpy()
    own = np.asarray(owner)[np.asarray(g.edge_mask)]
    member = np.zeros((k, g.n_vertices), bool)
    member[own, u] = True
    member[own, v] = True
    copies = member.sum(0)
    sum_vi = int(member.sum())                     # Σ|V_i|
    messages = int((member & (copies >= 2)).sum()) # Σ|F_i|
    frontier_total = int((copies >= 2).sum())
    return sum_vi, messages, frontier_total


def test_metrics_match_engine_exchange_plan():
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    for part_fn in (lambda: baselines.hash_partition(g, 4),
                    lambda: baselines.greedy_partition(g, 4, seed=0)):
        owner = part_fn()
        m = metrics.evaluate(g, owner, 4, compute_gain=False)
        plan = E.compile_plan(g, owner, 4)
        sum_vi, messages, frontier_total = _independent_counts(g, owner, 4)
        # metrics.py vs paper definitions
        assert m.messages == messages
        assert m.frontier_total == frontier_total
        assert m.replication_factor == sum_vi / g.n_vertices
        # metrics.py vs the engine's operational exchange volume
        assert plan.exchange_per_superstep() == m.messages
        assert plan.replication_factor() == m.replication_factor


def test_metrics_pinned_regression():
    """Exact pinned values (deterministic graph + partitioners)."""
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    assert g.n_vertices == 300 and g.n_edges == 898

    m = metrics.evaluate(g, baselines.hash_partition(g, 4), 4,
                         compute_gain=False)
    assert m.messages == 928
    assert m.frontier_total == 300
    assert m.replication_factor == 928 / 300

    m = metrics.evaluate(g, baselines.greedy_partition(g, 4, seed=0), 4,
                         compute_gain=False)
    assert m.messages == 438
    assert m.frontier_total == 205
    assert m.replication_factor == 533 / 300
    assert abs(m.largest_norm - 1.0111358574610245) < 1e-12


def test_engine_reports_exchange_volume():
    g = graph.watts_strogatz(300, 6, 0.1, seed=2)
    owner = baselines.greedy_partition(g, 4, seed=0)
    plan = E.compile_plan(g, owner, 4)
    res = E.engine_sssp(E.Engine(plan), 0)
    m = metrics.evaluate(g, owner, 4, compute_gain=False)
    assert res.exchange_per_superstep == m.messages
    assert res.total_exchanged == int(res.supersteps) * m.messages
    assert res.row()["exchange_per_superstep"] == m.messages

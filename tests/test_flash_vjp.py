"""FA2 custom-VJP flash attention: forward + gradients vs autodiff through
the baseline online-softmax scan, across shapes (incl. GQA and MLA-style
dv != dh)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import perf
from repro.models.flash_vjp import flash_fa2


@pytest.fixture(autouse=True)
def _baseline_perf():
    perf.set_perf(perf.BASELINE)
    yield
    perf.set_perf(perf.BASELINE)


@pytest.mark.parametrize("b,h,kv,s,dh,dv,causal", [
    (2, 4, 4, 128, 32, 32, True),      # MHA causal
    (2, 8, 2, 256, 32, 32, True),      # GQA
    (1, 4, 4, 64, 16, 48, True),       # MLA-style dv != dh
    (2, 4, 2, 128, 32, 32, False),     # bidirectional (encoder)
])
def test_fa2_matches_autodiff(b, h, kv, s, dh, dv, causal):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, dh)) * 0.3
    k = jax.random.normal(ks[1], (b, kv, s, dh)) * 0.3
    v = jax.random.normal(ks[2], (b, kv, s, dv)) * 0.3

    def loss_ref(q, k, v):
        return jnp.sum(L.flash_attention(q, k, v, causal=causal, block=64) ** 2)

    def loss_fa2(q, k, v):
        return jnp.sum(flash_fa2(q, k, v, causal, 64) ** 2)

    o1 = L.flash_attention(q, k, v, causal=causal, block=64)
    o2 = flash_fa2(q, k, v, causal, 64)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=1e-5)
    g1 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_fa2, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b_) / scale, atol=5e-4)


def test_tuned_profile_numerics_match_baseline():
    """One train step under TUNED must stay close to BASELINE (same math,
    different schedule/memory layout)."""
    from repro.configs import get_config
    from repro.models import lm
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_step import train_step
    from repro.data.pipeline import DataConfig, SyntheticPipeline

    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = lm.init_params(cfg, jax.random.key(0))
    batch = SyntheticPipeline(cfg, DataConfig(batch=2, seq_len=64)).batch_at(0)
    ocfg = AdamWConfig(warmup_steps=1, total_steps=10)

    losses = {}
    for name, pc in (("base", perf.BASELINE), ("tuned", perf.TUNED)):
        perf.set_perf(pc)
        opt = init_opt_state(params)
        _, _, m = train_step(cfg, ocfg, params, opt, batch)
        losses[name] = float(m["loss"])
    assert abs(losses["base"] - losses["tuned"]) < 1e-2, losses


def test_tuned_profile_ssm_numerics():
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("falcon-mamba-7b", smoke=True)
    params, _ = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
    outs = {}
    for name, pc in (("base", perf.BASELINE), ("tuned", perf.TUNED)):
        perf.set_perf(pc)
        logits, _, _ = lm.forward_lm(cfg, params, toks, remat=False)
        outs[name] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["base"], outs["tuned"],
                               atol=1e-2, rtol=1e-2)

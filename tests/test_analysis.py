"""Tests for repro.analysis — the AST invariant checker.

Three layers:
  * the repo itself must scan clean (this is the tier-1 replacement for
    the deleted grep-guard tests in test_registry.py / test_obs.py);
  * every rule must flag its bad fixture exactly at the `# FLAG: RULE`
    markers and pass its good fixture — including the three encoded
    incidents (PR 6 jnp.max overhead, PR 7 _reauction read-only view,
    pagerank iters=None cache identity);
  * suppressions round-trip, unknown rule ids hard-fail, and the JSON
    report keeps its schema.
"""
import json
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (SuppressionError, all_rules, parse, run_clean,
                            scan)
from repro.analysis.suppressions import apply as apply_suppressions

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"
_FLAG = re.compile(r"#\s*FLAG:\s*([A-Z]{2}\d{3})")


def expected_flags(path: Path) -> set[tuple[str, int]]:
    out = set()
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        for rule in _FLAG.findall(line):
            out.add((rule, lineno))
    return out


BAD_FIXTURES = sorted(FIXTURES.glob("*_bad.py")) + \
    sorted(FIXTURES.glob("incident_*.py"))
GOOD_FIXTURES = sorted(FIXTURES.glob("*_good.py"))


# ---------------------------------------------------------------------------
# the repo scans clean (the single tier-1 invariant gate)
# ---------------------------------------------------------------------------

def test_repo_scans_clean():
    assert run_clean(str(REPO / "src" / "repro")), (
        "unsuppressed analyzer findings in src/repro — run "
        "`python -m repro.analysis src/repro` for the list; fix them or "
        "add a justified entry to analysis_suppressions.txt")


def test_catalogue_has_five_families():
    families = {r.family for r in all_rules().values()}
    assert {"trace-safety", "retrace-hazard", "lock-discipline",
            "aliasing", "layering"} <= families
    assert len(all_rules()) >= 10


# ---------------------------------------------------------------------------
# per-rule fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", BAD_FIXTURES, ids=lambda p: p.stem)
def test_bad_fixture_flagged(path):
    expected = expected_flags(path)
    assert expected, f"{path.name} has no # FLAG markers"
    got = {(f.rule, f.line) for f in scan([str(path)])}
    assert got == expected, (
        f"{path.name}: expected {sorted(expected)}, got {sorted(got)}")


@pytest.mark.parametrize("path", GOOD_FIXTURES, ids=lambda p: p.stem)
def test_good_fixture_clean(path):
    got = [(f.rule, f.line, f.message) for f in scan([str(path)])]
    assert not got, f"{path.name}: unexpected findings {got}"


def test_every_rule_has_a_bad_fixture_hit():
    hit = set()
    for path in BAD_FIXTURES:
        hit |= {rule for rule, _ in expected_flags(path)}
    assert set(all_rules()) <= hit, (
        f"rules without a bad fixture: {sorted(set(all_rules()) - hit)}")


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_round_trip():
    bad = str(FIXTURES / "lp002_bad.py")
    findings = scan([bad])
    assert findings
    supps = parse(
        "LP002 tests/fixtures/analysis/lp002_bad.py -- fixture exemption\n",
        all_rules())
    kept, silenced = apply_suppressions(findings, supps)
    assert not kept and len(silenced) == len(findings)
    assert all(s.used for s in supps)


def test_suppression_symbol_glob_narrows():
    bad = str(FIXTURES / "ld001_bad.py")
    findings = scan([bad])
    supps = parse("LD001 *ld001_bad.py Widget.refresh -- only refresh\n",
                  all_rules())
    kept, silenced = apply_suppressions(findings, supps)
    assert silenced and kept  # refresh silenced, bump still flagged
    assert all(f.symbol == "Widget.refresh" for f in silenced)
    assert all(f.symbol != "Widget.refresh" for f in kept)


def test_unknown_rule_id_is_an_error():
    with pytest.raises(SuppressionError, match="unknown rule id"):
        parse("ZZ999 foo.py -- whatever\n", all_rules())


def test_missing_justification_is_an_error():
    with pytest.raises(SuppressionError):
        parse("LP002 foo.py\n", all_rules())
    with pytest.raises(SuppressionError, match="empty justification"):
        parse("LP002 foo.py --   \n", all_rules())


def test_unused_suppression_tracked():
    supps = parse("LP002 nowhere/*.py -- never matches\n", all_rules())
    kept, _ = apply_suppressions(scan([str(FIXTURES / "lp002_good.py")]),
                                 supps)
    assert not kept and not supps[0].used


def test_repo_suppressions_file_parses_and_is_fully_used():
    text = (REPO / "analysis_suppressions.txt").read_text()
    supps = parse(text, all_rules())
    assert supps, "repo suppressions file is empty?"
    findings = scan(_repo_sources())
    _, silenced = apply_suppressions(findings, supps)
    unused = [s for s in supps if not s.used]
    assert not unused, (
        f"stale suppressions (matched nothing): "
        f"{[(s.rule, s.path_glob, s.symbol_glob) for s in unused]}")


def _repo_sources():
    from repro.analysis.runner import iter_sources
    return iter_sources([str(REPO / "src" / "repro")])


# ---------------------------------------------------------------------------
# CLI + JSON report schema
# ---------------------------------------------------------------------------

def _cli(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


def test_cli_clean_repo_exit_zero():
    proc = _cli("src/repro")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_findings_exit_one_and_json_schema(tmp_path):
    report = tmp_path / "analysis_report.json"
    proc = _cli(str(FIXTURES / "lp001_bad.py"), "--no-suppressions",
                "--format", "json", "-o", str(report))
    assert proc.returncode == 1
    payload = json.loads(report.read_text())
    assert payload["schema"] == "repro.analysis/v1"
    assert payload["ok"] is False
    assert payload["counts"]["unsuppressed"] == \
        len(payload["findings"]) > 0
    for f in payload["findings"]:
        assert set(f) == {"rule", "file", "line", "col", "symbol",
                          "message"}
        assert f["rule"] in payload["rules"]
    assert "unused_suppressions" in payload


def test_cli_unknown_suppression_rule_exit_two(tmp_path):
    supp = tmp_path / "analysis_suppressions.txt"
    supp.write_text("XX123 foo.py -- stale\n")
    proc = _cli(str(FIXTURES / "lp002_good.py"),
                "--suppressions", str(supp))
    assert proc.returncode == 2
    assert "unknown rule id" in proc.stderr


def test_cli_unknown_rules_filter_exit_two():
    proc = _cli(str(FIXTURES / "lp002_good.py"), "--rules", "NOPE01")
    assert proc.returncode == 2


def test_cli_list_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rule_id in all_rules():
        assert rule_id in proc.stdout

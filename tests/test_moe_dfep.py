"""DFEP-balanced expert placement (beyond-paper feature) tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import moe_dfep
from repro.configs import get_config
from repro.models import lm, layers as L


def _skewed_routing(t=8000, e=32, k=2, seed=0):
    """Zipf-skewed expert selection with clustered co-activation."""
    rng = np.random.default_rng(seed)
    p = 1.0 / (np.arange(e) + 1.0)
    p /= p.sum()
    first = rng.choice(e, size=t, p=p)
    # second expert correlated with the first (cluster pairs)
    second = (first + rng.choice([1, 2, 3], size=t)) % e
    return np.stack([first, second], 1)


def test_placement_improves_imbalance():
    eidx = _skewed_routing()
    loads = np.bincount(eidx.reshape(-1), minlength=32).astype(float)
    placement = moe_dfep.place_experts(eidx, n_experts=32, k=4, seed=0)
    naive = moe_dfep.naive_imbalance(loads, 4)
    assert placement.imbalance < naive, (placement.imbalance, naive)
    # valid partition: every expert placed, capacity respected
    counts = np.bincount(placement.expert_to_shard, minlength=4)
    assert counts.sum() == 32 and counts.max() <= 8
    assert sorted(placement.permutation.tolist()) == list(range(32))


def test_permute_expert_params_preserves_moe_output():
    """Permuting experts + router columns must not change MoE output."""
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    params, _ = lm.init_params(cfg, jax.random.key(0))
    moe_p = jax.tree.map(lambda x: x[0], params["blocks"]["l0"]["ffn"])
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.1
    y0, aux0 = L.moe(cfg, moe_p, x)
    perm = np.random.default_rng(0).permutation(moe_p["router"].shape[1])
    moe_perm = moe_dfep.permute_expert_params(moe_p, perm)
    y1, aux1 = L.moe(cfg, moe_perm, x)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32), atol=2e-2, rtol=2e-2)

"""Distributed (shard_map) DFEP/ETSCH tests.

Run in a subprocess so XLA_FLAGS can request 8 host devices without
polluting the main pytest process (which must keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.core import algorithms as alg
    from repro.core import dfep, dfep_distributed, etsch, etsch_distributed, graph, metrics

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("data",))

    g = graph.watts_strogatz(600, 6, 0.1, seed=3)

    # --- distributed DFEP matches single-host quality --------------------
    cfg = dfep.DfepConfig(k=6)
    owner, info = dfep_distributed.run_dfep_sharded(g, cfg, jax.random.key(0), mesh)
    own = np.asarray(owner)[np.asarray(g.edge_mask)]
    assert own.min() >= 0 and own.max() < 6, "invalid owners"
    assert np.bincount(own, minlength=6).sum() == g.n_edges
    m = metrics.evaluate(g, owner, 6, compute_gain=False)
    assert m.largest_norm < 1.6, f"bad balance {m.largest_norm}"
    print("DFEP sharded ok:", info, "largest:", round(m.largest_norm, 3))

    # --- distributed ETSCH SSSP == reference ------------------------------
    part = etsch.compile_partitioning(g, owner, 6)
    dist, steps = etsch_distributed.sssp_sharded(part, 0, mesh)
    ref, ref_rounds = alg.reference_sssp(g, 0)
    finite = np.isfinite(np.asarray(ref))
    assert (np.asarray(dist)[finite] == np.asarray(ref)[finite]).all(), "sssp mismatch"
    assert steps <= int(ref_rounds)
    print("SSSP sharded ok:", steps, "supersteps vs", int(ref_rounds))

    # --- distributed PageRank == reference --------------------------------
    pr = etsch_distributed.pagerank_sharded(part, g.degrees(), mesh, iters=20)
    pr_ref = alg.reference_pagerank(g, iters=20)
    np.testing.assert_allclose(np.asarray(pr), np.asarray(pr_ref), rtol=1e-5)
    print("PageRank sharded ok")
    print("ALL_OK")
""")


@pytest.mark.slow
def test_distributed_graph_stack():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "ALL_OK" in res.stdout, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"

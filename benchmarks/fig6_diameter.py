"""Paper Fig. 6: DFEP behaviour vs graph diameter (remap protocol: random
edge remapping lowers the diameter of a road network at fixed |V|,|E|)."""
from __future__ import annotations

from repro.core import dfep, graph, metrics
from repro.core.algorithms import reference_sssp

from .common import SAMPLES, SCALE, emit


def run(fractions=(0.0, 0.01, 0.03, 0.1, 0.3), k=8, samples=SAMPLES,
        scale=SCALE) -> list[dict]:
    base = graph.load_dataset("usroads", scale=scale, seed=0)
    rows = []
    for frac in fractions:
        g = graph.remap_edges(base, frac, seed=1) if frac else base
        g = graph.largest_component(g)
        _, diam_rounds = reference_sssp(g, 0)
        slots = dfep.build_slots(g)
        for s in range(samples):
            owner, info = dfep.partition(g, k=k, key=s, slots=slots,
                                         max_rounds=4000, stall_rounds=64)
            m = metrics.evaluate(g, owner, k, rounds=info["rounds"])
            rows.append({
                "remap_frac": frac,
                "diameter_proxy": int(diam_rounds),
                "sample": s,
                "rounds": info["rounds"],
                "largest": round(m.largest_norm, 4),
                "nstdev": round(m.nstdev, 4),
                "messages": m.messages,
                "gain": round(m.gain, 4),
                "disconnected_pct": round(100 * (1 - m.connected_frac), 2),
            })
    return rows


def main() -> None:
    emit("fig6_diameter", run())


if __name__ == "__main__":
    main()

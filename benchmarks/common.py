"""Shared benchmark utilities: CSV/JSON emit, default reduced scales.

The paper runs 100 samples per point on full SNAP graphs; one CPU core gets
reduced scales + fewer samples (recorded per benchmark). Scale factors are
encoded here so EXPERIMENTS.md can state them exactly.
"""
from __future__ import annotations

import csv
import json
import os
import sys
import time

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.12"))
SAMPLES = int(os.environ.get("REPRO_BENCH_SAMPLES", "3"))


def emit(name: str, rows: list[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    # also print the table
    if rows:
        keys = list(rows[0].keys())
        print(f"\n== {name} ==")
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return path


def emit_json(name: str, payload: dict) -> str:
    """Write a structured benchmark record to OUT_DIR/<name>.json."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"\n== {name} ==")
    print(json.dumps(payload, indent=2, sort_keys=True))
    return path


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
        return False

"""CI bench-regression gate: compare fresh BENCH_*.json records against the
committed baselines in experiments/bench/ and fail on regression.

    python benchmarks/check_regression.py \
        --baseline experiments/bench --fresh /tmp/bench-fresh \
        [--tol 0.2] [--tol-perf 0.5] [--tolerances PATH]

Policy (per leaf value, walking the JSON trees in lockstep):

  * **structure** — every fresh ``BENCH_*.json`` must have a committed
    baseline, every baseline key must exist in the fresh record, lists must
    keep their length, and bool/str leaves must match exactly (an
    ``exact_vs_oracle`` flip or a vanished registered program is a
    regression no tolerance excuses).  Baselines with no fresh counterpart
    (figures outside the smoke set, e.g. BENCH_scalability.json from fig8)
    are reported and skipped.
  * **deterministic numerics** (superstep counts, replication factors,
    occupancies, graph sizes, ...) — relative tolerance ``--tol``
    (default 0.2): the seeds are fixed, so these only move when the code's
    behaviour moves.
  * **throughput** (keys containing ``qps`` or ``speedup``) — one-sided
    relative
    tolerance ``--tol-perf`` (default 0.5): higher-is-better, so only a
    DROP below ``baseline * (1 - tol_perf)`` fails — loose enough for
    runner-to-runner machine variance, tight enough to catch a serving
    path falling off a cliff; a big improvement is reported as a note
    (refresh the baselines to tighten the line).
  * **batch-shape accounting** (keys containing ``batches``,
    ``occupancy``, ``pad_waste``) — relative tolerance ``--tol-perf``
    both ways: scheduling under the timer-flush sweeps is load-timing
    dependent, but the shapes must stay in the same regime.
  * **wall-clock seconds** (keys ending ``_s`` / containing ``_s_``,
    ``wall``, ``warmup``, ``latency``) — skipped by default (pure machine
    speed; the qps and superstep lines already bound the same behaviour),
    listed in the report; ``--strict-seconds`` compares them one-sided
    (slower fails) at ``--tol-perf``.

Per-metric overrides — ``tolerances.json``
------------------------------------------
The key-substring heuristics above cannot express every contract (e.g.
"the obs recorder's serving overhead must stay under an ABSOLUTE 3%,
regardless of what the baseline happened to measure").  A checked-in
``<baseline>/tolerances.json`` (auto-loaded when present; ``--tolerances``
points elsewhere) carries per-metric rules matched by ``fnmatch`` pattern
against the full JSON path (``BENCH_obs.overhead_frac``,
``BENCH_serve.rows[*].qps``).  The FIRST matching override wins and
replaces the default policy for that leaf:

  * ``{"pattern": P, "mode": "skip"}`` — never compared (listed);
  * ``{"mode": "ceiling", "limit": L}`` — fresh value must be <= L,
    an absolute budget independent of the baseline;
  * ``{"mode": "floor", "limit": L}`` — fresh value must be >= L;
  * ``{"mode": "rel", "tol": T}`` — symmetric relative tolerance T
    against the baseline (overrides the key-based default);
  * ``{"mode": "higher_better", "tol": T}`` — one-sided: only a drop
    below ``baseline * (1 - T)`` fails.

Each entry may carry a ``"why"`` string — documentation, ignored here.

Exit status 0 = green, 1 = regression (each one printed with its JSON
path, baseline and fresh values).  Regenerating the committed baselines is
``REPRO_BENCH_OUT=experiments/bench python -m benchmarks.run`` under the
CI environment (see .github/workflows/ci.yml bench-smoke).
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import pathlib
import sys

_SECONDS_HINTS = ("wall", "warmup", "latency")
_OVERRIDE_MODES = ("skip", "ceiling", "floor", "rel", "higher_better")
_HIGHER_BETTER_HINTS = ("qps", "speedup")
_SHAPE_HINTS = ("batches", "occupancy", "pad_waste")


def _is_seconds_key(key: str) -> bool:
    k = key.lower()
    return (k.endswith("_s") or "_s_" in k
            or any(h in k for h in _SECONDS_HINTS))


def _is_higher_better_key(key: str) -> bool:
    k = key.lower()
    return any(h in k for h in _HIGHER_BETTER_HINTS)


def _is_shape_key(key: str) -> bool:
    k = key.lower()
    return any(h in k for h in _SHAPE_HINTS)


class Report:
    def __init__(self):
        self.errors: list[str] = []
        self.skipped: list[str] = []
        self.notes: list[str] = []

    def error(self, msg: str) -> None:
        self.errors.append(msg)

    def skip(self, msg: str) -> None:
        self.skipped.append(msg)

    def note(self, msg: str) -> None:
        self.notes.append(msg)


def load_tolerances(path: pathlib.Path) -> list[dict]:
    """Parse and validate tolerances.json; malformed entries are a config
    error (exit 1), not a silently ignored rule."""
    doc = json.loads(path.read_text())
    overrides = doc.get("overrides", [])
    for i, o in enumerate(overrides):
        where = f"{path}:overrides[{i}]"
        if "pattern" not in o:
            raise SystemExit(f"ERROR: {where}: missing 'pattern'")
        mode = o.get("mode")
        if mode not in _OVERRIDE_MODES:
            raise SystemExit(f"ERROR: {where}: mode {mode!r} not one of "
                             f"{_OVERRIDE_MODES}")
        if mode in ("ceiling", "floor") and "limit" not in o:
            raise SystemExit(f"ERROR: {where}: mode {mode!r} needs 'limit'")
        if mode in ("rel", "higher_better") and "tol" not in o:
            raise SystemExit(f"ERROR: {where}: mode {mode!r} needs 'tol'")
    return overrides


def _override_for(path: str, overrides: list[dict]) -> dict | None:
    for o in overrides:
        if fnmatch.fnmatchcase(path, o["pattern"]):
            return o
    return None


def _apply_override(o: dict, base, fresh, path: str, rep: Report) -> None:
    """One leaf under an explicit per-metric rule (default policy bypassed)."""
    mode = o["mode"]
    if mode == "skip":
        rep.skip(f"{path}: override skip ({base!r} -> {fresh!r})")
        return
    fv = float(fresh)
    if mode == "ceiling":
        limit = float(o["limit"])
        if fv > limit:
            rep.error(f"{path}: {fresh} exceeds absolute ceiling {limit} "
                      f"(override {o['pattern']!r})")
        return
    if mode == "floor":
        limit = float(o["limit"])
        if fv < limit:
            rep.error(f"{path}: {fresh} below absolute floor {limit} "
                      f"(override {o['pattern']!r})")
        return
    bv = float(base)
    rel = (fv - bv) / max(abs(bv), 1e-9)
    tol = float(o["tol"])
    if mode == "rel":
        if abs(rel) > tol:
            rep.error(f"{path}: {base} -> {fresh} (rel change {abs(rel):.1%}"
                      f" > override tolerance {tol:.0%})")
        return
    if -rel > tol:                                 # higher_better
        rep.error(f"{path}: {base} -> {fresh} (worse by {-rel:.1%} > "
                  f"override tolerance {tol:.0%})")


def _compare(base, fresh, path: str, key: str, args, rep: Report) -> None:
    # a skip override silences a whole subtree (variable-length diagnostic
    # lists, machine-specific records); value overrides apply at leaves
    ov = _override_for(path, args.overrides)
    if ov is not None and ov["mode"] == "skip":
        _apply_override(ov, base, fresh, path, rep)
        return
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            rep.error(f"{path}: baseline is an object, fresh is "
                      f"{type(fresh).__name__}")
            return
        for k in base:
            if k not in fresh:
                rep.error(f"{path}.{k}: key present in baseline, missing "
                          "from fresh record")
            else:
                _compare(base[k], fresh[k], f"{path}.{k}", k, args, rep)
        for k in fresh:
            if k not in base:
                rep.note(f"{path}.{k}: new key (no baseline) — commit "
                         "updated baselines to start gating it")
        return
    if isinstance(base, list):
        if not isinstance(fresh, list):
            rep.error(f"{path}: baseline is a list, fresh is "
                      f"{type(fresh).__name__}")
            return
        if len(base) != len(fresh):
            rep.error(f"{path}: list length {len(base)} -> {len(fresh)}")
            return
        for i, (b, f) in enumerate(zip(base, fresh)):
            _compare(b, f, f"{path}[{i}]", key, args, rep)
        return
    # leaf: an explicit per-metric override replaces the default policy
    if ov is not None:
        if all(isinstance(v, (int, float)) and not isinstance(v, bool)
               for v in (base, fresh)):
            _apply_override(ov, base, fresh, path, rep)
            return
        rep.error(f"{path}: override {ov['pattern']!r} (mode "
                  f"{ov['mode']!r}) targets a non-numeric leaf "
                  f"({base!r} -> {fresh!r})")
        return
    if base is None or fresh is None:
        if base is not fresh:
            rep.error(f"{path}: {base!r} -> {fresh!r}")
        return
    if isinstance(base, bool) or isinstance(fresh, bool) \
            or isinstance(base, str) or isinstance(fresh, str):
        if base != fresh:
            rep.error(f"{path}: {base!r} -> {fresh!r} (exact-match leaf)")
        return
    # numeric leaf
    seconds = _is_seconds_key(key)
    if seconds and not args.strict_seconds:
        rep.skip(f"{path}: wall-clock key ({base} -> {fresh})")
        return
    denom = max(abs(float(base)), 1e-9)
    rel = (float(fresh) - float(base)) / denom      # signed: >0 means grew
    if _is_higher_better_key(key) or (seconds and args.strict_seconds):
        # one-sided perf line: only the BAD direction fails (qps dropping,
        # seconds growing); a large move the other way is worth refreshing
        # the baseline for, but is not a regression
        bad = -rel if _is_higher_better_key(key) else rel
        if bad > args.tol_perf:
            rep.error(f"{path}: {base} -> {fresh} (worse by {bad:.1%} > "
                      f"tolerance {args.tol_perf:.0%})")
        elif -bad > args.tol_perf:
            rep.note(f"{path}: {base} -> {fresh} improved by {-bad:.1%} — "
                     "consider refreshing the committed baseline")
        return
    tol = args.tol_perf if _is_shape_key(key) else args.tol
    if abs(rel) > tol:
        rep.error(f"{path}: {base} -> {fresh} (rel change {abs(rel):.1%} > "
                  f"tolerance {tol:.0%})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when fresh BENCH_*.json records regress "
                    "against the committed baselines")
    ap.add_argument("--baseline", default="experiments/bench",
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--fresh", required=True,
                    help="directory of freshly generated BENCH_*.json")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="relative tolerance for deterministic numerics "
                         "(superstep counts, rf, sizes; default 0.2)")
    ap.add_argument("--tol-perf", type=float, default=0.5,
                    help="relative tolerance for throughput keys (qps; "
                         "default 0.5 — absorbs runner machine variance)")
    ap.add_argument("--strict-seconds", action="store_true",
                    help="also gate wall-clock seconds keys at --tol-perf "
                         "instead of skipping them")
    ap.add_argument("--tolerances", default=None,
                    help="per-metric override file (default: "
                         "<baseline>/tolerances.json when present)")
    args = ap.parse_args(argv)

    base_dir = pathlib.Path(args.baseline)
    fresh_dir = pathlib.Path(args.fresh)
    tol_path = (pathlib.Path(args.tolerances) if args.tolerances
                else base_dir / "tolerances.json")
    if tol_path.exists():
        args.overrides = load_tolerances(tol_path)
        print(f"loaded {len(args.overrides)} per-metric override(s) "
              f"from {tol_path}")
    elif args.tolerances:
        print(f"ERROR: --tolerances {tol_path} does not exist")
        return 1
    else:
        args.overrides = []
    fresh_files = sorted(fresh_dir.glob("BENCH_*.json"))
    if not fresh_files:
        print(f"ERROR: no fresh BENCH_*.json under {fresh_dir}")
        return 1

    rep = Report()
    for f in fresh_files:
        b = base_dir / f.name
        if not b.exists():
            rep.error(f"{f.name}: fresh record has NO committed baseline — "
                      f"run the benchmark with REPRO_BENCH_OUT={base_dir} "
                      "and commit the result")
            continue
        _compare(json.loads(b.read_text()), json.loads(f.read_text()),
                 f.stem, "", args, rep)
    fresh_names = {f.name for f in fresh_files}
    for b in sorted(base_dir.glob("BENCH_*.json")):
        if b.name not in fresh_names:
            rep.note(f"{b.name}: baseline has no fresh counterpart in this "
                     "run — not gated")

    for msg in rep.notes:
        print(f"NOTE      {msg}")
    for msg in rep.skipped:
        print(f"SKIPPED   {msg}")
    for msg in rep.errors:
        print(f"REGRESSED {msg}")
    n_cmp = len(fresh_files)
    if rep.errors:
        print(f"\nbench-regression gate: FAIL — {len(rep.errors)} "
              f"regression(s) across {n_cmp} record(s)")
        return 1
    print(f"\nbench-regression gate: OK — {n_cmp} record(s) within "
          f"tolerance (tol={args.tol:.0%}, tol-perf={args.tol_perf:.0%}, "
          f"{len(rep.skipped)} wall-clock leaves skipped)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

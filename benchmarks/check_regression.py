"""CI bench-regression gate: compare fresh BENCH_*.json records against the
committed baselines in experiments/bench/ and fail on regression.

    python benchmarks/check_regression.py \
        --baseline experiments/bench --fresh /tmp/bench-fresh \
        [--tol 0.2] [--tol-perf 0.5]

Policy (per leaf value, walking the JSON trees in lockstep):

  * **structure** — every fresh ``BENCH_*.json`` must have a committed
    baseline, every baseline key must exist in the fresh record, lists must
    keep their length, and bool/str leaves must match exactly (an
    ``exact_vs_oracle`` flip or a vanished registered program is a
    regression no tolerance excuses).  Baselines with no fresh counterpart
    (figures outside the smoke set, e.g. BENCH_scalability.json from fig8)
    are reported and skipped.
  * **deterministic numerics** (superstep counts, replication factors,
    occupancies, graph sizes, ...) — relative tolerance ``--tol``
    (default 0.2): the seeds are fixed, so these only move when the code's
    behaviour moves.
  * **throughput** (keys containing ``qps`` or ``speedup``) — one-sided
    relative
    tolerance ``--tol-perf`` (default 0.5): higher-is-better, so only a
    DROP below ``baseline * (1 - tol_perf)`` fails — loose enough for
    runner-to-runner machine variance, tight enough to catch a serving
    path falling off a cliff; a big improvement is reported as a note
    (refresh the baselines to tighten the line).
  * **batch-shape accounting** (keys containing ``batches``,
    ``occupancy``, ``pad_waste``) — relative tolerance ``--tol-perf``
    both ways: scheduling under the timer-flush sweeps is load-timing
    dependent, but the shapes must stay in the same regime.
  * **wall-clock seconds** (keys ending ``_s`` / containing ``_s_``,
    ``wall``, ``warmup``, ``latency``) — skipped by default (pure machine
    speed; the qps and superstep lines already bound the same behaviour),
    listed in the report; ``--strict-seconds`` compares them one-sided
    (slower fails) at ``--tol-perf``.

Exit status 0 = green, 1 = regression (each one printed with its JSON
path, baseline and fresh values).  Regenerating the committed baselines is
``REPRO_BENCH_OUT=experiments/bench python -m benchmarks.run`` under the
CI environment (see .github/workflows/ci.yml bench-smoke).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_SECONDS_HINTS = ("wall", "warmup", "latency")
_HIGHER_BETTER_HINTS = ("qps", "speedup")
_SHAPE_HINTS = ("batches", "occupancy", "pad_waste")


def _is_seconds_key(key: str) -> bool:
    k = key.lower()
    return (k.endswith("_s") or "_s_" in k
            or any(h in k for h in _SECONDS_HINTS))


def _is_higher_better_key(key: str) -> bool:
    k = key.lower()
    return any(h in k for h in _HIGHER_BETTER_HINTS)


def _is_shape_key(key: str) -> bool:
    k = key.lower()
    return any(h in k for h in _SHAPE_HINTS)


class Report:
    def __init__(self):
        self.errors: list[str] = []
        self.skipped: list[str] = []
        self.notes: list[str] = []

    def error(self, msg: str) -> None:
        self.errors.append(msg)

    def skip(self, msg: str) -> None:
        self.skipped.append(msg)

    def note(self, msg: str) -> None:
        self.notes.append(msg)


def _compare(base, fresh, path: str, key: str, args, rep: Report) -> None:
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            rep.error(f"{path}: baseline is an object, fresh is "
                      f"{type(fresh).__name__}")
            return
        for k in base:
            if k not in fresh:
                rep.error(f"{path}.{k}: key present in baseline, missing "
                          "from fresh record")
            else:
                _compare(base[k], fresh[k], f"{path}.{k}", k, args, rep)
        for k in fresh:
            if k not in base:
                rep.note(f"{path}.{k}: new key (no baseline) — commit "
                         "updated baselines to start gating it")
        return
    if isinstance(base, list):
        if not isinstance(fresh, list):
            rep.error(f"{path}: baseline is a list, fresh is "
                      f"{type(fresh).__name__}")
            return
        if len(base) != len(fresh):
            rep.error(f"{path}: list length {len(base)} -> {len(fresh)}")
            return
        for i, (b, f) in enumerate(zip(base, fresh)):
            _compare(b, f, f"{path}[{i}]", key, args, rep)
        return
    if base is None or fresh is None:
        if base is not fresh:
            rep.error(f"{path}: {base!r} -> {fresh!r}")
        return
    if isinstance(base, bool) or isinstance(fresh, bool) \
            or isinstance(base, str) or isinstance(fresh, str):
        if base != fresh:
            rep.error(f"{path}: {base!r} -> {fresh!r} (exact-match leaf)")
        return
    # numeric leaf
    seconds = _is_seconds_key(key)
    if seconds and not args.strict_seconds:
        rep.skip(f"{path}: wall-clock key ({base} -> {fresh})")
        return
    denom = max(abs(float(base)), 1e-9)
    rel = (float(fresh) - float(base)) / denom      # signed: >0 means grew
    if _is_higher_better_key(key) or (seconds and args.strict_seconds):
        # one-sided perf line: only the BAD direction fails (qps dropping,
        # seconds growing); a large move the other way is worth refreshing
        # the baseline for, but is not a regression
        bad = -rel if _is_higher_better_key(key) else rel
        if bad > args.tol_perf:
            rep.error(f"{path}: {base} -> {fresh} (worse by {bad:.1%} > "
                      f"tolerance {args.tol_perf:.0%})")
        elif -bad > args.tol_perf:
            rep.note(f"{path}: {base} -> {fresh} improved by {-bad:.1%} — "
                     "consider refreshing the committed baseline")
        return
    tol = args.tol_perf if _is_shape_key(key) else args.tol
    if abs(rel) > tol:
        rep.error(f"{path}: {base} -> {fresh} (rel change {abs(rel):.1%} > "
                  f"tolerance {tol:.0%})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when fresh BENCH_*.json records regress "
                    "against the committed baselines")
    ap.add_argument("--baseline", default="experiments/bench",
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--fresh", required=True,
                    help="directory of freshly generated BENCH_*.json")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="relative tolerance for deterministic numerics "
                         "(superstep counts, rf, sizes; default 0.2)")
    ap.add_argument("--tol-perf", type=float, default=0.5,
                    help="relative tolerance for throughput keys (qps; "
                         "default 0.5 — absorbs runner machine variance)")
    ap.add_argument("--strict-seconds", action="store_true",
                    help="also gate wall-clock seconds keys at --tol-perf "
                         "instead of skipping them")
    args = ap.parse_args(argv)

    base_dir = pathlib.Path(args.baseline)
    fresh_dir = pathlib.Path(args.fresh)
    fresh_files = sorted(fresh_dir.glob("BENCH_*.json"))
    if not fresh_files:
        print(f"ERROR: no fresh BENCH_*.json under {fresh_dir}")
        return 1

    rep = Report()
    for f in fresh_files:
        b = base_dir / f.name
        if not b.exists():
            rep.error(f"{f.name}: fresh record has NO committed baseline — "
                      f"run the benchmark with REPRO_BENCH_OUT={base_dir} "
                      "and commit the result")
            continue
        _compare(json.loads(b.read_text()), json.loads(f.read_text()),
                 f.stem, "", args, rep)
    fresh_names = {f.name for f in fresh_files}
    for b in sorted(base_dir.glob("BENCH_*.json")):
        if b.name not in fresh_names:
            rep.note(f"{b.name}: baseline has no fresh counterpart in this "
                     "run — not gated")

    for msg in rep.notes:
        print(f"NOTE      {msg}")
    for msg in rep.skipped:
        print(f"SKIPPED   {msg}")
    for msg in rep.errors:
        print(f"REGRESSED {msg}")
    n_cmp = len(fresh_files)
    if rep.errors:
        print(f"\nbench-regression gate: FAIL — {len(rep.errors)} "
              f"regression(s) across {n_cmp} record(s)")
        return 1
    print(f"\nbench-regression gate: OK — {n_cmp} record(s) within "
          f"tolerance (tol={args.tol:.0%}, tol-perf={args.tol_perf:.0%}, "
          f"{len(rep.skipped)} wall-clock leaves skipped)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Microbench: Pallas kernels (interpret mode) vs their jnp references.

Interpret-mode wall-clock is NOT TPU performance — the purpose here is
(a) proving the kernels run across shapes and (b) giving the jnp-oracle
baseline number the §Perf iterations compare against structurally."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # us


def run() -> list[dict]:
    rows = []
    key = jax.random.key(0)
    # lane_cumsum: DFEP step-1 rank hotspot shape (astroph-scale)
    x = jax.random.randint(key, (393728, 20), 0, 2, dtype=jnp.int32)
    rows.append({"name": "lane_cumsum_2E394k_K20",
                 "kernel_us": round(_time(lambda a: ops.lane_cumsum(a), x), 1),
                 "ref_us": round(_time(lambda a: ref.cumsum_lanes(a), x), 1)})
    # frontier_min: ETSCH aggregation shape
    st = jax.random.uniform(key, (20, 17903))
    mb = jax.random.bernoulli(key, 0.3, (20, 17903))
    rows.append({"name": "frontier_min_K20_V18k",
                 "kernel_us": round(_time(lambda a, b: ops.frontier_min(a, b), st, mb), 1),
                 "ref_us": round(_time(lambda a, b: ref.kreduce_min(a, b), st, mb), 1)})
    # minplus_sweep: local relax
    v, e = 17903, 98304
    src = jax.random.randint(key, (e,), 0, v, dtype=jnp.int32)
    dst = jax.random.randint(jax.random.key(1), (e,), 0, v, dtype=jnp.int32)
    mask = jnp.ones((e,), jnp.bool_)
    dist = jnp.where(jnp.arange(v) == 0, 0.0, jnp.inf).astype(jnp.float32)
    rows.append({"name": "minplus_sweep_V18k_E98k",
                 "kernel_us": round(_time(lambda d: ops.minplus_sweep(
                     d, src, dst, mask), dist), 1),
                 "ref_us": round(_time(lambda d: ref.minplus_relax(
                     d, src, dst, mask), dist), 1)})
    return rows


def main() -> None:
    emit("kernel_bench", run())


if __name__ == "__main__":
    main()

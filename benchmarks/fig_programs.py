"""Program-registry benchmark: registry-derived serving of the registered
program catalogue + warm-start repair vs cold recompute.

Three sweeps, all driven entirely off ``engine.registry`` (no program is
named in the harness — the registration IS the benchmark entry):

  1. **catalogue** — for every registered batchable program with an oracle
     (SSSP, weighted SSSP, BFS, ...), serve a multi-tenant burst through a
     ``GraphServer`` and validate each result against the oracle.  This is
     the extensibility acceptance: weighted SSSP and BFS flow partition →
     engine → serve through the same generic path as the built-ins.

  2. **property channels** — for every registered program declaring
     channel params (label propagation, personalized PageRank), serve a
     multi-tenant burst where most tenants share one feature plane and one
     supplies a different plane: results are oracle-validated per supplied
     plane, and the record reports how many answers the channel-hash cache
     legally shared (``cache_shared``) next to ``distinct_results`` >= 2.

  3. **warm-start repair** — the ROADMAP "incremental SSSP result repair"
     point: query, apply a small insert-only stream patch, query again.
     The warm server repairs from the previous epoch's distances
     (``warm_init`` upper-bound relaxation) while a control server with
     warm-starting disabled recomputes cold on the identical patched
     session.  Reports supersteps and wall-clock for both; acceptance is
     ``warm_supersteps < cold_supersteps``.

Emits ``BENCH_programs.json``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S
from repro.engine.registry import DEFAULT_REGISTRY

from .common import SCALE, emit_json


def _ring_graph(n: int) -> graph.Graph:
    """Low-beta small-world ring: enough diameter that cold SSSP needs
    several supersteps across partition cuts, so repair has room to win."""
    return graph.watts_strogatz(n, 4, 0.02, seed=0)


def _catalogue_sweep(g, k: int, n_queries: int) -> list[dict]:
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    rng = np.random.default_rng(1)
    rows = []
    for entry in DEFAULT_REGISTRY.entries():
        if not entry.batchable or entry.oracle is None:
            continue
        srv = G.GraphServer(E.Engine(plan), g, buckets=(n_queries,),
                            cache_entries=0)
        pname = entry.batch_param.name
        sources = rng.integers(0, g.n_vertices, size=n_queries)
        reqs = [G.QueryRequest(entry.name, tenant=f"t{i % 4}",
                               params={pname: int(s)})
                for i, s in enumerate(sources)]
        srv.serve(reqs)                     # warm the jit cache
        srv.metrics.reset()
        t0 = time.time()
        out = srv.serve([G.QueryRequest(entry.name, tenant=f"t{i % 4}",
                                        params={pname: int(s)})
                         for i, s in enumerate(sources)])
        wall = time.time() - t0
        exact = all(np.allclose(r.value,
                                entry.oracle(g, **r.request.params),
                                atol=entry.oracle_atol, equal_nan=True)
                    for r in out)
        rows.append({"program": entry.name, "n_queries": n_queries,
                     "qps": round(n_queries / max(wall, 1e-9), 2),
                     "supersteps": int(max(r.supersteps for r in out)),
                     "exact_vs_oracle": bool(exact)})
    return rows


def _channel_sweep(g, k: int, n_tenants: int) -> list[dict]:
    """Property-channel serving, driven entirely off the registry: for
    every registered non-batchable program declaring channel params and an
    oracle (labelprop, ppr, ...), serve ``n_tenants`` requests sharing one
    feature plane plus one tenant with a different plane.  Validates the
    channel-hash cache contract operationally — same plane: one dispatch +
    cache sharing; different plane: its own dispatch, never aliased — and
    each result against the oracle on the exact supplied plane."""
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    rng = np.random.default_rng(7)
    rows = []
    for entry in DEFAULT_REGISTRY.entries():
        if not entry.channel_params or entry.oracle is None \
                or entry.batchable:
            continue
        if any(s.channel == "dense" for s in entry.channel_params):
            # dense operands (e.g. gcn_layer's weight matrix) have
            # program-specific row counts a generic sweep can't synthesize;
            # fig_gnn.py exercises those end to end
            continue

        def plane(spec):
            n = g.n_vertices if spec.channel == "vertex" else g.e_pad
            return rng.random((n, spec.features)).astype(np.float32)

        params_a = {s.name: plane(s) for s in entry.channel_params}
        params_b = {s.name: plane(s) for s in entry.channel_params}
        srv = G.GraphServer(E.Engine(plan), g)
        srv.serve([G.QueryRequest(entry.name, params=params_a)])   # warm jit
        srv.metrics.reset()
        t0 = time.time()
        out = srv.serve(
            [G.QueryRequest(entry.name, tenant=f"t{i}", params=params_a)
             for i in range(n_tenants)]
            + [G.QueryRequest(entry.name, tenant="z", params=params_b)])
        wall = time.time() - t0
        exact = all(np.allclose(r.value,
                                entry.oracle(g, **r.request.params),
                                atol=entry.oracle_atol, equal_nan=True)
                    for r in out)
        distinct = len({r.value.tobytes() for r in out})
        rows.append({"program": entry.name, "n_queries": len(out),
                     "qps": round(len(out) / max(wall, 1e-9), 2),
                     "exact_vs_oracle": bool(exact),
                     "cache_shared": int(sum(r.from_cache for r in out)),
                     "distinct_results": int(distinct)})
        srv.close()
    return rows


def _warm_repair_sweep(g, k: int, program: str, n_patches: int) -> dict:
    """Repeated query across small insert-only patches: warm server repairs
    from the previous epoch, the control (warm_entries=0) recomputes."""
    sess = S.StreamSession(g, S.StreamConfig(k=k, chunk_size=64,
                                             drift_threshold=1e9), key=0)
    warm_srv = G.GraphServer.from_session(sess, cache_entries=0)
    cold_srv = G.GraphServer.from_session(sess, cache_entries=0,
                                          warm_entries=0)
    entry = DEFAULT_REGISTRY.get(program)
    pname = entry.batch_param.name
    req = {pname: 0}
    base = warm_srv.serve([G.QueryRequest(program, params=req)])[0]
    cold_srv.serve([G.QueryRequest(program, params=req)])
    rng = np.random.default_rng(2)
    warm_ss, cold_ss, warm_t, cold_t = [], [], [], []
    n_v = g.n_vertices
    for _ in range(n_patches):
        # a small, *local* insert-only patch (short chords on the ring):
        # most distances keep their old value, the repair region is tiny
        a = rng.integers(0, n_v, size=4)
        sess.apply(inserts=np.stack([a, (a + 3) % n_v], 1))
        t0 = time.time()
        rw = warm_srv.serve([G.QueryRequest(program, params=req)])[0]
        warm_t.append(time.time() - t0)
        t0 = time.time()
        rc = cold_srv.serve([G.QueryRequest(program, params=req)])[0]
        cold_t.append(time.time() - t0)
        assert rw.warm_start and not rc.warm_start
        assert np.array_equal(rw.value, rc.value), \
            "warm repair must be bit-identical to the cold recompute"
        warm_ss.append(rw.supersteps)
        cold_ss.append(rc.supersteps)
    warm_srv.close()
    cold_srv.close()
    return {
        "program": program, "n_patches": n_patches,
        "initial_supersteps": int(base.supersteps),
        "warm_supersteps_mean": round(float(np.mean(warm_ss)), 2),
        "cold_supersteps_mean": round(float(np.mean(cold_ss)), 2),
        "warm_supersteps_max": int(max(warm_ss)),
        "cold_supersteps_min": int(min(cold_ss)),
        "warm_wall_mean_s": round(float(np.mean(warm_t)), 4),
        "cold_wall_mean_s": round(float(np.mean(cold_t)), 4),
        "superstep_reduction": round(float(np.mean(cold_ss))
                                     / max(float(np.mean(warm_ss)), 1e-9), 2),
    }


def run(scale: float = SCALE, k: int = 8, n_queries: int = 16,
        n_patches: int = 4) -> dict:
    g = _ring_graph(max(int(4000 * scale), 256))
    catalogue = _catalogue_sweep(g, k, n_queries)
    channels = _channel_sweep(g, k, n_tenants=4)
    repair = [_warm_repair_sweep(_ring_graph(max(int(4000 * scale), 256)),
                                 k, prog, n_patches)
              for prog in ("sssp", "wsssp")]
    return {
        "n_vertices": g.n_vertices, "n_edges": g.n_edges, "k": k,
        "registered_programs": DEFAULT_REGISTRY.names(),
        "catalogue": catalogue,
        "channels": channels,
        "warm_repair": repair,
        # headline acceptance numbers
        "warm_supersteps": repair[0]["warm_supersteps_mean"],
        "cold_supersteps": repair[0]["cold_supersteps_mean"],
    }


def main() -> None:
    emit_json("BENCH_programs", run())


if __name__ == "__main__":
    main()

"""GNN inference benchmark: fused Pallas gSpMM vs the XLA reference paths,
plus the served vector-state programs.

Two sweeps:

  1. **kernel** — for F in {8, 32, 128}, one fused-Pallas ``gspmm``
     dispatch over a ``[K, Vmax, F]`` feature block against two reference
     executions of the same contraction:

       * ``colwise_ref`` — the **XLA reference path**: F scalar-plane
         gather/scatter passes, one per feature column.  This is the only
         execution shape the pre-``StateSpec`` API could express (every
         per-vertex plane was rank-1), so it is the baseline the
         vector-state redesign replaces.  ``speedup_vs_ref`` /
         ``speedup_f128`` gate against it (floor 1.5 in tolerances.json).
       * ``batched_ref`` — rank-3 ``gspmm_ref`` (gather, materialise the
         weighted message stream, scatter segment-sum in one XLA program).
         Diagnostic only: the Pallas kernel runs in interpret mode on CPU
         CI, and interpret-mode wall-clock is not device performance
         (kernel_bench.py states the same caveat) — on CPU, XLA's native
         scatter wins; the fused kernel exists for the lane-tiled TPU
         lowering.

     Parity between all three is asserted at 1e-4.

  2. **served** — ``gcn_layer`` and ``kge_score`` through a live
     ``StreamSession`` + ``GraphServer``: query, apply an insert-only
     stream patch, query again; every answer validated against the dense
     numpy oracle on the exact graph snapshot it was served from.

Emits ``BENCH_gnn.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S
from repro.engine import kernels
from repro.engine.programs import GCN_F_IN
from repro.engine.registry import DEFAULT_REGISTRY

from .common import SAMPLES, SCALE, emit_json

FEATURES = (8, 32, 128)


def _gnn_graph(n: int) -> graph.Graph:
    return graph.watts_strogatz(n, 8, 0.1, seed=0)


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _kernel_sweep(g: graph.Graph, k: int) -> list[dict]:
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k,
                          edge_slack=64, vertex_slack=64)
    weights = plan.edge_w
    n_edges = int(g.n_edges)
    rng = np.random.default_rng(3)
    rows = []
    for f in FEATURES:
        feats = jnp.asarray(rng.normal(size=(g.n_vertices, f))
                            .astype(np.float32))
        local = kernels.gather_vertex_channel(plan, feats)

        fused = jax.jit(lambda x: kernels.gspmm(plan, x, weights, "add"))
        batched_ref = jax.jit(
            lambda x: kernels.gspmm_ref(plan, x, weights, "add"))

        def colwise(x, f=f):
            # the pre-StateSpec shape: one rank-1 pass per feature column
            cols = [kernels.gspmm_ref(plan, x[:, :, c], weights,
                                      "add")[:, :, 0] for c in range(f)]
            return jnp.stack(cols, axis=-1)

        colwise_ref = jax.jit(colwise)

        a = np.asarray(fused(local).block_until_ready())
        b = np.asarray(batched_ref(local).block_until_ready())
        c = np.asarray(colwise_ref(local).block_until_ready())
        finite = np.isfinite(a)
        parity = bool(np.allclose(a[finite], b[finite], atol=1e-4)
                      and np.allclose(a[finite], c[finite], atol=1e-4))

        t_fused = _best_of(lambda: fused(local), SAMPLES)
        t_col = _best_of(lambda: colwise_ref(local), SAMPLES)
        t_bat = _best_of(lambda: batched_ref(local), SAMPLES)
        ef = n_edges * f  # edge-features contracted per dispatch
        rows.append({
            "features": f,
            "fused_qps": round(ef / max(t_fused, 1e-9), 1),
            "colwise_ref_qps": round(ef / max(t_col, 1e-9), 1),
            "batched_ref_qps": round(ef / max(t_bat, 1e-9), 1),
            "speedup_vs_ref": round(t_col / max(t_fused, 1e-9), 2),
            "parity": parity,
        })
    return rows


def _served_sweep(g_n: int, k: int) -> list[dict]:
    """gcn_layer + kge_score served oracle-exact across a stream patch."""
    sess = S.StreamSession(_gnn_graph(g_n),
                           S.StreamConfig(k=k, chunk_size=64,
                                          drift_threshold=1e9), key=0)
    srv = G.GraphServer.from_session(sess, cache_entries=0)
    rng = np.random.default_rng(4)
    rows = []
    for phase in ("initial", "patched"):
        if phase == "patched":
            n_v = sess.graph().n_vertices
            a = rng.integers(0, n_v, size=8)
            sess.apply(inserts=np.stack([a, (a + 5) % n_v], 1))
        g = sess.graph()
        for name in ("gcn_layer", "kge_score"):
            entry = DEFAULT_REGISTRY.get(name)
            params = {}
            for spec in entry.channel_params:
                if spec.channel == "vertex":
                    n = g.n_vertices
                elif spec.channel == "edge":
                    n = g.e_pad
                else:  # dense: the gcn weight matrix
                    n = GCN_F_IN
                params[spec.name] = rng.random((n, spec.features)) \
                    .astype(np.float32)
            t0 = time.perf_counter()
            out = srv.serve([G.QueryRequest(name, tenant=f"t{i}",
                                            params=params)
                             for i in range(4)])
            wall = time.perf_counter() - t0
            exact = all(np.allclose(r.value, entry.oracle(g, **params),
                                    atol=entry.oracle_atol)
                        for r in out)
            rows.append({"program": name, "phase": phase,
                         "n_queries": len(out),
                         "qps": round(len(out) / max(wall, 1e-9), 2),
                         "exact_vs_oracle": bool(exact)})
    srv.close()
    return rows


def run(scale: float = SCALE, k: int = 8) -> dict:
    g = _gnn_graph(max(int(64000 * scale), 2048))
    sweep = _kernel_sweep(g, k)
    served = _served_sweep(max(int(16000 * scale), 512), k)
    f128 = next(r for r in sweep if r["features"] == 128)
    return {
        "n_vertices": g.n_vertices, "n_edges": g.n_edges, "k": k,
        "sweep": sweep,
        "served": served,
        # headline acceptance: fused Pallas vs the column-at-a-time XLA
        # reference path at F=128 (floor 1.5 in tolerances.json)
        "speedup_f128": f128["speedup_vs_ref"],
        "all_parity": bool(all(r["parity"] for r in sweep)),
        "all_served_exact": bool(all(r["exact_vs_oracle"] for r in served)),
    }


def main() -> None:
    emit_json("BENCH_gnn", run())


if __name__ == "__main__":
    main()

"""Benchmark runner: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig9] [--fast]

Emits ``name,us_per_call,derived`` CSVs under experiments/bench/ and prints
each table. ``--fast`` shrinks scales/samples for a quick pass.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,fig7,fig8,fig9,fig10,"
                         "stream,serve,programs,kernels")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.fast:
        os.environ.setdefault("REPRO_BENCH_SCALE", "0.05")
        os.environ.setdefault("REPRO_BENCH_SAMPLES", "2")

    # imports AFTER env so common.py picks the scales up
    from . import (fig5_k_sweep, fig6_diameter, fig7_comparison,
                   fig8_scalability, fig9_sssp, fig10_engine, fig_programs,
                   fig_serve, fig_stream, kernel_bench)

    all_benches = {
        "fig5": fig5_k_sweep.main,
        "fig6": fig6_diameter.main,
        "fig7": fig7_comparison.main,
        "fig8": fig8_scalability.main,
        "fig9": fig9_sssp.main,
        "fig10": fig10_engine.main,
        "stream": fig_stream.main,
        "serve": fig_serve.main,
        "programs": fig_programs.main,
        "kernels": kernel_bench.main,
    }
    only = args.only.split(",") if args.only else list(all_benches)
    unknown = sorted(set(only) - set(all_benches))
    if unknown:
        ap.error(f"unknown benchmark(s) {','.join(unknown)}; "
                 f"available: {','.join(all_benches)}")
    for name in only:
        t0 = time.time()
        print(f"\n### running {name} ...", flush=True)
        all_benches[name]()
        print(f"### {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

"""Benchmark runner: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig9 | --all] [--fast]

Emits ``name,us_per_call,derived`` CSVs and BENCH_*.json records under
experiments/bench/ and prints each table. ``--fast`` shrinks scales/samples
for a quick pass.

Two guarantees the CI bench gate leans on:

  * **no silent skips** — every ``fig*.py`` / ``kernel_bench.py`` module in
    this package must be registered below; a module on disk that the
    registry does not know is a startup error, so a new figure cannot
    quietly drop out of ``--all``;
  * **non-zero on crash** — each selected benchmark runs even if an earlier
    one crashed, the tracebacks are printed, and the process exits 1 if
    ANY of them failed (previously the first crash aborted the rest and a
    partially-written artifact dir could pass for a finished run).
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,fig7,fig8,fig9,fig10,"
                         "stream,serve,serve_mesh,programs,obs,cost,kernels")
    ap.add_argument("--all", action="store_true",
                    help="run every registered benchmark (the default when "
                         "--only is absent; the two flags are exclusive)")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.only and args.all:
        ap.error("--only and --all are exclusive")
    if args.fast:
        os.environ.setdefault("REPRO_BENCH_SCALE", "0.05")
        os.environ.setdefault("REPRO_BENCH_SAMPLES", "2")

    # imports AFTER env so common.py picks the scales up
    from repro import obs
    from . import (fig5_k_sweep, fig6_diameter, fig7_comparison,
                   fig8_scalability, fig9_sssp, fig10_engine, fig_cost,
                   fig_gnn, fig_obs, fig_programs, fig_serve,
                   fig_serve_mesh, fig_stream, kernel_bench)

    all_benches = {
        "fig5": fig5_k_sweep.main,
        "fig6": fig6_diameter.main,
        "fig7": fig7_comparison.main,
        "fig8": fig8_scalability.main,
        "fig9": fig9_sssp.main,
        "fig10": fig10_engine.main,
        "stream": fig_stream.main,
        "serve": fig_serve.main,
        "serve_mesh": fig_serve_mesh.main,
        "programs": fig_programs.main,
        "obs": fig_obs.main,
        "cost": fig_cost.main,
        "gnn": fig_gnn.main,
        "kernels": kernel_bench.main,
    }
    # registry completeness: every benchmark module on disk must be wired
    # in, or --all silently under-reports (the CI gate assumes coverage)
    here = pathlib.Path(__file__).resolve().parent
    on_disk = {p.stem for p in here.glob("fig*.py")} | {"kernel_bench"}
    registered = {fn.__module__.rsplit(".", 1)[-1]
                  for fn in all_benches.values()}
    unwired = sorted(on_disk - registered)
    if unwired:
        ap.error(f"benchmark module(s) on disk but not registered in "
                 f"benchmarks.run: {', '.join(unwired)}")

    only = args.only.split(",") if args.only else list(all_benches)
    unknown = sorted(set(only) - set(all_benches))
    if unknown:
        ap.error(f"unknown benchmark(s) {','.join(unknown)}; "
                 f"available: {','.join(all_benches)}")
    # the recorder stays ON across the whole run so the summary table can
    # attribute events per figure; lifetime counts survive the per-figure
    # reset()s some benchmarks perform (fig_obs), so deltas stay correct
    rec = obs.get()
    rec.enable()
    # CI postmortems: with REPRO_FLIGHT_DIR set, a crashing figure dumps a
    # flight bundle (ring + snapshot + gauges) before the run moves on —
    # the workflow uploads the directory as an artifact on failure
    from repro.obs import flight as _flight
    from repro.gserve import metrics as _gmetrics
    flight_rec = _flight.from_env()
    failures: list[str] = []
    summary: list[tuple[str, str, float, int, int, float, int]] = []
    for name in only:
        t0 = time.time()
        s0 = rec.stats()
        x0 = _gmetrics.exec_totals()
        print(f"\n### running {name} ...", flush=True)
        try:
            all_benches[name]()
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"### {name} FAILED after {time.time()-t0:.1f}s",
                  flush=True)
            status = "FAILED"
            if flight_rec is not None:
                print(f"### flight bundle: "
                      f"{flight_rec.dump(f'bench.{name}.crash')}",
                      flush=True)
        else:
            print(f"### {name} done in {time.time()-t0:.1f}s", flush=True)
            status = "ok"
        rec.enable()       # re-arm in case the benchmark disabled it
        s1 = rec.stats()
        x1 = _gmetrics.exec_totals()
        summary.append((name, status, time.time() - t0,
                        s1["recorded"] - s0["recorded"],
                        s1["overwritten"] - s0["overwritten"],
                        x1["device_s"] - x0["device_s"],
                        x1["executes"] - x0["executes"]))

    # "overwr" = ring-buffer events silently overwritten during the figure
    # (lifetime monotone counter delta): non-zero means the exported trace
    # is missing that many events — resize the ring or trim the figure.
    # "dev_s"/"execs" = serving device-time spend (summed execute-span
    # durations / dispatch count, gserve.metrics.exec_totals deltas): the
    # attribution denominator the cost ledger reconciles against — zero for
    # figures that never touch the serving path
    print("\n### summary (obs recorder events + serving device time "
          "per figure)")
    print(f"{'figure':<12} {'status':<8} {'wall_s':>8} {'events':>8} "
          f"{'overwr':>8} {'dev_s':>8} {'execs':>6}")
    for name, status, wall, n_events, n_overwr, dev_s, execs in summary:
        print(f"{name:<12} {status:<8} {wall:>8.1f} {n_events:>8} "
              f"{n_overwr:>8} {dev_s:>8.2f} {execs:>6}")
    xt = _gmetrics.exec_totals()
    win = xt["windowed"]
    print(f"### serving device time: {xt['device_s']:.2f}s total over "
          f"{xt['executes']} executes; trailing {win['window_s']:.0f}s: "
          f"{win['n']} spans, p99 {win['p99']:.4f}s")
    from repro.obs.ledger import get_ledger
    lt = get_ledger().totals()
    if lt["requests"]:
        print(f"### global cost ledger: {lt['requests']} requests in "
              f"{lt['series']} series, {lt['device_s']:.2f} device-s, "
              f"{lt['flops']:.3g} flops")
    # static-analysis footer: the bench summary carries the same invariant
    # gate CI enforces, so a local --all run can't look green while the
    # tree has unsuppressed analyzer findings
    try:
        from repro.analysis import run_clean
        src_root = pathlib.Path(__file__).resolve().parents[1] / "src/repro"
        ok = run_clean(str(src_root))
        verdict = "PASS" if ok else \
            "FAIL — run `python -m repro.analysis src/repro` for findings"
        print(f"### static analysis (repro.analysis): {verdict}",
              flush=True)
        if not ok:
            failures.append("analysis")
    except Exception as e:  # pragma: no cover — never mask bench results
        print(f"### static analysis (repro.analysis): ERROR ({e})",
              flush=True)

    if failures:
        print(f"\n### {len(failures)} benchmark(s) crashed: "
              f"{', '.join(failures)}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()

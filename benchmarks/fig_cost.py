"""Cost-weighted vs count-weighted fairness under a skewed workload.

The scenario the usage ledger exists for: tenant "heavy" saturates the
server with few-but-huge queries (distinct-``iters`` pagerank runs, each
its own batch key and its own full engine run), tenant "cheap" issues a
single bucket of SSSP queries per round.  Count-weighted fair-share
admission treats the two as equals and FIFO flush ordering drains the
heavy backlog first, so the cheap tenant's p99 inflates by the whole
heavy queue.  With a ``CostLedger`` wired, the heavy tenant's windowed
device-time share shrinks its admission quota and pushes its queues to
the back of the flush order — the cheap tenant's p99 must stay within
2x its solo baseline while heavy still saturates (ISSUE 8 acceptance).

Three phases over identical seeded workloads, one fresh server each so
per-phase metrics stay attributable: ``solo`` (cheap alone — the
baseline), ``count`` (both tenants, no ledger), ``cost`` (both tenants,
ledger wired).  The cost phase also proves the accounting invariant:
per-tenant ledger device-seconds sum to the server's measured
execute-span total (±1%) and every completed request appears in exactly
one series.

A final alternating on/off sweep (fig_obs methodology: paired order
flips, trimmed-mean ratio, up-to-3 re-measure attempts taking the min)
holds the accounting overhead — profiling cache hits, per-request
sample posts, windowed share reads — under the same absolute 3% qps
ceiling as the recorder, toggled via ``set_ledger`` with the recorder
disabled throughout (the two switches are independent).

Emits ``BENCH_cost.json`` plus the rendered usage artifacts
(``usage_ledger.json`` / ``usage_report.txt``) that CI uploads.
Gated by ``tolerances.json``: ``fairness_gain_p99`` floor,
``cheap_p99_x_solo_cost`` ceiling 2.0, ``overhead_frac_ledger``
ceiling 0.03, ``ledger.shares_sum_ok`` exact-match.
"""
from __future__ import annotations

import os
import statistics
import time

import numpy as np

from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import obs
from repro.gserve.request import AdmissionError
from repro.obs import usage as _usage
from repro.obs.ledger import CostLedger

from .common import OUT_DIR, SAMPLES, SCALE, emit_json

OVERHEAD_BUDGET = 0.03   # must match the tolerances.json ceiling


def _p99(lats: list[float]) -> float:
    return float(np.percentile(np.asarray(lats, np.float64), 99))


def _round(srv, g, rng, cheap_n: int, heavy_n: int, heavy2_n: int,
           iters_base: int) -> tuple[list[float], int, int]:
    """One contention round, worst case for FIFO flush ordering:

      1. heavy queues ``heavy_n`` distinct-``iters`` pagerank requests
         (each its own batch key -> its own full engine run);
      2. cheap's SSSP bucket arrives BEHIND that backlog — under FIFO it
         waits out every heavy run, under cost-weighted ordering its key
         (cheap has the smaller device-time share) flushes first;
      3. heavy piles on a second wave of ``heavy2_n`` runs — with both
         tenants now active, this is where the cost-weighted admission
         quota (count-based quota scaled down by heavy's device-time
         share overdraft) sheds heavy load that plain counting admits.

    Returns (cheap latencies, heavy admitted, heavy rejected)."""
    admitted = rejected = 0

    def submit_heavy(iters: int) -> None:
        nonlocal admitted, rejected
        try:
            srv.submit(G.QueryRequest("pagerank", tenant="heavy",
                                      params={"iters": iters}))
            admitted += 1
        except AdmissionError:
            rejected += 1

    for j in range(heavy_n):
        submit_heavy(iters_base + j)
    ids = [srv.submit(G.QueryRequest(
               "sssp", tenant="cheap",
               params={"source": int(rng.integers(0, g.n_vertices))}))
           for _ in range(cheap_n)]
    for j in range(heavy2_n):
        submit_heavy(iters_base + heavy_n + j)
    srv.drain()
    return ([srv.result(i).latency_s for i in ids], admitted, rejected)


def _phase(srv, g, rounds: int, cheap_n: int, heavy_n: int, heavy2_n: int,
           iters_base: int, seed: int) -> tuple[list[float], int, int]:
    """One warm-up round (jit caches, cost models, ledger shares) then
    ``rounds`` timed rounds with a fresh identically-seeded rng."""
    _round(srv, g, np.random.default_rng(seed), cheap_n, heavy_n,
           heavy2_n, iters_base)
    lats: list[float] = []
    admitted = rejected = 0
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        ls, a, r = _round(srv, g, rng, cheap_n, heavy_n, heavy2_n,
                          iters_base)
        lats += ls
        admitted += a
        rejected += r
    return lats, admitted, rejected


def _qps_pass(srv, g, n_queries: int, seed: int) -> float:
    rng = np.random.default_rng(seed)
    reqs = [G.QueryRequest("sssp", tenant=f"t{i % 4}",
                           params={"source": int(rng.integers(0, g.n_vertices))})
            for i in range(n_queries)]
    t0 = time.perf_counter()
    srv.serve(reqs)
    return n_queries / max(time.perf_counter() - t0, 1e-9)


def _measure_overhead(srv, g, ledger, n_queries: int, pairs: int,
                      seed0: int) -> tuple[float, float, float]:
    """Alternating ledger-on/off sweep -> (overhead, qps_off, qps_on);
    same paired-ratio trimmed-mean estimator as fig_obs."""
    qps = {False: [], True: []}
    ratios = []
    for i in range(pairs):
        order = (False, True) if i % 2 == 0 else (True, False)
        pair = {}
        for enabled in order:
            srv.set_ledger(ledger if enabled else None)
            pair[enabled] = _qps_pass(srv, g, n_queries, seed=seed0 + i)
            qps[enabled].append(pair[enabled])
        ratios.append(pair[True] / pair[False])
    srv.set_ledger(None)
    trim = sorted(ratios)[2:-2] if len(ratios) > 4 else sorted(ratios)
    return (1.0 - statistics.fmean(trim),
            statistics.median(qps[False]), statistics.median(qps[True]))


def run(dataset: str = "email-enron", scale: float = SCALE, k: int = 8,
        rounds: int | None = None, cheap_n: int = 8, heavy_n: int = 10,
        heavy2_n: int = 6, iters_base: int = 24, max_pending: int = 32,
        pairs: int | None = None, n_queries: int = 64) -> dict:
    if rounds is None:
        rounds = max(5, SAMPLES)
    if pairs is None:
        pairs = max(10, SAMPLES)
    g = graph.load_dataset(dataset, scale=scale, seed=0)
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    obs.get().disable()

    # result/warm caches off: identical heavy params recur every round and
    # a cache hit would stop the heavy tenant from saturating anything
    def mk_server(ledger=None, pending=max_pending):
        return G.GraphServer(E.Engine(plan), g, buckets=(cheap_n,),
                             cache_entries=0, warm_entries=0,
                             max_pending=pending, ledger=ledger)

    srv_solo = mk_server()
    lats_solo, _, _ = _phase(srv_solo, g, rounds, cheap_n, heavy_n=0,
                             heavy2_n=0, iters_base=iters_base, seed=11)
    srv_solo.close()

    srv_count = mk_server()
    lats_count, adm_count, rej_count = _phase(
        srv_count, g, rounds, cheap_n, heavy_n, heavy2_n, iters_base,
        seed=11)
    srv_count.close()

    ledger = CostLedger(window_s=30.0)
    srv_cost = mk_server(ledger=ledger)
    lats_cost, adm_cost, rej_cost = _phase(
        srv_cost, g, rounds, cheap_n, heavy_n, heavy2_n, iters_base,
        seed=11)

    # accounting invariant: ledger totals reconcile with the server's
    # measured execute-span time and completed-request count
    tot = ledger.totals()
    dev = srv_cost.metrics.device_time_s
    rel_err = abs(tot["device_s"] - dev) / max(dev, 1e-9)
    shares = ledger.tenant_shares(None)      # lifetime, not windowed
    snap = ledger.snapshot()
    utils = {t: a["utilization"] for t, a in snap["tenants"].items()}
    srv_cost.close()

    os.makedirs(OUT_DIR, exist_ok=True)
    ledger_path = os.path.join(OUT_DIR, "usage_ledger.json")
    report_path = os.path.join(OUT_DIR, "usage_report.txt")
    ledger.dump(ledger_path)
    with open(report_path, "w") as f:
        f.write(_usage.render(snap) + "\n")
    print(_usage.render(snap))

    # accounting overhead: same alternating methodology as fig_obs, with
    # the ledger (not the recorder) as the toggled switch
    srv_ov = mk_server(pending=1024)   # admission out of the timed path
    ov_ledger = CostLedger(window_s=30.0)
    for warm_ledger in (ov_ledger, None):    # warm jit + cost models
        srv_ov.set_ledger(warm_ledger)
        _qps_pass(srv_ov, g, n_queries, seed=99)
    overheads = []
    overhead = qps_off = qps_on = None
    for attempt in range(3):
        overhead, qps_off, qps_on = _measure_overhead(
            srv_ov, g, ov_ledger, n_queries, pairs,
            seed0=100 + 1000 * attempt)
        overheads.append(overhead)
        if overhead <= 0.5 * OVERHEAD_BUDGET:
            break
    overhead = min(overheads)
    srv_ov.close()

    p99_solo, p99_count, p99_cost = (_p99(lats_solo), _p99(lats_count),
                                     _p99(lats_cost))
    return {
        "dataset": dataset, "scale": scale, "k": k,
        "n_vertices": g.n_vertices, "n_edges": g.n_edges,
        "rounds": rounds, "cheap_per_round": cheap_n,
        "heavy_per_round": heavy_n, "heavy2_per_round": heavy2_n,
        "iters_base": iters_base,
        "max_pending": max_pending,
        "p99_cheap_solo_s": round(p99_solo, 6),
        "p99_cheap_count_s": round(p99_count, 6),
        "p99_cheap_cost_s": round(p99_cost, 6),
        # the two gated fairness lines: cost-weighted must beat (or match)
        # count-weighted, and must hold the cheap tenant near its solo p99
        "fairness_gain_p99": round(p99_count / max(p99_cost, 1e-9), 3),
        "cheap_p99_x_solo_cost": round(p99_cost / max(p99_solo, 1e-9), 3),
        "cheap_p99_x_solo_count": round(p99_count / max(p99_solo, 1e-9), 3),
        "heavy_admitted_count": adm_count,
        "heavy_rejected_count": rej_count,
        "heavy_admitted_cost": adm_cost,
        "heavy_rejected_cost": rej_cost,
        "ledger": {
            "device_time_rel_err": round(rel_err, 6),
            "shares_sum_ok": bool(rel_err <= 0.01),
            "requests_reconciled": bool(
                tot["requests"] == srv_cost.metrics.n_completed),
            "series": tot["series"],
            "requests": tot["requests"],
            "dispatched": tot["dispatched"],
            "cached": tot["cached"],
            "share_heavy": round(shares.get("heavy", 0.0), 4),
            "share_cheap": round(shares.get("cheap", 0.0), 4),
            "utilization_heavy": round(utils.get("heavy", 0.0), 4),
            "utilization_cheap": round(utils.get("cheap", 0.0), 4),
        },
        "qps_ledger_off": round(qps_off, 2),
        "qps_ledger_on": round(qps_on, 2),
        "overhead_frac_ledger": round(overhead, 4),
        "overhead_sweeps_ledger": [round(o, 4) for o in overheads],
        "usage_ledger": os.path.basename(ledger_path),
        "usage_report": os.path.basename(report_path),
    }


def main() -> None:
    emit_json("BENCH_cost", run())


if __name__ == "__main__":
    main()

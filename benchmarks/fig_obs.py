"""Observability overhead benchmark: recorder enabled vs disabled serving.

The ``repro.obs`` recorder promises a no-op fast path — every recording
method starts with one ``enabled`` branch, and hot call sites guard their
keyword-argument building behind ``rec.enabled`` — so leaving the
instrumentation compiled into the serving path must cost (almost) nothing
when tracing is off, and only a small, bounded fraction when it is on.
This benchmark holds that contract: it replays the fig_serve workload
(a burst of multi-tenant SSSP queries through a micro-batched
``GraphServer``) in *alternating* disabled/enabled passes and compares
median qps.  Alternation (off,on / on,off per pair) cancels drift from
jit-cache warming and the warm-start store, which otherwise favour
whichever mode runs second.

The same sweep runs a second time against a server constructed with a
live SLO ``Monitor`` (wildcard burn-rate policy + gauge watch): the
monitor feed rides the same ``rec.enabled`` master switch, so the
alternating pairs measure the *full* monitoring-enabled overhead —
per-request windowed-histogram records plus rate-limited policy
evaluation — under the same 3% ceiling (``overhead_frac_monitored``).

A final enabled pass (after a recorder reset, so the ring holds exactly
one burst) is exported to ``trace_obs.jsonl`` and Perfetto-loadable
``trace_obs_chrome.json`` next to the BENCH record — CI uploads both as
artifacts, so every green run carries a browsable trace of a served burst.

Emits ``BENCH_obs.json``.  Acceptance (ISSUE 6): ``overhead_frac`` < 3%,
gated as a ``ceiling`` entry in ``experiments/bench/tolerances.json``.
"""
from __future__ import annotations

import os
import statistics
import time

import numpy as np

from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import obs

from .common import OUT_DIR, SAMPLES, SCALE, emit_json


def _queries(rng, n_v: int, n: int) -> list:
    return [G.QueryRequest("sssp", tenant=f"t{i % 4}",
                           params={"source": int(rng.integers(0, n_v))})
            for i in range(n)]


def _pass(srv, g, n_queries: int, seed: int) -> float:
    """Serve one burst; returns qps (monotonic clock)."""
    reqs = _queries(np.random.default_rng(seed), g.n_vertices, n_queries)
    t0 = time.perf_counter()
    srv.serve(reqs)
    return n_queries / max(time.perf_counter() - t0, 1e-9)


OVERHEAD_BUDGET = 0.03   # must match the tolerances.json ceiling


def _measure(srv, g, rec, n_queries: int, pairs: int,
             seed0: int) -> tuple[float, float, float]:
    """One alternating enabled/disabled sweep -> (overhead, qps_off, qps_on).

    Each pair serves identical queries back-to-back in alternating order
    (off,on / on,off), so its on/off qps ratio cancels slow process drift
    and neither mode systematically runs on a warmer process.  The
    overhead estimate is a trimmed mean of the paired ratios: dropping the
    two extreme ratios per side sheds one-off stalls AND one-off
    lucky-fast passes (both happen on a loaded machine), and averaging
    the survivors beats a bare median's sqrt(N) noise floor."""
    qps = {False: [], True: []}
    ratios = []
    for i in range(pairs):
        order = (False, True) if i % 2 == 0 else (True, False)
        pair = {}
        for enabled in order:
            (rec.enable if enabled else rec.disable)()
            pair[enabled] = _pass(srv, g, n_queries, seed=seed0 + i)
            qps[enabled].append(pair[enabled])
        ratios.append(pair[True] / pair[False])
    rec.disable()
    trim = sorted(ratios)[2:-2] if len(ratios) > 4 else sorted(ratios)
    return (1.0 - statistics.fmean(trim),
            statistics.median(qps[False]), statistics.median(qps[True]))


def run(dataset: str = "email-enron", scale: float = SCALE, k: int = 8,
        n_queries: int = 96, bucket: int = 8,
        pairs: int | None = None) -> dict:
    if pairs is None:
        pairs = max(12, SAMPLES)
    g = graph.load_dataset(dataset, scale=scale, seed=0)
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    # no result-cache assist: every pass must pay the full serve path, or
    # later passes would answer from cache and the comparison would be noise
    srv = G.GraphServer(E.Engine(plan), g, buckets=(bucket,),
                        cache_entries=0, warm_entries=0)

    rec = obs.get()
    rec.disable()
    # warm the jit cache for the bucket shape outside all timed passes
    _pass(srv, g, n_queries, seed=99)

    # CPU contention (CI runners, shared cores) INFLATES an overhead
    # estimate far more often than it deflates one, so a single suspicious
    # sweep is re-measured and the minimum taken — three independent
    # sweeps all landing above the budget means the overhead is real,
    # one doing so means the machine hiccuped
    overheads = []
    overhead = qps_off = qps_on = None
    for attempt in range(3):
        overhead, qps_off, qps_on = _measure(
            srv, g, rec, n_queries, pairs, seed0=100 + 1000 * attempt)
        overheads.append(overhead)
        if overhead <= 0.8 * OVERHEAD_BUDGET:
            break
    overhead = min(overheads)

    # monitoring-enabled serving: same alternating methodology, server
    # wired to a live Monitor (the feed is guarded by rec.enabled, so the
    # disabled half of each pair is the same baseline as above)
    monitor = obs.Monitor(policies=[obs.SLOPolicy(
        name="bench-slo", latency_objective_s=0.5,
        availability_target=0.99)])
    monitor.watch_gauge(obs.GaugeWatch(gauge="stream.replication_factor",
                                       max_rel_increase=0.5))
    srv_mon = G.GraphServer(E.Engine(plan), g, buckets=(bucket,),
                            cache_entries=0, warm_entries=0,
                            monitor=monitor)
    _pass(srv_mon, g, n_queries, seed=98)        # warm, untimed
    overheads_mon = []
    overhead_mon = qps_mon = None
    for attempt in range(3):
        overhead_mon, _, qps_mon = _measure(
            srv_mon, g, rec, n_queries, pairs, seed0=500 + 1000 * attempt)
        overheads_mon.append(overhead_mon)
        if overhead_mon <= 0.8 * OVERHEAD_BUDGET:
            break
    overhead_mon = min(overheads_mon)
    n_mon_evals = monitor.n_evaluations
    srv_mon.close()
    monitor.close()

    # clean exported trace: exactly one enabled burst in the ring
    rec.reset()
    rec.enable()
    _pass(srv, g, n_queries, seed=7)
    stats = rec.stats()
    names = sorted({e["name"] for e in rec.events()})
    jsonl = os.path.join(OUT_DIR, "trace_obs.jsonl")
    chrome = os.path.join(OUT_DIR, "trace_obs_chrome.json")
    os.makedirs(OUT_DIR, exist_ok=True)
    n_jsonl = obs.export_jsonl(jsonl)
    n_chrome = obs.export_chrome_trace(chrome)
    rec.disable()
    srv.close()

    return {
        "dataset": dataset, "scale": scale, "k": k,
        "n_vertices": g.n_vertices, "n_edges": g.n_edges,
        "n_queries_per_pass": n_queries, "bucket": bucket, "pairs": pairs,
        "qps_disabled": round(qps_off, 2),
        "qps_enabled": round(qps_on, 2),
        "overhead_frac": round(overhead, 4),
        "overhead_sweeps": [round(o, 4) for o in overheads],
        "qps_monitored": round(qps_mon, 2),
        "overhead_frac_monitored": round(overhead_mon, 4),
        "overhead_sweeps_monitored": [round(o, 4) for o in overheads_mon],
        "monitor_evaluations": n_mon_evals,
        "export_pass": {
            "events_recorded": stats["since_reset"],
            "dropped": stats["dropped"],
            "open_spans": stats["open_spans"],
            "event_names": names,
            "jsonl_events": n_jsonl,
            "chrome_events": n_chrome,
        },
        "trace_jsonl": os.path.basename(jsonl),
        "trace_chrome": os.path.basename(chrome),
    }


def main() -> None:
    emit_json("BENCH_obs", run())


if __name__ == "__main__":
    main()

"""Paper Fig. 9: end-to-end SSSP — ETSCH on a DFEP partitioning vs the
vertex-centric (Pregel-style) baseline, as worker count grows.

The paper's y-axis is Hadoop wall-clock; ours is (a) synchronisation
rounds — the quantity ETSCH compresses, machine-independent — and (b)
wall-clock of both implementations on this host."""
from __future__ import annotations

import time

import jax

from repro.core import algorithms as alg
from repro.core import dfep, etsch, graph

from .common import SCALE, emit


def run(ks=(2, 4, 8, 16), dataset="dblp", scale=SCALE) -> list[dict]:
    g = graph.load_dataset(dataset, scale=scale, seed=0)
    slots = dfep.build_slots(g)
    rows = []
    # vertex-centric baseline
    t0 = time.time()
    _, ref_rounds = jax.block_until_ready(alg.reference_sssp(g, 0))
    base_time = time.time() - t0
    for k in ks:
        owner, info = dfep.partition(g, k=k, key=0, slots=slots,
                                     max_rounds=4000, stall_rounds=64)
        part = etsch.compile_partitioning(g, owner, k)
        t0 = time.time()
        res = jax.block_until_ready(alg.etsch_sssp(part, 0))
        etsch_time = time.time() - t0
        rows.append({
            "dataset": dataset, "k": k,
            "etsch_supersteps": int(res.supersteps),
            "vertex_centric_rounds": int(ref_rounds),
            "gain": round(1 - int(res.supersteps) / int(ref_rounds), 4),
            "etsch_wall_s": round(etsch_time, 3),
            "baseline_wall_s": round(base_time, 3),
            "partition_rounds": info["rounds"],
        })
    return rows


def main() -> None:
    emit("fig9_sssp", run())


if __name__ == "__main__":
    main()

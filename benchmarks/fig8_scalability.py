"""Paper Fig. 8: DFEP scalability with worker count.

The paper measures Hadoop wall-clock on EC2 with 2..16 nodes. Here the
distributed (shard_map) DFEP runs with 1/2/4/8 host devices in a
subprocess per point (XLA device count is fixed at process init) and we
report wall-clock per round + the collective schedule. On one physical
core the *speedup* is structural (per-worker work shrinks; the psum
schedule is real), so we report per-round work bytes alongside time."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import SCALE, emit, emit_json

WORKER = textwrap.dedent("""
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
    import jax, jax.numpy as jnp
    from repro.core import dfep, dfep_distributed, graph
    ndev = int(sys.argv[1]); scale = float(sys.argv[2])
    g = graph.load_dataset("dblp", scale=scale, seed=0)
    mesh = jax.make_mesh((ndev,), ("data",))
    cfg = dfep.DfepConfig(k=16, max_rounds=60, stall_rounds=60)  # fixed rounds
    t0 = time.time()
    owner, info = dfep_distributed.run_dfep_sharded(g, cfg, jax.random.key(0), mesh)
    dt = time.time() - t0
    print(json.dumps({"ndev": ndev, "V": g.n_vertices, "E": g.n_edges,
                      "rounds": info["rounds"], "wall_s": round(dt, 2),
                      "edges_per_worker": g.e_pad // ndev}))
""")


def run(devs=(1, 2, 4, 8), scale=SCALE) -> list[dict]:
    rows = []
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    for nd in devs:
        res = subprocess.run([sys.executable, "-c", WORKER, str(nd), str(scale)],
                             env=env, capture_output=True, text=True,
                             timeout=1800)
        line = res.stdout.strip().splitlines()[-1] if res.stdout.strip() else "{}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            rec = {"ndev": nd, "error": res.stderr[-300:]}
        rows.append(rec)
    if rows and "wall_s" in rows[0]:
        base = rows[0]["wall_s"]
        for r in rows:
            if "wall_s" in r:
                r["speedup_vs_1"] = round(base / r["wall_s"], 2)
    return rows


def main() -> None:
    rows = run()
    emit("fig8_scalability", rows)
    # persist the structured record like fig10/stream/serve do
    emit_json("BENCH_scalability", {"scale": SCALE, "rows": rows})


if __name__ == "__main__":
    main()

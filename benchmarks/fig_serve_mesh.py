"""Mesh-backed serving benchmark: the GraphServer over a shard_map engine.

The ROADMAP's open serving point: ``fig_serve`` measures the single-device
path, while the engine has served batched queries over shard_map since
PR 3.  This figure runs the *same* micro-batched serving flow with the
partitions sharded over a forced 8-device host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and reports qps +
latency for the mesh path next to the single-device path on the identical
graph/plan/queries.

The device-count flag must be set before jax is imported, so ``main``
re-executes this module in a subprocess with the flag in the environment
(the same pattern as tests/test_engine_distributed.py); the inner run
emits ``BENCH_serve_mesh.json`` through the shared OUT_DIR machinery.

On a host CPU the 8 "devices" are one physical core time-sliced, so
mesh qps is *not* expected to beat single-device here — the record holds
the collective-bearing serving path to a perf line (it regresses if the
shard_map dispatch stops working or slows down disproportionately) and
documents occupancy/batch shape parity between the two paths.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

_INNER_ENV = "REPRO_SERVE_MESH_INNER"


def _queries(rng, n_v: int, n: int) -> list:
    from repro import gserve as G
    return [G.QueryRequest("sssp", tenant=f"t{i % 8}",
                           params={"source": int(rng.integers(0, n_v))})
            for i in range(n)]


def _serve_point(eng, g, reqs, bucket: int, mode: str) -> dict:
    import numpy as np
    from repro import gserve as G
    srv = G.GraphServer(eng, g, buckets=(bucket,), cache_entries=0)
    t0 = time.time()
    srv.serve(_queries(np.random.default_rng(99), g.n_vertices,
                       min(bucket, len(reqs))))
    warmup_s = time.time() - t0
    srv.metrics.reset()
    t_all = time.time()
    for r in reqs:
        srv.submit(r)
    srv.drain()
    wall = time.time() - t_all
    st = srv.stats()
    srv.close()
    return {"mode": mode, "bucket": bucket, "n_queries": len(reqs),
            "qps": round(len(reqs) / wall, 2),
            "p50_s": st["latency_p50_s"], "p99_s": st["latency_p99_s"],
            "warmup_s": round(warmup_s, 3), "batches": st["batches"],
            "mean_batch_occupancy": st["mean_batch_occupancy"],
            "pad_waste_frac": st["pad_waste_frac"]}


def _inner() -> None:
    import jax
    import numpy as np

    from repro.core import dfep, graph
    from repro import engine as E

    from .common import SCALE, emit_json

    n_dev = len(jax.devices())
    assert n_dev == 8, f"expected the forced 8-device host mesh, got {n_dev}"
    k, n_queries, bucket = 8, 32, 16
    g = graph.load_dataset("email-enron", scale=SCALE, seed=0)
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    mesh = jax.make_mesh((8,), ("parts",))
    # identical query streams (same seed), fresh request ids per server
    reqs_a = _queries(np.random.default_rng(0), g.n_vertices, n_queries)
    reqs_b = _queries(np.random.default_rng(0), g.n_vertices, n_queries)

    rows = [
        _serve_point(E.Engine(plan), g, reqs_a, bucket, "single-device"),
        _serve_point(E.Engine(plan, mesh=mesh), g, reqs_b, bucket,
                     "mesh-8dev"),
    ]
    # the two paths must agree on everything but wall-clock
    assert rows[0]["batches"] == rows[1]["batches"]
    mesh_row = rows[1]
    emit_json("BENCH_serve_mesh", {
        "dataset": "email-enron", "scale": SCALE, "k": k,
        "n_vertices": g.n_vertices, "n_edges": g.n_edges,
        "n_devices": n_dev, "n_queries": n_queries, "bucket": bucket,
        "rows": rows,
        "mesh_qps": mesh_row["qps"],
        "mesh_mean_batch_occupancy": mesh_row["mean_batch_occupancy"],
    })


def main() -> None:
    if os.environ.get(_INNER_ENV) == "1":
        _inner()
        return
    env = dict(os.environ)
    env[_INNER_ENV] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run([sys.executable, "-m", "benchmarks.fig_serve_mesh"],
                         env=env, cwd=root, timeout=1800)
    if res.returncode != 0:
        raise RuntimeError(
            f"mesh serving subprocess failed (exit {res.returncode})")


if __name__ == "__main__":
    main()

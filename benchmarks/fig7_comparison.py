"""Paper Fig. 7: DFEP vs DFEP-C vs JaBeJa (converted to edge partitions) on
the four simulation datasets; random/hash/greedy added as extra baselines."""
from __future__ import annotations

from repro.core import baselines, dfep, graph, metrics

from .common import SAMPLES, SCALE, emit


def run(datasets=("astroph", "email-enron", "usroads", "wordnet"), k=8,
        samples=SAMPLES, scale=SCALE) -> list[dict]:
    rows = []
    for ds in datasets:
        g = graph.load_dataset(ds, scale=scale, seed=0)
        slots = dfep.build_slots(g)
        for s in range(samples):
            runs = {}
            owner, info = dfep.partition(g, k=k, key=s, slots=slots,
                                         max_rounds=4000, stall_rounds=64)
            runs["DFEP"] = (owner, info["rounds"])
            owner, info = dfep.partition(g, k=k, key=s, variant_c=True,
                                         slots=slots, max_rounds=4000,
                                         stall_rounds=64)
            runs["DFEPC"] = (owner, info["rounds"])
            owner, info = baselines.jabeja_partition(g, k, seed=s)
            runs["JaBeJa"] = (owner, info["rounds"])
            runs["random"] = (baselines.random_partition(g, k, seed=s), 1)
            runs["greedy"] = (baselines.greedy_partition(g, k, seed=s), 1)
            for algo, (ow, rounds) in runs.items():
                m = metrics.evaluate(g, ow, k, rounds=rounds)
                rows.append({
                    "dataset": ds, "algo": algo, "sample": s,
                    "rounds": rounds,
                    "largest": round(m.largest_norm, 4),
                    "nstdev": round(m.nstdev, 4),
                    "messages": m.messages,
                    "gain": round(m.gain, 4),
                    "connected": round(m.connected_frac, 3),
                })
    return rows


def main() -> None:
    emit("fig7_comparison", run())


if __name__ == "__main__":
    main()

"""Paper Fig. 5: DFEP / DFEP-C behaviour vs number of partitions K
(largest partition, NSTDEV, messages, rounds, gain) on small-world and
road-network graphs."""
from __future__ import annotations

import jax

from repro.core import dfep, graph, metrics

from .common import SAMPLES, SCALE, emit


def run(datasets=("astroph", "usroads"), ks=(2, 4, 8, 16, 20),
        samples=SAMPLES, scale=SCALE) -> list[dict]:
    rows = []
    for ds in datasets:
        g = graph.load_dataset(ds, scale=scale, seed=0)
        slots = dfep.build_slots(g)
        for k in ks:
            for vc in (False, True):
                for s in range(samples):
                    owner, info = dfep.partition(
                        g, k=k, key=s, variant_c=vc, slots=slots,
                        max_rounds=4000, stall_rounds=64)
                    m = metrics.evaluate(g, owner, k, rounds=info["rounds"],
                                         source=0)
                    rows.append({
                        "dataset": ds, "k": k,
                        "algo": "DFEPC" if vc else "DFEP", "sample": s,
                        "rounds": info["rounds"],
                        "largest": round(m.largest_norm, 4),
                        "nstdev": round(m.nstdev, 4),
                        "messages": m.messages,
                        "gain": round(m.gain, 4),
                        "connected": round(m.connected_frac, 3),
                        "finalized": info["finalized"],
                    })
    return rows


def main() -> None:
    emit("fig5_k_sweep", run())


if __name__ == "__main__":
    main()

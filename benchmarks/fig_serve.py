"""Serving subsystem benchmark: micro-batched vs sequential query serving.

Offers a fixed burst of multi-tenant SSSP queries (SSSP only: it is the
batchable kind, so it isolates the micro-batching effect; the shared-run
WCC/PageRank path is covered by tests/test_gserve.py) to:

  * a *sequential* baseline — one synchronous ``Engine.run`` per query, the
    pre-gserve serving story;
  * a ``GraphServer`` with single-bucket configurations of increasing size
    — isolating the micro-batching win (one vmapped superstep loop answers
    the whole bucket; latency ~ the slowest query in the bucket instead of
    the sum).

Each point reports queries/sec and p50/p99 end-to-end latency, warm (the
first pass per bucket shape pays the jit trace and is measured separately
as ``warmup_s``).  A second sweep repeats the bucket=max point with
concurrent ``repro.stream`` update batches interleaved between micro-batch
pumps — serving under mutation, with the double-buffered plan swap and
epoch-keyed cache invalidation on the hot path.

Emits ``BENCH_serve.json``.  Acceptance (ISSUE 3): batched qps at
bucket >= 8 beats the sequential baseline.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import dfep, graph
from repro import engine as E
from repro import gserve as G
from repro import stream as S

from .common import SCALE, emit_json


def _queries(rng, n_v: int, n: int) -> list:
    return [G.QueryRequest("sssp", tenant=f"t{i % 8}",
                           params={"source": int(rng.integers(0, n_v))})
            for i in range(n)]


def _sequential(eng, reqs) -> dict:
    # same XLA segment-reduce path the server uses, for a fair comparison
    lat = []
    t_all = time.time()
    for r in reqs:
        t0 = time.time()
        E.engine_sssp(eng, r.params["source"]).state.block_until_ready()
        lat.append(time.time() - t0)
    wall = time.time() - t_all
    return {"mode": "sequential", "bucket": 1, "n_queries": len(reqs),
            "qps": round(len(reqs) / wall, 2),
            "p50_s": round(G.percentile(lat, 50), 4),
            "p99_s": round(G.percentile(lat, 99), 4)}


def _batched(plan, g, reqs, bucket: int, *, session=None,
             update_batches=0, rng=None) -> dict:
    if session is None:
        srv = G.GraphServer(E.Engine(plan), g, buckets=(bucket,),
                            cache_entries=0)      # no result-cache assist
    else:
        srv = G.GraphServer.from_session(session, buckets=(bucket,),
                                         cache_entries=0)
    # warm the jit cache for this bucket shape once, outside the timing
    t0 = time.time()
    srv.serve(_queries(np.random.default_rng(99), g.n_vertices,
                       min(bucket, len(reqs))))
    warmup_s = time.time() - t0
    srv.metrics.reset()

    t_all = time.time()
    for r in reqs:
        srv.submit(r)
    if update_batches and session is not None:
        # serving under mutation: pump and mutate in alternation
        for _ in range(update_batches):
            srv.pump()
            gu, gv = session.graph().as_numpy()
            kill = rng.choice(len(gu), size=8, replace=False)
            session.apply(
                inserts=rng.integers(0, g.n_vertices, size=(12, 2)),
                deletes=np.stack([gu[kill], gv[kill]], 1))
        srv.drain()
    else:
        srv.drain()
    wall = time.time() - t_all
    st = srv.stats()
    srv.close()
    return {"mode": "batched" if not update_batches else "batched+stream",
            "bucket": bucket, "n_queries": len(reqs),
            "qps": round(len(reqs) / wall, 2),
            "p50_s": st["latency_p50_s"], "p99_s": st["latency_p99_s"],
            "warmup_s": round(warmup_s, 3),
            "batches": st["batches"],
            "mean_batch_occupancy": st["mean_batch_occupancy"],
            "pad_waste_frac": st["pad_waste_frac"],
            "plan_buffer_swaps": st["plan_buffer_swaps"]}


def _timer_flush(plan, g, bucket: int, n_queries: int, gap_s: float,
                 max_wait_s: float | None, rng) -> dict:
    """Low-offered-load point: queries trickle in one at a time (``gap_s``
    apart, far too slow to fill a bucket) while the main thread drains.
    Without a timer the greedy drain dispatches singleton buckets; with
    ``max_wait_s`` partial buckets wait for the deadline to coalesce — the
    timer bounds p99 while raising occupancy."""
    srv = G.GraphServer(E.Engine(plan), g, buckets=(1, bucket),
                        cache_entries=0, max_wait_s=max_wait_s)
    srv.serve(_queries(np.random.default_rng(98), g.n_vertices, bucket))
    srv.serve(_queries(np.random.default_rng(97), g.n_vertices, 1))
    srv.metrics.reset()
    reqs = _queries(rng, g.n_vertices, n_queries)

    def trickle():
        for r in reqs:
            srv.submit(r)
            time.sleep(gap_s)

    t_all = time.time()
    feeder = threading.Thread(target=trickle)
    feeder.start()
    served = 0
    while served < n_queries:
        served += len(srv.drain())
        time.sleep(1e-3)
    wall = time.time() - t_all
    feeder.join()
    st = srv.stats()
    return {"mode": ("batched+timer" if max_wait_s is not None
                     else "batched+trickle"),
            "bucket": bucket, "n_queries": n_queries,
            "max_wait_s": max_wait_s, "offered_gap_s": gap_s,
            "qps": round(n_queries / wall, 2),
            "p50_s": st["latency_p50_s"], "p99_s": st["latency_p99_s"],
            "batches": st["batches"],
            "mean_batch_occupancy": st["mean_batch_occupancy"],
            "pad_waste_frac": st["pad_waste_frac"]}


def run(dataset: str = "email-enron", scale: float = SCALE, k: int = 8,
        n_queries: int = 48, buckets=(1, 4, 8, 16),
        stream_update_batches: int = 4) -> dict:
    g = graph.load_dataset(dataset, scale=scale, seed=0)
    owner, _ = dfep.partition(g, k=k, key=0)
    plan = E.compile_plan(g, np.asarray(owner), k)
    rng = np.random.default_rng(0)
    reqs = _queries(rng, g.n_vertices, n_queries)

    # sequential baseline (warm first)
    eng = E.Engine(plan, use_pallas=False)
    E.engine_sssp(eng, 0).state.block_until_ready()
    rows = [_sequential(eng, reqs)]

    # micro-batched sweep over bucket sizes
    for b in buckets:
        rows.append(_batched(plan, g, reqs, b))

    # serving under concurrent stream updates at the largest bucket
    sess = S.StreamSession(g, S.StreamConfig(k=k, drift_threshold=1e9),
                           key=0, owner=np.asarray(owner))
    rows.append(_batched(plan, g, reqs, max(buckets), session=sess,
                         update_batches=stream_update_batches,
                         rng=np.random.default_rng(5)))

    # timer-based flush at low offered load: trickled submissions with and
    # without a deadline (greedy singleton dispatch vs bounded coalescing)
    for wait in (None, 0.05):
        rows.append(_timer_flush(plan, g, bucket=max(buckets),
                                 n_queries=16, gap_s=0.01, max_wait_s=wait,
                                 rng=np.random.default_rng(11)))

    seq_qps = rows[0]["qps"]
    by_bucket = {r["bucket"]: r["qps"] for r in rows if r["mode"] == "batched"}
    big = max(b for b in by_bucket if b >= 8) if any(
        b >= 8 for b in by_bucket) else max(by_bucket)
    return {
        "dataset": dataset, "scale": scale, "k": k,
        "n_vertices": g.n_vertices, "n_edges": g.n_edges,
        "n_queries": n_queries,
        "rows": rows,
        "sequential_qps": seq_qps,
        "batched_qps_at_largest": by_bucket[big],
        "speedup_batched_vs_sequential": round(by_bucket[big]
                                               / max(seq_qps, 1e-9), 2),
    }


def main() -> None:
    emit_json("BENCH_serve", run())


if __name__ == "__main__":
    main()

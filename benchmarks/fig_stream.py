"""Streaming subsystem benchmark: incremental maintenance vs from-scratch.

Drives a ``StreamSession`` through a sequence of insertion+deletion batches
(~a few % of |E| each) and reports, per batch wave:

  * ingest throughput (edge updates applied per second, end-to-end:
    slot ingest + HDRF assignment + plan patch),
  * re-auction frequency and region sizes (drift-triggered),
  * replication-factor drift of incremental maintenance vs a full DFEP
    re-run on the final mutated graph,
  * the plan-patch vs full-recompile wall-clock gap, including the first
    post-update query: the patched plan answers warm (jit cache hit) while
    a recompiled plan pays the retrace — the streaming subsystem's reason
    to exist, in seconds,
  * a bursty-workload head-to-head of the two compaction policies
    (``bursty`` sub-record): identical burst/idle sequences driven through
    a reactive session (compacts only when forced, mid-burst) and an
    adaptive one (telemetry-driven idle compaction + slack sizing).  The
    gated numbers are per-burst apply-latency p99 and the forced-recompile
    count inside the timed phase — the adaptive policy's job is to push
    both down by paying the compactions in the idle gaps.

Emits ``BENCH_stream.json``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import dfep, graph
from repro import engine as E
from repro import stream as S
from repro.engine import runtime

from .common import SCALE, emit_json


def run(dataset: str = "email-enron", scale: float = SCALE, k: int = 8,
        n_batches: int = 4, batch_frac: float = 0.04,
        drift_threshold: float = 0.05) -> dict:
    g = graph.load_dataset(dataset, scale=scale, seed=0)
    rng = np.random.default_rng(0)
    sess = S.StreamSession(g, S.StreamConfig(
        k=k, chunk_size=256, drift_threshold=drift_threshold), key=0)

    # warm the engine's jit cache once
    jax.block_until_ready(E.engine_sssp(sess.engine, 0).state)

    waves = []
    for b in range(n_batches):
        gu, gv = sess.graph().as_numpy()
        n_mut = max(1, int(batch_frac * len(gu)))
        idx = rng.choice(len(gu), size=n_mut, replace=False)
        dels = np.stack([gu[idx], gv[idx]], 1)
        ins = rng.integers(0, g.n_vertices, size=(n_mut, 2))

        t0 = time.time()
        stats = sess.apply(inserts=ins, deletes=dels)
        apply_s = time.time() - t0

        traced_before = runtime.TRACE_COUNTER["run_loop"]
        t0 = time.time()
        jax.block_until_ready(E.engine_sssp(sess.engine, 0).state)
        query_after_patch_s = time.time() - t0

        waves.append({
            "batch": b,
            "updates": int(2 * n_mut),
            "updates_per_s": round(2 * n_mut / max(apply_s, 1e-9), 1),
            "apply_s": round(apply_s, 4),
            "rf": round(stats["rf"], 4),
            "reauction": stats["reauction"],
            "recompiles": stats["recompiles"],
            "query_after_patch_s": round(query_after_patch_s, 4),
            "query_retraced": runtime.TRACE_COUNTER["run_loop"]
                              > traced_before,
        })

    # plan-patch vs full-recompile wall-clock on one more batch ------------
    gu, gv = sess.graph().as_numpy()
    n_mut = max(1, int(batch_frac * len(gu)))
    idx = rng.choice(len(gu), size=n_mut, replace=False)
    live = np.flatnonzero(np.asarray(sess.graph().edge_mask))
    changes = [S.EdgeChange(int(gu[i]), int(gv[i]),
                            int(sess.owner[live[i]]), -1) for i in idx]
    t0 = time.time()
    patched = S.patch_plan(sess.plan, changes)
    patch_s = time.time() - t0
    t0 = time.time()
    recompiled = E.compile_plan(sess.graph(), sess.owner, k,
                                epoch=sess.epoch + 1)
    recompile_s = time.time() - t0

    # first query on each: the patched plan hits the warm jit cache, the
    # recompiled plan (new epoch => new treedef) must retrace
    t0 = time.time()
    jax.block_until_ready(E.engine_sssp(sess.engine.with_plan(patched),
                                        0).state)
    query_patched_s = time.time() - t0
    t0 = time.time()
    jax.block_until_ready(E.engine_sssp(sess.engine.with_plan(recompiled),
                                        0).state)
    query_recompiled_s = time.time() - t0

    # incremental vs full re-run on the final mutated graph ----------------
    g_final = sess.graph()
    t0 = time.time()
    owner_full, info_full = dfep.partition(g_final, k=k, key=1)
    full_dfep_s = time.time() - t0
    rf_full = E.compile_plan(g_final, np.asarray(owner_full),
                             k).replication_factor()
    rf_inc = sess.replication_factor()

    return {
        "dataset": dataset, "scale": scale, "k": k,
        "n_vertices": g.n_vertices, "n_edges_initial": g.n_edges,
        "n_edges_final": g_final.n_edges,
        "batch_frac": batch_frac,
        "waves": waves,
        "total_ingested": sess.n_ingested,
        "patches": sess.n_patches,
        "recompiles": sess.n_recompiles,
        "reauctions": sess.n_reauctions,
        "rf_incremental": round(rf_inc, 4),
        "rf_full_rerun": round(rf_full, 4),
        "rf_drift_vs_full": round(rf_inc / rf_full - 1.0, 4),
        "full_dfep_rerun_s": round(full_dfep_s, 3),
        "plan_patch_s": round(patch_s, 4),
        "plan_recompile_s": round(recompile_s, 4),
        "query_after_patch_s": round(query_patched_s, 4),
        "query_after_recompile_s": round(query_recompiled_s, 4),
    }


def _run_bursty_policy(policy, dataset: str, scale: float, k: int,
                       n_bursts: int, burst_frac: float) -> dict:
    """One policy through the scripted burst/idle sequence: a couple of
    untimed warmup bursts (telemetry + caches for both policies alike),
    then ``n_bursts`` timed bursts with an ``idle_tick()`` gap after each.
    The workload is seeded per policy, so both see identical edges."""
    g = graph.load_dataset(dataset, scale=scale, seed=0)
    rng = np.random.default_rng(11)
    # drift_threshold high: no re-auctions — the head-to-head isolates
    # compaction scheduling, and both counters stay deterministic
    sess = S.StreamSession(g, S.StreamConfig(
        k=k, chunk_size=64, drift_threshold=10.0), key=0, policy=policy)
    burst = max(64, int(burst_frac * g.n_edges))

    def burst_edges() -> np.ndarray:
        e = rng.integers(0, g.n_vertices, size=(burst, 2))
        return e[e[:, 0] != e[:, 1]]

    for _ in range(2):                       # warmup: untimed
        sess.apply(inserts=burst_edges())
        sess.idle_tick()
    forced0 = sess.n_forced_recompiles

    lat = []
    for _ in range(n_bursts):
        t0 = time.time()
        sess.apply(inserts=burst_edges())
        lat.append(time.time() - t0)
        sess.idle_tick()                     # the idle gap, untimed
    lat.sort()
    return {
        "apply_p50_s": round(lat[len(lat) // 2], 4),
        "apply_p99_s": round(lat[min(len(lat) - 1,
                                     int(0.99 * len(lat)))], 4),
        "forced_recompiles": sess.n_forced_recompiles - forced0,
        "idle_compactions": sess.n_idle_compactions,
        "recompiles_total": sess.n_recompiles,
    }


def run_bursty(dataset: str = "email-enron", scale: float = SCALE,
               k: int = 8, n_bursts: int = 8,
               burst_frac: float = 0.08) -> dict:
    reactive = _run_bursty_policy(S.ReactiveCompactionPolicy(), dataset,
                                  scale, k, n_bursts, burst_frac)
    adaptive = _run_bursty_policy(S.AdaptiveCompactionPolicy(), dataset,
                                  scale, k, n_bursts, burst_frac)
    return {
        "n_bursts": n_bursts, "burst_frac": burst_frac,
        "reactive": reactive, "adaptive": adaptive,
        # gated: >= 1.0 means adaptive is no slower at the tail; the real
        # win shows when reactive pays a mid-burst recompile and adaptive
        # already compacted in the gap
        "p99_speedup_adaptive": round(
            reactive["apply_p99_s"] / max(adaptive["apply_p99_s"], 1e-9),
            3),
        "forced_recompiles_reactive": reactive["forced_recompiles"],
        "forced_recompiles_adaptive": adaptive["forced_recompiles"],
    }


def main() -> None:
    out = run()
    out["bursty"] = run_bursty()
    emit_json("BENCH_stream", out)


if __name__ == "__main__":
    main()

"""Engine benchmark (extends the paper's Fig. 9 "gain" story): SSSP executed
by the partitioned engine on DFEP partitions vs the whole-graph
vertex-centric baseline.

Reported per K: synchronisation rounds (supersteps vs vertex-centric
rounds — the machine-independent gain), the measured per-superstep replica
exchange volume (= the paper's MESSAGES), and wall-clock on this host for
(a) the engine superstep loop, (b) the batched multi-source serving path,
(c) the whole-graph baseline.  Emits ``BENCH_engine.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import dfep, graph
from repro import engine as E

from .common import SCALE, emit_json


def _timed(fn):
    """(out, first_call_s, warm_s): first call pays trace+compile (and, in
    interpret mode, kernel interpretation); the second is the steady-state
    serving latency.  Reporting them separately keeps jit time out of the
    perf trajectory (BENCH_engine.json used to conflate them)."""
    t0 = time.time()
    fn()
    first = time.time() - t0
    t0 = time.time()
    out = fn()
    return out, first, time.time() - t0


def run(ks=(2, 4, 8, 16), dataset="dblp", scale=SCALE, n_sources=8) -> dict:
    g = graph.load_dataset(dataset, scale=scale, seed=0)
    slots = dfep.build_slots(g)
    sources = jnp.arange(n_sources, dtype=jnp.int32)

    (ref, ref_rounds), base_first, base_wall = _timed(
        lambda: jax.block_until_ready(alg.reference_sssp(g, 0)))
    points = []
    for k in ks:
        owner, info = dfep.partition(g, k=k, key=0, slots=slots,
                                     max_rounds=4000, stall_rounds=64)
        plan = E.compile_plan_cached(g, np.asarray(owner), k)
        eng = E.Engine(plan)

        def run_engine():
            r = E.engine_sssp(eng, 0)
            jax.block_until_ready(r.state)
            return r

        r, engine_first, engine_wall = _timed(run_engine)
        assert np.array_equal(np.asarray(r.state), np.asarray(ref)), \
            "engine SSSP diverged from the oracle"
        _, batch_first, batch_wall = _timed(lambda: jax.block_until_ready(
            E.multi_source_sssp(eng, sources).state))
        points.append({
            "k": k,
            "supersteps": int(r.supersteps),
            "vertex_centric_rounds": int(ref_rounds),
            "gain": round(1 - int(r.supersteps) / int(ref_rounds), 4),
            "exchange_per_superstep": r.exchange_per_superstep,
            "total_exchanged": r.total_exchanged,
            "replication_factor": round(plan.replication_factor(), 4),
            "partition_rounds": info["rounds"],
            "engine_first_call_s": round(engine_first, 3),
            "engine_warm_s": round(engine_wall, 3),
            "batched_first_call_s": round(batch_first, 3),
            "batched_warm_s_per_source": round(batch_wall / n_sources, 4),
            "baseline_first_call_s": round(base_first, 3),
            "baseline_warm_s": round(base_wall, 3),
        })
    return {
        "dataset": dataset, "scale": scale,
        "n_vertices": g.n_vertices, "n_edges": g.n_edges,
        "n_sources_batched": n_sources,
        "points": points,
    }


def main() -> None:
    emit_json("BENCH_engine", run())


if __name__ == "__main__":
    main()

"""Declarative program registry — one source of truth from ``EdgeProgram``
to ``GraphServer``.

The paper's framework claim is that an edge-partitioned runtime is
"flexible enough to be applied to several different graph problems"
(§III).  Before this module the serving stack hardwired exactly three:
the query layer duplicated the program list, carried per-kind request
fields and branched on kind strings in its scheduler and server.  Now a
program registers **once** with a declarative ``ParamSpec`` schema and
everything downstream is *derived*:

  * ``gserve.QueryRequest(kind, params={...})`` — validation, dtype
    coercion and default normalisation (so e.g. ``iters=None`` and the
    default 30 are the *same* query identity);
  * scheduler ``batch_key`` — which requests may share one engine
    dispatch (the single ``batchable`` param carries the micro-batch
    axis; all other params must agree);
  * epoch-cache ``cache_key`` — the identity of an answer within one
    graph snapshot;
  * server dispatch — batch-axis name/dtype, the superstep-count param
    (``role="supersteps"``), and derived per-snapshot ``resources``
    (e.g. PageRank's degree vector) all come from the entry;
  * tests and benchmarks — ``oracle`` names the whole-graph reference
    the program must reproduce (``oracle_atol`` its tolerance).

Registering a new program therefore makes it servable end-to-end with
zero serving-layer edits — see "Registering your own program" in
src/repro/engine/README.md, with weighted SSSP as the worked example.
All misuse raises the typed errors in ``engine.errors``.

**Property channels** (``role="channel"``): a program may declare named
external feature planes — per-vertex ``[V, F]`` or per-edge ``[E_pad, F]``
in graph edge-slot order — supplied at query time as arrays (or bound once
per epoch via ``bind_channel``).  Values are wrapped in content-addressed
``ChannelValue``s whose sha256 digest folds into the derived batch/cache
keys, so feature-dependent results never alias across tenants; at dispatch
``ProgramEntry.channel_args`` validates each plane against the concrete
plan and the program's ``prepare`` gathers it to partition-local padded
buffers (``engine.kernels.gather_vertex_channel`` /
``gather_edge_channel``).  Label propagation over external labels and
personalized PageRank register this way (engine/programs.py).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import numbers
import threading
from typing import Any, Callable, Mapping

import jax
import numpy as np

from .. import obs as _obs
from .errors import (BatchAxisError, ChannelError, DuplicateProgramError,
                     ParamTypeError, RegistryError, UnknownParamError,
                     UnknownProgramError)
from .state import SCALAR, StateSpec

_REQUIRED = object()        # sentinel: ParamSpec without a default
_DTYPES = (int, float)
_ROLES = ("ctx", "supersteps", "channel")
_CHANNELS = ("vertex", "edge", "dense")
_CHANNEL_SHAPES = {"vertex": "[V, F]", "edge": "[E_pad, F]",
                   "dense": "[R, F]"}


class ChannelValue:
    """One immutable, content-addressed property plane.

    Wraps a frozen float32 array — ``[V, F]`` for vertex channels, or
    ``[E_pad, F]`` in *graph edge-slot order* for edge channels — together
    with a sha256 digest of its contents.  Equality and hashing go through
    the digest, so a ``ChannelValue`` drops straight into the registry's
    derived ``batch_key``/``cache_key`` tuples: two tenants submitting
    byte-identical feature planes coalesce and share cached results, two
    tenants with *different* features never do — without the serving layer
    knowing channels exist.

    Construct once and reuse across requests ("bound once per epoch"
    client-side): the digest is computed a single time here, never per
    request.  ``np.asarray(cv)`` recovers the plane (oracles use this).
    """

    __slots__ = ("values", "digest")

    def __init__(self, values):
        try:
            # np.array (not asarray): ALWAYS copy, so the frozen plane can
            # never alias the caller's array — a caller mutating its own
            # buffer after construction must not change content the digest
            # already hashed, and freezing must not poison the caller
            v = np.array(values, np.float32)
        except (TypeError, ValueError) as e:
            raise ParamTypeError(
                f"channel values must be numeric arrays coercible to "
                f"float32, got {type(values).__name__}: {e}") from e
        if v.ndim == 1:
            v = v[:, None]
        if v.ndim != 2 or v.shape[0] == 0:
            raise ChannelError(
                f"a channel plane is a non-empty [N] or [N, F] array, got "
                f"shape {tuple(v.shape)}")
        v = np.ascontiguousarray(v)
        v.flags.writeable = False
        self.values = v
        h = hashlib.sha256()
        h.update(np.int64(v.shape[0]).tobytes())
        h.update(np.int64(v.shape[1]).tobytes())
        h.update(v.tobytes())
        self.digest = h.hexdigest()

    @property
    def shape(self) -> tuple:
        return tuple(self.values.shape)

    def __array__(self, dtype=None):
        return self.values if dtype is None else self.values.astype(dtype)

    def __eq__(self, other):
        return isinstance(other, ChannelValue) and self.digest == other.digest

    def __hash__(self):
        return hash(self.digest)

    def __repr__(self):
        return f"ChannelValue(shape={self.shape}, {self.digest[:12]}…)"


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative schema for one per-query parameter.

    dtype      — python scalar type (``int`` or ``float``); values are
                 coerced (numpy scalars accepted, bools rejected for int).
    default    — applied at request construction, so two spellings of the
                 same logical query share batch/cache identity; omit to
                 make the parameter required.
    batchable  — this parameter may carry the micro-batch axis: the
                 scheduler coalesces requests that differ only here into
                 one vmapped dispatch.  At most one per program.
    role       — "ctx": forwarded into the program's traced ``ctx`` via
                 engine kwargs; "supersteps": consumed host-side as the
                 superstep cap (``max_supersteps``); "channel": an external
                 property plane (see below).
    validate   — optional callback run on the coerced value; raise
                 ``ValueError`` to reject.
    channel    — for role="channel": "vertex" (a global ``[V, F]`` plane),
                 "edge" (an ``[E_pad, F]`` plane in graph edge-slot
                 order), or "dense" (a free-shape ``[R, F]`` operand tied
                 to no plan axis — e.g. a ``[F_in, F_out]`` GNN weight
                 matrix).  Values arrive as arrays (or pre-built
                 ``ChannelValue``); they are content-hashed into batch and
                 cache keys and laid out against the partition plan by
                 ``ProgramEntry.channel_args`` at dispatch (dense planes
                 pass through untouched).
    features   — declared feature width F of a channel plane (static, so
                 every query of the program jits to one cache entry).
    """
    name: str
    dtype: type = int
    default: Any = _REQUIRED
    batchable: bool = False
    role: str = "ctx"
    validate: Callable[[Any], None] | None = None
    channel: str | None = None
    features: int = 1

    @property
    def required(self) -> bool:
        return self.default is _REQUIRED

    def coerce(self, program: str, value: Any) -> Any:
        """Validate + coerce one value; raises the typed errors."""
        if self.role == "channel":
            return self._coerce_channel(program, value)
        if isinstance(value, (list, tuple, set)) \
                or getattr(value, "ndim", 0) > 0:
            if self.batchable:
                raise BatchAxisError(
                    f"{program}.{self.name} is batchable, but one request "
                    f"carries one scalar value (got {type(value).__name__}) "
                    "— submit one request per value; the scheduler forms "
                    "the batch axis by coalescing requests")
            raise BatchAxisError(
                f"{program}.{self.name} is not batchable and takes a "
                f"scalar {self.dtype.__name__} (got "
                f"{type(value).__name__}) — a batch axis may only ride on "
                "the program's batchable parameter")
        if self.dtype is int:
            if isinstance(value, bool) \
                    or not isinstance(value, numbers.Integral):
                raise ParamTypeError(
                    f"{program}.{self.name} expects int, got "
                    f"{type(value).__name__} ({value!r})")
            value = int(value)
        else:  # float: accept any real number
            if isinstance(value, bool) or not isinstance(value, numbers.Real):
                raise ParamTypeError(
                    f"{program}.{self.name} expects float, got "
                    f"{type(value).__name__} ({value!r})")
            value = float(value)
        if self.validate is not None:
            self.validate(value)
        return value

    def _coerce_channel(self, program: str, value: Any) -> "ChannelValue":
        if np.isscalar(value) or getattr(value, "ndim", None) == 0:
            raise ChannelError(
                f"{program}.{self.name} is a {self.channel} property "
                f"channel and takes an array plane "
                f"({_CHANNEL_SHAPES[self.channel]}"
                f" with F={self.features}), got a scalar "
                f"{type(value).__name__}")
        cv = value if isinstance(value, ChannelValue) else ChannelValue(value)
        if cv.values.shape[1] != self.features:
            raise ChannelError(
                f"{program}.{self.name} declares {self.features} "
                f"feature(s) per {self.channel}, got a plane of shape "
                f"{cv.shape} — reshape to [N, {self.features}]")
        if self.validate is not None:
            self.validate(cv)
        return cv


class _ResidentPlanes:
    """Device residency for channel planes, keyed by content digest.

    PR 5 left bound planes host-side: every dispatch re-uploaded the same
    ``[V, F]`` array through ``jnp.asarray``.  ``channel_args`` now routes
    planes through this LRU — the first dispatch of a digest pays one
    ``jax.device_put`` (uncommitted, so mesh paths reshard freely) and
    every later dispatch, including across stream patches that leave the
    plane unchanged, reuses the resident buffer (``jnp.asarray`` on a jax
    array is a no-op).  Keyed by (digest, padded rows) because an edge
    plane's zero-padding to ``plan.e_slots`` is part of the uploaded
    bytes.  Size and hit/miss counts surface as the ``channels.*`` obs
    gauges so fig_obs can watch residency.
    """

    def __init__(self, capacity: int = 64):
        self._lock = threading.Lock()
        self._planes: collections.OrderedDict = collections.OrderedDict()
        self._bytes = 0
        self._capacity = capacity
        self.hits = 0
        self.misses = 0

    def get(self, digest: str, vals: np.ndarray):
        key = (digest, vals.shape[0])
        with self._lock:
            arr = self._planes.get(key)
            if arr is not None:
                self._planes.move_to_end(key)
                self.hits += 1
                return arr
            self.misses += 1
            arr = jax.device_put(vals)      # uncommitted: no device pinning
            self._planes[key] = arr
            self._bytes += int(vals.nbytes)
            while len(self._planes) > self._capacity:
                _, old = self._planes.popitem(last=False)
                self._bytes -= int(old.size * old.dtype.itemsize)
            total = self._bytes
        _obs.get().gauge("channels.resident_bytes", total)
        return arr

    def stats(self) -> dict:
        with self._lock:
            return {"resident_bytes": self._bytes,
                    "planes": len(self._planes),
                    "hits": self.hits, "misses": self.misses}


_RESIDENT = _ResidentPlanes()
_obs.get().register_provider("channels", _RESIDENT.stats)


def resident_stats() -> dict:
    """Snapshot of the device-resident channel-plane cache."""
    return _RESIDENT.stats()


@dataclasses.dataclass(frozen=True)
class ProgramEntry:
    """One registered program: the EdgeProgram plus everything the query
    layer derives (validation, batching, caching, dispatch, oracle)."""
    name: str
    program: Any                                # engine.runtime.EdgeProgram
    params: tuple[ParamSpec, ...]
    cacheable: bool = True                      # answers may enter the
                                                #   epoch-keyed result cache
    resources: tuple[tuple[str, Callable], ...] = ()
                                                # engine-kw -> fn(graph),
                                                #   derived per snapshot
    oracle: Callable | None = None              # oracle(graph, **params)
    oracle_atol: float = 0.0                    # 0.0 -> bit-identical
    # live channel bindings: param name -> ChannelValue, set through
    # bind_channel ("bound once per epoch") and consulted by normalize for
    # requests that omit the channel. Mutable contents on a frozen entry —
    # excluded from equality, never part of the schema.
    bindings: dict = dataclasses.field(default_factory=dict, compare=False,
                                       repr=False)

    # -- schema accessors ----------------------------------------------------
    @property
    def batch_param(self) -> ParamSpec | None:
        for p in self.params:
            if p.batchable:
                return p
        return None

    @property
    def batchable(self) -> bool:
        return self.batch_param is not None

    @property
    def channel_params(self) -> tuple[ParamSpec, ...]:
        return tuple(p for p in self.params if p.role == "channel")

    @property
    def state(self) -> StateSpec:
        """The program's declared per-vertex state shape.  Everything
        downstream (engine warm checks, gserve warm store and cold rows,
        result materialisation) derives shapes from this one property;
        programs predating the spec read as scalar."""
        return getattr(self.program, "state", SCALAR)

    def spec(self, name: str) -> ParamSpec:
        for p in self.params:
            if p.name == name:
                return p
        known = sorted(p.name for p in self.params) or ["<none>"]
        raise UnknownParamError(
            f"program {self.name!r} has no parameter {name!r}; "
            f"declared: {', '.join(known)}")

    # -- derivation ----------------------------------------------------------
    def normalize(self, params: Mapping[str, Any] | None) -> dict[str, Any]:
        """Coerce + default-fill a request's params. Normalisation at
        construction makes param identity canonical: omitted-with-default
        and explicitly-passed-default spell the SAME query (batch and
        cache keys are derived from the normalized dict)."""
        params = dict(params or {})
        out: dict[str, Any] = {}
        for spec in self.params:
            if spec.name in params:
                out[spec.name] = spec.coerce(self.name,
                                             params.pop(spec.name))
            elif spec.role == "channel" and spec.name in self.bindings:
                # a bound plane (bind_channel) stands in for the omitted
                # param — already coerced, digest already folded into keys
                out[spec.name] = self.bindings[spec.name]
            elif spec.required:
                raise ParamTypeError(
                    f"program {self.name!r} requires parameter "
                    f"{spec.name!r} ({spec.dtype.__name__}) and it has no "
                    "default — pass it in params={...}")
            else:
                # coerced so a numpy-scalar default lands canonical, same
                # as a caller-passed value (validated at registration too)
                out[spec.name] = spec.coerce(self.name, spec.default)
        if params:
            bad = sorted(params)
            known = sorted(p.name for p in self.params) or ["<none>"]
            raise UnknownParamError(
                f"program {self.name!r} has no parameter(s) "
                f"{', '.join(map(repr, bad))}; declared: {', '.join(known)}")
        return out

    def supersteps_of(self, params: Mapping[str, Any]) -> int | None:
        """The superstep cap for a dispatch (role="supersteps" param)."""
        for p in self.params:
            if p.role == "supersteps":
                return int(params[p.name])
        return None

    def ctx_args(self, params: Mapping[str, Any]) -> dict[str, Any]:
        """Non-batchable role="ctx" params, forwarded as engine kwargs."""
        return {p.name: params[p.name] for p in self.params
                if p.role == "ctx" and not p.batchable}

    # -- property channels ---------------------------------------------------
    def bind_channel(self, name: str, values) -> "ChannelValue":
        """Bind a plane once per epoch: requests that omit the channel
        param then resolve to this value at construction (and inherit its
        content digest in their batch/cache keys). Rebinding replaces the
        plane; a new digest is a new query identity, so results computed
        from the old plane are never served for the new one."""
        spec = self.spec(name)
        if spec.role != "channel":
            raise ChannelError(
                f"{self.name}.{name} has role={spec.role!r}, not 'channel' "
                "— only property channels can be bound")
        cv = spec.coerce(self.name, values)
        self.bindings[name] = cv
        return cv

    def unbind_channel(self, name: str) -> None:
        self.bindings.pop(name, None)

    def validate_channels(self, params: Mapping[str, Any], plan
                          ) -> dict[str, "ChannelValue"]:
        """Pure shape validation of the request's channel planes against a
        concrete plan — no layout work, cheap enough for the serving
        admission path.  A vertex plane must be ``[V, F]``; an edge plane
        ``[n, F]`` in graph edge-slot order with n covering every live
        slot and not exceeding the plan's static slot capacity.  Returns
        the coerced ``ChannelValue`` per param name."""
        out: dict[str, ChannelValue] = {}
        for spec in self.channel_params:
            cv = params[spec.name]
            if not isinstance(cv, ChannelValue):    # direct engine users
                cv = spec.coerce(self.name, cv)
            n = cv.values.shape[0]
            if spec.channel == "dense":
                # free-shape operand: no plan axis to agree with — rank and
                # feature width were already enforced at coercion
                out[spec.name] = cv
                continue
            if spec.channel == "vertex":
                if n != plan.n_vertices:
                    raise ChannelError(
                        f"{self.name}.{spec.name} is a VERTEX channel: "
                        f"expected [{plan.n_vertices}, {spec.features}] "
                        f"(one row per vertex), got {cv.shape} — an edge "
                        f"plane would be [{plan.e_slots}, {spec.features}] "
                        "in graph edge-slot order; did you mix them up?")
            else:
                need, cap = plan.edge_slot_hwm, plan.e_slots
                if n < need or n > cap:
                    raise ChannelError(
                        f"{self.name}.{spec.name} is an EDGE channel: "
                        f"expected [n, {spec.features}] rows in graph "
                        f"edge-slot order with {need} <= n <= {cap} (live "
                        f"slots .. padded capacity), got {cv.shape} — a "
                        f"vertex plane would be [{plan.n_vertices}, "
                        f"{spec.features}]; did you mix them up?")
            out[spec.name] = cv
        return out

    def channel_args(self, params: Mapping[str, Any], plan) -> dict[str, Any]:
        """Lay the request's channel planes out against ``plan`` and return
        them as engine kwargs (the program's ``prepare`` gathers them to
        partition-local ``[K, Vmax, F]`` / ``[K, Emax, F]`` buffers via
        ``kernels.gather_vertex_channel`` / ``gather_edge_channel``).

        Validates via ``validate_channels``; edge planes shorter than the
        plan's static slot capacity (e.g. exactly ``[E, F]`` on a freshly
        built graph) are zero-padded up to it so jit caches stay warm.

        Returned planes are *device-resident*: each (digest, rows) pair is
        uploaded once through the process-wide ``_ResidentPlanes`` LRU and
        reused across dispatches and stream patches.
        """
        out: dict[str, Any] = {}
        for spec, cv in zip(self.channel_params,
                            self.validate_channels(params, plan).values()):
            vals = cv.values
            if spec.channel == "edge" and vals.shape[0] < plan.e_slots:
                pad = np.zeros((plan.e_slots - vals.shape[0],
                                vals.shape[1]), np.float32)
                vals = np.concatenate([vals, pad], axis=0)
            out[spec.name] = _RESIDENT.get(cv.digest, vals)
        return out

    def batch_key_of(self, params: Mapping[str, Any]) -> tuple:
        """Requests sharing a batch key may be answered by one dispatch:
        same program, same value for every non-batchable parameter."""
        return (self.name,) + tuple(
            (p.name, params[p.name]) for p in self.params if not p.batchable)

    def cache_key_of(self, params: Mapping[str, Any]) -> tuple:
        """Identity of the *answer* within one graph snapshot: the program
        plus every normalized parameter (tenant deliberately excluded —
        result sharing across tenants is the point of the cache)."""
        return (self.name,) + tuple(
            (p.name, params[p.name]) for p in self.params)

    def lane_cache_key(self, params: Mapping[str, Any], value: Any) -> tuple:
        """Cache key of one lane of a micro-batch: the shared non-batch
        params with the batch param set to this lane's value."""
        bp = self.batch_param
        if bp is None:
            return self.cache_key_of(params)
        return self.cache_key_of({**params, bp.name: value})


class ProgramRegistry:
    """Name -> ProgramEntry map with validated registration."""

    def __init__(self):
        self._entries: dict[str, ProgramEntry] = {}

    def register(self, name: str, program, params=(), *,
                 cacheable: bool = True,
                 resources: Mapping[str, Callable] | None = None,
                 oracle: Callable | None = None,
                 oracle_atol: float = 0.0) -> ProgramEntry:
        """Register one EdgeProgram under ``name``. Everything the query
        layer needs is derived from this single call."""
        if name in self._entries:
            raise DuplicateProgramError(
                f"program {name!r} is already registered — unregister it "
                "first or register under a new name")
        params = tuple(params)
        seen: set[str] = set()
        batchable = []
        for p in params:
            if not isinstance(p, ParamSpec):
                raise RegistryError(
                    f"program {name!r}: params must be ParamSpec instances, "
                    f"got {type(p).__name__}")
            if p.name in seen:
                raise RegistryError(
                    f"program {name!r}: duplicate parameter {p.name!r}")
            seen.add(p.name)
            if p.dtype not in _DTYPES:
                raise RegistryError(
                    f"program {name!r}: parameter {p.name!r} dtype must be "
                    f"int or float, got {p.dtype!r}")
            if p.role not in _ROLES:
                raise RegistryError(
                    f"program {name!r}: parameter {p.name!r} role must be "
                    f"one of {_ROLES}, got {p.role!r}")
            if p.role == "channel":
                if p.channel not in _CHANNELS:
                    raise RegistryError(
                        f"program {name!r}: channel parameter {p.name!r} "
                        f"must set channel= to one of {_CHANNELS}, got "
                        f"{p.channel!r}")
                if p.dtype is not float:
                    raise RegistryError(
                        f"program {name!r}: channel parameter {p.name!r} "
                        "carries a float32 plane — declare dtype=float")
                if p.batchable:
                    raise RegistryError(
                        f"program {name!r}: channel parameter {p.name!r} "
                        "cannot be batchable — one plane is shared by the "
                        "whole micro-batch (its content hash is part of "
                        "the batch key)")
                if int(p.features) < 1:
                    raise RegistryError(
                        f"program {name!r}: channel parameter {p.name!r} "
                        f"needs features >= 1, got {p.features}")
            elif p.channel is not None:
                raise RegistryError(
                    f"program {name!r}: parameter {p.name!r} sets "
                    f"channel={p.channel!r} but role={p.role!r} — channel "
                    "planes must declare role='channel'")
            if p.batchable:
                batchable.append(p)
                if p.role != "ctx":
                    raise RegistryError(
                        f"program {name!r}: batchable parameter {p.name!r} "
                        "must have role='ctx' (the superstep cap is a "
                        "static jit argument and cannot carry a batch axis)")
            if not p.required:
                # defaults are injected into normalized params verbatim, so
                # they must pass the same dtype/validate gauntlet as caller
                # values — fail HERE, not deep inside a dispatch
                try:
                    p.coerce(name, p.default)
                except RegistryError as e:
                    raise RegistryError(
                        f"program {name!r}: default for parameter "
                        f"{p.name!r} is invalid: {e}") from e
        if len(batchable) > 1:
            names = ", ".join(p.name for p in batchable)
            raise RegistryError(
                f"program {name!r}: at most one batchable parameter is "
                f"supported (the micro-batch axis), got: {names}")
        entry = ProgramEntry(
            name=name, program=program, params=params, cacheable=cacheable,
            resources=tuple(sorted((resources or {}).items())),
            oracle=oracle, oracle_atol=float(oracle_atol))
        self._entries[name] = entry
        return entry

    def unregister(self, name: str) -> None:
        self._entries.pop(name, None)

    def get(self, name: str) -> ProgramEntry:
        entry = self._entries.get(name)
        if entry is None:
            raise UnknownProgramError(
                f"unknown program {name!r}; registered: "
                f"{', '.join(sorted(self._entries)) or '<none>'}")
        return entry

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> list[str]:
        return sorted(self._entries)

    def entries(self) -> list[ProgramEntry]:
        return [self._entries[n] for n in self.names()]


#: The process-wide registry every layer derives from. ``engine.programs``
#: registers the built-ins on import; user programs register through the
#: same public ``register`` call.
DEFAULT_REGISTRY = ProgramRegistry()


def register(name: str, program, params=(), **kwargs) -> ProgramEntry:
    """Register into the default registry (the public extension point)."""
    return DEFAULT_REGISTRY.register(name, program, params, **kwargs)


def unregister(name: str) -> None:
    DEFAULT_REGISTRY.unregister(name)


def get_program(name: str) -> ProgramEntry:
    return DEFAULT_REGISTRY.get(name)


def bind_channel(program: str, param: str, values) -> ChannelValue:
    """Bind a property plane on a default-registry program (the public
    "bound once per epoch" entry point; see ProgramEntry.bind_channel)."""
    return DEFAULT_REGISTRY.get(program).bind_channel(param, values)


def unbind_channel(program: str, param: str) -> None:
    DEFAULT_REGISTRY.get(program).unbind_channel(param)


def program_names() -> list[str]:
    return DEFAULT_REGISTRY.names()

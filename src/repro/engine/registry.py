"""Declarative program registry — one source of truth from ``EdgeProgram``
to ``GraphServer``.

The paper's framework claim is that an edge-partitioned runtime is
"flexible enough to be applied to several different graph problems"
(§III).  Before this module the serving stack hardwired exactly three:
the query layer duplicated the program list, carried per-kind request
fields and branched on kind strings in its scheduler and server.  Now a
program registers **once** with a declarative ``ParamSpec`` schema and
everything downstream is *derived*:

  * ``gserve.QueryRequest(kind, params={...})`` — validation, dtype
    coercion and default normalisation (so e.g. ``iters=None`` and the
    default 30 are the *same* query identity);
  * scheduler ``batch_key`` — which requests may share one engine
    dispatch (the single ``batchable`` param carries the micro-batch
    axis; all other params must agree);
  * epoch-cache ``cache_key`` — the identity of an answer within one
    graph snapshot;
  * server dispatch — batch-axis name/dtype, the superstep-count param
    (``role="supersteps"``), and derived per-snapshot ``resources``
    (e.g. PageRank's degree vector) all come from the entry;
  * tests and benchmarks — ``oracle`` names the whole-graph reference
    the program must reproduce (``oracle_atol`` its tolerance).

Registering a new program therefore makes it servable end-to-end with
zero serving-layer edits — see "Registering your own program" in
src/repro/engine/README.md, with weighted SSSP as the worked example.
All misuse raises the typed errors in ``engine.errors``.
"""
from __future__ import annotations

import dataclasses
import numbers
from typing import Any, Callable, Mapping

from .errors import (BatchAxisError, DuplicateProgramError, ParamTypeError,
                     RegistryError, UnknownParamError, UnknownProgramError)

_REQUIRED = object()        # sentinel: ParamSpec without a default
_DTYPES = (int, float)
_ROLES = ("ctx", "supersteps")


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative schema for one per-query parameter.

    dtype      — python scalar type (``int`` or ``float``); values are
                 coerced (numpy scalars accepted, bools rejected for int).
    default    — applied at request construction, so two spellings of the
                 same logical query share batch/cache identity; omit to
                 make the parameter required.
    batchable  — this parameter may carry the micro-batch axis: the
                 scheduler coalesces requests that differ only here into
                 one vmapped dispatch.  At most one per program.
    role       — "ctx": forwarded into the program's traced ``ctx`` via
                 engine kwargs; "supersteps": consumed host-side as the
                 superstep cap (``max_supersteps``).
    validate   — optional callback run on the coerced value; raise
                 ``ValueError`` to reject.
    """
    name: str
    dtype: type = int
    default: Any = _REQUIRED
    batchable: bool = False
    role: str = "ctx"
    validate: Callable[[Any], None] | None = None

    @property
    def required(self) -> bool:
        return self.default is _REQUIRED

    def coerce(self, program: str, value: Any) -> Any:
        """Validate + coerce one value; raises the typed errors."""
        if isinstance(value, (list, tuple, set)) \
                or getattr(value, "ndim", 0) > 0:
            if self.batchable:
                raise BatchAxisError(
                    f"{program}.{self.name} is batchable, but one request "
                    f"carries one scalar value (got {type(value).__name__}) "
                    "— submit one request per value; the scheduler forms "
                    "the batch axis by coalescing requests")
            raise BatchAxisError(
                f"{program}.{self.name} is not batchable and takes a "
                f"scalar {self.dtype.__name__} (got "
                f"{type(value).__name__}) — a batch axis may only ride on "
                "the program's batchable parameter")
        if self.dtype is int:
            if isinstance(value, bool) \
                    or not isinstance(value, numbers.Integral):
                raise ParamTypeError(
                    f"{program}.{self.name} expects int, got "
                    f"{type(value).__name__} ({value!r})")
            value = int(value)
        else:  # float: accept any real number
            if isinstance(value, bool) or not isinstance(value, numbers.Real):
                raise ParamTypeError(
                    f"{program}.{self.name} expects float, got "
                    f"{type(value).__name__} ({value!r})")
            value = float(value)
        if self.validate is not None:
            self.validate(value)
        return value


@dataclasses.dataclass(frozen=True)
class ProgramEntry:
    """One registered program: the EdgeProgram plus everything the query
    layer derives (validation, batching, caching, dispatch, oracle)."""
    name: str
    program: Any                                # engine.runtime.EdgeProgram
    params: tuple[ParamSpec, ...]
    cacheable: bool = True                      # answers may enter the
                                                #   epoch-keyed result cache
    resources: tuple[tuple[str, Callable], ...] = ()
                                                # engine-kw -> fn(graph),
                                                #   derived per snapshot
    oracle: Callable | None = None              # oracle(graph, **params)
    oracle_atol: float = 0.0                    # 0.0 -> bit-identical

    # -- schema accessors ----------------------------------------------------
    @property
    def batch_param(self) -> ParamSpec | None:
        for p in self.params:
            if p.batchable:
                return p
        return None

    @property
    def batchable(self) -> bool:
        return self.batch_param is not None

    def spec(self, name: str) -> ParamSpec:
        for p in self.params:
            if p.name == name:
                return p
        known = sorted(p.name for p in self.params) or ["<none>"]
        raise UnknownParamError(
            f"program {self.name!r} has no parameter {name!r}; "
            f"declared: {', '.join(known)}")

    # -- derivation ----------------------------------------------------------
    def normalize(self, params: Mapping[str, Any] | None) -> dict[str, Any]:
        """Coerce + default-fill a request's params. Normalisation at
        construction makes param identity canonical: omitted-with-default
        and explicitly-passed-default spell the SAME query (batch and
        cache keys are derived from the normalized dict)."""
        params = dict(params or {})
        out: dict[str, Any] = {}
        for spec in self.params:
            if spec.name in params:
                out[spec.name] = spec.coerce(self.name,
                                             params.pop(spec.name))
            elif spec.required:
                raise ParamTypeError(
                    f"program {self.name!r} requires parameter "
                    f"{spec.name!r} ({spec.dtype.__name__}) and it has no "
                    "default — pass it in params={...}")
            else:
                # coerced so a numpy-scalar default lands canonical, same
                # as a caller-passed value (validated at registration too)
                out[spec.name] = spec.coerce(self.name, spec.default)
        if params:
            bad = sorted(params)
            known = sorted(p.name for p in self.params) or ["<none>"]
            raise UnknownParamError(
                f"program {self.name!r} has no parameter(s) "
                f"{', '.join(map(repr, bad))}; declared: {', '.join(known)}")
        return out

    def supersteps_of(self, params: Mapping[str, Any]) -> int | None:
        """The superstep cap for a dispatch (role="supersteps" param)."""
        for p in self.params:
            if p.role == "supersteps":
                return int(params[p.name])
        return None

    def ctx_args(self, params: Mapping[str, Any]) -> dict[str, Any]:
        """Non-batchable role="ctx" params, forwarded as engine kwargs."""
        return {p.name: params[p.name] for p in self.params
                if p.role == "ctx" and not p.batchable}

    def batch_key_of(self, params: Mapping[str, Any]) -> tuple:
        """Requests sharing a batch key may be answered by one dispatch:
        same program, same value for every non-batchable parameter."""
        return (self.name,) + tuple(
            (p.name, params[p.name]) for p in self.params if not p.batchable)

    def cache_key_of(self, params: Mapping[str, Any]) -> tuple:
        """Identity of the *answer* within one graph snapshot: the program
        plus every normalized parameter (tenant deliberately excluded —
        result sharing across tenants is the point of the cache)."""
        return (self.name,) + tuple(
            (p.name, params[p.name]) for p in self.params)

    def lane_cache_key(self, params: Mapping[str, Any], value: Any) -> tuple:
        """Cache key of one lane of a micro-batch: the shared non-batch
        params with the batch param set to this lane's value."""
        bp = self.batch_param
        if bp is None:
            return self.cache_key_of(params)
        return self.cache_key_of({**params, bp.name: value})


class ProgramRegistry:
    """Name -> ProgramEntry map with validated registration."""

    def __init__(self):
        self._entries: dict[str, ProgramEntry] = {}

    def register(self, name: str, program, params=(), *,
                 cacheable: bool = True,
                 resources: Mapping[str, Callable] | None = None,
                 oracle: Callable | None = None,
                 oracle_atol: float = 0.0) -> ProgramEntry:
        """Register one EdgeProgram under ``name``. Everything the query
        layer needs is derived from this single call."""
        if name in self._entries:
            raise DuplicateProgramError(
                f"program {name!r} is already registered — unregister it "
                "first or register under a new name")
        params = tuple(params)
        seen: set[str] = set()
        batchable = []
        for p in params:
            if not isinstance(p, ParamSpec):
                raise RegistryError(
                    f"program {name!r}: params must be ParamSpec instances, "
                    f"got {type(p).__name__}")
            if p.name in seen:
                raise RegistryError(
                    f"program {name!r}: duplicate parameter {p.name!r}")
            seen.add(p.name)
            if p.dtype not in _DTYPES:
                raise RegistryError(
                    f"program {name!r}: parameter {p.name!r} dtype must be "
                    f"int or float, got {p.dtype!r}")
            if p.role not in _ROLES:
                raise RegistryError(
                    f"program {name!r}: parameter {p.name!r} role must be "
                    f"one of {_ROLES}, got {p.role!r}")
            if p.batchable:
                batchable.append(p)
                if p.role != "ctx":
                    raise RegistryError(
                        f"program {name!r}: batchable parameter {p.name!r} "
                        "must have role='ctx' (the superstep cap is a "
                        "static jit argument and cannot carry a batch axis)")
            if not p.required:
                # defaults are injected into normalized params verbatim, so
                # they must pass the same dtype/validate gauntlet as caller
                # values — fail HERE, not deep inside a dispatch
                try:
                    p.coerce(name, p.default)
                except RegistryError as e:
                    raise RegistryError(
                        f"program {name!r}: default for parameter "
                        f"{p.name!r} is invalid: {e}") from e
        if len(batchable) > 1:
            names = ", ".join(p.name for p in batchable)
            raise RegistryError(
                f"program {name!r}: at most one batchable parameter is "
                f"supported (the micro-batch axis), got: {names}")
        entry = ProgramEntry(
            name=name, program=program, params=params, cacheable=cacheable,
            resources=tuple(sorted((resources or {}).items())),
            oracle=oracle, oracle_atol=float(oracle_atol))
        self._entries[name] = entry
        return entry

    def unregister(self, name: str) -> None:
        self._entries.pop(name, None)

    def get(self, name: str) -> ProgramEntry:
        entry = self._entries.get(name)
        if entry is None:
            raise UnknownProgramError(
                f"unknown program {name!r}; registered: "
                f"{', '.join(sorted(self._entries)) or '<none>'}")
        return entry

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> list[str]:
        return sorted(self._entries)

    def entries(self) -> list[ProgramEntry]:
        return [self._entries[n] for n in self.names()]


#: The process-wide registry every layer derives from. ``engine.programs``
#: registers the built-ins on import; user programs register through the
#: same public ``register`` call.
DEFAULT_REGISTRY = ProgramRegistry()


def register(name: str, program, params=(), **kwargs) -> ProgramEntry:
    """Register into the default registry (the public extension point)."""
    return DEFAULT_REGISTRY.register(name, program, params, **kwargs)


def unregister(name: str) -> None:
    DEFAULT_REGISTRY.unregister(name)


def get_program(name: str) -> ProgramEntry:
    return DEFAULT_REGISTRY.get(name)


def program_names() -> list[str]:
    return DEFAULT_REGISTRY.names()

"""repro.engine — edge-centric partitioned execution engine.

Pipeline: partition (core/dfep.py, core/baselines.py) → compile_plan →
Engine.run(program). Programs declare themselves once in the
``ProgramRegistry`` (engine/registry.py) and the serving stack derives
everything downstream from the entry. See src/repro/engine/README.md for
the design and for registering your own program.
"""
from .errors import (BatchAxisError, DuplicateProgramError, ParamTypeError,
                     RegistryError, UnknownParamError, UnknownProgramError,
                     WarmStateError)
from .plan import (PartitionPlan, compile_plan, compile_plan_cached,
                   plan_cache_clear, plan_cache_stats)
from .registry import (DEFAULT_REGISTRY, ParamSpec, ProgramEntry,
                       ProgramRegistry, get_program, program_names, register,
                       unregister)
from .runtime import (TRACE_COUNTER, EdgeProgram, Engine, EngineResult,
                      PendingResult)
from .programs import (BFS, PAGERANK, SSSP, WCC, WEIGHTED_SSSP, engine_bfs,
                       engine_pagerank, engine_sssp, engine_wcc,
                       engine_weighted_sssp, multi_source_sssp)

__all__ = [
    "BFS", "BatchAxisError", "DEFAULT_REGISTRY", "DuplicateProgramError",
    "EdgeProgram", "Engine", "EngineResult", "PAGERANK", "ParamSpec",
    "ParamTypeError", "PartitionPlan", "PendingResult", "ProgramEntry",
    "ProgramRegistry", "RegistryError", "SSSP", "TRACE_COUNTER",
    "UnknownParamError", "UnknownProgramError", "WCC", "WEIGHTED_SSSP",
    "WarmStateError", "compile_plan", "compile_plan_cached", "engine_bfs",
    "engine_pagerank", "engine_sssp", "engine_wcc", "engine_weighted_sssp",
    "get_program", "multi_source_sssp", "plan_cache_clear",
    "plan_cache_stats", "program_names", "register", "unregister",
]

"""repro.engine — edge-centric partitioned execution engine.

Pipeline: partition (core/dfep.py, core/baselines.py) → compile_plan →
Engine.run(program). See src/repro/engine/README.md for the design.
"""
from .plan import (PartitionPlan, compile_plan, compile_plan_cached,
                   plan_cache_clear, plan_cache_stats)
from .runtime import (TRACE_COUNTER, EdgeProgram, Engine, EngineResult,
                      PendingResult)
from .programs import (PAGERANK, SSSP, WCC, engine_pagerank, engine_sssp,
                       engine_wcc, multi_source_sssp)

__all__ = [
    "PartitionPlan", "compile_plan", "compile_plan_cached",
    "plan_cache_clear", "plan_cache_stats", "EdgeProgram", "Engine",
    "EngineResult", "PendingResult", "TRACE_COUNTER", "SSSP", "WCC",
    "PAGERANK", "engine_sssp", "engine_wcc", "engine_pagerank",
    "multi_source_sssp",
]

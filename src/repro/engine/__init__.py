"""repro.engine — edge-centric partitioned execution engine.

Pipeline: partition (core/dfep.py, core/baselines.py) → compile_plan →
Engine.run(program). Programs declare themselves once in the
``ProgramRegistry`` (engine/registry.py) and the serving stack derives
everything downstream from the entry. See src/repro/engine/README.md for
the design and for registering your own program.
"""
from .errors import (BatchAxisError, ChannelError, DuplicateProgramError,
                     ParamTypeError, RegistryError, StateError,
                     UnknownParamError, UnknownProgramError, WarmStateError)
from .plan import (PartitionPlan, compile_plan, compile_plan_cached,
                   plan_cache_clear, plan_cache_stats)
from .registry import (DEFAULT_REGISTRY, ChannelValue, ParamSpec,
                       ProgramEntry, ProgramRegistry, bind_channel,
                       get_program, program_names, register, resident_stats,
                       unbind_channel, unregister)
from .runtime import (TRACE_COUNTER, EdgeProgram, Engine, EngineResult,
                      PendingResult)
from .state import SCALAR, StateSpec
from .kernels import (gather_edge_channel, gather_vertex_channel, gspmm,
                      gspmm_ref)
from .programs import (BFS, GCN_LAYER, KGE_SCORE, LABELPROP, PAGERANK, PPR,
                       SSSP, WCC, WEIGHTED_SSSP, engine_bfs,
                       engine_gcn_layer, engine_kge_score,
                       engine_label_propagation, engine_pagerank,
                       engine_personalized_pagerank, engine_sssp, engine_wcc,
                       engine_weighted_sssp, multi_source_sssp)

__all__ = [
    "BFS", "BatchAxisError", "ChannelError", "ChannelValue",
    "DEFAULT_REGISTRY", "DuplicateProgramError", "EdgeProgram", "Engine",
    "EngineResult", "GCN_LAYER", "KGE_SCORE", "LABELPROP", "PAGERANK", "PPR",
    "ParamSpec", "ParamTypeError", "PartitionPlan", "PendingResult",
    "ProgramEntry", "ProgramRegistry", "RegistryError", "SCALAR", "SSSP",
    "StateError", "StateSpec", "TRACE_COUNTER", "UnknownParamError",
    "UnknownProgramError", "WCC", "WEIGHTED_SSSP", "WarmStateError",
    "bind_channel", "compile_plan", "compile_plan_cached", "engine_bfs",
    "engine_gcn_layer", "engine_kge_score", "engine_label_propagation",
    "engine_pagerank", "engine_personalized_pagerank", "engine_sssp",
    "engine_wcc", "engine_weighted_sssp", "gather_edge_channel",
    "gather_vertex_channel", "get_program", "gspmm", "gspmm_ref",
    "multi_source_sssp", "plan_cache_clear", "plan_cache_stats",
    "program_names", "register", "resident_stats", "unbind_channel",
    "unregister",
]

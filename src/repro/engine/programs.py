"""Graph programs expressed against the engine API, validated against the
whole-graph oracles in ``core/algorithms.py``:

  * SSSP      — unit-weight shortest paths (paper Algorithm 1),
  * WCC       — connected components via min-label epidemic (Algorithm 2;
                labels are vertex ids so results are bit-identical to
                ``reference_cc``),
  * PageRank  — partial in-flow sums per partition, completed across the
                cut each superstep (§III sketch).

Programs are module-level constants (static jit arguments); per-query
values (source vertex, degree vector) travel in the traced ``ctx`` dict.
``multi_source_sssp`` vmaps one compiled superstep loop over a batch of
sources — the serving-oriented batched-query path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .plan import PartitionPlan
from .runtime import EdgeProgram, Engine, EngineResult

INF = jnp.float32(jnp.inf)
DAMPING = 0.85


# ---------------------------------------------------------------------------
# SSSP
# ---------------------------------------------------------------------------

def _sssp_prepare(plan, kw):
    return {"source": kw["source"]}


def _sssp_init(plan, ctx):
    hit = plan.vmask & (plan.local2global == ctx["source"])
    return jnp.where(hit, 0.0, INF)


def _sssp_pre(state, ctx):
    return state + 1.0


def _min_apply(old, agg, ctx):
    return jnp.minimum(old, agg)


def _sssp_finalize(glob, present, plan, ctx):
    iota = jnp.arange(plan.n_vertices)
    isolated = jnp.where(iota == ctx["source"], 0.0, INF)
    return jnp.where(present, glob, isolated)


SSSP = EdgeProgram(
    name="sssp", mode="replica", combine="min",
    prepare=_sssp_prepare, init=_sssp_init, pre=_sssp_pre, apply=_min_apply,
    finalize=_sssp_finalize, local_fixpoint=True)


# ---------------------------------------------------------------------------
# WCC (min-label propagation; labels = vertex ids, matching reference_cc)
# ---------------------------------------------------------------------------

def _wcc_prepare(plan, kw):
    # labels live in float32 state; ids above 2^24 would collide silently
    assert plan.n_vertices < 2 ** 24, \
        "WCC float32 labels need n_vertices < 2**24"
    return {}


def _wcc_init(plan, ctx):
    return jnp.where(plan.vmask, plan.local2global.astype(jnp.float32), INF)


def _wcc_pre(state, ctx):
    return state


def _wcc_finalize(glob, present, plan, ctx):
    own = jnp.arange(plan.n_vertices, dtype=jnp.float32)
    return jnp.where(present, glob, own)


WCC = EdgeProgram(
    name="wcc", mode="replica", combine="min",
    prepare=_wcc_prepare, init=_wcc_init, pre=_wcc_pre, apply=_min_apply,
    finalize=_wcc_finalize, local_fixpoint=True)


# ---------------------------------------------------------------------------
# PageRank (partial aggregation across the cut each superstep)
# ---------------------------------------------------------------------------

def _pr_prepare(plan, kw):
    deg = jnp.maximum(kw["degrees"].astype(jnp.float32), 1.0)
    return {"deg_local": deg[plan.local2global],
            "inv_v": jnp.float32(1.0 / plan.n_vertices)}


def _pr_init(plan, ctx):
    return jnp.where(plan.vmask, 1.0 / plan.n_vertices, 0.0)


def _pr_pre(state, ctx):
    return state / ctx["deg_local"]


def _pr_apply(old, inflow, ctx):
    return (1.0 - DAMPING) * ctx["inv_v"] + DAMPING * inflow


def _pr_finalize(glob, present, plan, ctx):
    # a vertex in no partition has no edges: its rank is the teleport term
    return jnp.where(present, glob, (1.0 - DAMPING) / plan.n_vertices)


PAGERANK = EdgeProgram(
    name="pagerank", mode="partial", combine="add",
    prepare=_pr_prepare, init=_pr_init, pre=_pr_pre,
    apply=_pr_apply, finalize=_pr_finalize,
    local_fixpoint=False, default_supersteps=30)


# ---------------------------------------------------------------------------
# Convenience entry points
# ---------------------------------------------------------------------------

def engine_sssp(engine: Engine, source: int) -> EngineResult:
    return engine.run(SSSP, source=jnp.int32(source))


def engine_wcc(engine: Engine) -> EngineResult:
    return engine.run(WCC)


def engine_pagerank(engine: Engine, degrees: jax.Array,
                    iters: int = 30) -> EngineResult:
    return engine.run(PAGERANK, max_supersteps=iters, degrees=degrees)


def multi_source_sssp(engine: Engine, sources) -> EngineResult:
    """Batched multi-source distances: one vmapped superstep loop answers
    every query; ``result.state`` is [S, V]."""
    sources = jnp.asarray(sources, jnp.int32)
    return engine.run_batched(SSSP, {"source": sources})

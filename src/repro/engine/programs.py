"""Graph programs expressed against the engine API, validated against the
whole-graph oracles in ``core/algorithms.py``:

  * SSSP       — unit-weight shortest paths (paper Algorithm 1),
  * WCC        — connected components via min-label epidemic (Algorithm 2;
                 labels are vertex ids so results are bit-identical to
                 ``reference_cc``),
  * PageRank   — partial in-flow sums per partition, completed across the
                 cut each superstep (§III sketch),
  * wsssp      — weighted shortest paths over the plan's per-half-edge
                 content-hash weights (``plan.edge_w``), via the
                 ``EdgeProgram.edge`` hook,
  * BFS        — hop levels with -1.0 marking unreachable vertices,
  * labelprop  — min-label propagation over an EXTERNAL [V] label plane
                 (vertex property channel; bit-identical to
                 ``reference_label_propagation``),
  * ppr        — personalized PageRank with an external teleport vector
                 (vertex property channel + degree resource),
  * gcn_layer  — one GCN layer forward pass over [V, F] feature planes:
                 ``out = (D^{-1/2} A_w D^{-1/2} X) W`` with a bound weight
                 matrix (dense channel), flowing through the fused Pallas
                 gSpMM via the ``edge_mul`` hook (vector state,
                 ``StateSpec(features=F_out)``),
  * kge_score  — DistMult-style triple scoring over bound entity/relation
                 embedding channels, accumulated per vertex.

Programs are module-level constants (static jit arguments); per-query
values (source vertex, degree vector) travel in the traced ``ctx`` dict.
``multi_source_sssp`` vmaps one compiled superstep loop over a batch of
sources — the serving-oriented batched-query path.

Every program registers ONCE in ``engine.registry`` at the bottom of this
module — through the same public ``registry.register`` call user programs
use — and the serving stack (``repro.gserve``) derives request validation,
batching, caching and dispatch from those entries.  The min-style programs
also carry a ``warm_init`` hook: served queries can repair from a previous
epoch's result after insert-only stream patches (old distances are valid
upper bounds, so min-relaxation tightens them to the exact fixpoint in
fewer supersteps than a cold recompute).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..core import algorithms as _alg
from . import registry
from .kernels import gather_edge_channel, gather_vertex_channel
from .plan import PartitionPlan
from .runtime import EdgeProgram, Engine, EngineResult
from .state import StateSpec

INF = jnp.float32(jnp.inf)
DAMPING = 0.85


# ---------------------------------------------------------------------------
# SSSP
# ---------------------------------------------------------------------------

def _sssp_prepare(plan, kw):
    return {"source": kw["source"]}


def _sssp_init(plan, ctx):
    hit = plan.vmask & (plan.local2global == ctx["source"])
    return jnp.where(hit, 0.0, INF)


def _sssp_pre(state, ctx):
    return state + 1.0


def _min_apply(old, agg, ctx):
    return jnp.minimum(old, agg)


def _sssp_finalize(glob, present, plan, ctx):
    iota = jnp.arange(plan.n_vertices)
    isolated = jnp.where(iota == ctx["source"], 0.0, INF)
    return jnp.where(present, glob, isolated)


def _sssp_warm(plan, prev, ctx):
    """Warm start from a previous epoch's [V] distances.

    Valid whenever the graph changed by *insertions only* since ``prev``
    was computed: old distances are then upper bounds on the true ones, and
    min-relaxation from any upper bound converges to the exact fixpoint —
    in as many supersteps as the *change* needs to propagate, not the whole
    graph. +inf entries mean "no prior information" and reduce to the cold
    init via the min below. (The serving layer tracks insert-only lineage
    and never warm-starts across a deletion.)
    """
    local = jnp.where(plan.vmask, prev[plan.local2global], INF)
    return jnp.minimum(_sssp_init(plan, ctx), local)


SSSP = EdgeProgram(
    name="sssp", mode="replica", combine="min",
    prepare=_sssp_prepare, init=_sssp_init, pre=_sssp_pre, apply=_min_apply,
    finalize=_sssp_finalize, local_fixpoint=True, warm_init=_sssp_warm)


# ---------------------------------------------------------------------------
# WCC (min-label propagation; labels = vertex ids, matching reference_cc)
# ---------------------------------------------------------------------------

def _wcc_prepare(plan, kw):
    # labels live in float32 state; ids above 2^24 would collide silently
    assert plan.n_vertices < 2 ** 24, \
        "WCC float32 labels need n_vertices < 2**24"
    return {}


def _wcc_init(plan, ctx):
    return jnp.where(plan.vmask, plan.local2global.astype(jnp.float32), INF)


def _wcc_pre(state, ctx):
    return state


def _wcc_finalize(glob, present, plan, ctx):
    own = jnp.arange(plan.n_vertices, dtype=jnp.float32)
    return jnp.where(present, glob, own)


WCC = EdgeProgram(
    name="wcc", mode="replica", combine="min",
    prepare=_wcc_prepare, init=_wcc_init, pre=_wcc_pre, apply=_min_apply,
    finalize=_wcc_finalize, local_fixpoint=True)


# ---------------------------------------------------------------------------
# PageRank (partial aggregation across the cut each superstep)
# ---------------------------------------------------------------------------

def _pr_prepare(plan, kw):
    deg = jnp.maximum(kw["degrees"].astype(jnp.float32), 1.0)
    return {"deg_local": deg[plan.local2global],
            "inv_v": jnp.float32(1.0 / plan.n_vertices)}


def _pr_init(plan, ctx):
    return jnp.where(plan.vmask, 1.0 / plan.n_vertices, 0.0)


def _pr_pre(state, ctx):
    return state / ctx["deg_local"]


def _pr_apply(old, inflow, ctx):
    return (1.0 - DAMPING) * ctx["inv_v"] + DAMPING * inflow


def _pr_finalize(glob, present, plan, ctx):
    # a vertex in no partition has no edges: its rank is the teleport term
    return jnp.where(present, glob, (1.0 - DAMPING) / plan.n_vertices)


PAGERANK = EdgeProgram(
    name="pagerank", mode="partial", combine="add",
    prepare=_pr_prepare, init=_pr_init, pre=_pr_pre,
    apply=_pr_apply, finalize=_pr_finalize,
    local_fixpoint=False, default_supersteps=30)


# ---------------------------------------------------------------------------
# Weighted SSSP — per-half-edge weights via the ``edge`` hook. The weights
# are baked into the plan at compile/patch time (plan.edge_w, a content
# hash of the endpoints — core/graph.py::edge_weights), so the weighted
# message stream flows through the same segment-reduce kernels.
# ---------------------------------------------------------------------------

def _ident_pre(state, ctx):
    return state


def _wsssp_edge(msgs, plan, ctx):
    return msgs + plan.edge_w


WEIGHTED_SSSP = EdgeProgram(
    name="wsssp", mode="replica", combine="min",
    prepare=_sssp_prepare, init=_sssp_init, pre=_ident_pre,
    apply=_min_apply, finalize=_sssp_finalize, local_fixpoint=True,
    edge=_wsssp_edge, warm_init=_sssp_warm)


# ---------------------------------------------------------------------------
# BFS hop levels — unit costs through the ``edge`` hook; unreachable
# vertices are finalized to -1.0 (distinguishing the *result space* from
# the +inf-based relaxation state, which warm_init must map back).
# ---------------------------------------------------------------------------

def _bfs_edge(msgs, plan, ctx):
    return msgs + 1.0


def _bfs_finalize(glob, present, plan, ctx):
    iota = jnp.arange(plan.n_vertices)
    isolated = jnp.where(iota == ctx["source"], 0.0, INF)
    d = jnp.where(present, glob, isolated)
    return jnp.where(jnp.isinf(d), -1.0, d)


def _bfs_warm(plan, prev, ctx):
    # the finalized result marks unreachable as -1.0: back to +inf before
    # reuse (a vertex unreachable pre-insert may be reachable now)
    prev = jnp.where(prev < 0.0, INF, prev)
    local = jnp.where(plan.vmask, prev[plan.local2global], INF)
    return jnp.minimum(_sssp_init(plan, ctx), local)


BFS = EdgeProgram(
    name="bfs", mode="replica", combine="min",
    prepare=_sssp_prepare, init=_sssp_init, pre=_ident_pre,
    apply=_min_apply, finalize=_bfs_finalize, local_fixpoint=True,
    edge=_bfs_edge, warm_init=_bfs_warm)


# ---------------------------------------------------------------------------
# Label propagation over an EXTERNAL label plane (vertex property channel).
# The labels come from outside the graph — a [V] (or [V, 1]) float32 plane
# supplied at query time or bound once per epoch — and flow through the
# same min-combine machinery as WCC: every vertex converges to the
# smallest label in its component.  ``prepare`` gathers the global plane
# to the partition-local layout with the slack-aware channel gather, so
# the program is exact on single-device and shard_map paths and across
# stream patches without any plan surgery.
# ---------------------------------------------------------------------------

def _lp_prepare(plan, kw):
    lab = kw["labels"]
    if lab.ndim == 1:
        lab = lab[:, None]
    return {"labels_glob": lab[:, 0],
            "labels_local": gather_vertex_channel(plan, lab)[:, :, 0]}


def _lp_init(plan, ctx):
    return jnp.where(plan.vmask, ctx["labels_local"], INF)


def _lp_warm(plan, prev, ctx):
    # labels only shrink as edges arrive (a bigger component can only
    # lower the min), so a previous epoch's result is a valid upper bound
    # after insert-only patches — identical contract to SSSP warm-start
    local = jnp.where(plan.vmask, prev[plan.local2global], INF)
    return jnp.minimum(_lp_init(plan, ctx), local)


def _lp_finalize(glob, present, plan, ctx):
    return jnp.where(present, glob, ctx["labels_glob"])


LABELPROP = EdgeProgram(
    name="labelprop", mode="replica", combine="min",
    prepare=_lp_prepare, init=_lp_init, pre=_wcc_pre, apply=_min_apply,
    finalize=_lp_finalize, local_fixpoint=True, warm_init=_lp_warm)


# ---------------------------------------------------------------------------
# Personalized PageRank — degree-weighted rank flow with an external
# teleport vector (vertex property channel).  rank <- (1-d)*p + d*inflow,
# with p supplied per query; the channel digest keys the cache, so two
# tenants with different personalization vectors never share an answer.
# ---------------------------------------------------------------------------

def _ppr_prepare(plan, kw):
    p = kw["personalization"]
    if p.ndim == 1:
        p = p[:, None]
    deg = jnp.maximum(kw["degrees"].astype(jnp.float32), 1.0)
    return {"p_glob": p[:, 0],
            "p_local": gather_vertex_channel(plan, p)[:, :, 0],
            "deg_local": deg[plan.local2global]}


def _ppr_init(plan, ctx):
    return jnp.where(plan.vmask, ctx["p_local"], 0.0)


def _ppr_apply(old, inflow, ctx):
    return (1.0 - DAMPING) * ctx["p_local"] + DAMPING * inflow


def _ppr_finalize(glob, present, plan, ctx):
    # a vertex in no partition has no edges: rank settles at its teleport
    return jnp.where(present, glob, (1.0 - DAMPING) * ctx["p_glob"])


PPR = EdgeProgram(
    name="ppr", mode="partial", combine="add",
    prepare=_ppr_prepare, init=_ppr_init, pre=_pr_pre,
    apply=_ppr_apply, finalize=_ppr_finalize,
    local_fixpoint=False, default_supersteps=30)


# ---------------------------------------------------------------------------
# GCN layer — the vector-state flagship: one graph-convolution forward pass
# ``out = (D^{-1/2} A_w D^{-1/2} X) W`` over the plan's content-hash edge
# weights.  State is a [K, Vmax, F_in] feature plane; the sweep runs the
# fused Pallas gSpMM (``edge_mul`` hook: gather · multiply-by-edge_w ·
# segment-reduce in one kernel pass); the bound [F_in, F_out] weight matrix
# (dense channel) applies once at finalize.  Feature widths are static per
# registration — like a deployed model's layer shapes — so every query jits
# to one cache entry.
# ---------------------------------------------------------------------------

GCN_F_IN = 8
GCN_F_OUT = 4


def _gcn_prepare(plan, kw):
    inv_sqrt = 1.0 / jnp.sqrt(jnp.maximum(
        kw["degrees"].astype(jnp.float32), 1.0))
    return {"x_local": gather_vertex_channel(plan, kw["x"]),
            "inv_sqrt_local": jnp.where(
                plan.vmask, inv_sqrt[plan.local2global], 0.0)[:, :, None],
            "weight": kw["weight"]}


def _gcn_init(plan, ctx):
    return ctx["x_local"]           # already vmask-pinned to zero rows


def _gcn_pre(state, ctx):
    return state * ctx["inv_sqrt_local"]


def _gcn_edge_mul(plan, ctx):
    return plan.edge_w


def _gcn_apply(old, agg, ctx):
    return agg * ctx["inv_sqrt_local"]


def _gcn_finalize(glob, present, plan, ctx):
    h = jnp.where(present[:, None], glob, 0.0)
    return jnp.dot(h, ctx["weight"])


GCN_LAYER = EdgeProgram(
    name="gcn_layer", mode="partial", combine="add",
    prepare=_gcn_prepare, init=_gcn_init, pre=_gcn_pre,
    apply=_gcn_apply, finalize=_gcn_finalize,
    local_fixpoint=False, default_supersteps=1,
    edge_mul=_gcn_edge_mul, state=StateSpec(features=GCN_F_OUT, fill=0.0))


# ---------------------------------------------------------------------------
# KGE triple scoring — DistMult interaction over bound embedding channels:
# every live edge e = (u, v) scores sum_f ent_u[f]·rel_e[f]·ent_v[f] and the
# score accumulates onto both endpoints.  The relation plane is an EDGE
# channel in graph slot order (slack-aware gather: patched-in edges without
# covered slots score 0); the per-feature ``edge_mul`` planes drive the
# fused gSpMM with [K, Emax, F] weights.  Scalar [V] result state.
# ---------------------------------------------------------------------------

KGE_F = 8


def _kge_prepare(plan, kw):
    return {"ent_local": gather_vertex_channel(plan, kw["entity"]),
            "rel_local": gather_edge_channel(plan, kw["relation"], fill=0.0)}


def _kge_init(plan, ctx):
    return ctx["ent_local"]


def _kge_edge_mul(plan, ctx):
    return ctx["rel_local"]


def _kge_apply(old, agg, ctx):
    return ctx["ent_local"] * agg


def _kge_finalize(glob, present, plan, ctx):
    return jnp.where(present, jnp.sum(glob, axis=1), 0.0)


KGE_SCORE = EdgeProgram(
    name="kge_score", mode="partial", combine="add",
    prepare=_kge_prepare, init=_kge_init, pre=_ident_pre,
    apply=_kge_apply, finalize=_kge_finalize,
    local_fixpoint=False, default_supersteps=1,
    edge_mul=_kge_edge_mul, state=StateSpec(fill=0.0))


# ---------------------------------------------------------------------------
# Convenience entry points
# ---------------------------------------------------------------------------

def engine_sssp(engine: Engine, source: int) -> EngineResult:
    return engine.run(SSSP, source=jnp.int32(source))


def engine_wcc(engine: Engine) -> EngineResult:
    return engine.run(WCC)


def engine_pagerank(engine: Engine, degrees: jax.Array,
                    iters: int = 30) -> EngineResult:
    return engine.run(PAGERANK, max_supersteps=iters, degrees=degrees)


def engine_weighted_sssp(engine: Engine, source: int) -> EngineResult:
    return engine.run(WEIGHTED_SSSP, source=jnp.int32(source))


def engine_bfs(engine: Engine, source: int) -> EngineResult:
    return engine.run(BFS, source=jnp.int32(source))


def multi_source_sssp(engine: Engine, sources) -> EngineResult:
    """Batched multi-source distances: one vmapped superstep loop answers
    every query; ``result.state`` is [S, V]."""
    sources = jnp.asarray(sources, jnp.int32)
    return engine.run_batched(SSSP, {"source": sources})


def engine_label_propagation(engine: Engine, labels) -> EngineResult:
    """Min-label propagation over an external [V] / [V, 1] label plane."""
    return engine.run(LABELPROP, labels=jnp.asarray(labels, jnp.float32))


def engine_personalized_pagerank(engine: Engine, degrees: jax.Array,
                                 personalization,
                                 iters: int = 30) -> EngineResult:
    return engine.run(PPR, max_supersteps=iters, degrees=degrees,
                      personalization=jnp.asarray(personalization,
                                                  jnp.float32))


def engine_gcn_layer(engine: Engine, degrees: jax.Array, x,
                     weight) -> EngineResult:
    """One GCN layer forward pass; ``result.state`` is [V, GCN_F_OUT]."""
    return engine.run(GCN_LAYER, degrees=degrees,
                      x=jnp.asarray(x, jnp.float32),
                      weight=jnp.asarray(weight, jnp.float32))


def engine_kge_score(engine: Engine, entity, relation) -> EngineResult:
    """Per-vertex DistMult triple-score mass; ``result.state`` is [V]."""
    return engine.run(KGE_SCORE,
                      entity=jnp.asarray(entity, jnp.float32),
                      relation=jnp.asarray(relation, jnp.float32))


# ---------------------------------------------------------------------------
# Registry entries — the single declaration each program ever needs. The
# whole serving stack (request validation, batch/cache keys, dispatch,
# benchmark and test registration) derives from these; none of it names a
# program again. User programs extend the system with exactly one more
# ``registry.register`` call (see src/repro/engine/README.md).
# ---------------------------------------------------------------------------

def _non_negative(v):
    if v < 0:
        raise ValueError(f"iters must be >= 0, got {v}")


registry.register(
    "sssp", SSSP,
    params=[registry.ParamSpec("source", int, batchable=True)],
    oracle=lambda g, source: np.asarray(_alg.reference_sssp(g, source)[0]),
)

registry.register(
    "wcc", WCC,
    oracle=lambda g: np.asarray(_alg.reference_cc(g)[0]),
)

registry.register(
    "pagerank", PAGERANK,
    params=[registry.ParamSpec("iters", int, default=30, role="supersteps",
                               validate=_non_negative)],
    resources={"degrees": lambda g: g.degrees()},
    oracle=lambda g, iters: np.asarray(_alg.reference_pagerank(g,
                                                               iters=iters)),
    oracle_atol=1e-5,
)

registry.register(
    "wsssp", WEIGHTED_SSSP,
    params=[registry.ParamSpec("source", int, batchable=True)],
    oracle=_alg.reference_weighted_sssp,
)

registry.register(
    "bfs", BFS,
    params=[registry.ParamSpec("source", int, batchable=True)],
    oracle=_alg.reference_bfs,
)

registry.register(
    "labelprop", LABELPROP,
    params=[registry.ParamSpec("labels", float, role="channel",
                               channel="vertex", features=1)],
    oracle=lambda g, labels: _alg.reference_label_propagation(
        g, np.asarray(labels)),
)

registry.register(
    "ppr", PPR,
    params=[registry.ParamSpec("personalization", float, role="channel",
                               channel="vertex", features=1),
            registry.ParamSpec("iters", int, default=30, role="supersteps",
                               validate=_non_negative)],
    resources={"degrees": lambda g: g.degrees()},
    oracle=lambda g, personalization, iters: np.asarray(
        _alg.reference_personalized_pagerank(g, np.asarray(personalization),
                                             iters=iters)),
    oracle_atol=1e-5,
)


def _gcn_weight_rows(cv):
    # the dense channel's plan-free shape still has a program contract:
    # rows must match the layer's input width or the finalize matmul
    # would fail deep inside jit instead of at the server door
    if cv.shape[0] != GCN_F_IN:
        raise ValueError(
            f"gcn_layer.weight is the [F_in, F_out] = "
            f"[{GCN_F_IN}, {GCN_F_OUT}] layer matrix, got {cv.shape}")


registry.register(
    "gcn_layer", GCN_LAYER,
    params=[registry.ParamSpec("x", float, role="channel",
                               channel="vertex", features=GCN_F_IN),
            registry.ParamSpec("weight", float, role="channel",
                               channel="dense", features=GCN_F_OUT,
                               validate=_gcn_weight_rows)],
    resources={"degrees": lambda g: g.degrees()},
    oracle=lambda g, x, weight: _alg.reference_gcn_layer(
        g, np.asarray(x), np.asarray(weight)),
    oracle_atol=1e-5,
)

registry.register(
    "kge_score", KGE_SCORE,
    params=[registry.ParamSpec("entity", float, role="channel",
                               channel="vertex", features=KGE_F),
            registry.ParamSpec("relation", float, role="channel",
                               channel="edge", features=KGE_F)],
    oracle=lambda g, entity, relation: _alg.reference_kge_score(
        g, np.asarray(entity), np.asarray(relation)),
    oracle_atol=1e-5,
)

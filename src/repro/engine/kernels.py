"""Pallas TPU kernels for the partition-local engine layout.

Three kernels, all specialized to the ``PartitionPlan`` CSR blocks:

``segment_reduce``
    The gather/aggregate hot-spot of a superstep: reduce per-half-edge
    messages into per-target-vertex aggregates.  The CSR stream is sorted by
    target, so this is a *segmented* scan.  TPU mapping follows
    kernels/lane_cumsum.py: partitions are the 128-wide lane axis (each lane
    is one partition's independent edge stream), the edge-slot axis is
    blocked into [BLK_S, K] VMEM tiles walked sequentially, and a [1, K]
    VMEM scratch carries the running value of each lane's open segment
    across tiles.  Inside a tile the segmented combine runs as an
    associative scan on (segment-start flag, value) pairs.  The caller then
    picks each vertex's aggregate out of the scanned stream at
    ``plan.last_slot`` (a plain gather; padding slots hold the identity
    because the padding region starts a fresh identity-valued segment).

``gspmm``
    The fused GNN hot path (PR 10): gather neighbour feature rows,
    multiply by per-half-edge weights (scalar or per-feature planes),
    segment-reduce per target — DGL's ``u_mul_e_{sum,max,mean}`` gSpMM
    shape.  The multiply and the segmented combine run in ONE Pallas
    pass over the edge stream ([BLK_S, K·F] VMEM tiles, partitions
    major / features minor on the lane axis), so the weighted message
    stream is never materialised to HBM between them.  ``gspmm_ref`` is
    the unfused XLA scatter reference (and the shard_map-path
    implementation).

``masked_update``
    The frontier/replica-update step of the exchange: replicated slots take
    the exchanged (cut-combined) value, private slots keep their local
    value, padding slots are pinned to the identity.  Mirrors the masked
    [K, V]-tile style of kernels/frontier_min.py.

All support combine ∈ {"min", "add", "max"} (SSSP/WCC, PageRank, GNN
max-pooling) and run in interpret mode on CPU.  ``segment_reduce``,
``segment_reduce_ref`` and ``masked_update`` accept either scalar
[K, ·] streams or [K, ·, F] feature planes — the F axis is folded onto
the 128-wide lane axis, so scalar programs are literally the F=1 case
of the same kernels.

The message stream is per-half-edge, so weighted programs need no kernel
changes: the runtime applies the ``EdgeProgram.edge`` hook (e.g.
``msgs + plan.edge_w`` for weighted SSSP) after the neighbour gather, and
the weighted messages flow through the same segmented scan — masked
(deleted/padding) slots are pinned to the combine identity *after* the
hook, so they stay inert regardless of their weight.

``gather_vertex_channel`` / ``gather_edge_channel`` lay externally
supplied property planes (registry ``role="channel"`` params) out to the
partition-local padded shapes the programs consume — slack-aware (pad and
reserved slots pinned to the fill value) and fully traced, so the same
compiled gather serves every in-place plan patch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_IDENTITY = {"min": jnp.inf, "add": 0.0, "max": -jnp.inf}
_OPS = {"min": jnp.minimum, "add": jnp.add, "max": jnp.maximum}


def _scatter_combine(tgt: jax.Array, rows: jax.Array, cols: jax.Array,
                     vals: jax.Array, combine: str) -> jax.Array:
    """Scatter-combine ``vals`` into ``tgt[rows, cols]`` (identity-masked
    values are inert for every combine: inf/min, 0/add, -inf/max)."""
    at = tgt.at[rows, cols]
    if combine == "min":
        return at.min(vals)
    if combine == "max":
        return at.max(vals)
    return at.add(vals)


def _seg_kernel(flags_ref, vals_ref, o_ref, carry_ref, *, combine: str):
    op = _OPS[combine]
    ident = jnp.float32(_IDENTITY[combine])
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.full_like(carry_ref, ident)

    f = flags_ref[...]                        # [BLK_S, K] bool
    v = vals_ref[...]                         # [BLK_S, K] f32

    def comb(a, b):
        af, av = a
        bf, bv = b
        return af | bf, jnp.where(bf, bv, op(av, bv))

    f_scan, v_scan = jax.lax.associative_scan(comb, (f, v), axis=0)
    # rows before the tile's first segment start continue the carried segment
    out = jnp.where(f_scan, v_scan, op(carry_ref[...], v_scan))
    o_ref[...] = out
    carry_ref[...] = out[-1:, :]


@functools.partial(jax.jit,
                   static_argnames=("combine", "block_s", "interpret"))
def segment_scan(flags: jax.Array, vals: jax.Array, combine: str = "min",
                 block_s: int = 1024, interpret: bool = True) -> jax.Array:
    """Segmented inclusive scan along axis 0 of [S, K] streams.

    ``flags[s, k]`` True starts a new segment in lane k.  Returns the
    running combine of each open segment; the value at a segment's last row
    is the full segment reduction.
    """
    s, k = vals.shape
    ident = _IDENTITY[combine]
    s_pad = -(-s // block_s) * block_s
    k_pad = -(-k // 128) * 128
    fp = jnp.zeros((s_pad, k_pad), jnp.bool_).at[:s, :k].set(flags)
    # padding rows/lanes: identity values, no segment starts — harmless
    vp = jnp.full((s_pad, k_pad), ident, jnp.float32).at[:s, :k].set(vals)
    out = pl.pallas_call(
        functools.partial(_seg_kernel, combine=combine),
        grid=(s_pad // block_s,),
        in_specs=[pl.BlockSpec((block_s, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((block_s, k_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_s, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, k_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, k_pad), jnp.float32)],
        interpret=interpret,
    )(fp, vp)
    return out[:s, :k]


def segment_reduce(plan, messages: jax.Array, combine: str = "min",
                   block_s: int = 1024, interpret: bool = True) -> jax.Array:
    """Per-target aggregates over the plan's CSR stream.

    messages [K, Emax] or [K, Emax, F] (identity at masked slots) ->
    aggregates [K, Vmax] / [K, Vmax, F] (identity at padding vertices).
    Feature planes fold onto the lane axis (partition-major,
    feature-minor), so the scalar case is exactly F=1 of the same scan.

    Slack-aware bounds: the segmented scan covers only the sorted CSR prefix
    ``[0, csr_fill)`` of each lane; half-edges appended by the streaming
    patch path live in ``[csr_fill, e_max)`` in arbitrary order, so their
    contribution is combined by a masked scatter on top of the scanned
    aggregate.  Masked (deleted/padding) slots are pinned to the combine
    identity in both regions and are therefore inert for every combine.
    """
    ident = _IDENTITY[combine]
    squeeze = messages.ndim == 2
    msgs3 = messages[:, :, None] if squeeze else messages       # [K, Emax, F]
    k, e_max, f = msgs3.shape
    slot = jnp.arange(e_max, dtype=jnp.int32)[None, :]
    in_csr = slot < plan.csr_fill[:, None]                          # [K, Emax]
    msgs = jnp.where((plan.emask & in_csr)[:, :, None], msgs3, ident)
    stream = msgs.transpose(1, 0, 2).reshape(e_max, k * f)       # [Emax, K·F]
    flags = jnp.repeat(plan.seg_start.T, f, axis=1)
    scanned = segment_scan(flags, stream, combine=combine,
                           block_s=block_s, interpret=interpret)
    scanned = scanned.reshape(e_max, k, f).transpose(1, 0, 2)    # [K, Emax, F]
    rows = jnp.arange(k, dtype=jnp.int32)[:, None]
    agg = scanned[rows, plan.last_slot]                          # [K, Vmax, F]
    # append-region contributions (each appended half-edge is one segment)
    slack = jnp.where((plan.emask & ~in_csr)[:, :, None], msgs3, ident)
    agg = _scatter_combine(agg, rows, plan.edge_tgt, slack, combine)
    agg = jnp.where(plan.vmask[:, :, None], agg, ident)
    return agg[:, :, 0] if squeeze else agg


def gather_vertex_channel(plan, values: jax.Array) -> jax.Array:
    """Slack-aware layout of a global vertex property plane.

    values [V, F] (or [V]) -> [K, Vmax, F]: each live local slot takes its
    vertex's feature row via ``plan.local2global``; padding AND reserved
    slack slots (``vmask`` False) are pinned to 0.0 so a patched plan that
    populates a slack slot later picks the right row automatically — the
    gather runs traced, against the dynamic plan children, so it is valid
    for every in-place patch without retracing.  Programs call this from
    ``prepare`` (inside the shard_map body on mesh paths, where the local
    plan block gathers from the replicated [V, F] plane).
    """
    if values.ndim == 1:
        values = values[:, None]
    local = values[plan.local2global]                   # [K, Vmax, F]
    return jnp.where(plan.vmask[:, :, None], local, 0.0)


def gather_edge_channel(plan, values: jax.Array, fill: float = 0.0
                        ) -> jax.Array:
    """Slack-aware layout of an edge property plane in graph slot order.

    values [E_pad, F] (or [E_pad]) -> [K, Emax, F]: every live half-edge
    (CSR prefix *and* append/slack region — ``plan.edge_slot`` is
    maintained by both compile_plan and the streaming patch path) takes the
    feature row of its undirected edge's graph slot; pad slots and
    half-edges whose slot is unknown (patched in without slot provenance,
    edge_slot == -1) take ``fill``.  Masked slots are additionally pinned
    to the combine identity downstream of the ``edge`` hook, so garbage can
    never leak into an aggregate.
    """
    if values.ndim == 1:
        values = values[:, None]
    # slots beyond the supplied plane read ``fill``, never a clamped row —
    # a plane covering only the CSR prefix must fail soft, not alias row n-1
    ok = plan.emask & (plan.edge_slot >= 0) \
        & (plan.edge_slot < values.shape[0])
    rows = jnp.clip(plan.edge_slot, 0, values.shape[0] - 1)
    local = values[rows]                                # [K, Emax, F]
    return jnp.where(ok[:, :, None], local, jnp.float32(fill))


def segment_reduce_ref(plan, messages: jax.Array,
                       combine: str = "min") -> jax.Array:
    """XLA scatter reference (also the shard_map-path implementation).

    Accepts [K, Emax] or [K, Emax, F] messages like :func:`segment_reduce`.
    """
    ident = _IDENTITY[combine]
    squeeze = messages.ndim == 2
    msgs3 = messages[:, :, None] if squeeze else messages
    msgs = jnp.where(plan.emask[:, :, None], msgs3, ident)
    k = plan.edge_tgt.shape[0]
    rows = jnp.arange(k, dtype=jnp.int32)[:, None]
    out = jnp.full((k, plan.v_max, msgs3.shape[2]), ident, jnp.float32)
    out = _scatter_combine(out, rows, plan.edge_tgt, msgs, combine)
    out = jnp.where(plan.vmask[:, :, None], out, ident)
    return out[:, :, 0] if squeeze else out


def _gspmm_kernel(flags_ref, mask_ref, w_ref, vals_ref, o_ref, carry_ref, *,
                  combine: str, features: int):
    """Fused multiply + segmented combine over one [BLK_S, K·F] tile.

    ``flags``/``mask``/scalar ``w`` arrive K-wide and are broadcast to the
    K·F lane layout in VMEM (features minor); per-feature weight planes
    arrive K·F-wide already.  The weighted message x = v·w is formed and
    identity-masked inside the kernel — the weighted stream never exists
    in HBM.
    """
    op = _OPS[combine]
    ident = jnp.float32(_IDENTITY[combine])
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.full_like(carry_ref, ident)

    fl = jnp.repeat(flags_ref[...], features, axis=1)     # [BLK_S, K·F]
    ok = jnp.repeat(mask_ref[...], features, axis=1)
    w = w_ref[...]
    if w.shape[1] != fl.shape[1]:       # scalar per-half-edge weights
        w = jnp.repeat(w, features, axis=1)
    # multiply BEFORE masking: a dead slot's weight can never rescue it,
    # and the identity (±inf for min/max) is never multiplied by 0
    x = jnp.where(ok, vals_ref[...] * w, ident)

    def comb(a, b):
        af, av = a
        bf, bv = b
        return af | bf, jnp.where(bf, bv, op(av, bv))

    f_scan, v_scan = jax.lax.associative_scan(comb, (fl, x), axis=0)
    out = jnp.where(f_scan, v_scan, op(carry_ref[...], v_scan))
    o_ref[...] = out
    carry_ref[...] = out[-1:, :]


@functools.partial(jax.jit,
                   static_argnames=("combine", "block_s", "interpret"))
def _gspmm_scan(flags: jax.Array, mask: jax.Array, w: jax.Array,
                vals: jax.Array, combine: str, block_s: int,
                interpret: bool) -> jax.Array:
    """Segmented scan of masked v·w streams: flags/mask [S, K] bool,
    w [S, K] or [S, K·F], vals [S, K·F] -> scanned [S, K·F]."""
    s, kf = vals.shape
    k = flags.shape[1]
    f = kf // k
    ident = _IDENTITY[combine]
    s_pad = -(-s // block_s) * block_s
    fp = jnp.zeros((s_pad, k), jnp.bool_).at[:s].set(flags)
    mp = jnp.zeros((s_pad, k), jnp.bool_).at[:s].set(mask)
    wp = jnp.zeros((s_pad, w.shape[1]), jnp.float32).at[:s].set(w)
    vp = jnp.zeros((s_pad, kf), jnp.float32).at[:s].set(vals)
    out = pl.pallas_call(
        functools.partial(_gspmm_kernel, combine=combine, features=f),
        grid=(s_pad // block_s,),
        in_specs=[pl.BlockSpec((block_s, k), lambda i: (i, 0)),
                  pl.BlockSpec((block_s, k), lambda i: (i, 0)),
                  pl.BlockSpec((block_s, w.shape[1]), lambda i: (i, 0)),
                  pl.BlockSpec((block_s, kf), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_s, kf), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, kf), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, kf), jnp.float32)],
        interpret=interpret,
    )(fp, mp, wp, vp)
    return out[:s]


def _pad_k(x: jax.Array, k_pad: int, fill) -> jax.Array:
    """Pad the leading partition axis to ``k_pad`` lanes with ``fill``."""
    k = x.shape[0]
    if k_pad == k:
        return x
    pad = jnp.full((k_pad - k,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def gspmm(plan, feats: jax.Array, weights: jax.Array, combine: str = "add",
          *, block_s: int = 1024, interpret: bool = True) -> jax.Array:
    """Fused gSpMM: gather · multiply · segment-reduce in one kernel pass.

    DGL's ``u_mul_e_{sum,max,mean}`` shape on the partition-local layout:

    feats   [K, Vmax, F] (or [K, Vmax]) local feature rows, e.g. from
            :func:`gather_vertex_channel` or a program's ``pre``;
    weights [K, Emax] scalar per-half-edge (``plan.edge_w``) or
            [K, Emax, F] per-feature planes (a bound edge channel);
    combine "add"/"sum", "max", or "mean" (sum / clamped live-degree,
            isolated vertices aggregate to 0)
    -> [K, Vmax, F] per-target aggregates, identity at padding slots.

    The neighbour gather reuses the slack-aware ``plan.edge_nbr`` indices
    (maintained by the streaming patch path), the CSR prefix flows through
    ONE fused Pallas multiply+scan pass, and append-region half-edges are
    folded in by the same masked scatter as :func:`segment_reduce` — so
    the result is exact under in-place plan patches.  Partitions are
    padded so K·F stays a multiple of the 128-lane tile.
    """
    if combine == "sum":
        combine = "add"
    if combine == "mean":
        s = gspmm(plan, feats, weights, "add", block_s=block_s,
                  interpret=interpret)
        cnt = segment_reduce(plan, jnp.ones(plan.emask.shape, jnp.float32),
                             "add", block_s=block_s, interpret=interpret)
        return s / jnp.maximum(cnt, 1.0)[:, :, None]
    if feats.ndim == 2:
        feats = feats[:, :, None]
    k, e_max = plan.emask.shape
    f = feats.shape[2]
    ident = _IDENTITY[combine]
    rows = jnp.arange(k, dtype=jnp.int32)[:, None]
    msgs = feats[rows, plan.edge_nbr]                       # [K, Emax, F]
    w3 = weights[:, :, None] if weights.ndim == 2 else weights
    slot = jnp.arange(e_max, dtype=jnp.int32)[None, :]
    in_csr = slot < plan.csr_fill[:, None]
    live = plan.emask & in_csr
    # lane padding: k_pad·F a multiple of 128 so the folded lane axis tiles
    step = 128 // math.gcd(f, 128)
    k_pad = -(-k // step) * step
    flags = _pad_k(plan.seg_start, k_pad, False).T          # [Emax, k_pad]
    maskt = _pad_k(live, k_pad, False).T
    vals = _pad_k(msgs, k_pad, 0.0).transpose(1, 0, 2).reshape(
        e_max, k_pad * f)
    if weights.ndim == 2:
        wop = _pad_k(weights, k_pad, 0.0).T                 # [Emax, k_pad]
    else:
        wop = _pad_k(w3, k_pad, 0.0).transpose(1, 0, 2).reshape(
            e_max, k_pad * f)
    scanned = _gspmm_scan(flags, maskt, wop, vals, combine=combine,
                          block_s=block_s, interpret=interpret)
    scanned = scanned.reshape(e_max, k_pad, f).transpose(1, 0, 2)[:k]
    agg = scanned[rows, plan.last_slot]                     # [K, Vmax, F]
    # append-region half-edges: weighted outside the kernel (the region is
    # a small bounded slack), combined by the same masked scatter
    slack = jnp.where((plan.emask & ~in_csr)[:, :, None], msgs * w3, ident)
    agg = _scatter_combine(agg, rows, plan.edge_tgt, slack, combine)
    return jnp.where(plan.vmask[:, :, None], agg, ident)


def gspmm_ref(plan, feats: jax.Array, weights: jax.Array,
              combine: str = "add") -> jax.Array:
    """Unfused XLA reference for :func:`gspmm`: gather, materialise the
    weighted message stream, scatter segment-reduce (also the
    shard_map-path implementation)."""
    if combine == "sum":
        combine = "add"
    if combine == "mean":
        s = gspmm_ref(plan, feats, weights, "add")
        cnt = segment_reduce_ref(plan, jnp.ones(plan.emask.shape,
                                                jnp.float32), "add")
        return s / jnp.maximum(cnt, 1.0)[:, :, None]
    if feats.ndim == 2:
        feats = feats[:, :, None]
    rows = jnp.arange(plan.emask.shape[0], dtype=jnp.int32)[:, None]
    msgs = feats[rows, plan.edge_nbr]
    w3 = weights[:, :, None] if weights.ndim == 2 else weights
    return segment_reduce_ref(plan, msgs * w3, combine)


def _update_kernel(state_ref, inc_ref, vmask_ref, rep_ref, o_ref, *,
                   combine: str):
    ident = jnp.float32(_IDENTITY[combine])
    st = state_ref[...]
    inc = inc_ref[...]
    new = jnp.where(rep_ref[...], inc, st)
    o_ref[...] = jnp.where(vmask_ref[...], new, ident)


@functools.partial(jax.jit,
                   static_argnames=("combine", "block_v", "interpret"))
def masked_update(state: jax.Array, incoming: jax.Array, vmask: jax.Array,
                  replicated: jax.Array, combine: str = "min",
                  block_v: int = 2048, interpret: bool = True) -> jax.Array:
    """Apply exchanged values to replicated slots: state/incoming [K, Vmax]
    or [K, Vmax, F] (the feature axis folds onto the slot axis — masks are
    per-vertex, so they broadcast by repetition)."""
    if state.ndim == 3:
        k, v, f = state.shape
        out = masked_update(state.reshape(k, v * f),
                            incoming.reshape(k, v * f),
                            jnp.repeat(vmask, f, axis=1),
                            jnp.repeat(replicated, f, axis=1),
                            combine=combine, block_v=block_v,
                            interpret=interpret)
        return out.reshape(k, v, f)
    k, v = state.shape
    ident = _IDENTITY[combine]
    k_pad = -(-k // 8) * 8
    v_pad = -(-v // block_v) * block_v
    sp = jnp.full((k_pad, v_pad), ident, jnp.float32).at[:k, :v].set(state)
    ip = jnp.full((k_pad, v_pad), ident, jnp.float32).at[:k, :v].set(incoming)
    mp = jnp.zeros((k_pad, v_pad), jnp.bool_).at[:k, :v].set(vmask)
    rp = jnp.zeros((k_pad, v_pad), jnp.bool_).at[:k, :v].set(replicated)
    out = pl.pallas_call(
        functools.partial(_update_kernel, combine=combine),
        grid=(v_pad // block_v,),
        in_specs=[pl.BlockSpec((k_pad, block_v), lambda i: (0, i))] * 4,
        out_specs=pl.BlockSpec((k_pad, block_v), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k_pad, v_pad), jnp.float32),
        interpret=interpret,
    )(sp, ip, mp, rp)
    return out[:k, :v]

"""Pallas TPU kernels for the partition-local engine layout.

Two kernels, both specialized to the ``PartitionPlan`` CSR blocks:

``segment_reduce``
    The gather/aggregate hot-spot of a superstep: reduce per-half-edge
    messages into per-target-vertex aggregates.  The CSR stream is sorted by
    target, so this is a *segmented* scan.  TPU mapping follows
    kernels/lane_cumsum.py: partitions are the 128-wide lane axis (each lane
    is one partition's independent edge stream), the edge-slot axis is
    blocked into [BLK_S, K] VMEM tiles walked sequentially, and a [1, K]
    VMEM scratch carries the running value of each lane's open segment
    across tiles.  Inside a tile the segmented combine runs as an
    associative scan on (segment-start flag, value) pairs.  The caller then
    picks each vertex's aggregate out of the scanned stream at
    ``plan.last_slot`` (a plain gather; padding slots hold the identity
    because the padding region starts a fresh identity-valued segment).

``masked_update``
    The frontier/replica-update step of the exchange: replicated slots take
    the exchanged (cut-combined) value, private slots keep their local
    value, padding slots are pinned to the identity.  Mirrors the masked
    [K, V]-tile style of kernels/frontier_min.py.

Both support combine ∈ {"min", "add"} (SSSP/WCC vs PageRank) and run in
interpret mode on CPU.

The message stream is per-half-edge, so weighted programs need no kernel
changes: the runtime applies the ``EdgeProgram.edge`` hook (e.g.
``msgs + plan.edge_w`` for weighted SSSP) after the neighbour gather, and
the weighted messages flow through the same segmented scan — masked
(deleted/padding) slots are pinned to the combine identity *after* the
hook, so they stay inert regardless of their weight.

``gather_vertex_channel`` / ``gather_edge_channel`` lay externally
supplied property planes (registry ``role="channel"`` params) out to the
partition-local padded shapes the programs consume — slack-aware (pad and
reserved slots pinned to the fill value) and fully traced, so the same
compiled gather serves every in-place plan patch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_IDENTITY = {"min": jnp.inf, "add": 0.0}
_OPS = {"min": jnp.minimum, "add": jnp.add}


def _seg_kernel(flags_ref, vals_ref, o_ref, carry_ref, *, combine: str):
    op = _OPS[combine]
    ident = jnp.float32(_IDENTITY[combine])
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.full_like(carry_ref, ident)

    f = flags_ref[...]                        # [BLK_S, K] bool
    v = vals_ref[...]                         # [BLK_S, K] f32

    def comb(a, b):
        af, av = a
        bf, bv = b
        return af | bf, jnp.where(bf, bv, op(av, bv))

    f_scan, v_scan = jax.lax.associative_scan(comb, (f, v), axis=0)
    # rows before the tile's first segment start continue the carried segment
    out = jnp.where(f_scan, v_scan, op(carry_ref[...], v_scan))
    o_ref[...] = out
    carry_ref[...] = out[-1:, :]


@functools.partial(jax.jit,
                   static_argnames=("combine", "block_s", "interpret"))
def segment_scan(flags: jax.Array, vals: jax.Array, combine: str = "min",
                 block_s: int = 1024, interpret: bool = True) -> jax.Array:
    """Segmented inclusive scan along axis 0 of [S, K] streams.

    ``flags[s, k]`` True starts a new segment in lane k.  Returns the
    running combine of each open segment; the value at a segment's last row
    is the full segment reduction.
    """
    s, k = vals.shape
    ident = _IDENTITY[combine]
    s_pad = -(-s // block_s) * block_s
    k_pad = -(-k // 128) * 128
    fp = jnp.zeros((s_pad, k_pad), jnp.bool_).at[:s, :k].set(flags)
    # padding rows/lanes: identity values, no segment starts — harmless
    vp = jnp.full((s_pad, k_pad), ident, jnp.float32).at[:s, :k].set(vals)
    out = pl.pallas_call(
        functools.partial(_seg_kernel, combine=combine),
        grid=(s_pad // block_s,),
        in_specs=[pl.BlockSpec((block_s, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((block_s, k_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_s, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, k_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, k_pad), jnp.float32)],
        interpret=interpret,
    )(fp, vp)
    return out[:s, :k]


def segment_reduce(plan, messages: jax.Array, combine: str = "min",
                   block_s: int = 1024, interpret: bool = True) -> jax.Array:
    """Per-target aggregates over the plan's CSR stream.

    messages [K, Emax] (identity at masked slots) -> aggregates [K, Vmax]
    (identity at padding vertices).

    Slack-aware bounds: the segmented scan covers only the sorted CSR prefix
    ``[0, csr_fill)`` of each lane; half-edges appended by the streaming
    patch path live in ``[csr_fill, e_max)`` in arbitrary order, so their
    contribution is combined by a masked scatter on top of the scanned
    aggregate.  Masked (deleted/padding) slots are pinned to the combine
    identity in both regions and are therefore inert for min and add alike.
    """
    ident = _IDENTITY[combine]
    slot = jnp.arange(plan.emask.shape[1], dtype=jnp.int32)[None, :]
    in_csr = slot < plan.csr_fill[:, None]                          # [K, Emax]
    msgs = jnp.where(plan.emask & in_csr, messages, ident)
    scanned = segment_scan(plan.seg_start.T, msgs.T, combine=combine,
                           block_s=block_s, interpret=interpret).T  # [K, Emax]
    rows = jnp.arange(plan.emask.shape[0], dtype=jnp.int32)[:, None]
    agg = scanned[rows, plan.last_slot]                             # [K, Vmax]
    # append-region contributions (each appended half-edge is one segment)
    slack = jnp.where(plan.emask & ~in_csr, messages, ident)
    if combine == "min":
        agg = agg.at[rows, plan.edge_tgt].min(slack)
    else:  # add identity is 0.0, so the masked scatter is exact
        agg = agg.at[rows, plan.edge_tgt].add(slack)
    return jnp.where(plan.vmask, agg, ident)


def gather_vertex_channel(plan, values: jax.Array) -> jax.Array:
    """Slack-aware layout of a global vertex property plane.

    values [V, F] (or [V]) -> [K, Vmax, F]: each live local slot takes its
    vertex's feature row via ``plan.local2global``; padding AND reserved
    slack slots (``vmask`` False) are pinned to 0.0 so a patched plan that
    populates a slack slot later picks the right row automatically — the
    gather runs traced, against the dynamic plan children, so it is valid
    for every in-place patch without retracing.  Programs call this from
    ``prepare`` (inside the shard_map body on mesh paths, where the local
    plan block gathers from the replicated [V, F] plane).
    """
    if values.ndim == 1:
        values = values[:, None]
    local = values[plan.local2global]                   # [K, Vmax, F]
    return jnp.where(plan.vmask[:, :, None], local, 0.0)


def gather_edge_channel(plan, values: jax.Array, fill: float = 0.0
                        ) -> jax.Array:
    """Slack-aware layout of an edge property plane in graph slot order.

    values [E_pad, F] (or [E_pad]) -> [K, Emax, F]: every live half-edge
    (CSR prefix *and* append/slack region — ``plan.edge_slot`` is
    maintained by both compile_plan and the streaming patch path) takes the
    feature row of its undirected edge's graph slot; pad slots and
    half-edges whose slot is unknown (patched in without slot provenance,
    edge_slot == -1) take ``fill``.  Masked slots are additionally pinned
    to the combine identity downstream of the ``edge`` hook, so garbage can
    never leak into an aggregate.
    """
    if values.ndim == 1:
        values = values[:, None]
    # slots beyond the supplied plane read ``fill``, never a clamped row —
    # a plane covering only the CSR prefix must fail soft, not alias row n-1
    ok = plan.emask & (plan.edge_slot >= 0) \
        & (plan.edge_slot < values.shape[0])
    rows = jnp.clip(plan.edge_slot, 0, values.shape[0] - 1)
    local = values[rows]                                # [K, Emax, F]
    return jnp.where(ok[:, :, None], local, jnp.float32(fill))


def segment_reduce_ref(plan, messages: jax.Array,
                       combine: str = "min") -> jax.Array:
    """XLA scatter reference (also the shard_map-path implementation)."""
    ident = _IDENTITY[combine]
    msgs = jnp.where(plan.emask, messages, ident)
    rows = jnp.arange(plan.edge_tgt.shape[0], dtype=jnp.int32)[:, None]
    out = jnp.full((plan.edge_tgt.shape[0], plan.v_max), ident, jnp.float32)
    if combine == "min":
        out = out.at[rows, plan.edge_tgt].min(msgs)
    else:  # msgs already masked to the add identity 0.0
        out = out.at[rows, plan.edge_tgt].add(msgs)
    return jnp.where(plan.vmask, out, ident)


def _update_kernel(state_ref, inc_ref, vmask_ref, rep_ref, o_ref, *,
                   combine: str):
    ident = jnp.float32(_IDENTITY[combine])
    st = state_ref[...]
    inc = inc_ref[...]
    new = jnp.where(rep_ref[...], inc, st)
    o_ref[...] = jnp.where(vmask_ref[...], new, ident)


@functools.partial(jax.jit,
                   static_argnames=("combine", "block_v", "interpret"))
def masked_update(state: jax.Array, incoming: jax.Array, vmask: jax.Array,
                  replicated: jax.Array, combine: str = "min",
                  block_v: int = 2048, interpret: bool = True) -> jax.Array:
    """Apply exchanged values to replicated slots: state/incoming [K, Vmax]."""
    k, v = state.shape
    ident = _IDENTITY[combine]
    k_pad = -(-k // 8) * 8
    v_pad = -(-v // block_v) * block_v
    sp = jnp.full((k_pad, v_pad), ident, jnp.float32).at[:k, :v].set(state)
    ip = jnp.full((k_pad, v_pad), ident, jnp.float32).at[:k, :v].set(incoming)
    mp = jnp.zeros((k_pad, v_pad), jnp.bool_).at[:k, :v].set(vmask)
    rp = jnp.zeros((k_pad, v_pad), jnp.bool_).at[:k, :v].set(replicated)
    out = pl.pallas_call(
        functools.partial(_update_kernel, combine=combine),
        grid=(v_pad // block_v,),
        in_specs=[pl.BlockSpec((k_pad, block_v), lambda i: (0, i))] * 4,
        out_specs=pl.BlockSpec((k_pad, block_v), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k_pad, v_pad), jnp.float32),
        interpret=interpret,
    )(sp, ip, mp, rp)
    return out[:k, :v]

"""Declarative per-vertex state shape for superstep programs.

Before PR 10 every program's state was implicitly a scalar ``[V]``
float32 plane: the engine allocated it, the warm store cached it, and
gserve materialised it, all with the rank hard-coded.  ``StateSpec``
makes the rank declarative — a program states how many features each
vertex carries and what a "cold" (no prior information) row looks like,
and every layer derives its shapes from that one declaration:

* ``runtime.Engine`` validates ``warm_state`` against ``spec.shape(V)``
  (or ``spec.batch_shape(S, V)`` for batched dispatch) and raises a
  typed :class:`~repro.engine.errors.WarmStateError` instead of letting
  a rank mismatch surface as a reshape crash inside jit;
* the gserve warm store keys its blocks by ``spec.key()`` and builds
  cold rows with ``spec.cold(V)``, so a program re-registered with a
  different state rank can never replay an old-rank block;
* scalar programs are simply the default ``StateSpec()`` — the F=1
  special case of the one code path, not a separate branch.

The module imports only stdlib + numpy so both ``registry`` and
``runtime`` can depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["SCALAR", "StateSpec"]


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """Shape/dtype/init contract for one program's per-vertex state.

    ``features == 1`` means scalar state served as a rank-1 ``[V]``
    plane (the legacy shape, bit-identical to the pre-StateSpec path);
    ``features > 1`` means a ``[V, F]`` feature plane.  ``fill`` is the
    cold-row value warm blocks use for vertices with no prior epoch —
    ``inf`` for min-combine distances, typically ``0`` for feature
    planes.
    """

    features: int = 1
    dtype: str = "float32"
    fill: float = math.inf

    def __post_init__(self) -> None:
        if not isinstance(self.features, int) or self.features < 1:
            raise ValueError(
                f"StateSpec.features must be a positive int, "
                f"got {self.features!r}")
        np.dtype(self.dtype)  # raises TypeError on gibberish

    def shape(self, n_vertices: int) -> tuple[int, ...]:
        """Finalized result shape for ``n_vertices`` vertices.

        The single place the scalar-vs-vector rank decision lives:
        ``(V,)`` for scalar programs, ``(V, F)`` for feature planes.
        """
        if self.features == 1:
            return (n_vertices,)
        return (n_vertices, self.features)

    def batch_shape(self, batch: int, n_vertices: int) -> tuple[int, ...]:
        """Shape of a batched (leading lane axis) result block."""
        return (batch,) + self.shape(n_vertices)

    def cold(self, n_vertices: int) -> np.ndarray:
        """A fresh "no prior information" row block (warm-store filler)."""
        return np.full(self.shape(n_vertices), self.fill,
                       np.dtype(self.dtype))

    def key(self) -> tuple:
        """Hashable identity for warm-store keying: two programs whose
        state blocks are interchangeable share a key, nothing else does."""
        return (self.features, self.dtype, self.fill)

    def describe(self) -> str:
        """Human-readable shape tag for error messages."""
        if self.features == 1:
            return f"scalar [V] {self.dtype}"
        return f"[V, {self.features}] {self.dtype}"


#: The legacy implicit contract, now spelled out: scalar float32, cold=inf.
SCALAR = StateSpec()

"""Compile a Graph + edge-partition assignment into an executable plan.

The ETSCH runtime in ``core/etsch.py`` keeps per-partition state as a dense
``[K, V]`` matrix — every partition carries a slot for every global vertex,
so memory and sweep cost scale with ``K * V`` regardless of how good the
partitioning is.  The engine instead *compacts* each partition to the
vertices it actually touches:

  * each partition i gets a local id space ``0 .. n_local[i]`` over the
    endpoints of its owned edges (``local2global`` maps back),
  * owned undirected edges are expanded to two directed half-edges and laid
    out in CSR order by target local id — the layout the segment-reduce
    kernel (engine/kernels.py) consumes,
  * the replica-exchange plan records which local slots are replicas of a
    vertex that also lives in other partitions (``replicated``), and which
    partition is the designated master (``is_master``, lowest partition id).

Only replicated slots ever need to cross the partition boundary during a
superstep: a vertex that lives in a single partition has *all* of its
incident edges there (edge partitioning guarantees this), so its aggregate
is already complete locally.  Per-superstep exchange volume is therefore
exactly ``sum(replicated)`` = Σ|F_i| — the paper's MESSAGES metric (§V-A),
which ``core/metrics.py`` computes combinatorially; the engine gives the
same number operationally (see tests/test_metrics_engine.py).

All arrays are padded to static lane-aligned shapes so every superstep jits
and shard_maps: ``v_max`` / ``e_max`` are the max over partitions, rounded
up to 128, with at least one guaranteed padding slot in the edge stream
(the segment-scan parks degree-0 / padding vertices there).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from ..core.graph import Graph


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Per-partition compacted CSR blocks + replica exchange plan."""

    # static
    k: int                   # number of partitions
    n_vertices: int          # global |V|
    v_max: int               # padded local-vertex capacity
    e_max: int               # padded directed-half-edge capacity (>= 1 pad slot)
    exchange_volume: int     # Σ|F_i| — replica slots crossing the cut/superstep
    sum_local_vertices: int  # Σ|V_i|

    # local vertex space
    local2global: jax.Array  # [K, Vmax] int32 — global id per local slot (pad: 0)
    vmask: jax.Array         # [K, Vmax] bool  — slot holds a real vertex
    # CSR half-edge stream, sorted by target local id
    edge_tgt: jax.Array      # [K, Emax] int32 — target local id (nondecreasing)
    edge_nbr: jax.Array      # [K, Emax] int32 — neighbour local id
    emask: jax.Array         # [K, Emax] bool  — real half-edge
    seg_start: jax.Array     # [K, Emax] bool  — first half-edge of its target
    last_slot: jax.Array     # [K, Vmax] int32 — last CSR slot per target
                             #   (pad vertices -> a pad edge slot holding identity)
    # replica exchange plan
    replicated: jax.Array    # [K, Vmax] bool — vertex also lives elsewhere
    is_master: jax.Array     # [K, Vmax] bool — this partition owns the vertex
    n_local: jax.Array       # [K] int32 — real local vertices per partition
    n_edges_local: jax.Array # [K] int32 — real owned (undirected) edges

    def tree_flatten(self):
        children = (self.local2global, self.vmask, self.edge_tgt,
                    self.edge_nbr, self.emask, self.seg_start, self.last_slot,
                    self.replicated, self.is_master, self.n_local,
                    self.n_edges_local)
        return children, (self.k, self.n_vertices, self.v_max, self.e_max,
                          self.exchange_volume, self.sum_local_vertices)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*aux, *children)

    # -- replica-exchange accounting (compile-time constants) ---------------
    def exchange_per_superstep(self) -> int:
        """Vertex states crossing the cut per superstep: Σ|F_i| (MESSAGES)."""
        return self.exchange_volume

    def replication_factor(self) -> float:
        """Σ|V_i| / |V| — the paper's replication factor."""
        return self.sum_local_vertices / max(self.n_vertices, 1)

    def local_edges(self) -> list[np.ndarray]:
        """Per-partition [e_i, 2] arrays of owned undirected edges (global
        ids, u < v) — used by the round-trip test."""
        l2g = np.asarray(self.local2global)
        tgt = np.asarray(self.edge_tgt)
        nbr = np.asarray(self.edge_nbr)
        em = np.asarray(self.emask)
        out = []
        for i in range(self.k):
            t = l2g[i, tgt[i, em[i]]]
            n = l2g[i, nbr[i, em[i]]]
            u, v = np.minimum(t, n), np.maximum(t, n)
            # every undirected edge appears as two half-edges
            pairs = np.unique(np.stack([u, v], 1), axis=0)
            out.append(pairs)
        return out


def _align(x: int, to: int = 128) -> int:
    return max(to, -(-x // to) * to)


def compile_plan(g: Graph, owner, k: int) -> PartitionPlan:
    """Host-side compilation (numpy): bucket, compact, CSR-sort, pad."""
    owner = np.asarray(owner)
    u = np.asarray(g.src)
    v = np.asarray(g.dst)
    em = np.asarray(g.edge_mask)
    u, v, owner = u[em], v[em], owner[em]
    assert len(u) == 0 or (owner.min() >= 0 and owner.max() < k), \
        "owner must assign every real edge to [0, k)"

    # per-partition compacted vertex sets ---------------------------------
    locals_: list[np.ndarray] = []
    for i in range(k):
        sel = owner == i
        locals_.append(np.unique(np.concatenate([u[sel], v[sel]])))
    n_local = np.array([len(x) for x in locals_], np.int32)
    e_cnt = np.array([int((owner == i).sum()) for i in range(k)], np.int32)
    v_max = _align(int(n_local.max(initial=1)))
    # 2 half-edges per owned edge; +1 guarantees a padding slot for last_slot
    e_max = _align(int(2 * e_cnt.max(initial=1)) + 1)

    l2g = np.zeros((k, v_max), np.int32)
    vmask = np.zeros((k, v_max), bool)
    tgt = np.zeros((k, e_max), np.int32)
    nbr = np.zeros((k, e_max), np.int32)
    emask_p = np.zeros((k, e_max), bool)
    seg_start = np.zeros((k, e_max), bool)
    # degree-0/pad vertices point at the last slot, which is always padding
    last_slot = np.full((k, v_max), e_max - 1, np.int32)

    for i in range(k):
        verts = locals_[i]
        nl = len(verts)
        l2g[i, :nl] = verts
        vmask[i, :nl] = True
        sel = owner == i
        g2l = np.zeros(g.n_vertices, np.int64)
        g2l[verts] = np.arange(nl)
        ut, vt = g2l[u[sel]], g2l[v[sel]]
        t = np.concatenate([ut, vt])            # half-edge targets
        n = np.concatenate([vt, ut])            # half-edge sources
        order = np.argsort(t, kind="stable")
        t, n = t[order], n[order]
        ne = len(t)
        tgt[i, :ne] = t
        nbr[i, :ne] = n
        emask_p[i, :ne] = True
        if ne:
            seg_start[i, 0] = True
            seg_start[i, 1:ne] = t[1:] != t[:-1]
            # last slot of each target's run
            is_last = np.ones(ne, bool)
            is_last[:-1] = t[1:] != t[:-1]
            last_slot[i, t[is_last]] = np.flatnonzero(is_last)
        # padding region starts a fresh (identity-valued) segment
        if ne < e_max:
            seg_start[i, ne] = True

    # replica exchange plan ------------------------------------------------
    copies = np.zeros(g.n_vertices, np.int32)
    for i in range(k):
        copies[locals_[i]] += 1
    master_of = np.full(g.n_vertices, -1, np.int32)
    for i in reversed(range(k)):                # lowest partition id wins
        master_of[locals_[i]] = i
    replicated = vmask & (copies[l2g] >= 2)
    is_master = vmask & (master_of[l2g] == np.arange(k)[:, None])

    return PartitionPlan(
        k=int(k), n_vertices=int(g.n_vertices), v_max=int(v_max),
        e_max=int(e_max),
        exchange_volume=int(replicated.sum()),
        sum_local_vertices=int(vmask.sum()),
        local2global=jnp.asarray(l2g), vmask=jnp.asarray(vmask),
        edge_tgt=jnp.asarray(tgt), edge_nbr=jnp.asarray(nbr),
        emask=jnp.asarray(emask_p), seg_start=jnp.asarray(seg_start),
        last_slot=jnp.asarray(last_slot),
        replicated=jnp.asarray(replicated), is_master=jnp.asarray(is_master),
        n_local=jnp.asarray(n_local), n_edges_local=jnp.asarray(e_cnt),
    )

"""Compile a Graph + edge-partition assignment into an executable plan.

The ETSCH runtime in ``core/etsch.py`` keeps per-partition state as a dense
``[K, V]`` matrix — every partition carries a slot for every global vertex,
so memory and sweep cost scale with ``K * V`` regardless of how good the
partitioning is.  The engine instead *compacts* each partition to the
vertices it actually touches:

  * each partition i gets a local id space ``0 .. n_local[i]`` over the
    endpoints of its owned edges (``local2global`` maps back),
  * owned undirected edges are expanded to two directed half-edges and laid
    out in CSR order by target local id — the layout the segment-reduce
    kernel (engine/kernels.py) consumes,
  * the replica-exchange plan records which local slots are replicas of a
    vertex that also lives in other partitions (``replicated``), and which
    partition is the designated master (``is_master``, lowest partition id).

Only replicated slots ever need to cross the partition boundary during a
superstep: a vertex that lives in a single partition has *all* of its
incident edges there (edge partitioning guarantees this), so its aggregate
is already complete locally.  Per-superstep exchange volume is therefore
exactly ``sum(replicated)`` = Σ|F_i| — the paper's MESSAGES metric (§V-A),
which ``core/metrics.py`` computes combinatorially; the engine gives the
same number operationally (see tests/test_metrics_engine.py).

All arrays are padded to static lane-aligned shapes so every superstep jits
and shard_maps: ``v_max`` / ``e_max`` are the max over partitions, rounded
up to 128, with at least one guaranteed padding slot in the edge stream
(the segment-scan parks degree-0 / padding vertices there).

Streaming support (repro.stream): plans can be compiled with reserved
*slack* — extra CSR edge slots and local-vertex slots per partition.
``csr_fill`` / ``v_fill`` mark the boundary between the sorted CSR prefix
and the append region; ``patch.py`` appends half-edges for inserted edges
into the slack, clears ``emask`` bits for deletions, and rewrites the
replica masks in place.  Everything that changes under a patch is a pytree
*child* (dynamic), so a patched plan has the identical treedef and avals —
jitted supersteps hit their existing compilation cache.  The static aux
carries ``epoch``: only a compaction (full recompile) bumps it, making the
epoch the cache key for anything derived from static plan structure.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib

import numpy as np

import jax
import jax.numpy as jnp

from .. import obs as _obs
from ..core.graph import Graph, edge_weights


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Per-partition compacted CSR blocks + replica exchange plan."""

    # static (pytree aux — stable across in-place patches)
    k: int                   # number of partitions
    n_vertices: int          # global |V|
    v_max: int               # padded local-vertex capacity
    e_max: int               # padded directed-half-edge capacity (>= 1 pad slot)
    epoch: int               # compaction epoch; bumps only on full recompile
    e_slots: int             # Graph.e_pad the plan was compiled against —
                             #   the static row capacity of edge property
                             #   channels ([E_pad, F] planes in slot order)

    # local vertex space
    local2global: jax.Array  # [K, Vmax] int32 — global id per local slot (pad: 0)
    vmask: jax.Array         # [K, Vmax] bool  — slot holds a real vertex
    # CSR half-edge stream, sorted by target local id in [0, csr_fill);
    # [csr_fill, e_max) is the append/slack region (each appended half-edge
    # is its own segment — the kernels combine it by masked scatter)
    edge_tgt: jax.Array      # [K, Emax] int32 — target local id (nondecreasing
                             #   within the CSR prefix)
    edge_nbr: jax.Array      # [K, Emax] int32 — neighbour local id
    emask: jax.Array         # [K, Emax] bool  — real half-edge
    seg_start: jax.Array     # [K, Emax] bool  — first half-edge of its target
    last_slot: jax.Array     # [K, Vmax] int32 — last CSR slot per target
                             #   (pad vertices -> a pad edge slot holding identity)
    # replica exchange plan
    replicated: jax.Array    # [K, Vmax] bool — vertex also lives elsewhere
    is_master: jax.Array     # [K, Vmax] bool — this partition owns the vertex
    n_local: jax.Array       # [K] int32 — real local vertices per partition
    n_edges_local: jax.Array # [K] int32 — real owned (undirected) edges
    n_replicated: jax.Array  # [K] int32 — replicated slots per partition
    csr_fill: jax.Array      # [K] int32 — first slot of the append region
    v_fill: jax.Array        # [K] int32 — next free local-vertex slot
    # per-half-edge weights (graph.edge_weights content hash; pad: 1.0) —
    # weighted programs consume them via the EdgeProgram ``edge`` hook
    # (messages flow weighted through the segment-reduce kernels; masked
    # slots are still pinned to the combine identity there)
    edge_w: jax.Array        # [K, Emax] float32
    # graph edge slot of each half-edge (-1 at pad / unknown slots) — the
    # index plane edge property channels gather through
    # (kernels.gather_edge_channel); maintained by compile_plan AND the
    # streaming patch path so externally supplied [E_pad, F] planes stay
    # aligned across in-place plan patches
    edge_slot: jax.Array     # [K, Emax] int32

    def tree_flatten(self):
        children = (self.local2global, self.vmask, self.edge_tgt,
                    self.edge_nbr, self.emask, self.seg_start, self.last_slot,
                    self.replicated, self.is_master, self.n_local,
                    self.n_edges_local, self.n_replicated, self.csr_fill,
                    self.v_fill, self.edge_w, self.edge_slot)
        return children, (self.k, self.n_vertices, self.v_max, self.e_max,
                          self.epoch, self.e_slots)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*aux, *children)

    # -- replica-exchange accounting ----------------------------------------
    # These are *dynamic* (children-derived) so streaming patches can change
    # them without invalidating jit caches keyed on the plan treedef.  The
    # host sums are memoized per instance so the serving path (Engine.run
    # reads exchange_volume every query) never repeats the device sync.
    @property
    def exchange_volume(self) -> int:
        """Vertex states crossing the cut per superstep: Σ|F_i| (MESSAGES)."""
        cached = self.__dict__.get("_exchange_volume")
        if cached is None:
            cached = int(jnp.sum(self.n_replicated))
            object.__setattr__(self, "_exchange_volume", cached)
        return cached

    @property
    def sum_local_vertices(self) -> int:
        cached = self.__dict__.get("_sum_local_vertices")
        if cached is None:
            cached = int(jnp.sum(self.n_local))
            object.__setattr__(self, "_sum_local_vertices", cached)
        return cached

    @property
    def edge_slot_hwm(self) -> int:
        """1 + the highest graph edge slot any live half-edge references —
        the minimum row count an edge channel plane must supply. Memoized
        host-side like the other replica stats (one device sync per plan
        instance; the serving path validates every channel dispatch)."""
        cached = self.__dict__.get("_edge_slot_hwm")
        if cached is None:
            cached = int(jnp.max(jnp.where(self.emask, self.edge_slot,
                                           -1))) + 1
            object.__setattr__(self, "_edge_slot_hwm", cached)
        return cached

    def exchange_per_superstep(self) -> int:
        return self.exchange_volume

    def replication_factor(self) -> float:
        """Σ|V_i| / |V| — the paper's replication factor."""
        return self.sum_local_vertices / max(self.n_vertices, 1)

    def local_edges(self) -> list[np.ndarray]:
        """Per-partition [e_i, 2] arrays of owned undirected edges (global
        ids, u < v) — used by the round-trip test."""
        l2g = np.asarray(self.local2global)
        tgt = np.asarray(self.edge_tgt)
        nbr = np.asarray(self.edge_nbr)
        em = np.asarray(self.emask)
        out = []
        for i in range(self.k):
            t = l2g[i, tgt[i, em[i]]]
            n = l2g[i, nbr[i, em[i]]]
            u, v = np.minimum(t, n), np.maximum(t, n)
            # every undirected edge appears as two half-edges
            pairs = np.unique(np.stack([u, v], 1), axis=0)
            out.append(pairs)
        return out


def _align(x: int, to: int = 128) -> int:
    return max(to, -(-x // to) * to)


def replica_masks(l2g: np.ndarray, vmask: np.ndarray, n_vertices: int,
                  k: int) -> tuple[np.ndarray, np.ndarray]:
    """(replicated, is_master) recomputed from scratch — shared by
    compile_plan and the streaming patch path."""
    copies = np.zeros(n_vertices, np.int32)
    master_of = np.full(n_vertices, -1, np.int32)
    for i in reversed(range(k)):                # lowest partition id wins
        present = l2g[i, vmask[i]]
        master_of[present] = i
    for i in range(k):
        copies[l2g[i, vmask[i]]] += 1
    replicated = vmask & (copies[l2g] >= 2)
    is_master = vmask & (master_of[l2g] == np.arange(k)[:, None])
    return replicated, is_master


def compile_plan(g: Graph, owner, k: int, *, edge_slack: int = 0,
                 vertex_slack: int = 0, epoch: int = 0) -> PartitionPlan:
    """Host-side compilation (numpy): bucket, compact, CSR-sort, pad.

    ``edge_slack`` / ``vertex_slack`` reserve per-partition capacity (in
    undirected edges / local vertices) for the streaming patch path.
    """
    owner = np.asarray(owner)
    u = np.asarray(g.src)
    v = np.asarray(g.dst)
    em = np.asarray(g.edge_mask)
    gslot = np.flatnonzero(em).astype(np.int32)   # graph slot per live edge
    u, v, owner = u[em], v[em], owner[em]
    assert len(u) == 0 or (owner.min() >= 0 and owner.max() < k), \
        "owner must assign every real edge to [0, k)"

    # per-partition compacted vertex sets ---------------------------------
    locals_: list[np.ndarray] = []
    for i in range(k):
        sel = owner == i
        locals_.append(np.unique(np.concatenate([u[sel], v[sel]])))
    n_local = np.array([len(x) for x in locals_], np.int32)
    e_cnt = np.array([int((owner == i).sum()) for i in range(k)], np.int32)
    v_max = _align(int(n_local.max(initial=1)) + int(vertex_slack))
    # 2 half-edges per owned edge; +1 guarantees a padding slot for last_slot
    e_max = _align(int(2 * e_cnt.max(initial=1)) + 1 + 2 * int(edge_slack))

    l2g = np.zeros((k, v_max), np.int32)
    vmask = np.zeros((k, v_max), bool)
    tgt = np.zeros((k, e_max), np.int32)
    nbr = np.zeros((k, e_max), np.int32)
    emask_p = np.zeros((k, e_max), bool)
    seg_start = np.zeros((k, e_max), bool)
    ew = np.ones((k, e_max), np.float32)
    eslot = np.full((k, e_max), -1, np.int32)
    # degree-0/pad vertices point at the last slot, which is always padding
    last_slot = np.full((k, v_max), e_max - 1, np.int32)

    for i in range(k):
        verts = locals_[i]
        nl = len(verts)
        l2g[i, :nl] = verts
        vmask[i, :nl] = True
        sel = owner == i
        g2l = np.zeros(g.n_vertices, np.int64)
        g2l[verts] = np.arange(nl)
        ut, vt = g2l[u[sel]], g2l[v[sel]]
        t = np.concatenate([ut, vt])            # half-edge targets
        n = np.concatenate([vt, ut])            # half-edge sources
        w2 = np.tile(edge_weights(u[sel], v[sel]), 2)   # both half-edges
        s2 = np.tile(gslot[sel], 2)             # graph slot, both half-edges
        order = np.argsort(t, kind="stable")
        t, n, w2, s2 = t[order], n[order], w2[order], s2[order]
        ne = len(t)
        tgt[i, :ne] = t
        nbr[i, :ne] = n
        ew[i, :ne] = w2
        eslot[i, :ne] = s2
        emask_p[i, :ne] = True
        if ne:
            seg_start[i, 0] = True
            seg_start[i, 1:ne] = t[1:] != t[:-1]
            # last slot of each target's run
            is_last = np.ones(ne, bool)
            is_last[:-1] = t[1:] != t[:-1]
            last_slot[i, t[is_last]] = np.flatnonzero(is_last)
        # padding region starts a fresh (identity-valued) segment
        if ne < e_max:
            seg_start[i, ne] = True

    # replica exchange plan ------------------------------------------------
    replicated, is_master = replica_masks(l2g, vmask, g.n_vertices, k)

    rec = _obs.get()
    if rec.enabled:
        rec.counter("plan.compiles")
        rec.event("plan.compile", k=int(k), epoch=int(epoch),
                  n_vertices=int(g.n_vertices), v_max=int(v_max),
                  e_max=int(e_max), edge_slack=int(edge_slack),
                  vertex_slack=int(vertex_slack))
    return PartitionPlan(
        k=int(k), n_vertices=int(g.n_vertices), v_max=int(v_max),
        e_max=int(e_max), epoch=int(epoch), e_slots=int(g.e_pad),
        local2global=jnp.asarray(l2g), vmask=jnp.asarray(vmask),
        edge_tgt=jnp.asarray(tgt), edge_nbr=jnp.asarray(nbr),
        emask=jnp.asarray(emask_p), seg_start=jnp.asarray(seg_start),
        last_slot=jnp.asarray(last_slot),
        replicated=jnp.asarray(replicated), is_master=jnp.asarray(is_master),
        n_local=jnp.asarray(n_local), n_edges_local=jnp.asarray(e_cnt),
        n_replicated=jnp.asarray(replicated.sum(1).astype(np.int32)),
        csr_fill=jnp.asarray(2 * e_cnt),
        v_fill=jnp.asarray(n_local),
        edge_w=jnp.asarray(ew),
        edge_slot=jnp.asarray(eslot),
    )


# ---------------------------------------------------------------------------
# Content-addressed plan cache: keyed by Graph.fingerprint() + assignment
# digest, NOT object identity — logically equal (graph, owner, k) triples
# share one compiled plan even across Graph/owner array rebuilds.
# ---------------------------------------------------------------------------

_PLAN_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_PLAN_CACHE_MAX = 32    # LRU bound: plans are multi-MB of device arrays
# Observability for the serving layer (gserve.metrics polls these): hits
# mean a query re-used an already-compiled plan; a climbing eviction count
# under steady load means the working set exceeds _PLAN_CACHE_MAX.
_PLAN_CACHE_COUNTERS = {"hits": 0, "misses": 0, "evictions": 0}


def _owner_digest(g: Graph, owner) -> str:
    """Digest of the assignment in canonical (sorted-edge-key) order, so the
    key is invariant under slot permutation, like Graph.fingerprint()."""
    u, v = g.as_numpy()
    own = np.asarray(owner)[np.asarray(g.edge_mask)].astype(np.int32)
    order = np.argsort(u.astype(np.int64) * g.n_vertices + v)
    return hashlib.sha256(own[order].tobytes()).hexdigest()


def compile_plan_cached(g: Graph, owner, k: int, *, edge_slack: int = 0,
                        vertex_slack: int = 0, epoch: int = 0) -> PartitionPlan:
    """Memoized compile_plan, keyed by graph/assignment *content*.

    Caveat for edge property channels: the cache key is slot-order
    invariant but ``plan.edge_slot`` is not — two content-equal graphs
    whose live edges occupy different slots (delete + re-insert through a
    StreamingGraph) would read an [E_pad, F] plane differently.  The
    streaming session therefore compiles uncached; use this entry point
    for static graphs (where slot order is canonical) or vertex-channel /
    channel-free workloads.
    """
    key = (g.fingerprint(), _owner_digest(g, owner), int(k),
           int(edge_slack), int(vertex_slack), int(epoch))
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        _PLAN_CACHE_COUNTERS["misses"] += 1
        plan = compile_plan(g, owner, k, edge_slack=edge_slack,
                            vertex_slack=vertex_slack, epoch=epoch)
        _PLAN_CACHE[key] = plan
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
            _PLAN_CACHE.popitem(last=False)
            _PLAN_CACHE_COUNTERS["evictions"] += 1
    else:
        _PLAN_CACHE_COUNTERS["hits"] += 1
        _PLAN_CACHE.move_to_end(key)
    return plan


def plan_cache_stats() -> dict:
    """Snapshot of the plan cache's hit/miss/eviction counters + size."""
    return dict(_PLAN_CACHE_COUNTERS, size=len(_PLAN_CACHE),
                max_size=_PLAN_CACHE_MAX)


# rebased onto the observability layer: obs.snapshot() always includes the
# live plan-cache counters, one level of the cache hierarchy (result cache
# -> plan cache -> jit cache -> device) in a single record
_obs.get().register_provider("plan_cache", plan_cache_stats)


def plan_cache_clear(reset_counters: bool = False) -> None:
    _PLAN_CACHE.clear()
    if reset_counters:
        for k in _PLAN_CACHE_COUNTERS:
            _PLAN_CACHE_COUNTERS[k] = 0

"""Edge-centric superstep runtime over a ``PartitionPlan``.

Execution model (paper §III, compacted):

  1. *local phase* — every partition runs Gather-Apply sweeps over its own
     CSR block (gather neighbour values along half-edges, segment-reduce per
     target, apply) — to a local fixed point for min-style programs, exactly
     one sweep for partial-aggregation programs (PageRank);
  2. *replica exchange* — only ``plan.replicated`` slots are scattered to a
     global frontier array, combined across partitions (min for replica
     state, add for partial aggregates) and gathered back.  Private
     vertices never cross the cut: an edge partition keeps every edge of a
     private vertex local, so its aggregate is already complete.

Steps 1–2 repeat until the exchanged state reaches a global fixed point
(or for a fixed number of supersteps).  ``supersteps`` is the paper's
*rounds* metric; the exchanged-slot count per superstep is its MESSAGES.

Two device mappings, same numerics:

  * **single-device fallback** — the [K, ...] partition axis is a batch
    axis; segment-reduce runs in the Pallas kernel (interpret mode on CPU);
  * **shard_map** — partitions are sharded over a 1-d device mesh axis
    (``K % n_devices == 0``, each device holds a [K/D, ...] block); the
    exchange's cross-partition combine becomes a device-local scatter
    followed by ``lax.pmin``/``psum`` over the mesh axis.  Collectives sit
    only in the exchange, so local fixed-point loops run fully
    device-local, exactly like the paper's workers between
    synchronisations.

Batched multi-source queries (the serving scenario) vmap the superstep
loop over the source axis — one compiled program answers S queries in one
superstep loop, on one device or with the batch axis vmapped inside the
shard_map body.  ``dispatch``/``dispatch_batched`` return a
``PendingResult`` without syncing so a serving scheduler can overlap batch
formation with device execution (``jax.block_until_ready`` on completion).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import kernels
from .plan import PartitionPlan

# Trace accounting: _run_loop's Python body executes only while jax traces
# (i.e. on a jit-cache miss), so this counter counts compilations, not calls.
# The streaming tests assert it stays flat across plan patches — patched
# plans keep the same treedef/avals and must reuse the warm cache; only a
# compaction epoch (new static aux) is allowed to retrace.
TRACE_COUNTER = {"run_loop": 0}


class EdgeProgram(NamedTuple):
    """A "think-like-an-edge" program. All callables are pure and module
    level (the program is a static jit argument; dynamic per-query values
    travel in the traced ``ctx`` dict).

    mode "replica": state slots are replicas of one logical per-vertex value
                    (combine = min); ``apply`` runs inside the local sweep.
    mode "partial": local sweeps produce partial aggregates that sum across
                    partitions (combine = add); ``apply`` runs after the
                    exchange completes the aggregate.
    """
    name: str
    mode: str                       # "replica" | "partial"
    combine: str                    # "min" | "add"
    prepare: Callable               # (plan, kw) -> ctx dict (traced, once)
    init: Callable                  # (plan, ctx) -> [K, Vmax] state
    pre: Callable                   # (state, ctx) -> per-vertex msg values
    apply: Callable                 # (old, agg, ctx) -> new
    finalize: Callable              # (glob [V], present [V], plan, ctx) -> [V]
    local_fixpoint: bool = True
    default_supersteps: int | None = None   # None -> run to fixed point


@dataclasses.dataclass(frozen=True)
class EngineResult:
    state: jax.Array                # [V] global vertex state
    supersteps: jax.Array           # int32 — the paper's "rounds"
    local_iters: jax.Array          # int32 — local sweeps on the critical path
    converged: jax.Array            # bool — False iff the superstep cap was
                                    #   hit first (state is then a truncation)
    exchange_per_superstep: int     # replica slots crossing the cut per round
    total_exchanged: int            # supersteps * exchange_per_superstep

    def row(self) -> dict:
        # batched runs carry per-source vectors; report the critical path
        return {"supersteps": int(jnp.max(self.supersteps)),
                "local_iters": int(jnp.max(self.local_iters)),
                "converged": bool(jnp.all(self.converged)),
                "exchange_per_superstep": self.exchange_per_superstep,
                "total_exchanged": self.total_exchanged}


@dataclasses.dataclass(frozen=True)
class PendingResult:
    """In-flight engine computation: the superstep loop has been dispatched
    (XLA runs it asynchronously) but nothing host-side has synced on it.

    ``result()`` blocks until the device arrays are ready and materialises
    the ``EngineResult``; until then the caller is free to form and dispatch
    further batches — the serving scheduler's overlap primitive."""
    _arrays: tuple                  # (state, supersteps, local_iters, converged)
    exchange_per_superstep: int

    def block_until_ready(self) -> "PendingResult":
        jax.block_until_ready(self._arrays)
        return self

    def result(self) -> EngineResult:
        state, supersteps, local_iters, converged = \
            jax.block_until_ready(self._arrays)
        ex = self.exchange_per_superstep
        return EngineResult(state, supersteps, local_iters, converged, ex,
                            int(jnp.max(supersteps)) * ex)


def _ident(combine: str) -> float:
    return kernels._IDENTITY[combine]


def _steps(prog: EdgeProgram, max_supersteps: int | None) -> int:
    if max_supersteps is not None:    # an explicit 0 means zero supersteps
        return max_supersteps
    if prog.default_supersteps is not None:
        return prog.default_supersteps
    return 512


def _rows(arr: jax.Array) -> jax.Array:
    return jnp.arange(arr.shape[0], dtype=jnp.int32)[:, None]


def _sweep(plan, prog, state, ctx, *, use_pallas: bool, interpret: bool):
    """One Gather-Apply sweep: returns the per-target aggregate [K, Vmax]."""
    pre = prog.pre(state, ctx)                              # [K, Vmax]
    msgs = pre[_rows(plan.edge_nbr), plan.edge_nbr]         # [K, Emax]
    if use_pallas:
        return kernels.segment_reduce(plan, msgs, prog.combine,
                                      interpret=interpret)
    return kernels.segment_reduce_ref(plan, msgs, prog.combine)


def _exchange(plan, values, combine, axis: str | None, *,
              use_pallas: bool, interpret: bool):
    """Combine replicated slots across partitions; private slots unchanged.

    values [K, Vmax] -> [K, Vmax]. With ``axis`` set (shard_map body) the
    cross-device combine is a psum/pmin over the mesh axis.
    """
    ident = _ident(combine)
    send = jnp.where(plan.vmask & plan.replicated, values, ident)
    glob = jnp.full((plan.n_vertices,), ident, jnp.float32)
    flat_idx = plan.local2global.reshape(-1)
    if combine == "min":
        glob = glob.at[flat_idx].min(send.reshape(-1))
        if axis is not None:
            glob = jax.lax.pmin(glob, axis)
    else:  # add identity is 0.0, so the masked send scatters exactly
        glob = glob.at[flat_idx].add(send.reshape(-1))
        if axis is not None:
            glob = jax.lax.psum(glob, axis)
    inc = glob[plan.local2global]                           # [K, Vmax]
    if use_pallas:
        return kernels.masked_update(values, inc, plan.vmask, plan.replicated,
                                     combine, interpret=interpret)
    new = jnp.where(plan.replicated, inc, values)
    return jnp.where(plan.vmask, new, ident)


def _gather_global(plan, state, axis: str | None):
    """Master-slot scatter of the final local states to a global [V]."""
    out = jnp.zeros((plan.n_vertices,), jnp.float32)
    out = out.at[plan.local2global.reshape(-1)].add(
        jnp.where(plan.is_master, state, 0.0).reshape(-1))
    present = jnp.zeros((plan.n_vertices,), jnp.bool_)
    present = present.at[plan.local2global.reshape(-1)].max(
        plan.is_master.reshape(-1))
    if axis is not None:
        out = jax.lax.psum(out, axis)
        present = jax.lax.psum(present.astype(jnp.int32), axis) > 0
    return out, present


def _run_loop(plan: PartitionPlan, prog: EdgeProgram, kw: dict,
              axis: str | None, max_supersteps: int, max_local_iters: int,
              use_pallas: bool, interpret: bool):
    """The superstep loop (runs as-is on one device or inside shard_map)."""
    TRACE_COUNTER["run_loop"] += 1
    ctx = prog.prepare(plan, kw)
    state0 = prog.init(plan, ctx)
    opts = dict(use_pallas=use_pallas, interpret=interpret)

    if prog.mode == "replica":
        def local_phase(st):
            def body(c):
                s, it, _ = c
                agg = _sweep(plan, prog, s, ctx, **opts)
                ns = prog.apply(s, agg, ctx)
                return ns, it + 1, jnp.any(ns != s)

            if not prog.local_fixpoint:
                s, it, _ = body((st, jnp.int32(0), True))
                return s, it
            st, iters, _ = jax.lax.while_loop(
                lambda c: c[2] & (c[1] < max_local_iters), body,
                (st, jnp.int32(0), jnp.bool_(True)))
            return st, iters

        def superstep(carry):
            st, steps, litot, _ = carry
            st1, li = local_phase(st)
            st2 = _exchange(plan, st1, prog.combine, axis, **opts)
            changed = jnp.any(st2 != st)
            if axis is not None:
                changed = jax.lax.pmax(changed.astype(jnp.int32), axis) > 0
            return st2, steps + 1, litot + li, changed

        st, steps, litot, changed = jax.lax.while_loop(
            lambda c: c[3] & (c[1] < max_supersteps), superstep,
            (state0, jnp.int32(0), jnp.int32(0), jnp.bool_(True)))
        converged = ~changed    # still changing => the cap cut us off
    else:  # partial aggregation: lock-step, fixed superstep count
        def superstep(st, _):
            agg = _sweep(plan, prog, st, ctx, **opts)
            agg_full = _exchange(plan, agg, prog.combine, axis, **opts)
            return prog.apply(st, agg_full, ctx), None

        st, _ = jax.lax.scan(superstep, state0, None, length=max_supersteps)
        steps = jnp.int32(max_supersteps)
        litot = steps
        converged = jnp.bool_(True)   # fixed-iteration programs by design

    if axis is not None:  # local sweep counts diverge per device: report the
        litot = jax.lax.pmax(litot, axis)  # critical path, as documented
    glob, present = _gather_global(plan, st, axis)
    return prog.finalize(glob, present, plan, ctx), steps, litot, converged


@partial(jax.jit, static_argnames=("prog", "max_supersteps",
                                   "max_local_iters", "use_pallas",
                                   "interpret"))
def _run_single(plan, prog, kw, max_supersteps, max_local_iters,
                use_pallas, interpret):
    return _run_loop(plan, prog, kw, None, max_supersteps, max_local_iters,
                     use_pallas, interpret)


@partial(jax.jit, static_argnames=("prog", "mesh", "axis", "k_local",
                                   "max_supersteps", "max_local_iters",
                                   "interpret"))
def _run_sharded(plan, kw, *, prog, mesh, axis, k_local, max_supersteps,
                 max_local_iters, interpret):
    """Module-level so repeated queries hit one jit cache entry per
    (program, mesh, shape) — the serving path never retraces."""
    plan_spec = jax.tree_util.tree_map(lambda _: P(axis), plan)
    kw_spec = jax.tree_util.tree_map(lambda _: P(), kw)

    def body(plan_local, kw_local):
        plan_local = dataclasses.replace(plan_local, k=k_local)
        return _run_loop(plan_local, prog, kw_local, axis,
                         max_supersteps, max_local_iters,
                         use_pallas=False, interpret=interpret)

    fn = shard_map(body, mesh=mesh, in_specs=(plan_spec, kw_spec),
                   out_specs=(P(), P(), P(), P()), check_rep=False)
    return fn(plan, kw)


@partial(jax.jit, static_argnames=("prog", "mesh", "axis", "k_local",
                                   "max_supersteps", "max_local_iters",
                                   "interpret"))
def _run_sharded_batched(plan, kw, batched_kw, *, prog, mesh, axis, k_local,
                         max_supersteps, max_local_iters, interpret):
    """Batched queries on the shard_map path: partitions stay sharded over
    the mesh axis while the batch axis is vmapped *inside* the sharded body,
    so one superstep loop answers the whole micro-batch with the same
    collective schedule as the unbatched path (the XLA segment-reduce is
    used — vmapping the Pallas grid is unsupported)."""
    plan_spec = jax.tree_util.tree_map(lambda _: P(axis), plan)
    kw_spec = jax.tree_util.tree_map(lambda _: P(), kw)
    bkw_spec = jax.tree_util.tree_map(lambda _: P(), batched_kw)

    def body(plan_local, kw_local, bkw_local):
        plan_local = dataclasses.replace(plan_local, k=k_local)

        def one(bkw):
            return _run_loop(plan_local, prog, {**kw_local, **bkw}, axis,
                             max_supersteps, max_local_iters,
                             use_pallas=False, interpret=interpret)

        return jax.vmap(one)(bkw_local)

    fn = shard_map(body, mesh=mesh, in_specs=(plan_spec, kw_spec, bkw_spec),
                   out_specs=(P(), P(), P(), P()), check_rep=False)
    return fn(plan, kw, batched_kw)


@dataclasses.dataclass(frozen=True)
class Engine:
    """Partitioned execution engine bound to a plan (and optionally a mesh).

    ``mesh`` must be 1-d with axis name ``axis`` and a device count dividing
    ``plan.k``; without a mesh the single-device fallback runs with the
    Pallas kernels in interpret mode.
    """
    plan: PartitionPlan
    mesh: Mesh | None = None
    axis: str = "parts"
    use_pallas: bool = True
    interpret: bool = True

    def with_plan(self, plan: PartitionPlan) -> "Engine":
        """Rebind to a (patched or recompiled) plan. A patched plan shares
        the old plan's treedef and avals, so jitted superstep loops keep
        their compilation cache across the swap; only a plan with a bumped
        compaction ``epoch`` retraces."""
        return dataclasses.replace(self, plan=plan)

    def dispatch(self, prog: EdgeProgram, max_supersteps: int | None = None,
                 max_local_iters: int = 100_000, **kw: Any) -> PendingResult:
        """Non-blocking single-query dispatch: hands the superstep loop to
        XLA and returns immediately. ``.result()`` syncs."""
        steps = _steps(prog, max_supersteps)
        kw = {k: jnp.asarray(v) for k, v in kw.items()}
        if self.mesh is None:
            out = _run_single(self.plan, prog, kw, steps, max_local_iters,
                              self.use_pallas, self.interpret)
        else:
            out = _run_sharded(self._sharded_plan(), kw, prog=prog,
                               mesh=self.mesh, axis=self.axis,
                               k_local=self._k_local(),
                               max_supersteps=steps,
                               max_local_iters=max_local_iters,
                               interpret=self.interpret)
        return PendingResult(out, self.plan.exchange_volume)

    def run(self, prog: EdgeProgram, max_supersteps: int | None = None,
            max_local_iters: int = 100_000, **kw: Any) -> EngineResult:
        return self.dispatch(prog, max_supersteps, max_local_iters,
                             **kw).result()

    def dispatch_batched(self, prog: EdgeProgram, batched_kw: dict,
                         max_supersteps: int | None = None,
                         max_local_iters: int = 100_000,
                         **kw: Any) -> PendingResult:
        """Non-blocking micro-batch dispatch: vmap the superstep loop over a
        batch axis of ``batched_kw`` (e.g. ``{"source": sources}`` for
        multi-source SSSP). Runs on one device or, with a mesh bound, with
        the batch axis vmapped inside the shard_map body. The XLA
        segment-reduce path is used (vmapping the interpreted Pallas grid is
        unsupported). The serving scheduler dispatches the next micro-batch
        while this one computes and syncs via ``.result()``."""
        steps = _steps(prog, max_supersteps)
        kw = {k: jnp.asarray(v) for k, v in kw.items()}
        batched_kw = {k: jnp.asarray(v) for k, v in batched_kw.items()}
        if self.mesh is None:
            def one(bkw):
                return _run_single(self.plan, prog, {**kw, **bkw}, steps,
                                   max_local_iters, False, self.interpret)

            out = jax.vmap(one)(batched_kw)
        else:
            out = _run_sharded_batched(self._sharded_plan(), kw, batched_kw,
                                       prog=prog, mesh=self.mesh,
                                       axis=self.axis,
                                       k_local=self._k_local(),
                                       max_supersteps=steps,
                                       max_local_iters=max_local_iters,
                                       interpret=self.interpret)
        return PendingResult(out, self.plan.exchange_volume)

    def run_batched(self, prog: EdgeProgram, batched_kw: dict,
                    max_supersteps: int | None = None,
                    max_local_iters: int = 100_000,
                    **kw: Any) -> EngineResult:
        return self.dispatch_batched(prog, batched_kw, max_supersteps,
                                     max_local_iters, **kw).result()

    # -- shard_map plumbing -------------------------------------------------
    def _k_local(self) -> int:
        ndev = self.mesh.shape[self.axis]
        assert self.plan.k % ndev == 0, \
            f"k={self.plan.k} must be divisible by mesh axis size {ndev}"
        return self.plan.k // ndev

    def _sharded_plan(self) -> PartitionPlan:
        """Plan with leaves placed along the mesh axis, transferred once per
        Engine and reused across queries (stashed on the instance; frozen
        dataclasses still allow object.__setattr__)."""
        cached = getattr(self, "_plan_placed", None)
        if cached is None:
            cached = jax.device_put(
                self.plan, jax.tree_util.tree_map(
                    lambda _: NamedSharding(self.mesh, P(self.axis)),
                    self.plan))
            object.__setattr__(self, "_plan_placed", cached)
        return cached

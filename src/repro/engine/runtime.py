"""Edge-centric superstep runtime over a ``PartitionPlan``.

Execution model (paper §III, compacted):

  1. *local phase* — every partition runs Gather-Apply sweeps over its own
     CSR block (gather neighbour values along half-edges, segment-reduce per
     target, apply) — to a local fixed point for min-style programs, exactly
     one sweep for partial-aggregation programs (PageRank);
  2. *replica exchange* — only ``plan.replicated`` slots are scattered to a
     global frontier array, combined across partitions (min for replica
     state, add for partial aggregates) and gathered back.  Private
     vertices never cross the cut: an edge partition keeps every edge of a
     private vertex local, so its aggregate is already complete.

Steps 1–2 repeat until the exchanged state reaches a global fixed point
(or for a fixed number of supersteps).  ``supersteps`` is the paper's
*rounds* metric; the exchanged-slot count per superstep is its MESSAGES.

Two device mappings, same numerics:

  * **single-device fallback** — the [K, ...] partition axis is a batch
    axis; segment-reduce runs in the Pallas kernel (interpret mode on CPU);
  * **shard_map** — partitions are sharded over a 1-d device mesh axis
    (``K % n_devices == 0``, each device holds a [K/D, ...] block); the
    exchange's cross-partition combine becomes a device-local scatter
    followed by ``lax.pmin``/``psum`` over the mesh axis.  Collectives sit
    only in the exchange, so local fixed-point loops run fully
    device-local, exactly like the paper's workers between
    synchronisations.

Batched multi-source queries (the serving scenario) vmap the superstep
loop over the source axis — one compiled program answers S queries in one
superstep loop, on one device or with the batch axis vmapped inside the
shard_map body.  ``dispatch``/``dispatch_batched`` return a
``PendingResult`` without syncing so a serving scheduler can overlap batch
formation with device execution (``jax.block_until_ready`` on completion).
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import obs as _obs
from . import kernels
from .errors import WarmStateError
from .plan import PartitionPlan
from .state import SCALAR, StateSpec

# Trace accounting: _run_loop's Python body executes only while jax traces
# (i.e. on a jit-cache miss), so this counter counts compilations, not calls.
# The streaming tests assert it stays flat across plan patches — patched
# plans keep the same treedef/avals and must reuse the warm cache; only a
# compaction epoch (new static aux) is allowed to retrace.
# The counter is folded into repro.obs: each bump also records an
# ``engine.retrace`` event on the process recorder, attributed to the plan
# epoch, padded shapes, and (via the dispatch sites' ambient tags) the
# program and bucket shape that triggered it — an unexpected retrace in a
# trace export is a visible, attributable event, not a silent bump.
TRACE_COUNTER = {"run_loop": 0}

_obs.get().register_provider(
    "jit", lambda: {"run_loop_traces": TRACE_COUNTER["run_loop"]})


class EdgeProgram(NamedTuple):
    """A "think-like-an-edge" program. All callables are pure and module
    level (the program is a static jit argument; dynamic per-query values
    travel in the traced ``ctx`` dict).

    mode "replica": state slots are replicas of one logical per-vertex value
                    (combine = min); ``apply`` runs inside the local sweep.
    mode "partial": local sweeps produce partial aggregates that sum across
                    partitions (combine = add); ``apply`` runs after the
                    exchange completes the aggregate.

    State rank is declarative (PR 10): ``state`` is the program's
    :class:`~repro.engine.state.StateSpec`.  With the default scalar spec
    every hook sees/returns [K, Vmax] blocks and the finalized result is
    [V] — bit-identical to the pre-StateSpec path.  With
    ``StateSpec(features=F)`` the same hooks carry [K, Vmax, F] planes
    and finalize to [V, F]; the engine, warm store and serving layers
    derive every shape from the spec, no per-rank branching anywhere.
    """
    name: str
    mode: str                       # "replica" | "partial"
    combine: str                    # "min" | "add" | "max"
    prepare: Callable               # (plan, kw) -> ctx dict (traced, once)
    init: Callable                  # (plan, ctx) -> [K, Vmax(, F)] state
    pre: Callable                   # (state, ctx) -> per-vertex msg values
    apply: Callable                 # (old, agg, ctx) -> new
    finalize: Callable              # (glob [V(, F)], present [V], plan, ctx)
                                    #   -> [V(, F)]
    local_fixpoint: bool = True
    default_supersteps: int | None = None   # None -> run to fixed point
    # optional hooks (None: disabled)
    edge: Callable | None = None    # (msgs [K, Emax(, F)], plan, ctx) -> msgs
                                    #   — per-half-edge transform applied
                                    #   after the neighbour gather, before
                                    #   the segment reduce (e.g. + plan.edge_w)
    warm_init: Callable | None = None
                                    # (plan, prev [V(, F)], ctx) ->
                                    #   [K, Vmax(, F)] — warm-start state from
                                    #   a previous epoch's *finalized* result.
                                    #   ``state.fill`` entries of prev mean
                                    #   "no prior information" and must reduce
                                    #   to the cold init value for that vertex.
    edge_mul: Callable | None = None
                                    # (plan, ctx) -> [K, Emax] or [K, Emax, F]
                                    #   multiplicative per-half-edge weights;
                                    #   routes the sweep through the fused
                                    #   Pallas gSpMM (gather · multiply ·
                                    #   segment-reduce in one kernel pass)
                                    #   instead of the edge hook + plain
                                    #   segment reduce
    state: StateSpec = SCALAR       # per-vertex state shape declaration


@dataclasses.dataclass(frozen=True)
class EngineResult:
    state: jax.Array                # [V(, F)] global vertex state (rank per
                                    #   the program's StateSpec)
    supersteps: jax.Array           # int32 — the paper's "rounds"
    local_iters: jax.Array          # int32 — local sweeps on the critical path
    converged: jax.Array            # bool — False iff the superstep cap was
                                    #   hit first (state is then a truncation)
    exchange_per_superstep: int     # replica slots crossing the cut per round
    total_exchanged: int            # supersteps * exchange_per_superstep

    def row(self) -> dict:
        # batched runs carry per-source vectors; report the critical path
        return {"supersteps": int(jnp.max(self.supersteps)),
                "local_iters": int(jnp.max(self.local_iters)),
                "converged": bool(jnp.all(self.converged)),
                "exchange_per_superstep": self.exchange_per_superstep,
                "total_exchanged": self.total_exchanged}


@dataclasses.dataclass(frozen=True)
class PendingResult:
    """In-flight engine computation: the superstep loop has been dispatched
    (XLA runs it asynchronously) but nothing host-side has synced on it.

    ``result()`` blocks until the device arrays are ready and materialises
    the ``EngineResult``; until then the caller is free to form and dispatch
    further batches — the serving scheduler's overlap primitive."""
    _arrays: tuple                  # (state, supersteps, local_iters, converged)
    exchange_per_superstep: int

    def block_until_ready(self) -> "PendingResult":
        jax.block_until_ready(self._arrays)
        return self

    def result(self) -> EngineResult:
        state, supersteps, local_iters, converged = \
            jax.block_until_ready(self._arrays)
        ex = self.exchange_per_superstep
        steps = int(jnp.max(supersteps))
        rec = _obs.get()
        if rec.enabled:   # per-dispatch superstep + exchange accounting
            # numpy on the already-synced host arrays: a jnp reduction here
            # would dispatch a fresh XLA computation per served result and
            # show up as recorder overhead
            rec.event("engine.result", supersteps=steps,
                      local_iters=int(np.max(np.asarray(local_iters))),
                      converged=bool(np.all(np.asarray(converged))),
                      exchange_per_superstep=ex, exchanged=steps * ex)
            rec.counter("engine.supersteps", steps)
            rec.counter("engine.exchanged", steps * ex)
        return EngineResult(state, supersteps, local_iters, converged, ex,
                            steps * ex)


def _ident(combine: str) -> float:
    return kernels._IDENTITY[combine]


def _steps(prog: EdgeProgram, max_supersteps: int | None) -> int:
    if max_supersteps is not None:    # an explicit 0 means zero supersteps
        return max_supersteps
    if prog.default_supersteps is not None:
        return prog.default_supersteps
    return 512


def _rows(arr: jax.Array) -> jax.Array:
    return jnp.arange(arr.shape[0], dtype=jnp.int32)[:, None]


def _expand(mask: jax.Array, ref: jax.Array) -> jax.Array:
    """Broadcast a [K, Vmax] mask against scalar or feature-plane state —
    the one shape-polymorphism point the superstep loop needs: everything
    else is rank-generic indexing/reshapes driven by the data."""
    return mask[:, :, None] if ref.ndim == 3 else mask


def _sweep(plan, prog, state, ctx, *, use_pallas: bool, interpret: bool):
    """One Gather-Apply sweep: per-target aggregate [K, Vmax(, F)]."""
    pre = prog.pre(state, ctx)                              # [K, Vmax(, F)]
    if prog.edge_mul is not None:   # fused gSpMM path (GNN programs)
        w = prog.edge_mul(plan, ctx)
        if use_pallas:
            agg = kernels.gspmm(plan, pre, w, prog.combine,
                                interpret=interpret)
        else:
            agg = kernels.gspmm_ref(plan, pre, w, prog.combine)
        return agg[:, :, 0] if pre.ndim == 2 else agg
    msgs = pre[_rows(plan.edge_nbr), plan.edge_nbr]         # [K, Emax(, F)]
    if prog.edge is not None:   # per-half-edge hook (weighted programs)
        msgs = prog.edge(msgs, plan, ctx)
    if use_pallas:
        return kernels.segment_reduce(plan, msgs, prog.combine,
                                      interpret=interpret)
    return kernels.segment_reduce_ref(plan, msgs, prog.combine)


def _exchange(plan, values, combine, axis: str | None, *,
              use_pallas: bool, interpret: bool):
    """Combine replicated slots across partitions; private slots unchanged.

    values [K, Vmax(, F)] -> same shape. With ``axis`` set (shard_map body)
    the cross-device combine is a psum/pmin/pmax over the mesh axis.
    Feature planes ride the same scatter with a trailing feature axis.
    """
    ident = _ident(combine)
    send = jnp.where(_expand(plan.vmask & plan.replicated, values),
                     values, ident)
    tail = values.shape[2:]
    glob = jnp.full((plan.n_vertices,) + tail, ident, jnp.float32)
    flat_idx = plan.local2global.reshape(-1)
    flat_send = send.reshape((-1,) + tail)
    if combine == "min":
        glob = glob.at[flat_idx].min(flat_send)
        if axis is not None:
            glob = jax.lax.pmin(glob, axis)
    elif combine == "max":
        glob = glob.at[flat_idx].max(flat_send)
        if axis is not None:
            glob = jax.lax.pmax(glob, axis)
    else:  # add identity is 0.0, so the masked send scatters exactly
        glob = glob.at[flat_idx].add(flat_send)
        if axis is not None:
            glob = jax.lax.psum(glob, axis)
    inc = glob[plan.local2global]                           # [K, Vmax(, F)]
    if use_pallas:
        return kernels.masked_update(values, inc, plan.vmask, plan.replicated,
                                     combine, interpret=interpret)
    new = jnp.where(_expand(plan.replicated, values), inc, values)
    return jnp.where(_expand(plan.vmask, values), new, ident)


def _gather_global(plan, state, axis: str | None):
    """Master-slot scatter of the final local states to a global [V(, F)]."""
    tail = state.shape[2:]
    out = jnp.zeros((plan.n_vertices,) + tail, jnp.float32)
    out = out.at[plan.local2global.reshape(-1)].add(
        jnp.where(_expand(plan.is_master, state),
                  state, 0.0).reshape((-1,) + tail))
    present = jnp.zeros((plan.n_vertices,), jnp.bool_)
    present = present.at[plan.local2global.reshape(-1)].max(
        plan.is_master.reshape(-1))
    if axis is not None:
        out = jax.lax.psum(out, axis)
        present = jax.lax.psum(present.astype(jnp.int32), axis) > 0
    return out, present


def _run_loop(plan: PartitionPlan, prog: EdgeProgram, kw: dict,
              prev: jax.Array | None, axis: str | None, max_supersteps: int,
              max_local_iters: int, use_pallas: bool, interpret: bool):
    """The superstep loop (runs as-is on one device or inside shard_map).

    ``prev`` (None or a [V] previous-epoch result) selects cold vs warm
    initialisation; None is pytree *structure*, so each variant is its own
    jit cache entry and the branch below is resolved at trace time.
    """
    TRACE_COUNTER["run_loop"] += 1
    rec = _obs.get()
    if rec.enabled:   # trace-time only: never runs on a warm jit cache hit
        rec.counter("engine.retraces")
        rec.event("engine.retrace", loop="run_loop", program=prog.name,
                  epoch=plan.epoch, k=plan.k, v_max=plan.v_max,
                  e_max=plan.e_max, sharded=axis is not None)
    ctx = prog.prepare(plan, kw)
    if prev is None:
        state0 = prog.init(plan, ctx)
    else:
        state0 = prog.warm_init(plan, prev, ctx)
    opts = dict(use_pallas=use_pallas, interpret=interpret)

    if prog.mode == "replica":
        def local_phase(st):
            def body(c):
                s, it, _ = c
                agg = _sweep(plan, prog, s, ctx, **opts)
                ns = prog.apply(s, agg, ctx)
                return ns, it + 1, jnp.any(ns != s)

            if not prog.local_fixpoint:
                s, it, _ = body((st, jnp.int32(0), True))
                return s, it
            st, iters, _ = jax.lax.while_loop(
                lambda c: c[2] & (c[1] < max_local_iters), body,
                (st, jnp.int32(0), jnp.bool_(True)))
            return st, iters

        def superstep(carry):
            st, steps, litot, _ = carry
            st1, li = local_phase(st)
            st2 = _exchange(plan, st1, prog.combine, axis, **opts)
            changed = jnp.any(st2 != st)
            if axis is not None:
                changed = jax.lax.pmax(changed.astype(jnp.int32), axis) > 0
            return st2, steps + 1, litot + li, changed

        st, steps, litot, changed = jax.lax.while_loop(
            lambda c: c[3] & (c[1] < max_supersteps), superstep,
            (state0, jnp.int32(0), jnp.int32(0), jnp.bool_(True)))
        converged = ~changed    # still changing => the cap cut us off
    else:  # partial aggregation: lock-step, fixed superstep count
        def superstep(st, _):
            agg = _sweep(plan, prog, st, ctx, **opts)
            agg_full = _exchange(plan, agg, prog.combine, axis, **opts)
            return prog.apply(st, agg_full, ctx), None

        st, _ = jax.lax.scan(superstep, state0, None, length=max_supersteps)
        steps = jnp.int32(max_supersteps)
        litot = steps
        converged = jnp.bool_(True)   # fixed-iteration programs by design

    if axis is not None:  # local sweep counts diverge per device: report the
        litot = jax.lax.pmax(litot, axis)  # critical path, as documented
    glob, present = _gather_global(plan, st, axis)
    return prog.finalize(glob, present, plan, ctx), steps, litot, converged


@partial(jax.jit, static_argnames=("prog", "max_supersteps",
                                   "max_local_iters", "use_pallas",
                                   "interpret"))
def _run_single(plan, prog, kw, prev, max_supersteps, max_local_iters,
                use_pallas, interpret):
    return _run_loop(plan, prog, kw, prev, None, max_supersteps,
                     max_local_iters, use_pallas, interpret)


@partial(jax.jit, static_argnames=("prog", "mesh", "axis", "k_local",
                                   "max_supersteps", "max_local_iters",
                                   "interpret"))
def _run_sharded(plan, kw, prev, *, prog, mesh, axis, k_local,
                 max_supersteps, max_local_iters, interpret):
    """Module-level so repeated queries hit one jit cache entry per
    (program, mesh, shape) — the serving path never retraces."""
    plan_spec = jax.tree_util.tree_map(lambda _: P(axis), plan)
    kw_spec = jax.tree_util.tree_map(lambda _: P(), kw)
    prev_spec = jax.tree_util.tree_map(lambda _: P(), prev)

    def body(plan_local, kw_local, prev_local):
        plan_local = dataclasses.replace(plan_local, k=k_local)
        return _run_loop(plan_local, prog, kw_local, prev_local, axis,
                         max_supersteps, max_local_iters,
                         use_pallas=False, interpret=interpret)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(plan_spec, kw_spec, prev_spec),
                   out_specs=(P(), P(), P(), P()), check_rep=False)
    return fn(plan, kw, prev)


@partial(jax.jit, static_argnames=("prog", "mesh", "axis", "k_local",
                                   "max_supersteps", "max_local_iters",
                                   "interpret"))
def _run_sharded_batched(plan, kw, batched_kw, prev, *, prog, mesh, axis,
                         k_local, max_supersteps, max_local_iters,
                         interpret):
    """Batched queries on the shard_map path: partitions stay sharded over
    the mesh axis while the batch axis is vmapped *inside* the sharded body,
    so one superstep loop answers the whole micro-batch with the same
    collective schedule as the unbatched path (the XLA segment-reduce is
    used — vmapping the Pallas grid is unsupported)."""
    plan_spec = jax.tree_util.tree_map(lambda _: P(axis), plan)
    kw_spec = jax.tree_util.tree_map(lambda _: P(), kw)
    bkw_spec = jax.tree_util.tree_map(lambda _: P(), batched_kw)
    prev_spec = jax.tree_util.tree_map(lambda _: P(), prev)

    def body(plan_local, kw_local, bkw_local, prev_local):
        plan_local = dataclasses.replace(plan_local, k=k_local)

        def one(bkw, pv):
            return _run_loop(plan_local, prog, {**kw_local, **bkw}, pv,
                             axis, max_supersteps, max_local_iters,
                             use_pallas=False, interpret=interpret)

        if prev_local is None:
            return jax.vmap(lambda bkw: one(bkw, None))(bkw_local)
        return jax.vmap(one)(bkw_local, prev_local)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(plan_spec, kw_spec, bkw_spec, prev_spec),
                   out_specs=(P(), P(), P(), P()), check_rep=False)
    return fn(plan, kw, batched_kw, prev)


@dataclasses.dataclass(frozen=True)
class Engine:
    """Partitioned execution engine bound to a plan (and optionally a mesh).

    ``mesh`` must be 1-d with axis name ``axis`` and a device count dividing
    ``plan.k``; without a mesh the single-device fallback runs with the
    Pallas kernels in interpret mode.
    """
    plan: PartitionPlan
    mesh: Mesh | None = None
    axis: str = "parts"
    use_pallas: bool = True
    interpret: bool = True

    def with_plan(self, plan: PartitionPlan) -> "Engine":
        """Rebind to a (patched or recompiled) plan. A patched plan shares
        the old plan's treedef and avals, so jitted superstep loops keep
        their compilation cache across the swap; only a plan with a bumped
        compaction ``epoch`` retraces."""
        return dataclasses.replace(self, plan=plan)

    def _check_warm(self, prog: EdgeProgram, warm_state,
                    batch: int | None) -> jax.Array | None:
        """Validate a warm-start state (typed errors, actionable messages).

        A warm state is a previous epoch's *finalized* result in the
        program's declared state shape — ``spec.shape(V)``, or the batched
        ``spec.batch_shape(S, V)`` block with one row per lane; cold rows
        (``spec.fill``) mean "no prior information" and fall back to cold
        init.  A rank mismatch (scalar block for a [V, F] program or vice
        versa) raises the same typed error as a wrong vertex count — never
        a reshape crash inside jit.
        """
        if warm_state is None:
            return None
        if prog.warm_init is None:
            raise WarmStateError(
                f"program {prog.name!r} has no warm_init hook — pass "
                "warm_init= when constructing the EdgeProgram to enable "
                "warm-started dispatch, or drop warm_state")
        spec = prog.state
        prev = jnp.asarray(warm_state, jnp.dtype(spec.dtype))
        want = spec.shape(self.plan.n_vertices) if batch is None \
            else spec.batch_shape(batch, self.plan.n_vertices)
        if prev.shape != want:
            raise WarmStateError(
                f"warm_state for program {prog.name!r} has shape "
                f"{tuple(prev.shape)} but the plan serves "
                f"{self.plan.n_vertices} vertices with per-vertex state "
                f"{spec.describe()} — expected {want} "
                "(the previous epoch's finalized result state)")
        return prev

    def _obs_dispatch(self, prog: EdgeProgram, bucket: int):
        """Per-dispatch telemetry: records the dispatch event (program,
        bucket, plan epoch, exchange volume, lane occupancy) and returns an
        ambient-tag context so any jit retrace triggered while tracing
        inside it is attributed to this program + bucket shape."""
        rec = _obs.get()
        if not rec.enabled:
            return contextlib.nullcontext()
        health = _obs.plan_health(self.plan)
        rec.event("engine.dispatch", program=prog.name, bucket=bucket,
                  epoch=self.plan.epoch, sharded=self.mesh is not None,
                  exchange_per_superstep=health["exchange_per_superstep"],
                  edge_lane_occupancy_max=health["edge_lane_occupancy_max"],
                  vertex_lane_occupancy_max=
                      health["vertex_lane_occupancy_max"])
        rec.counter("engine.dispatches")
        for name, value in health.items():
            rec.gauge(f"plan.{name}", value)
        return rec.tags(program=prog.name, bucket=bucket)

    def dispatch(self, prog: EdgeProgram, max_supersteps: int | None = None,
                 max_local_iters: int = 100_000, warm_state=None,
                 **kw: Any) -> PendingResult:
        """Non-blocking single-query dispatch: hands the superstep loop to
        XLA and returns immediately. ``.result()`` syncs. ``warm_state``
        (a previous [V] result) initialises via ``prog.warm_init``."""
        steps = _steps(prog, max_supersteps)
        prev = self._check_warm(prog, warm_state, None)
        kw = {k: jnp.asarray(v) for k, v in kw.items()}
        with self._obs_dispatch(prog, 0):
            if self.mesh is None:
                out = _run_single(self.plan, prog, kw, prev, steps,
                                  max_local_iters, self.use_pallas,
                                  self.interpret)
            else:
                out = _run_sharded(self._sharded_plan(), kw, prev, prog=prog,
                                   mesh=self.mesh, axis=self.axis,
                                   k_local=self._k_local(),
                                   max_supersteps=steps,
                                   max_local_iters=max_local_iters,
                                   interpret=self.interpret)
        return PendingResult(out, self.plan.exchange_volume)

    def run(self, prog: EdgeProgram, max_supersteps: int | None = None,
            max_local_iters: int = 100_000, warm_state=None,
            **kw: Any) -> EngineResult:
        return self.dispatch(prog, max_supersteps, max_local_iters,
                             warm_state=warm_state, **kw).result()

    def dispatch_batched(self, prog: EdgeProgram, batched_kw: dict,
                         max_supersteps: int | None = None,
                         max_local_iters: int = 100_000, warm_state=None,
                         **kw: Any) -> PendingResult:
        """Non-blocking micro-batch dispatch: vmap the superstep loop over a
        batch axis of ``batched_kw`` (e.g. ``{"source": sources}`` for
        multi-source SSSP). Runs on one device or, with a mesh bound, with
        the batch axis vmapped inside the shard_map body. The XLA
        segment-reduce path is used (vmapping the interpreted Pallas grid is
        unsupported). The serving scheduler dispatches the next micro-batch
        while this one computes and syncs via ``.result()``.
        ``warm_state`` is a [S, V] block, one previous-result row per lane
        (+inf rows cold-start their lane)."""
        steps = _steps(prog, max_supersteps)
        kw = {k: jnp.asarray(v) for k, v in kw.items()}
        batched_kw = {k: jnp.asarray(v) for k, v in batched_kw.items()}
        n_batch = next(iter(batched_kw.values())).shape[0]
        prev = self._check_warm(prog, warm_state, n_batch)
        with self._obs_dispatch(prog, n_batch):
            if self.mesh is None:
                if prev is None:
                    def one(bkw):
                        return _run_single(self.plan, prog, {**kw, **bkw},
                                           None, steps, max_local_iters,
                                           False, self.interpret)

                    out = jax.vmap(one)(batched_kw)
                else:
                    def one_warm(bkw, pv):
                        return _run_single(self.plan, prog, {**kw, **bkw},
                                           pv, steps, max_local_iters,
                                           False, self.interpret)

                    out = jax.vmap(one_warm)(batched_kw, prev)
            else:
                out = _run_sharded_batched(self._sharded_plan(), kw,
                                           batched_kw, prev, prog=prog,
                                           mesh=self.mesh, axis=self.axis,
                                           k_local=self._k_local(),
                                           max_supersteps=steps,
                                           max_local_iters=max_local_iters,
                                           interpret=self.interpret)
        return PendingResult(out, self.plan.exchange_volume)

    def run_batched(self, prog: EdgeProgram, batched_kw: dict,
                    max_supersteps: int | None = None,
                    max_local_iters: int = 100_000, warm_state=None,
                    **kw: Any) -> EngineResult:
        return self.dispatch_batched(prog, batched_kw, max_supersteps,
                                     max_local_iters, warm_state=warm_state,
                                     **kw).result()

    def lower_hlo(self, prog: EdgeProgram, batched_kw: dict | None = None,
                  max_supersteps: int | None = None,
                  max_local_iters: int = 100_000, **kw: Any) -> str:
        """Post-optimization HLO text of the executable a ``dispatch``
        (``batched_kw=None``) or ``dispatch_batched`` of the same shape
        would run — the input ``repro.obs.profile`` feeds the
        ``roofline.hlo_parse`` analyzer to build per-plan cost models.

        This pays one AOT trace + XLA compile per call (the ``.lower()``
        path does not share the C++ jit executable cache), so callers must
        memoize per (program, plan shape, bucket) — ``obs.profile`` does.
        Always lowers the cold-start variant: a warm-started dispatch is
        the same superstep loop with a different init, cost-identical to
        first order."""
        steps = _steps(prog, max_supersteps)
        kw = {k: jnp.asarray(v) for k, v in kw.items()}
        if batched_kw is None:
            if self.mesh is None:
                lowered = _run_single.lower(
                    self.plan, prog, kw, None, steps, max_local_iters,
                    self.use_pallas, self.interpret)
            else:
                lowered = _run_sharded.lower(
                    self._sharded_plan(), kw, None, prog=prog,
                    mesh=self.mesh, axis=self.axis,
                    k_local=self._k_local(), max_supersteps=steps,
                    max_local_iters=max_local_iters,
                    interpret=self.interpret)
        else:
            batched_kw = {k: jnp.asarray(v) for k, v in batched_kw.items()}
            if self.mesh is None:
                # jit(vmap(...)) compiles the same batched superstep loop
                # the eager dispatch path executes (jit under vmap fuses
                # into one XLA computation either way)
                def one(bkw):
                    return _run_single(self.plan, prog, {**kw, **bkw},
                                       None, steps, max_local_iters,
                                       False, self.interpret)

                lowered = jax.jit(jax.vmap(one)).lower(batched_kw)
            else:
                lowered = _run_sharded_batched.lower(
                    self._sharded_plan(), kw, batched_kw, None, prog=prog,
                    mesh=self.mesh, axis=self.axis,
                    k_local=self._k_local(), max_supersteps=steps,
                    max_local_iters=max_local_iters,
                    interpret=self.interpret)
        return lowered.compile().as_text()

    # -- shard_map plumbing -------------------------------------------------
    def _k_local(self) -> int:
        ndev = self.mesh.shape[self.axis]
        assert self.plan.k % ndev == 0, \
            f"k={self.plan.k} must be divisible by mesh axis size {ndev}"
        return self.plan.k // ndev

    def _sharded_plan(self) -> PartitionPlan:
        """Plan with leaves placed along the mesh axis, transferred once per
        Engine and reused across queries (stashed on the instance; frozen
        dataclasses still allow object.__setattr__)."""
        cached = getattr(self, "_plan_placed", None)
        if cached is None:
            cached = jax.device_put(
                self.plan, jax.tree_util.tree_map(
                    lambda _: NamedSharding(self.mesh, P(self.axis)),
                    self.plan))
            object.__setattr__(self, "_plan_placed", cached)
        return cached

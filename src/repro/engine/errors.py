"""Typed errors for the program registry and warm-started dispatch.

Every registry misuse raises a distinct subclass of ``RegistryError`` with
an actionable message (what was wrong, what the caller should pass
instead).  ``RegistryError`` subclasses ``ValueError`` so pre-registry
callers that caught ``ValueError`` on bad requests keep working.

Kept in their own module so both ``engine.registry`` (validation) and
``engine.runtime`` (warm-state shape checks at dispatch) can raise them
without importing each other.
"""
from __future__ import annotations


class RegistryError(ValueError):
    """Base class for program-registry misuse."""


class DuplicateProgramError(RegistryError):
    """A program name was registered twice."""


class UnknownProgramError(RegistryError):
    """A query named a program that was never registered."""


class UnknownParamError(RegistryError):
    """A query passed a parameter the program's ParamSpec does not declare."""


class ParamTypeError(RegistryError):
    """A parameter value has the wrong dtype, or a required one is missing."""


class BatchAxisError(RegistryError):
    """A scalar parameter was passed a sequence/array (a batch axis).

    The micro-batch axis is formed by the scheduler coalescing *requests*;
    a single request always carries scalar parameter values.
    """


class StateError(RegistryError):
    """Base class for state-plane shape violations at the server door.

    A program's per-vertex state rank is declared by its ``StateSpec``
    (PR 10); every array whose shape must agree with that declaration —
    warm-start blocks, bound channel planes — raises a ``StateError``
    subclass when it does not, instead of a reshape crash inside jit.
    """


class WarmStateError(StateError):
    """``warm_state`` was passed to a program without a ``warm_init`` hook,
    or its shape does not match the plan's vertex space under the
    program's ``StateSpec`` (wrong vertex count *or* wrong feature rank)."""


class ChannelError(StateError):
    """A property-channel value is malformed: wrong rank/feature width at
    construction, or — at dispatch — a plane whose leading length does not
    match the plan it is being served against (e.g. a ``[V, F]`` vertex
    plane passed where an edge-slot plane was declared, or vice versa)."""

"""Typed errors for the program registry and warm-started dispatch.

Every registry misuse raises a distinct subclass of ``RegistryError`` with
an actionable message (what was wrong, what the caller should pass
instead).  ``RegistryError`` subclasses ``ValueError`` so pre-registry
callers that caught ``ValueError`` on bad requests keep working.

Kept in their own module so both ``engine.registry`` (validation) and
``engine.runtime`` (warm-state shape checks at dispatch) can raise them
without importing each other.
"""
from __future__ import annotations


class RegistryError(ValueError):
    """Base class for program-registry misuse."""


class DuplicateProgramError(RegistryError):
    """A program name was registered twice."""


class UnknownProgramError(RegistryError):
    """A query named a program that was never registered."""


class UnknownParamError(RegistryError):
    """A query passed a parameter the program's ParamSpec does not declare."""


class ParamTypeError(RegistryError):
    """A parameter value has the wrong dtype, or a required one is missing."""


class BatchAxisError(RegistryError):
    """A scalar parameter was passed a sequence/array (a batch axis).

    The micro-batch axis is formed by the scheduler coalescing *requests*;
    a single request always carries scalar parameter values.
    """


class WarmStateError(RegistryError):
    """``warm_state`` was passed to a program without a ``warm_init`` hook,
    or its shape does not match the plan's vertex space."""


class ChannelError(RegistryError):
    """A property-channel value is malformed: wrong rank/feature width at
    construction, or — at dispatch — a plane whose leading length does not
    match the plan it is being served against (e.g. a ``[V, F]`` vertex
    plane passed where an edge-slot plane was declared, or vice versa)."""

"""DFEP — Distributed Funding-based Edge Partitioning (paper §IV) in JAX.

Fully vectorised re-expression of Algorithms 3–6. Funding is kept in
**integer units** — the paper prices every edge at exactly "one unit" and
speaks of units throughout; integer arithmetic is also what keeps the
auction alive: with real-valued equal splits the diffusion equalises every
bid *just below* the 1-unit threshold and the market freezes (we verified
this empirically — max bid 0.77 with 180k liquid units), whereas integer
division with remainder-to-first-edges concentrates at least one whole unit
somewhere and the endgame always progresses.

State per round:
  * ``mv``  [V, K] int32 — units partition *i* holds at vertex *v*;
  * edge commitments are transient within a round (losers refunded, the
    winner's residual flows to the edge endpoints — Algorithm 5).

One round == the paper's (step 1, step 2, step 3):
  step 1  every vertex spreads each partition's units over incident
          *eligible* edges (free, or owned by that partition; DFEP-C
          additionally lets "poor" partitions bid on "rich" edges):
          ``base = mv // n_eligible`` per edge, remainder one extra unit to
          the first ``mv %% n_eligible`` eligible edges in CSR order;
  step 2  every free edge is sold to the highest bidder with ≥ 1 unit
          (ties broken by a per-round hash), winner pays 1, residual splits
          half/half (odd unit to the lower endpoint), losers refunded
          equally over their funding endpoints (odd unit to the first);
  step 3  the coordinator grants each partition ``min(cap, ceil(mean/size))``
          units, one unit each to that many of its presence vertices.

Hardware adaptation (DESIGN.md §3): both endpoint copies of every edge
compute the auction deterministically — the paper's single-MapReduce-round
trick — which here becomes dense [E, K] arithmetic plus a handful of
``segment_sum``-style scatters per round (the only "shuffles").
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .graph import Graph

FREE = -1  # owner value for unsold edges


class Slots(NamedTuple):
    """Directed slot layout: 2 slots per undirected edge (u-side, v-side),
    sorted by slot vertex so per-vertex ranks are a segmented cumsum."""
    edge: jax.Array        # [2E] int32 — edge id of sorted slot
    vertex: jax.Array      # [2E] int32 — vertex of sorted slot
    seg_first: jax.Array   # [2E] int32 — sorted-index of this vertex's first slot
    inv: jax.Array         # [2E] int32 — sorted idx of (u-sides ++ v-sides) slot


def build_slots(g: Graph) -> Slots:
    u = np.asarray(g.src)
    v = np.asarray(g.dst)
    e = g.e_pad
    slot_vertex = np.concatenate([u, v])
    slot_edge = np.concatenate([np.arange(e), np.arange(e)]).astype(np.int32)
    order = np.argsort(slot_vertex, kind="stable").astype(np.int32)
    sv = slot_vertex[order].astype(np.int32)
    se = slot_edge[order]
    # first sorted index of each vertex segment
    first_of_vertex = np.zeros(g.n_vertices, np.int32)
    seen = np.ones(len(sv), bool)
    seen[1:] = sv[1:] != sv[:-1]
    first_of_vertex[sv[seen]] = np.flatnonzero(seen)
    seg_first = first_of_vertex[sv]
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order), dtype=np.int32)
    return Slots(jnp.asarray(se), jnp.asarray(sv), jnp.asarray(seg_first),
                 jnp.asarray(inv))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DfepState:
    owner: jax.Array     # [E] int32, FREE where unsold (padding slots: -2)
    mv: jax.Array        # [V, K] int32 vertex funding
    rounds: jax.Array    # scalar int32
    stalled: jax.Array   # scalar int32 — rounds without progress

    def tree_flatten(self):
        return (self.owner, self.mv, self.rounds, self.stalled), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class DfepConfig:
    k: int                       # number of partitions
    cap: int = 10                # per-round funding cap (paper: 10)
    variant_c: bool = False      # DFEP-C: poor partitions may raid rich ones
    poor_p: float = 2.0          # poor iff size < mean/p  (paper's parameter p)
    max_rounds: int = 10_000
    stall_rounds: int = 256      # no-progress rounds before bailing out
    init_funding: int | None = None  # default ceil(|E|/K) (paper §IV)


def init_state(g: Graph, cfg: DfepConfig, key: jax.Array) -> DfepState:
    """Algorithm 3: K random distinct starting vertices, ceil(|E|/K) units."""
    k = cfg.k
    starts = jax.random.choice(key, g.n_vertices, shape=(k,), replace=False)
    funding = cfg.init_funding if cfg.init_funding is not None else -(-g.n_edges // k)
    mv = jnp.zeros((g.n_vertices, k), jnp.int32)
    mv = mv.at[starts, jnp.arange(k)].set(jnp.int32(funding))
    owner = jnp.where(g.edge_mask, jnp.int32(FREE), jnp.int32(-2))
    return DfepState(owner, mv, jnp.int32(0), jnp.int32(0))


def _hash01(e: jax.Array, i: jax.Array, r: jax.Array) -> jax.Array:
    """Stateless per-(edge, partition, round) tie-break in [0, 1)."""
    x = (e.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
         ^ (i.astype(jnp.uint32) * jnp.uint32(0x85EBCA77))
         ^ (r.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D)))
    x = (x ^ (x >> 15)) * jnp.uint32(0x2C1B3C6D)
    x = (x ^ (x >> 12)) * jnp.uint32(0x297A2D39)
    x = x ^ (x >> 15)
    return x.astype(jnp.float32) / jnp.float32(2**32)


def _sizes(owner: jax.Array, k: int) -> jax.Array:
    onehot = owner[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]
    return jnp.sum(onehot.astype(jnp.int32), axis=0)


def _round(g: Graph, slots: Slots, cfg: DfepConfig, state: DfepState,
           active: jax.Array | None = None,
           grant_v: jax.Array | None = None) -> DfepState:
    """One auction round. ``active`` (default: every real edge) restricts
    steps 1–2 to a subset of edges — the bounded local re-auction of the
    streaming subsystem runs the same machinery with ``active`` set to the
    h-hop region around touched vertices and ``grant_v`` restricting step-3
    grants to region vertices. With both None this is exactly the paper's
    full-graph round."""
    k = cfg.k
    u, v = g.src, g.dst
    emask = g.edge_mask if active is None else (g.edge_mask & active)
    owner, mv = state.owner, state.mv
    part_ids = jnp.arange(k, dtype=jnp.int32)

    free = owner == FREE                                             # [E]
    owned_by = owner[:, None] == part_ids[None, :]                   # [E, K]

    # ---- step 1: spread units over eligible incident edges ---------------
    elig = (free[:, None] | owned_by) & emask[:, None]               # [E, K]
    if cfg.variant_c:
        sizes0 = _sizes(owner, k)
        mean0 = jnp.sum(sizes0) // k
        poor = sizes0 < (mean0 / cfg.poor_p)                         # [K]
        rich_edge = jnp.where(owner >= 0, ~poor[jnp.clip(owner, 0)], False)
        raid = rich_edge[:, None] & poor[None, :] & ~owned_by & emask[:, None]
        elig = elig | raid

    eligi = elig.astype(jnp.int32)
    cnt = jnp.zeros((g.n_vertices, k), jnp.int32)
    cnt = cnt.at[u].add(eligi).at[v].add(eligi)                      # [V, K]
    safe_cnt = jnp.maximum(cnt, 1)
    base = mv // safe_cnt                                            # [V, K]
    rem = mv - base * safe_cnt                                       # [V, K]

    # per-slot rank among this vertex's eligible edges (segmented cumsum),
    # rotated by a per-(vertex, partition, round) hash so the remainder units
    # don't starve late-ranked edges (Hadoop's arbitrary iteration order)
    elig_slot = eligi[slots.edge]                                    # [2E, K]
    cum = jnp.cumsum(elig_slot, axis=0)
    exc = cum - elig_slot                                            # exclusive
    rank = exc - exc[slots.seg_first]                                # [2E, K]
    sv = slots.vertex
    rot = (_hash01(sv[:, None], part_ids[None, :], state.rounds)
           * safe_cnt[sv].astype(jnp.float32)).astype(jnp.int32)
    rank = jnp.where(safe_cnt[sv] > 0,
                     (rank + rot) % safe_cnt[sv], rank)
    contrib = elig_slot * (base[sv] + (rank < rem[sv]).astype(jnp.int32))
    moved = cnt > 0
    mv_left = jnp.where(moved, 0, mv)                                # [V, K]

    # back to (u-side, v-side) order
    e_pad = g.e_pad
    contrib_uv = contrib[slots.inv]                                  # [2E, K]
    cu, cv = contrib_uv[:e_pad], contrib_uv[e_pad:]                  # [E, K]
    me = cu + cv                                                     # committed

    # ---- step 2: auction --------------------------------------------------
    tie = _hash01(jnp.arange(e_pad, dtype=jnp.int32)[:, None],
                  part_ids[None, :], state.rounds)
    score = me.astype(jnp.float32) + tie
    best = jnp.argmax(score, axis=1).astype(jnp.int32)               # [E]
    best_amt = jnp.take_along_axis(me, best[:, None], axis=1)[:, 0]
    can_buy = (best_amt >= 1) & emask
    bought_free = free & can_buy
    if cfg.variant_c:
        best_is_poor = poor[best]
        steal = (~free) & can_buy & best_is_poor & (best != owner) & rich_edge
        paid = bought_free | steal
    else:
        paid = bought_free
    new_owner = jnp.where(paid, best, owner)

    now_owned = new_owner[:, None] == part_ids[None, :]              # [E, K]
    pay = (paid[:, None] & now_owned).astype(jnp.int32)
    residual = me - pay                                              # [E, K] int

    # winner residual: half/half (odd unit to u). losers: equal over funders
    fu = (cu > 0).astype(jnp.int32)
    fv = (cv > 0).astype(jnp.int32)
    funders = jnp.maximum(fu + fv, 1)
    half = residual // 2
    loser_share = residual // funders
    loser_rem = residual - loser_share * funders                     # 0 or 1
    ref_u = jnp.where(now_owned, half + (residual - 2 * half),
                      fu * (loser_share + loser_rem * fu))
    ref_v = jnp.where(now_owned, half,
                      fv * jnp.where(fu > 0, loser_share, loser_share + loser_rem))
    mv_new = mv_left.at[u].add(ref_u).at[v].add(ref_v)

    # ---- step 3: coordinator grants (replicated, O(K)) --------------------
    # grant_i = min(cap, ceil(|E| / size_i)) — "inversely proportional to the
    # number of edges already bought", with the paper's cap (10) binding for
    # any partition smaller than |E|/cap (i.e. for most of the run, which is
    # what makes the cap meaningful).
    sizes = _sizes(new_owner, k)
    remaining = jnp.sum(jnp.where(new_owner == FREE, 1, 0))
    grant = jnp.minimum(jnp.int32(cfg.cap),
                        -(-jnp.int32(g.n_edges) // jnp.maximum(sizes, 1)))
    grant = jnp.where(remaining > 0, grant, 0)                       # [K]

    # distribute over the vertices where the partition *committed* funding to
    # a still-free edge this round (its active frontier); if it has no such
    # vertex, fall back to its full presence set.
    still_free = new_owner == FREE                                   # [E]
    fr_u = jnp.zeros((g.n_vertices, k), jnp.bool_)
    fr_u = fr_u.at[u].max((cu > 0) & still_free[:, None])
    fr_u = fr_u.at[v].max((cv > 0) & still_free[:, None])
    presence = mv_new > 0                                            # [V, K]
    owned_at = jnp.zeros((g.n_vertices, k), jnp.bool_)
    owned_mask = now_owned & emask[:, None]
    owned_at = owned_at.at[u].max(owned_mask).at[v].max(owned_mask)
    presence = presence | owned_at
    has_frontier = jnp.any(fr_u, axis=0)                             # [K]
    presence = jnp.where(has_frontier[None, :], fr_u, presence)
    if grant_v is not None:   # local re-auction: grants stay in the region
        presence = presence & grant_v[:, None]
    pres_i = presence.astype(jnp.int32)
    n_pres = jnp.maximum(jnp.sum(pres_i, axis=0), 1)                 # [K]
    p_base = grant // n_pres
    p_rem = grant - p_base * n_pres                                  # [K]
    p_rank = jnp.cumsum(pres_i, axis=0) - pres_i                     # [V, K]
    p_rot = (_hash01(jnp.full((1,), 7, jnp.int32), part_ids[None, :],
                     state.rounds) * n_pres.astype(jnp.float32)).astype(jnp.int32)
    p_rank = (p_rank + p_rot) % n_pres[None, :]
    mv_new = mv_new + pres_i * (p_base[None, :]
                                + (p_rank < p_rem[None, :]).astype(jnp.int32))

    progressed = jnp.sum(jnp.where(paid, 1, 0)) > 0
    return DfepState(
        owner=new_owner,
        mv=mv_new,
        rounds=state.rounds + 1,
        stalled=jnp.where(progressed, 0, state.stalled + 1),
    )


@partial(jax.jit, static_argnames=("cfg",))
def run_dfep(g: Graph, slots: Slots, cfg: DfepConfig, key: jax.Array) -> DfepState:
    """Run rounds until every real edge is owned (or stall/round caps hit)."""
    state = init_state(g, cfg, key)

    def cond(s: DfepState):
        unsold = jnp.sum(jnp.where(s.owner == FREE, 1, 0))
        return ((unsold > 0)
                & (s.rounds < cfg.max_rounds)
                & (s.stalled < cfg.stall_rounds))

    return jax.lax.while_loop(cond, lambda s: _round(g, slots, cfg, s), state)


# ---------------------------------------------------------------------------
# Incremental (region-restricted) DFEP — entry points for repro.stream
# ---------------------------------------------------------------------------

def init_region_state(g: Graph, cfg: DfepConfig, owner: jax.Array,
                      active: jax.Array, region_v: jax.Array) -> DfepState:
    """Seed a bounded local re-auction.

    Edges under ``active`` are released (owner -> FREE); each partition gets
    ``ceil(|active| / K)`` units spread over its presence vertices *inside*
    the region (anchoring the auction to its existing territory). A
    partition with no region presence seeds at the first region vertex, like
    Algorithm 3's random start.
    """
    k = cfg.k
    owner0 = jnp.where(active, jnp.int32(FREE), owner)
    n_active = jnp.sum(active.astype(jnp.int32))
    funding = -(-n_active // k)                                      # ceil
    # partition presence at region vertices (from still-owned edges)
    part_ids = jnp.arange(k, dtype=jnp.int32)
    owned = (owner0[:, None] == part_ids[None, :]) & g.edge_mask[:, None]
    pres = jnp.zeros((g.n_vertices, k), jnp.bool_)
    pres = pres.at[g.src].max(owned).at[g.dst].max(owned)
    pres = pres & region_v[:, None]
    pres_i = pres.astype(jnp.int32)
    cnt = jnp.sum(pres_i, axis=0)                                    # [K]
    safe = jnp.maximum(cnt, 1)
    base = funding // safe
    rem = funding - base * safe
    rank = jnp.cumsum(pres_i, axis=0) - pres_i
    mv = pres_i * (base[None, :] + (rank < rem[None, :]).astype(jnp.int32))
    # no-presence fallback: everything at the first region vertex
    fallback = jnp.argmax(region_v).astype(jnp.int32)
    mv = mv.at[fallback].add(jnp.where(cnt == 0, funding, 0))
    return DfepState(owner0, mv, jnp.int32(0), jnp.int32(0))


@partial(jax.jit, static_argnames=("cfg",))
def run_dfep_region(g: Graph, slots: Slots, cfg: DfepConfig,
                    owner: jax.Array, active: jax.Array,
                    region_v: jax.Array) -> DfepState:
    """DFEP steps 1–2 (plus region-restricted step-3 grants) over only the
    ``active`` edges, holding every other assignment fixed. This is the
    bounded local re-auction the streaming subsystem runs when replication
    drift crosses its threshold; cost scales with the region, not |E|."""
    state = init_region_state(g, cfg, owner, active, region_v)

    def cond(s: DfepState):
        unsold = jnp.sum(jnp.where(s.owner == FREE, 1, 0))
        return ((unsold > 0)
                & (s.rounds < cfg.max_rounds)
                & (s.stalled < cfg.stall_rounds))

    return jax.lax.while_loop(
        cond, lambda s: _round(g, slots, cfg, s, active=active,
                               grant_v=region_v), state)


@partial(jax.jit, static_argnames=("k",))
def finalize(g: Graph, owner: jax.Array, k: int, iters: int = 64) -> jax.Array:
    """Assign any leftover FREE edges to the least-loaded adjacent partition
    (fallback so a valid partitioning is always returned; flagged upstream)."""

    def body(_, own):
        sizes = _sizes(own, k).astype(jnp.float32)
        # per-vertex: adjacent partition with the smallest size
        score = jnp.where(own >= 0, sizes[jnp.clip(own, 0)], jnp.inf)
        best_lab = jnp.full((g.n_vertices,), jnp.float32(jnp.inf))
        enc = score * (k + 1) + jnp.where(own >= 0, own, 0).astype(jnp.float32)
        enc = jnp.where(own >= 0, enc, jnp.inf)
        best_lab = best_lab.at[g.src].min(jnp.where(g.edge_mask, enc, jnp.inf))
        best_lab = best_lab.at[g.dst].min(jnp.where(g.edge_mask, enc, jnp.inf))
        cand_enc = jnp.minimum(best_lab[g.src], best_lab[g.dst])
        cand = jnp.where(jnp.isfinite(cand_enc),
                         (cand_enc % (k + 1)).astype(jnp.int32), -1)
        take = (own == FREE) & (cand >= 0)
        return jnp.where(take, cand, own)

    own = jax.lax.fori_loop(0, iters, body, owner)
    return jnp.where(own == FREE, 0, own)


def partition(g: Graph, k: int, key: jax.Array | int = 0,
              variant_c: bool = False, slots: Slots | None = None,
              **kw) -> tuple[jax.Array, dict]:
    """Convenience wrapper: run DFEP and return (owner [E], info dict)."""
    if isinstance(key, int):
        key = jax.random.key(key)
    if slots is None:
        slots = build_slots(g)
    cfg = DfepConfig(k=k, variant_c=variant_c, **kw)
    st = run_dfep(g, slots, cfg, key)
    unsold = int(jnp.sum(jnp.where(st.owner == FREE, 1, 0)))
    owner = finalize(g, st.owner, k) if unsold else st.owner
    owner = jnp.where(g.edge_mask, owner, -2)
    info = {"rounds": int(st.rounds), "unsold_at_stop": unsold,
            "finalized": bool(unsold)}
    return owner, info

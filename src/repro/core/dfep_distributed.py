"""Distributed DFEP: the paper's one-MapReduce-round-per-iteration scheme
mapped onto ``shard_map`` (DESIGN.md §3).

Sharding model — one device == one Hadoop worker:

  * the *edge* set (and its funding slots) is sharded across the mesh axis;
  * the [V, K] vertex-funding matrix is replicated and reconciled once per
    round with a ``psum`` — this is the shuffle of the paper's MR round,
    and the only cross-worker traffic (plus two tiny [K] reductions);
  * the auction (step 2) runs shard-locally: every edge lives on exactly
    one worker;
  * the coordinator (step 3) is O(K) and replicated — every worker computes
    identical grants (cheaper than a host round-trip).

Semantics match the single-host ``dfep.py`` exactly except that step-1
remainder units are ranked among a vertex's *worker-local* eligible slots
(each worker distributes its own remainders — precisely how per-reducer
iteration order behaves in the Hadoop implementation).

At 1000+ node scale the [V, K] replica itself would be sharded over a
second mesh axis (vertex blocks × psum→reduce_scatter); the round structure
is unchanged. See DESIGN.md §6.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .dfep import FREE, DfepConfig, _hash01, finalize
from .graph import Graph


class ShardedGraph(NamedTuple):
    """Edge-sharded graph + per-shard slot layout (device-major leading dim).

    All arrays carry a leading [ndev] axis so a plain ``shard_map`` over
    axis 0 gives every worker its contiguous edge block.
    """
    n_vertices: int
    n_edges: int
    src: jax.Array        # [ndev, E_loc]
    dst: jax.Array        # [ndev, E_loc]
    edge_mask: jax.Array  # [ndev, E_loc]
    slot_edge: jax.Array  # [ndev, 2*E_loc] local edge index of sorted slot
    slot_vertex: jax.Array
    slot_seg_first: jax.Array
    slot_inv: jax.Array


def shard_graph(g: Graph, ndev: int) -> ShardedGraph:
    """Host-side: split edges into ``ndev`` contiguous blocks (padded) and
    build each worker's vertex-sorted slot layout."""
    u, v = np.asarray(g.src), np.asarray(g.dst)
    em = np.asarray(g.edge_mask)
    e_pad = g.e_pad
    e_loc = -(-e_pad // ndev)
    tot = e_loc * ndev
    pu = np.zeros(tot, np.int32); pu[:e_pad] = u
    pv = np.zeros(tot, np.int32); pv[:e_pad] = v
    pm = np.zeros(tot, bool); pm[:e_pad] = em
    pu, pv, pm = (x.reshape(ndev, e_loc) for x in (pu, pv, pm))

    se = np.zeros((ndev, 2 * e_loc), np.int32)
    sv = np.zeros((ndev, 2 * e_loc), np.int32)
    sf = np.zeros((ndev, 2 * e_loc), np.int32)
    si = np.zeros((ndev, 2 * e_loc), np.int32)
    for d in range(ndev):
        slot_vertex = np.concatenate([pu[d], pv[d]])
        slot_edge = np.concatenate([np.arange(e_loc), np.arange(e_loc)]).astype(np.int32)
        order = np.argsort(slot_vertex, kind="stable").astype(np.int32)
        svd = slot_vertex[order].astype(np.int32)
        sed = slot_edge[order]
        first = np.zeros(g.n_vertices, np.int32)
        seen = np.ones(len(svd), bool)
        seen[1:] = svd[1:] != svd[:-1]
        first[svd[seen]] = np.flatnonzero(seen)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order), dtype=np.int32)
        se[d], sv[d], sf[d], si[d] = sed, svd, first[svd], inv
    return ShardedGraph(g.n_vertices, g.n_edges,
                        jnp.asarray(pu), jnp.asarray(pv), jnp.asarray(pm),
                        jnp.asarray(se), jnp.asarray(sv), jnp.asarray(sf),
                        jnp.asarray(si))


def _sizes_local(owner: jax.Array, k: int) -> jax.Array:
    onehot = owner[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]
    return jnp.sum(onehot.astype(jnp.int32), axis=0)


def run_dfep_sharded(g: Graph, cfg: DfepConfig, key: jax.Array,
                     mesh: Mesh, axis: str = "data"
                     ) -> tuple[jax.Array, dict]:
    """Run DFEP edge-sharded over ``mesh[axis]``. Returns (owner [E_pad], info)."""
    ndev = mesh.shape[axis]
    sg = shard_graph(g, ndev)
    v_n, k = g.n_vertices, cfg.k
    e_loc = sg.src.shape[1]
    part_ids = jnp.arange(k, dtype=jnp.int32)

    # initial state (replicated mv, sharded owner)
    starts = jax.random.choice(key, v_n, shape=(k,), replace=False)
    funding = cfg.init_funding if cfg.init_funding is not None else -(-g.n_edges // k)
    mv0 = jnp.zeros((v_n, k), jnp.int32).at[starts, part_ids].set(jnp.int32(funding))
    owner0 = jnp.where(sg.edge_mask, jnp.int32(FREE), jnp.int32(-2))  # [ndev, E_loc]

    def worker(src, dst, emask, s_edge, s_vertex, s_first, s_inv,
               owner, mv, carry_rounds, carry_stall):
        """Body of one round; all args are this worker's shard ([1, ...] squeezed)."""
        src, dst, emask = src[0], dst[0], emask[0]
        s_edge, s_vertex = s_edge[0], s_vertex[0]
        s_first, s_inv = s_first[0], s_inv[0]
        owner = owner[0]

        def one_round(state):
            owner, mv, rounds, stalled = state
            free = owner == FREE
            owned_by = owner[:, None] == part_ids[None, :]
            elig = (free[:, None] | owned_by) & emask[:, None]
            if cfg.variant_c:
                sizes0 = jax.lax.psum(_sizes_local(owner, k), axis)
                mean0 = jnp.sum(sizes0) // k
                poor = sizes0 < (mean0 / cfg.poor_p)
                rich_edge = jnp.where(owner >= 0, ~poor[jnp.clip(owner, 0)], False)
                raid = (rich_edge[:, None] & poor[None, :]
                        & ~owned_by & emask[:, None])
                elig = elig | raid

            eligi = elig.astype(jnp.int32)
            cnt_local = (jnp.zeros((v_n, k), jnp.int32)
                         .at[src].add(eligi).at[dst].add(eligi))
            cnt = jax.lax.psum(cnt_local, axis)                  # MR shuffle #1
            safe_cnt = jnp.maximum(cnt, 1)
            base = mv // safe_cnt
            rem = mv - base * safe_cnt

            elig_slot = eligi[s_edge]
            cum = jnp.cumsum(elig_slot, axis=0)
            exc = cum - elig_slot
            rank = exc - exc[s_first]
            # local eligible count per (vertex, partition) for rotation
            my = jax.lax.axis_index(axis).astype(jnp.int32)
            rot = (_hash01(s_vertex[:, None] * 131 + my,
                           part_ids[None, :], rounds)
                   * safe_cnt[s_vertex].astype(jnp.float32)).astype(jnp.int32)
            rank = (rank + rot) % safe_cnt[s_vertex]
            contrib = elig_slot * (base[s_vertex]
                                   + (rank < rem[s_vertex]).astype(jnp.int32))
            mv_left = jnp.where(cnt > 0, 0, mv)

            contrib_uv = contrib[s_inv]
            cu, cv = contrib_uv[:e_loc], contrib_uv[e_loc:]
            me = cu + cv

            tie = _hash01(jnp.arange(e_loc, dtype=jnp.int32)[:, None]
                          + my * e_loc, part_ids[None, :], rounds)
            score = me.astype(jnp.float32) + tie
            best = jnp.argmax(score, axis=1).astype(jnp.int32)
            best_amt = jnp.take_along_axis(me, best[:, None], axis=1)[:, 0]
            can_buy = (best_amt >= 1) & emask
            bought_free = free & can_buy
            if cfg.variant_c:
                steal = ((~free) & can_buy & poor[best]
                         & (best != owner) & rich_edge)
                paid = bought_free | steal
            else:
                paid = bought_free
            new_owner = jnp.where(paid, best, owner)

            now_owned = new_owner[:, None] == part_ids[None, :]
            pay = (paid[:, None] & now_owned).astype(jnp.int32)
            residual = me - pay
            fu = (cu > 0).astype(jnp.int32)
            fv = (cv > 0).astype(jnp.int32)
            funders = jnp.maximum(fu + fv, 1)
            half = residual // 2
            loser_share = residual // funders
            loser_rem = residual - loser_share * funders
            ref_u = jnp.where(now_owned, half + (residual - 2 * half),
                              fu * (loser_share + loser_rem * fu))
            ref_v = jnp.where(now_owned, half,
                              fv * jnp.where(fu > 0, loser_share,
                                             loser_share + loser_rem))
            dmv = (jnp.zeros((v_n, k), jnp.int32)
                   .at[src].add(ref_u).at[dst].add(ref_v))
            mv_new = mv_left + jax.lax.psum(dmv, axis)           # MR shuffle #2

            # step 3 — replicated coordinator
            sizes = jax.lax.psum(_sizes_local(new_owner, k), axis)
            remaining = jax.lax.psum(
                jnp.sum(jnp.where(new_owner == FREE, 1, 0)), axis)
            grant = jnp.minimum(jnp.int32(cfg.cap),
                                -(-jnp.int32(g.n_edges) // jnp.maximum(sizes, 1)))
            grant = jnp.where(remaining > 0, grant, 0)

            still_free = new_owner == FREE
            fr_local = jnp.zeros((v_n, k), jnp.bool_)
            fr_local = fr_local.at[src].max((cu > 0) & still_free[:, None])
            fr_local = fr_local.at[dst].max((cv > 0) & still_free[:, None])
            owned_mask = now_owned & emask[:, None]
            owned_at = (jnp.zeros((v_n, k), jnp.bool_)
                        .at[src].max(owned_mask).at[dst].max(owned_mask))
            fr = jax.lax.psum(fr_local.astype(jnp.int32), axis) > 0
            owned_any = jax.lax.psum(owned_at.astype(jnp.int32), axis) > 0
            presence = (mv_new > 0) | owned_any
            has_frontier = jnp.any(fr, axis=0)
            presence = jnp.where(has_frontier[None, :], fr, presence)
            pres_i = presence.astype(jnp.int32)
            n_pres = jnp.maximum(jnp.sum(pres_i, axis=0), 1)
            p_base = grant // n_pres
            p_rem = grant - p_base * n_pres
            p_rank = jnp.cumsum(pres_i, axis=0) - pres_i
            p_rot = (_hash01(jnp.full((1,), 7, jnp.int32),
                             part_ids[None, :], rounds)
                     * n_pres.astype(jnp.float32)).astype(jnp.int32)
            p_rank = (p_rank + p_rot) % n_pres[None, :]
            mv_new = mv_new + pres_i * (p_base[None, :]
                                        + (p_rank < p_rem[None, :]).astype(jnp.int32))

            progressed = jax.lax.psum(jnp.sum(jnp.where(paid, 1, 0)), axis) > 0
            return (new_owner, mv_new, rounds + 1,
                    jnp.where(progressed, 0, stalled + 1))

        def cond(state):
            owner, _, rounds, stalled = state
            unsold = jax.lax.psum(jnp.sum(jnp.where(owner == FREE, 1, 0)), axis)
            return ((unsold > 0) & (rounds < cfg.max_rounds)
                    & (stalled < cfg.stall_rounds))

        owner, mv, rounds, stalled = jax.lax.while_loop(
            cond, one_round, (owner, mv, carry_rounds, carry_stall))
        return owner[None], mv, rounds, stalled

    spec_e = P(axis)
    fn = shard_map(
        worker, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, spec_e, spec_e, spec_e, spec_e,
                  spec_e, P(), P(), P()),
        out_specs=(spec_e, P(), P(), P()),
        check_rep=False,
    )
    owner, mv, rounds, stalled = jax.jit(fn)(
        sg.src, sg.dst, sg.edge_mask, sg.slot_edge, sg.slot_vertex,
        sg.slot_seg_first, sg.slot_inv, owner0, mv0,
        jnp.int32(0), jnp.int32(0))
    owner_flat = owner.reshape(-1)[:g.e_pad]
    unsold = int(jnp.sum(jnp.where(owner_flat == FREE, 1, 0)))
    if unsold:
        owner_flat = finalize(g, owner_flat, cfg.k)
        owner_flat = jnp.where(g.edge_mask, owner_flat, -2)
    info = {"rounds": int(rounds), "unsold_at_stop": unsold,
            "finalized": bool(unsold), "ndev": ndev}
    return owner_flat, info

"""Distributed ETSCH: partitions → workers, frontier aggregation → collective.

This is the paper's Fig.-2 deployment: each worker holds ``K/ndev`` edge
partitions (subgraphs), runs the local phase independently, and the
aggregation phase is a single ``pmin``/``psum`` over the mesh axis — the
only communication, sized by Σ|F_i| (the paper's MESSAGES metric).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .etsch import Partitioning


def _pad_partitions(part: Partitioning, ndev: int) -> Partitioning:
    """Pad K to a multiple of ndev with empty partitions."""
    k = part.k
    k_pad = -(-k // ndev) * ndev
    if k_pad == k:
        return part
    pad = k_pad - k

    def padk(x, fill=0):
        return jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0)

    return Partitioning(k_pad, part.n_vertices, part.e_max,
                        padk(part.src), padk(part.dst), padk(part.mask, False),
                        padk(part.member, False), padk(part.frontier, False))


def sssp_sharded(part: Partitioning, source: int, mesh: Mesh,
                 axis: str = "data", max_supersteps: int = 512):
    """Distributed SSSP over an edge partitioning. Returns (dist [V], supersteps).

    Local phase: masked Bellman-Ford sweeps to each worker's local fixed
    point. Aggregation: ``psum``-min over the mesh axis (frontier reconcile).
    """
    ndev = mesh.shape[axis]
    part = _pad_partitions(part, ndev)
    k_loc = part.k // ndev
    v_n = part.n_vertices
    src_v = jnp.asarray(source, jnp.int32)

    def worker(psrc, pdst, pmask, member):
        # shapes: [k_loc, E_max], member [k_loc, V]
        rows = jnp.arange(k_loc)[:, None]
        inf = jnp.float32(jnp.inf)
        is_src = (jnp.arange(v_n) == src_v)[None, :]
        dist = jnp.where(member & is_src, 0.0, inf)

        def local_sweep(d):
            du = jnp.where(pmask, d[rows, psrc] + 1.0, inf)
            dv = jnp.where(pmask, d[rows, pdst] + 1.0, inf)
            return d.at[rows, pdst].min(du).at[rows, psrc].min(dv)

        def local_fixpoint(d):
            def body(c):
                dd, _ = c
                nd = local_sweep(dd)
                return nd, jnp.any(nd != dd)
            d, _ = jax.lax.while_loop(lambda c: c[1], body, (d, jnp.bool_(True)))
            return d

        def superstep(carry):
            d, steps, _ = carry
            d1 = local_fixpoint(d)
            local_min = jnp.min(jnp.where(member, d1, inf), axis=0)   # [V]
            agg = jax.lax.pmin(local_min, axis)                       # frontier
            d2 = jnp.where(member, agg[None, :], inf)
            changed = jax.lax.psum(jnp.sum(jnp.where(d2 != d, 1, 0)), axis) > 0
            return d2, steps + 1, changed

        def cond(carry):
            _, steps, changed = carry
            return changed & (steps < max_supersteps)

        dist, steps, _ = jax.lax.while_loop(
            cond, superstep, (dist, jnp.int32(0), jnp.bool_(True)))
        out = jax.lax.pmin(jnp.min(jnp.where(member, dist, inf), axis=0), axis)
        return out, steps

    fn = shard_map(worker, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis), P(axis)),
                   out_specs=(P(), P()),
                   check_rep=False)
    dist, steps = jax.jit(fn)(part.src, part.dst, part.mask, part.member)
    return dist, int(steps)


def pagerank_sharded(part: Partitioning, degrees: jax.Array, mesh: Mesh,
                     axis: str = "data", iters: int = 30,
                     damping: float = 0.85) -> jax.Array:
    """Distributed PageRank: local partial in-flows, psum aggregation."""
    ndev = mesh.shape[axis]
    part = _pad_partitions(part, ndev)
    k_loc = part.k // ndev
    v_n = part.n_vertices
    deg = jnp.maximum(degrees.astype(jnp.float32), 1.0)

    def worker(psrc, pdst, pmask):
        rows = jnp.arange(k_loc)[:, None]
        rank = jnp.full((v_n,), 1.0 / v_n, jnp.float32)

        def step(rank, _):
            c = rank / deg
            cu = jnp.where(pmask, c[psrc], 0.0)
            cv = jnp.where(pmask, c[pdst], 0.0)
            part_in = jnp.zeros((k_loc, v_n), jnp.float32)
            part_in = part_in.at[rows, pdst].add(cu).at[rows, psrc].add(cv)
            local = jnp.sum(part_in, axis=0)
            inflow = jax.lax.psum(local, axis)            # aggregation phase
            return (1.0 - damping) / v_n + damping * inflow, None

        rank, _ = jax.lax.scan(step, rank, None, length=iters)
        return rank

    fn = shard_map(worker, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=P(), check_rep=False)
    return jax.jit(fn)(part.src, part.dst, part.mask)

"""Concrete ETSCH problems (paper Algorithms 1–2 + the two it sketches) and
whole-graph vertex-centric references used both as correctness oracles and as
the paper's baseline for the *gain* metric.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

import numpy as np

from .etsch import (EtschResult, Partitioning, Problem, min_relax_sweep,
                    run_etsch)
from .graph import Graph, edge_weights

INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------------------
# Algorithm 1: single-source shortest paths (unit weights)
# ---------------------------------------------------------------------------

def _sssp_init(part: Partitioning, *, source: jax.Array) -> jax.Array:
    st = jnp.where(part.member, INF, INF)
    src_col = (jnp.arange(part.n_vertices) == source)[None, :]
    return jnp.where(part.member & src_col, 0.0, st)


SSSP = Problem(
    init=_sssp_init,
    local_sweep=min_relax_sweep,
    reduce=lambda st: jnp.min(st, axis=0),
    identity=jnp.inf,
    mode="replica",
)


def etsch_sssp(part: Partitioning, source: int | jax.Array) -> EtschResult:
    return run_etsch(part, SSSP, source=jnp.asarray(source, jnp.int32))


# ---------------------------------------------------------------------------
# Algorithm 2: connected components (random ids -> epidemic min)
# ---------------------------------------------------------------------------

def _cc_init(part: Partitioning, *, key: jax.Array) -> jax.Array:
    ids = jax.random.permutation(key, part.n_vertices).astype(jnp.float32)
    return jnp.where(part.member, ids[None, :], INF)


def _cc_sweep(part: Partitioning, state: jax.Array) -> jax.Array:
    return min_relax_sweep(part, state, edge_cost=0.0)


CC = Problem(
    init=_cc_init,
    local_sweep=_cc_sweep,
    reduce=lambda st: jnp.min(st, axis=0),
    identity=jnp.inf,
    mode="replica",
)


def etsch_cc(part: Partitioning, key: jax.Array | int = 0) -> EtschResult:
    if isinstance(key, int):
        key = jax.random.key(key)
    return run_etsch(part, CC, key=key)


# ---------------------------------------------------------------------------
# PageRank over an edge partitioning (sum-aggregation; paper §III sketch)
# ---------------------------------------------------------------------------

class PageRankResult(NamedTuple):
    rank: jax.Array
    supersteps: jax.Array


@partial(jax.jit, static_argnames=("iters",))
def etsch_pagerank(part: Partitioning, degrees: jax.Array, iters: int = 30,
                   damping: float = 0.85) -> PageRankResult:
    """Each superstep: partitions compute *partial* in-flows over their own
    edges; frontier aggregation sums the partials (each edge lives in exactly
    one partition, so the sum is exact)."""
    v_n = part.n_vertices
    rank = jnp.full((v_n,), 1.0 / v_n, jnp.float32)
    deg = jnp.maximum(degrees.astype(jnp.float32), 1.0)
    rows = jnp.arange(part.k)[:, None]

    def step(rank, _):
        contrib = rank / deg                                       # [V]
        cu = jnp.where(part.mask, contrib[part.src], 0.0)          # [K, E]
        cv = jnp.where(part.mask, contrib[part.dst], 0.0)
        partial_in = jnp.zeros((part.k, v_n), jnp.float32)
        partial_in = partial_in.at[rows, part.dst].add(cu)         # u -> v
        partial_in = partial_in.at[rows, part.src].add(cv)         # v -> u
        inflow = jnp.sum(partial_in, axis=0)                       # aggregation
        new = (1.0 - damping) / v_n + damping * inflow
        return new, None

    rank, _ = jax.lax.scan(step, rank, None, length=iters)
    return PageRankResult(rank, jnp.int32(iters))


# ---------------------------------------------------------------------------
# Luby maximal independent set (paper §III: "also possible in ETSCH")
# ---------------------------------------------------------------------------

class MisResult(NamedTuple):
    in_set: jax.Array       # [V] bool
    supersteps: jax.Array


@partial(jax.jit, static_argnames=("max_supersteps",))
def etsch_mis(part: Partitioning, key: jax.Array,
              max_supersteps: int = 256) -> MisResult:
    """Luby's algorithm: local phase spreads random priorities along
    partition edges; aggregation takes the min over replicas; vertices that
    beat every undecided neighbour join the set, their neighbours drop out."""
    v_n = part.n_vertices
    prio = jax.random.uniform(key, (v_n,), jnp.float32, 1e-6, 1.0)
    # status: 0 undecided / 1 in set / 2 excluded
    status0 = jnp.zeros((v_n,), jnp.int32)
    rows = jnp.arange(part.k)[:, None]

    def superstep(carry):
        status, steps, _ = carry
        undecided = status == 0
        p = jnp.where(undecided, prio, INF)                        # [V]
        # local phase: min undecided-neighbour priority over partition edges
        mn = jnp.full((part.k, v_n), INF)
        pu = jnp.where(part.mask, p[part.src], INF)
        pv = jnp.where(part.mask, p[part.dst], INF)
        mn = mn.at[rows, part.dst].min(pu)
        mn = mn.at[rows, part.src].min(pv)
        min_nbr = jnp.min(mn, axis=0)                              # aggregation
        join = undecided & (p < min_nbr)
        # second half-superstep: neighbours of joiners are excluded
        j = join.astype(jnp.float32)
        ex = jnp.zeros((part.k, v_n), jnp.float32)
        ex = ex.at[rows, part.dst].max(jnp.where(part.mask, j[part.src], 0.0))
        ex = ex.at[rows, part.src].max(jnp.where(part.mask, j[part.dst], 0.0))
        excluded = jnp.max(ex, axis=0) > 0                         # aggregation
        new_status = jnp.where(join, 1, status)
        new_status = jnp.where(excluded & (new_status == 0), 2, new_status)
        changed = jnp.any(new_status != status)
        return new_status, steps + 1, changed

    def cond(carry):
        status, steps, changed = carry
        return changed & (steps < max_supersteps)

    status, steps, _ = jax.lax.while_loop(
        cond, superstep, (status0, jnp.int32(0), jnp.bool_(True)))
    return MisResult(status == 1, steps)


# ---------------------------------------------------------------------------
# Whole-graph vertex-centric references (correctness oracles + gain baseline)
# ---------------------------------------------------------------------------

@jax.jit
def reference_sssp(g: Graph, source) -> tuple[jax.Array, jax.Array]:
    """Pregel-style BFS: one relaxation hop per round. Returns (dist, rounds).
    ``rounds`` is the vertex-centric superstep count the paper's *gain*
    compares against."""
    dist0 = jnp.full((g.n_vertices,), INF).at[source].set(0.0)

    def body(carry):
        d, r, _ = carry
        du = jnp.where(g.edge_mask, d[g.src] + 1.0, INF)
        dv = jnp.where(g.edge_mask, d[g.dst] + 1.0, INF)
        nd = d.at[g.dst].min(du).at[g.src].min(dv)
        return nd, r + 1, jnp.any(nd != d)

    def cond(carry):
        _, r, changed = carry
        return changed & (r < g.n_vertices)

    d, r, _ = jax.lax.while_loop(cond, body, (dist0, jnp.int32(0), jnp.bool_(True)))
    return d, r


@jax.jit
def reference_cc(g: Graph) -> tuple[jax.Array, jax.Array]:
    label0 = jnp.arange(g.n_vertices, dtype=jnp.float32)

    def body(carry):
        l, r, _ = carry
        lu = jnp.where(g.edge_mask, l[g.src], INF)
        lv = jnp.where(g.edge_mask, l[g.dst], INF)
        nl = l.at[g.dst].min(lu).at[g.src].min(lv)
        return nl, r + 1, jnp.any(nl != l)

    def cond(carry):
        _, r, changed = carry
        return changed & (r < g.n_vertices)

    l, r, _ = jax.lax.while_loop(cond, body, (label0, jnp.int32(0), jnp.bool_(True)))
    return l, r


@partial(jax.jit, static_argnames=("iters",))
def reference_pagerank(g: Graph, iters: int = 30, damping: float = 0.85):
    v_n = g.n_vertices
    deg = jnp.maximum(g.degrees().astype(jnp.float32), 1.0)
    rank = jnp.full((v_n,), 1.0 / v_n, jnp.float32)

    def step(rank, _):
        c = rank / deg
        inflow = (jnp.zeros_like(rank)
                  .at[g.dst].add(jnp.where(g.edge_mask, c[g.src], 0.0))
                  .at[g.src].add(jnp.where(g.edge_mask, c[g.dst], 0.0)))
        return (1.0 - damping) / v_n + damping * inflow, None

    rank, _ = jax.lax.scan(step, rank, None, length=iters)
    return rank


def reference_weighted_sssp(g: Graph, source: int) -> np.ndarray:
    """Weighted shortest paths under the deterministic content-hash weights
    (``graph.edge_weights``), iterated to the relaxation fixpoint.

    Host-side numpy, float32 throughout: each relaxation computes
    ``min(d[v], f32(d[u] + w))`` — the identical IEEE op sequence the
    engine's min-plus sweeps perform, so f32 min-plus relaxation converges
    to the same unique fixpoint and engine results are *bit-identical*
    (both iterate a monotone map over the finite f32 lattice from +inf).
    """
    u, v = g.as_numpy()
    w = edge_weights(u, v)
    dist = np.full(g.n_vertices, np.inf, np.float32)
    dist[int(source)] = 0.0
    for _ in range(g.n_vertices):
        nd = dist.copy()
        np.minimum.at(nd, v, (dist[u] + w).astype(np.float32))
        np.minimum.at(nd, u, (dist[v] + w).astype(np.float32))
        if np.array_equal(nd, dist, equal_nan=True):
            break
        dist = nd
    return dist


def reference_label_propagation(g: Graph, labels) -> np.ndarray:
    """Min-label propagation over an *external* label plane: every vertex
    converges to the smallest label present in its connected component
    (vertices keep their own label if isolated).

    ``labels`` is a [V] or [V, 1] float32 plane (the engine's vertex
    property channel format).  Labels flow through ``min`` only — no
    arithmetic — so the engine result is bit-identical to this oracle
    regardless of partitioning or padding.
    """
    lab = np.asarray(labels, np.float32).reshape(-1)
    u, v = g.as_numpy()
    out = lab.copy()
    for _ in range(g.n_vertices):
        new = out.copy()
        np.minimum.at(new, v, out[u])
        np.minimum.at(new, u, out[v])
        if np.array_equal(new, out):
            break
        out = new
    return out


def reference_personalized_pagerank(g: Graph, personalization, iters: int = 30,
                                    damping: float = 0.85) -> np.ndarray:
    """Degree-weighted PageRank with an external personalization (teleport)
    vector: ``rank <- (1-d) * p + d * inflow`` where each vertex spreads
    ``rank/deg`` along its edges.  ``p`` is a [V] or [V, 1] plane supplied
    by the caller (the engine's vertex property channel); it is used as
    given — normalise it to a distribution if you want a distribution out.
    Float32 partial sums reassociate across partitions, so engine results
    match to ``oracle_atol`` (1e-5), like plain PageRank.
    """
    p = jnp.asarray(np.asarray(personalization, np.float32).reshape(-1))
    v_n = g.n_vertices
    deg = jnp.maximum(g.degrees().astype(jnp.float32), 1.0)
    rank = p

    def step(rank, _):
        c = rank / deg
        inflow = (jnp.zeros_like(rank)
                  .at[g.dst].add(jnp.where(g.edge_mask, c[g.src], 0.0))
                  .at[g.src].add(jnp.where(g.edge_mask, c[g.dst], 0.0)))
        return (1.0 - damping) * p + damping * inflow, None

    rank, _ = jax.lax.scan(step, rank, None, length=int(iters))
    return np.asarray(rank)


def reference_gcn_layer(g: Graph, x, weight) -> np.ndarray:
    """Dense numpy reference for one GCN layer forward pass over the
    undirected weighted graph: ``out = (D^{-1/2} A_w D^{-1/2} X) W``.

    ``A_w`` carries the deterministic content-hash ``edge_weights`` (no
    self-loops), ``D`` is the unit-degree vector clamped to >= 1 (isolated
    vertices aggregate to a zero row, they are never divided by zero).
    ``x`` is a [V, F_in] vertex feature plane, ``weight`` a [F_in, F_out]
    dense matrix.  Float32 throughout; partition-order reassociation of
    the f32 sums keeps engine results within ``oracle_atol`` (1e-5).
    """
    x = np.asarray(x, np.float32)
    w = np.asarray(weight, np.float32)
    u, v = g.as_numpy()
    ew = edge_weights(u, v)
    inv_sqrt = (1.0 / np.sqrt(np.maximum(
        np.asarray(g.degrees(), np.float32), 1.0))).astype(np.float32)
    xn = x * inv_sqrt[:, None]
    agg = np.zeros_like(x)
    np.add.at(agg, v, xn[u] * ew[:, None])
    np.add.at(agg, u, xn[v] * ew[:, None])
    return ((agg * inv_sqrt[:, None]) @ w).astype(np.float32)


def reference_kge_score(g: Graph, entity, relation) -> np.ndarray:
    """Dense numpy reference for DistMult-style triple scoring summed per
    vertex: for every live edge e = (u, v) with relation embedding r_e,
    ``score(e) = sum_f ent_u[f] * r_e[f] * ent_v[f]`` — the symmetric
    DistMult interaction — accumulated onto BOTH endpoints, so a vertex's
    output is the total plausibility mass of its incident triples.

    ``entity`` is a [V, F] vertex plane; ``relation`` a [rows, F] plane in
    *graph edge-slot order* (rows may stop anywhere past the live slots —
    slots beyond the supplied rows score 0, exactly like the engine's
    slack-aware edge gather).  Isolated vertices score 0.

    Scores are unnormalized degree-length f32 sums, so on hub-heavy
    graphs the engine's partition-order reassociation can drift past an
    absolute 1e-5 on high-degree vertices (~1e-4 *relative*, plain f32
    accumulation error); the registered ``oracle_atol`` holds on the
    gated test/bench graphs, but comparisons on larger graphs should
    add ``rtol≈2e-4``.
    """
    ent = np.asarray(entity, np.float32)
    rel = np.asarray(relation, np.float32)
    slots = np.flatnonzero(np.asarray(g.edge_mask))
    u = np.asarray(g.src)[slots]
    v = np.asarray(g.dst)[slots]
    covered = slots < rel.shape[0]
    r = np.where(covered[:, None], rel[np.minimum(slots, rel.shape[0] - 1)],
                 np.float32(0.0))
    s = np.sum(ent[u] * r * ent[v], axis=1, dtype=np.float32)
    out = np.zeros(g.n_vertices, np.float32)
    np.add.at(out, u, s)
    np.add.at(out, v, s)
    return out


def reference_bfs(g: Graph, source: int) -> np.ndarray:
    """BFS hop levels: 0.0 at the source, the hop count elsewhere, and
    -1.0 for vertices unreachable from the source (float32, matching the
    engine program's finalized output)."""
    d, _ = reference_sssp(g, jnp.int32(source))
    d = np.asarray(d)
    return np.where(np.isinf(d), np.float32(-1.0), d).astype(np.float32)


def is_independent_set(g: Graph, in_set: jax.Array) -> jax.Array:
    both = in_set[g.src] & in_set[g.dst] & g.edge_mask
    return ~jnp.any(both)


def is_maximal_independent_set(g: Graph, in_set: jax.Array) -> jax.Array:
    nbr_in = (jnp.zeros(g.n_vertices, jnp.bool_)
              .at[g.dst].max(in_set[g.src] & g.edge_mask)
              .at[g.src].max(in_set[g.dst] & g.edge_mask))
    covered = in_set | nbr_in
    deg = g.degrees() > 0
    return is_independent_set(g, in_set) & jnp.all(covered | ~deg)


# ---------------------------------------------------------------------------
# Multi-source distances (building block for betweenness centrality — the
# paper motivates distance computation via Brandes §III) — one ETSCH run
# computes distances from S sources simultaneously (state [K, S, V]).
# ---------------------------------------------------------------------------

class MultiSsspResult(NamedTuple):
    dist: jax.Array         # [S, V]
    supersteps: jax.Array


@partial(jax.jit, static_argnames=("max_supersteps",))
def etsch_multi_sssp(part: Partitioning, sources: jax.Array,
                     max_supersteps: int = 512) -> MultiSsspResult:
    """Distances from every source in ``sources`` [S] at once; the frontier
    aggregation reconciles an [S, V] replica block per partition."""
    v_n = part.n_vertices
    n_src = sources.shape[0]
    rows = jnp.arange(part.k)[:, None, None]
    is_src = sources[:, None] == jnp.arange(v_n)[None, :]      # [S, V]
    member = part.member[:, None, :]                           # [K, 1, V]
    dist0 = jnp.where(member & is_src[None], 0.0, INF)         # [K, S, V]

    def local_sweep(d):
        du = jnp.where(part.mask[:, None, :],
                       d[rows, jnp.arange(n_src)[None, :, None],
                         part.src[:, None, :]] + 1.0, INF)
        dv = jnp.where(part.mask[:, None, :],
                       d[rows, jnp.arange(n_src)[None, :, None],
                         part.dst[:, None, :]] + 1.0, INF)
        d = d.at[rows, jnp.arange(n_src)[None, :, None],
                 part.dst[:, None, :]].min(du)
        d = d.at[rows, jnp.arange(n_src)[None, :, None],
                 part.src[:, None, :]].min(dv)
        return d

    def local_fixpoint(d):
        def body(c):
            dd, _ = c
            nd = local_sweep(dd)
            return nd, jnp.any(nd != dd)
        d, _ = jax.lax.while_loop(lambda c: c[1], body, (d, jnp.bool_(True)))
        return d

    def superstep(carry):
        d, steps, _ = carry
        d1 = local_fixpoint(d)
        agg = jnp.min(d1, axis=0)                              # [S, V]
        d2 = jnp.where(member, agg[None], INF)
        return d2, steps + 1, jnp.any(d2 != d)

    def cond(carry):
        return carry[2] & (carry[1] < max_supersteps)

    d, steps, _ = jax.lax.while_loop(
        cond, superstep, (dist0, jnp.int32(0), jnp.bool_(True)))
    return MultiSsspResult(jnp.min(d, axis=0), steps)


# ---------------------------------------------------------------------------
# k-core decomposition (iterative peeling) on ETSCH: local phase counts
# partition-local degrees among active vertices; aggregation sums the
# partials (each edge lives in exactly one partition, so the sum is exact).
# ---------------------------------------------------------------------------

class KCoreResult(NamedTuple):
    in_core: jax.Array      # [V] bool — member of the k-core
    supersteps: jax.Array


@partial(jax.jit, static_argnames=("k_core", "max_supersteps"))
def etsch_kcore(part: Partitioning, k_core: int,
                max_supersteps: int = 512) -> KCoreResult:
    v_n = part.n_vertices
    rows = jnp.arange(part.k)[:, None]
    active0 = (jnp.zeros((v_n,), jnp.bool_)
               .at[part.src.reshape(-1)].max(part.mask.reshape(-1))
               .at[part.dst.reshape(-1)].max(part.mask.reshape(-1)))

    def superstep(carry):
        active, steps, _ = carry
        live = part.mask & active[part.src] & active[part.dst]   # [K, E]
        partial_deg = jnp.zeros((part.k, v_n), jnp.int32)
        partial_deg = partial_deg.at[rows, part.src].add(live.astype(jnp.int32))
        partial_deg = partial_deg.at[rows, part.dst].add(live.astype(jnp.int32))
        deg = jnp.sum(partial_deg, axis=0)                       # aggregation
        new_active = active & (deg >= k_core)
        return new_active, steps + 1, jnp.any(new_active != active)

    def cond(carry):
        return carry[2] & (carry[1] < max_supersteps)

    active, steps, _ = jax.lax.while_loop(
        cond, superstep, (active0, jnp.int32(0), jnp.bool_(True)))
    return KCoreResult(active, steps)


@partial(jax.jit, static_argnames=("k_core",))
def reference_kcore(g: Graph, k_core: int) -> jax.Array:
    active0 = (g.degrees() > 0)

    def body(carry):
        active, _ = carry
        live = g.edge_mask & active[g.src] & active[g.dst]
        deg = (jnp.zeros(g.n_vertices, jnp.int32)
               .at[g.src].add(live.astype(jnp.int32))
               .at[g.dst].add(live.astype(jnp.int32)))
        new = active & (deg >= k_core)
        return new, jnp.any(new != active)

    active, _ = jax.lax.while_loop(lambda c: c[1], body,
                                   (active0, jnp.bool_(True)))
    return active

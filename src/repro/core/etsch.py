"""ETSCH — the paper's edge-partition graph-processing framework (§III).

Computation model (Fig. 2):

  1. *init*        — per-vertex state initialised on each induced subgraph,
  2. *local phase* — each partition independently runs a sequential algorithm
                     on its subgraph to a local fixed point,
  3. *aggregation* — replicated (frontier) vertex states are reconciled with
                     a commutative/associative reducer and copied back.

Steps 2–3 repeat ("supersteps") until a global fixed point. The number of
supersteps is the paper's *rounds* metric; the fraction saved vs a
vertex-centric (Pregel-style, one-hop-per-round) execution is its *gain*.

Hardware adaptation: the paper's local phase uses Dijkstra/heaps; on TPU we
run masked relaxation sweeps (same fixed point, data-parallel — DESIGN.md §3).
State is held as a dense [K, V] matrix (partition-local vertex copies);
non-member entries hold the reducer's identity, so aggregation is a plain
axis-0 reduce followed by a masked broadcast back to members.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .graph import Graph


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Partitioning:
    """An edge partitioning compiled into static per-partition arrays."""

    k: int                  # static
    n_vertices: int         # static
    e_max: int              # static: padded per-partition edge capacity
    src: jax.Array          # [K, E_max] int32 (padding: 0, masked)
    dst: jax.Array          # [K, E_max] int32
    mask: jax.Array         # [K, E_max] bool
    member: jax.Array       # [K, V] bool — v ∈ V_i
    frontier: jax.Array     # [K, V] bool — v ∈ F_i (member of ≥ 2 partitions)

    def tree_flatten(self):
        return ((self.src, self.dst, self.mask, self.member, self.frontier),
                (self.k, self.n_vertices, self.e_max))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], aux[1], aux[2], *children)

    @property
    def sizes(self) -> jax.Array:
        return jnp.sum(self.mask.astype(jnp.int32), axis=1)


def compile_partitioning(g: Graph, owner, k: int,
                         e_max: int | None = None) -> Partitioning:
    """Host-side: bucket edges by owner into padded [K, E_max] arrays."""
    owner = np.asarray(owner)
    u = np.asarray(g.src)
    v = np.asarray(g.dst)
    emask = np.asarray(g.edge_mask)
    u, v, owner = u[emask], v[emask], owner[emask]
    assert owner.min() >= 0 and owner.max() < k, "owner must be a valid partitioning"

    counts = np.bincount(owner, minlength=k)
    if e_max is None:
        e_max = max(int(counts.max()), 1)
        e_max = -(-e_max // 128) * 128  # lane-align
    ps = np.zeros((k, e_max), np.int32)
    pd = np.zeros((k, e_max), np.int32)
    pm = np.zeros((k, e_max), bool)
    order = np.argsort(owner, kind="stable")
    so, su_, sv_ = owner[order], u[order], v[order]
    group_start = np.searchsorted(so, np.arange(k))
    pos = np.arange(len(so)) - group_start[so]
    ps[so, pos] = su_
    pd[so, pos] = sv_
    pm[so, pos] = True

    member = np.zeros((k, g.n_vertices), bool)
    rows = np.repeat(np.arange(k)[:, None], e_max, 1)
    member[rows[pm], ps[pm]] = True
    member[rows[pm], pd[pm]] = True
    replicas = member.sum(0)
    frontier = member & (replicas[None, :] >= 2)

    return Partitioning(k, g.n_vertices, e_max,
                        jnp.asarray(ps), jnp.asarray(pd), jnp.asarray(pm),
                        jnp.asarray(member), jnp.asarray(frontier))


# ---------------------------------------------------------------------------
# Generic superstep engine
# ---------------------------------------------------------------------------

class Problem(NamedTuple):
    """An ETSCH problem: init / local one-sweep relaxation / aggregation.

    ``local_sweep(p, state) -> state`` performs ONE edge-relaxation sweep of
    the partition-local sequential algorithm; the engine iterates it to the
    local fixed point (that iteration is *free* in the paper's cost model —
    it happens inside a worker between synchronisations).

    ``reduce`` must be commutative/associative with identity ``identity``.
    ``mode`` = "replica"  → replicas hold copies of one logical value; the
                            aggregate replaces every replica (min/max style).
             = "partial"  → replicas hold *partial* values that must be
                            summed across partitions (PageRank style).
    """
    init: Callable          # (part, **kw) -> [K, V] state
    local_sweep: Callable   # (part, [K, V]) -> [K, V]
    reduce: Callable        # ([K, V]) -> [V]
    identity: float
    mode: str = "replica"


class EtschResult(NamedTuple):
    state: jax.Array        # [V] final aggregated vertex state
    supersteps: jax.Array   # scalar int32 — the paper's "rounds"
    local_iters: jax.Array  # scalar int32 — total local sweeps executed


def _local_fixed_point(part: Partitioning, prob: Problem, state, max_iters: int):
    """Iterate local sweeps until no partition changes (bounded)."""

    def cond(c):
        st, it, changed = c
        return changed & (it < max_iters)

    def body(c):
        st, it, _ = c
        new = prob.local_sweep(part, st)
        changed = jnp.any(new != st)
        return new, it + 1, changed

    st, iters, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0), jnp.bool_(True)))
    return st, iters


@partial(jax.jit, static_argnames=("prob", "max_supersteps", "max_local_iters"))
def run_etsch(part: Partitioning, prob: Problem,
              max_supersteps: int = 512, max_local_iters: int = 100_000,
              **init_kw) -> EtschResult:
    state0 = prob.init(part, **init_kw)

    def agg(st):
        red = prob.reduce(st)                                    # [V]
        if prob.mode == "partial":
            return red
        return red  # replica mode: same reduce; broadcast handled below

    def superstep(carry):
        st, steps, litot, _ = carry
        st1, li = _local_fixed_point(part, prob, st, max_local_iters)
        red = agg(st1)                                           # [V]
        st2 = jnp.where(part.member, red[None, :], prob.identity)
        changed = jnp.any(st2 != st)
        return st2, steps + 1, litot + li, changed

    def cond(carry):
        _, steps, _, changed = carry
        return changed & (steps < max_supersteps)

    st, steps, litot, _ = jax.lax.while_loop(
        cond, superstep, (state0, jnp.int32(0), jnp.int32(0), jnp.bool_(True)))
    return EtschResult(prob.reduce(st), steps, litot)


# ---------------------------------------------------------------------------
# Relaxation helpers shared by the concrete problems (algorithms.py)
# ---------------------------------------------------------------------------

def min_relax_sweep(part: Partitioning, state: jax.Array,
                    edge_cost: float = 1.0) -> jax.Array:
    """One min-plus sweep over every partition's edges simultaneously.

    state [K, V]; for every partition-k edge (u,v):
        state[k, v] <- min(state[k, v], state[k, u] + cost)   (both directions)
    Flattened into a single scatter-min on [K*V].
    """
    k, v_n = state.shape
    flat = state.reshape(-1)
    base = (jnp.arange(k, dtype=jnp.int32) * v_n)[:, None]       # [K, 1]
    iu = (base + part.src).reshape(-1)                           # [K*E] flat idx
    iv = (base + part.dst).reshape(-1)
    su = state[jnp.arange(k)[:, None], part.src]                 # [K, E]
    sv = state[jnp.arange(k)[:, None], part.dst]
    big = jnp.float32(jnp.inf)
    cu = jnp.where(part.mask, su + edge_cost, big).reshape(-1)
    cv = jnp.where(part.mask, sv + edge_cost, big).reshape(-1)
    flat = flat.at[iv].min(cu)   # u -> v
    flat = flat.at[iu].min(cv)   # v -> u
    return flat.reshape(k, v_n)

"""Partition-quality metrics (paper §V-A): balance/NSTDEV, communication
cost (MESSAGES = Σ|F_i|), connectedness, and the *gain* of ETSCH SSSP vs the
vertex-centric baseline."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .algorithms import etsch_sssp, reference_sssp
from .etsch import Partitioning, compile_partitioning
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class PartitionMetrics:
    k: int
    sizes: np.ndarray            # [K] edges per partition
    largest_norm: float          # max |E_i| / (|E|/K)     (paper fig 5a/7a)
    nstdev: float                # paper's NSTDEV formula  (fig 5/6f/7)
    messages: int                # Σ|F_i|                  (fig 5c/6c/7c)
    frontier_total: int          # number of distinct frontier vertices
    replication_factor: float    # Σ|V_i| / |V|
    connected_frac: float        # fraction of partitions that are connected
    rounds: int | None = None    # partitioner rounds (when known)
    gain: float | None = None    # ETSCH SSSP gain       (fig 5d/6d/7d)

    def row(self) -> dict:
        d = dataclasses.asdict(self)
        d["sizes"] = None
        return d


def _sizes(owner: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(owner[owner >= 0], minlength=k)


def nstdev(sizes: np.ndarray, n_edges: int) -> float:
    k = len(sizes)
    norm = sizes / (n_edges / k)
    return float(np.sqrt(np.mean((norm - 1.0) ** 2)))


@partial(jax.jit, static_argnames=("k",))
def _membership(g: Graph, owner: jax.Array, k: int):
    member = jnp.zeros((k, g.n_vertices), jnp.bool_)
    ow = jnp.where(g.edge_mask, owner, 0)
    valid = g.edge_mask & (owner >= 0)
    member = member.at[ow, g.src].max(valid)
    member = member.at[ow, g.dst].max(valid)
    return member


def connected_fraction(part: Partitioning) -> float:
    """Fraction of partitions whose induced subgraph is connected
    (paper fig 6e plots the complement). Label-propagation per partition."""
    k, v_n = part.k, part.n_vertices
    # seed labels: vertex index where member else +inf; propagate min via edges
    lab = jnp.where(part.member,
                    jnp.arange(v_n, dtype=jnp.float32)[None, :], jnp.inf)
    rows = jnp.arange(k)[:, None]

    def body(carry):
        l, _ = carry
        lu = jnp.where(part.mask, l[rows, part.src], jnp.inf)
        lv = jnp.where(part.mask, l[rows, part.dst], jnp.inf)
        nl = l.at[rows, part.dst].min(lu).at[rows, part.src].min(lv)
        return nl, jnp.any(nl != l)

    lab, _ = jax.lax.while_loop(lambda c: c[1], body, (lab, jnp.bool_(True)))
    # connected iff all members share one label
    mn = jnp.min(jnp.where(part.member, lab, jnp.inf), axis=1, keepdims=True)
    same = jnp.where(part.member, lab == mn, True)
    conn = jnp.all(same, axis=1)
    nonempty = jnp.any(part.member, axis=1)
    return float(jnp.sum(conn & nonempty) / jnp.maximum(jnp.sum(nonempty), 1))


def evaluate(g: Graph, owner, k: int, *, rounds: int | None = None,
             compute_gain: bool = True, part: Partitioning | None = None,
             source: int = 0) -> PartitionMetrics:
    owner_np = np.asarray(owner)
    emask = np.asarray(g.edge_mask)
    sizes = _sizes(owner_np[emask], k)

    member = np.asarray(_membership(g, jnp.asarray(owner), k))
    replicas = member.sum(0)
    frontier_per_part = (member & (replicas[None, :] >= 2)).sum(1)
    messages = int(frontier_per_part.sum())

    if part is None:
        part = compile_partitioning(g, owner, k)

    gain = None
    if compute_gain:
        res = etsch_sssp(part, source)
        _, ref_rounds = reference_sssp(g, source)
        gain = float(1.0 - int(res.supersteps) / max(int(ref_rounds), 1))

    return PartitionMetrics(
        k=k,
        sizes=sizes,
        largest_norm=float(sizes.max() / (g.n_edges / k)),
        nstdev=nstdev(sizes, g.n_edges),
        messages=messages,
        frontier_total=int((replicas >= 2).sum()),
        replication_factor=float(member.sum() / max(g.n_vertices, 1)),
        connected_frac=connected_fraction(part),
        rounds=rounds,
        gain=gain,
    )

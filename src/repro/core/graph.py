"""Static-shape undirected graph container + synthetic generators.

The paper evaluates on SNAP graphs (Table II/III). This container keeps the
graph in flat, fixed-shape arrays so every DFEP/ETSCH step is jittable:

  * ``src``/``dst``  — one row per *undirected* edge (padded slots hold 0/0
    and are masked out by ``edge_mask``),
  * degrees / CSR derived lazily where needed.

Generators are host-side (numpy) and deterministic given a seed; parameters
for each paper dataset profile live in ``DATASETS`` (no network access in the
container, so we synthesise graphs matching the published |V|, |E|, diameter
class and clustering-coefficient class — see DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import partial
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph, one row per undirected edge, padded to a static size."""

    n_vertices: int          # static
    n_edges: int             # static: number of REAL edges (<= padded size)
    src: jax.Array           # [E_pad] int32
    dst: jax.Array           # [E_pad] int32
    edge_mask: jax.Array     # [E_pad] bool — True for real edges

    # -- pytree plumbing (n_vertices / n_edges are static aux data) --------
    def tree_flatten(self):
        return (self.src, self.dst, self.edge_mask), (self.n_vertices, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, edge_mask = children
        return cls(aux[0], aux[1], src, dst, edge_mask)

    # -- convenience --------------------------------------------------------
    @property
    def e_pad(self) -> int:
        return int(self.src.shape[0])

    def degrees(self) -> jax.Array:
        """Vertex degrees, [V] int32 (each undirected edge counts once per side)."""
        m = self.edge_mask.astype(jnp.int32)
        d = jnp.zeros(self.n_vertices, jnp.int32)
        d = d.at[self.src].add(m)
        d = d.at[self.dst].add(m)
        return d

    def as_numpy(self) -> tuple[np.ndarray, np.ndarray]:
        m = np.asarray(self.edge_mask)
        return np.asarray(self.src)[m], np.asarray(self.dst)[m]

    def fingerprint(self) -> str:
        """Stable content hash over the *masked* edge set.

        Invariant under slot order and padding size: two graphs with the
        same vertices and the same real (u, v) set hash identically. Used to
        key the engine's plan cache (compile_plan_cached) so logically equal
        graphs share compiled plans regardless of object identity.
        """
        u, v = self.as_numpy()
        keys = np.sort(u.astype(np.int64) * self.n_vertices + v)
        h = hashlib.sha256()
        h.update(np.int64(self.n_vertices).tobytes())
        h.update(keys.tobytes())
        return h.hexdigest()


#: Modulus of the deterministic edge-weight hash (prime, so the low bits of
#: the endpoint mix spread evenly over [1, 2)).
EDGE_WEIGHT_MOD = 1_000_003


def edge_weights(u, v) -> np.ndarray:
    """Deterministic per-edge float32 weights in [1, 2).

    Weights are a pure content hash of the (undirected) endpoint pair, so
    every layer reconstructs identical values independently — plan
    compilation bakes them into ``PartitionPlan.edge_w``, the streaming
    patch path recomputes them for appended half-edges, and the
    whole-graph oracles (``core.algorithms.reference_weighted_sssp``) use
    the same function — without any layer shipping a weight array around
    or the graph fingerprint having to cover more than the edge set.
    The [1, 2) range keeps weighted relaxation convergence within the same
    superstep bounds as unit-weight SSSP.
    """
    a = np.minimum(u, v).astype(np.int64)
    b = np.maximum(u, v).astype(np.int64)
    h = (a * 2654435761 + b * 97_571 + 12_345) % EDGE_WEIGHT_MOD
    return (1.0 + h / EDGE_WEIGHT_MOD).astype(np.float32)


def apply_edge_updates(g: Graph, slots: np.ndarray, new_src: np.ndarray,
                       new_dst: np.ndarray, new_mask: np.ndarray) -> Graph:
    """Functional slot-level mutation: write (src, dst, mask) at ``slots``.

    ``StreamingGraph.graph()`` (repro.stream.ingest) materialises mutated
    graphs through this: insertions claim masked (spare) slots, deletions
    clear ``edge_mask``, and only the dirty slots are rewritten on device.
    Shapes never change, so plans and jitted programs keyed on the padded
    shape stay valid.
    """
    slots = jnp.asarray(slots, jnp.int32)
    src = g.src.at[slots].set(jnp.asarray(new_src, jnp.int32))
    dst = g.dst.at[slots].set(jnp.asarray(new_dst, jnp.int32))
    mask = g.edge_mask.at[slots].set(jnp.asarray(new_mask, bool))
    return Graph(g.n_vertices, int(jnp.sum(mask)), src, dst, mask)


def from_edge_array(n_vertices: int, edges: np.ndarray, pad_to: int | None = None) -> Graph:
    """Build a Graph from an [E, 2] int array of undirected edges.

    Dedupes (u,v)/(v,u), drops self loops, pads to ``pad_to`` (default: next
    multiple of 128 — TPU-lane friendly).
    """
    edges = np.asarray(edges, dtype=np.int64)
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v
    u, v = u[keep], v[keep]
    uniq = np.unique(u * n_vertices + v)
    u, v = (uniq // n_vertices).astype(np.int32), (uniq % n_vertices).astype(np.int32)
    e = len(u)
    if pad_to is None:
        pad_to = max(128, -(-e // 128) * 128)
    assert pad_to >= e, (pad_to, e)
    pu = np.zeros(pad_to, np.int32)
    pv = np.zeros(pad_to, np.int32)
    pm = np.zeros(pad_to, bool)
    pu[:e], pv[:e], pm[:e] = u, v, True
    return Graph(int(n_vertices), int(e),
                 jnp.asarray(pu), jnp.asarray(pv), jnp.asarray(pm))


# ---------------------------------------------------------------------------
# Generators (host-side numpy; deterministic by seed)
# ---------------------------------------------------------------------------

def barabasi_albert(n: int, m: int, seed: int = 0) -> Graph:
    """Preferential-attachment graph: small diameter, power-law degrees.

    Matches the ASTROPH / EMAIL-ENRON / DBLP dataset class of the paper.
    """
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    repeated: list[int] = []
    edges = []
    for v in range(m, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m)
        # sample next targets from the degree-weighted multiset
        idx = rng.integers(0, len(repeated), size=3 * m)
        cand = {repeated[i] for i in idx}
        targets = list(cand)[:m]
        while len(targets) < m:
            t = int(rng.integers(0, v + 1))
            if t not in targets:
                targets.append(t)
    return from_edge_array(n, np.array(edges))


def watts_strogatz(n: int, k: int, beta: float, seed: int = 0) -> Graph:
    """Ring lattice with rewiring: high clustering coefficient (WORDNET class)."""
    rng = np.random.default_rng(seed)
    u = np.repeat(np.arange(n), k // 2)
    off = np.tile(np.arange(1, k // 2 + 1), n)
    v = (u + off) % n
    rewire = rng.random(len(u)) < beta
    v = np.where(rewire, rng.integers(0, n, size=len(u)), v)
    return from_edge_array(n, np.stack([u, v], 1))


def road_network(rows: int, cols: int, extra_frac: float = 0.25, seed: int = 0) -> Graph:
    """USROADS class: near-tree planar grid — huge diameter, degree ≈ 2.6.

    Random spanning tree of the rows×cols grid + ``extra_frac·V`` extra grid
    edges. Diameter is O(rows+cols) like a road network.
    """
    rng = np.random.default_rng(seed)
    n = rows * cols

    def vid(r, c):
        return r * cols + c

    # all grid edges
    es = []
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                es.append((vid(r, c), vid(r, c + 1)))
            if r + 1 < rows:
                es.append((vid(r, c), vid(r + 1, c)))
    es = np.array(es)
    perm = rng.permutation(len(es))
    es = es[perm]
    # Kruskal spanning tree (union-find)
    parent = np.arange(n)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    tree, extra = [], []
    for a, b in es:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
            tree.append((a, b))
        else:
            extra.append((a, b))
    n_extra = int(extra_frac * n)
    keep = extra[:n_extra]
    return from_edge_array(n, np.array(tree + keep))


def erdos_renyi(n: int, e: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=int(e * 1.3))
    v = rng.integers(0, n, size=int(e * 1.3))
    g = from_edge_array(n, np.stack([u, v], 1))
    if g.n_edges > e:  # trim to target
        su, sv = g.as_numpy()
        return from_edge_array(n, np.stack([su[:e], sv[:e]], 1))
    return g


def remap_edges(g: Graph, fraction: float, seed: int = 0) -> Graph:
    """Paper Fig-6 protocol: remap a random fraction of edges to random
    endpoints, lowering the diameter while keeping |V|, |E| fixed."""
    rng = np.random.default_rng(seed)
    u, v = g.as_numpy()
    n = g.n_vertices
    k = int(fraction * len(u))
    idx = rng.choice(len(u), size=k, replace=False)
    side = rng.random(k) < 0.5
    new_end = rng.integers(0, n, size=k)
    u2, v2 = u.copy(), v.copy()
    u2[idx] = np.where(side, new_end, u2[idx])
    v2[idx] = np.where(~side, new_end, v2[idx])
    return from_edge_array(n, np.stack([u2, v2], 1), pad_to=g.e_pad)


def largest_component(g: Graph) -> Graph:
    """Restrict to the largest connected component (paper cleans SNAP data
    the same way)."""
    u, v = g.as_numpy()
    n = g.n_vertices
    label = np.arange(n)
    # label propagation until fixpoint (numpy; bounded by diameter)
    for _ in range(n):
        lu, lv = label[u], label[v]
        m = np.minimum(lu, lv)
        new = label.copy()
        np.minimum.at(new, u, m)
        np.minimum.at(new, v, m)
        if np.array_equal(new, label):
            break
        label = new
    roots, counts = np.unique(label, return_counts=True)
    big = roots[np.argmax(counts)]
    keep = (label[u] == big) & (label[v] == big)
    u, v = u[keep], v[keep]
    # compact vertex ids
    verts = np.unique(np.concatenate([u, v]))
    remap = np.full(n, -1, np.int64)
    remap[verts] = np.arange(len(verts))
    return from_edge_array(len(verts), np.stack([remap[u], remap[v]], 1))


# ---------------------------------------------------------------------------
# Paper dataset profiles (synthetic stand-ins; scale=1.0 matches published |V|)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    builder: Callable[[float, int], Graph]
    table: str        # "II" (simulation) or "III" (EC2)
    v_published: int
    e_published: int
    diameter_published: int


def _astroph(scale: float, seed: int) -> Graph:
    return largest_component(barabasi_albert(int(17903 * scale), 11, seed))


def _email_enron(scale: float, seed: int) -> Graph:
    return largest_component(barabasi_albert(int(33696 * scale), 5, seed))


def _usroads(scale: float, seed: int) -> Graph:
    side = int(np.sqrt(126146 * scale))
    return largest_component(road_network(side, side, 0.28, seed))


def _wordnet(scale: float, seed: int) -> Graph:
    return largest_component(watts_strogatz(int(75606 * scale), 6, 0.1, seed))


def _dblp(scale: float, seed: int) -> Graph:
    return largest_component(barabasi_albert(int(317080 * scale), 3, seed))


def _youtube(scale: float, seed: int) -> Graph:
    return largest_component(barabasi_albert(int(1134890 * scale), 3, seed))


def _amazon(scale: float, seed: int) -> Graph:
    return largest_component(barabasi_albert(int(400727 * scale), 6, seed))


DATASETS: dict[str, DatasetSpec] = {
    "astroph":     DatasetSpec("astroph", _astroph, "II", 17903, 196972, 14),
    "email-enron": DatasetSpec("email-enron", _email_enron, "II", 33696, 180811, 13),
    "usroads":     DatasetSpec("usroads", _usroads, "II", 126146, 161950, 617),
    "wordnet":     DatasetSpec("wordnet", _wordnet, "II", 75606, 231622, 14),
    "dblp":        DatasetSpec("dblp", _dblp, "III", 317080, 1049866, 21),
    "youtube":     DatasetSpec("youtube", _youtube, "III", 1134890, 2987624, 20),
    "amazon":      DatasetSpec("amazon", _amazon, "III", 400727, 2349869, 18),
}


def load_dataset(name: str, scale: float = 1.0, seed: int = 0) -> Graph:
    return DATASETS[name].builder(scale, seed)

"""Baseline partitioners the paper compares against (§V-C, §VI-B).

* ``random_partition`` / ``hash_partition`` — the trivial balance-only
  baselines (perfect balance, terrible locality).
* ``greedy_partition`` — PowerGraph-style streaming greedy edge placement
  (standard edge-partitioning baseline from the literature).
* ``jabeja_partition`` — the paper's chosen competitor: JaBeJa vertex
  partitioning (local search + simulated annealing, swap-based so balance is
  preserved), converted to an edge partitioning by assigning each cut edge
  uniformly at random to one of its two endpoint partitions (the conversion
  the paper uses — §V-C explains the line-graph alternative is unfeasible).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .graph import Graph


def random_partition(g: Graph, k: int, seed: int = 0) -> jax.Array:
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, k, size=g.e_pad).astype(np.int32)
    return jnp.where(g.edge_mask, jnp.asarray(owner), -2)


def hash_partition(g: Graph, k: int) -> jax.Array:
    u = g.src.astype(jnp.uint32)
    v = g.dst.astype(jnp.uint32)
    h = (u * jnp.uint32(2654435761) ^ (v * jnp.uint32(40503) + jnp.uint32(0x9E3779B9)))
    owner = (h % jnp.uint32(k)).astype(jnp.int32)
    return jnp.where(g.edge_mask, owner, -2)


def greedy_partition(g: Graph, k: int, seed: int = 0) -> jax.Array:
    """PowerGraph greedy: stream edges; prefer partitions already holding both
    endpoints, then one endpoint, then the emptiest. Tie-break: least loaded."""
    rng = np.random.default_rng(seed)
    u, v = g.as_numpy()
    order = rng.permutation(len(u))
    has = np.zeros((g.n_vertices, k), bool)      # vertex v replicated on p
    load = np.zeros(k, np.int64)
    owner = np.full(g.e_pad, -2, np.int32)
    for idx in order:
        a, b = u[idx], v[idx]
        both = has[a] & has[b]
        one = has[a] | has[b]
        if both.any():
            cand = np.flatnonzero(both)
        elif one.any():
            cand = np.flatnonzero(one)
        else:
            cand = np.arange(k)
        p = cand[np.argmin(load[cand])]
        owner[idx] = p
        has[a, p] = has[b, p] = True
        load[p] += 1
    return jnp.asarray(owner)


# ---------------------------------------------------------------------------
# JaBeJa (vectorised swap-based local search with simulated annealing)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "rounds", "swaps_per_round"))
def _jabeja_colors(g: Graph, k: int, key: jax.Array, rounds: int = 150,
                   swaps_per_round: int = 4096,
                   t0: float = 2.0) -> jax.Array:
    """Vertex colouring minimising cut edges under swap moves (balance is
    invariant under swaps — JaBeJa's core idea). Vectorised: each round
    samples disjoint candidate pairs, computes the swap delta on the cut,
    and accepts improving (or SA-tolerated) swaps."""
    v_n = g.n_vertices
    swaps_per_round = min(swaps_per_round, v_n // 2)
    key, k0 = jax.random.split(key)
    colors0 = jax.random.randint(k0, (v_n,), 0, k, dtype=jnp.int32)

    def same_color_degree(colors, verts, col):
        """For each query vertex, #incident edges whose other endpoint has
        colour ``col``. One scatter per round over the edge list."""
        cu, cv = colors[g.src], colors[g.dst]
        # contribution of each edge endpoint to per-(vertex,colour) counts is
        # expensive densely; instead count via gather on the two sides:
        # deg_same[v, c] built as scatter into [V] for the queried colour only.
        m = g.edge_mask
        q = jnp.zeros((v_n,), jnp.int32)
        col_of = jnp.zeros((v_n,), jnp.int32).at[verts].set(col)
        hit_u = m & (cv == col_of[g.src])
        hit_v = m & (cu == col_of[g.dst])
        q = q.at[g.src].add(hit_u.astype(jnp.int32))
        q = q.at[g.dst].add(hit_v.astype(jnp.int32))
        return q[verts]

    def round_fn(carry, t):
        colors, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        perm = jax.random.permutation(k1, v_n)
        a = perm[:swaps_per_round]
        b = perm[swaps_per_round:2 * swaps_per_round]
        ca, cb = colors[a], colors[b]
        # benefit of swapping colours of a and b
        aa = same_color_degree(colors, a, ca)   # a's neighbours with a's col
        ab = same_color_degree(colors, a, cb)   # a's neighbours with b's col
        bb = same_color_degree(colors, b, cb)
        ba = same_color_degree(colors, b, ca)
        old = aa + bb
        new = ab + ba
        accept = (new.astype(jnp.float32) * t > old.astype(jnp.float32)) & (ca != cb)
        colors = colors.at[a].set(jnp.where(accept, cb, ca))
        colors = colors.at[b].set(jnp.where(accept, ca, cb))
        return (colors, key), None

    temps = jnp.linspace(t0, 1.0, rounds)
    (colors, _), _ = jax.lax.scan(round_fn, (colors0, key), temps)
    return colors


def jabeja_partition(g: Graph, k: int, seed: int = 0, rounds: int = 150
                     ) -> tuple[jax.Array, dict]:
    key = jax.random.key(seed)
    key, kc, ke = jax.random.split(key, 3)
    colors = _jabeja_colors(g, k, kc, rounds=rounds)
    cu, cv = colors[g.src], colors[g.dst]
    side = jax.random.bernoulli(ke, 0.5, (g.e_pad,))
    owner = jnp.where(cu == cv, cu, jnp.where(side, cu, cv)).astype(jnp.int32)
    owner = jnp.where(g.edge_mask, owner, -2)
    # JaBeJa's round count is structure-independent (paper §V-C): the SA
    # schedule length is the round count.
    return owner, {"rounds": rounds}

"""DFEP-balanced MoE expert placement (beyond-paper; DESIGN.md §4).

The token→expert assignment of an MoE layer is a bipartite graph that
changes slowly during training. Expert-parallel sharding assigns experts to
"model"-axis shards; skewed routing makes some shards' dispatch buffers
overflow (token drops) while others idle — a *balance* failure, exactly the
objective DFEP optimises.

Mapping (paper-faithful use of the algorithm):
  * vertices  = experts;
  * edges     = co-activation events — expert pairs selected together by
    one token (sampled proportionally to their observed frequency, so edge
    *count* encodes weight and DFEP stays unweighted, as in the paper);
  * partitions = EP shards; DFEP buys co-activation edges with its funding
    auction, producing connected, balanced edge groups;
  * an expert is placed on the shard owning the majority of its incident
    edges (ties → lighter shard), with per-shard capacity E/K enforced by
    bumping overflow experts to the lightest shard.

Balanced co-activation edges ≈ balanced per-shard routed-token load, and
co-activated experts land together, which also shrinks the cross-shard
combine fan-in.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from . import dfep
from .graph import Graph


def coactivation_graph(expert_idx: np.ndarray, n_experts: int,
                       n_edges: int = 4096, seed: int = 0) -> Graph:
    """expert_idx [T, k] routed expert ids per token -> sampled co-activation
    graph (edge multiplicity ∝ co-activation frequency)."""
    rng = np.random.default_rng(seed)
    t, k = expert_idx.shape
    pairs = []
    for i in range(k):
        for j in range(i + 1, k):
            pairs.append(np.stack([expert_idx[:, i], expert_idx[:, j]], 1))
    pairs = np.concatenate(pairs, 0)
    pairs = pairs[pairs[:, 0] != pairs[:, 1]]
    sel = rng.integers(0, len(pairs), size=n_edges)
    e = pairs[sel].astype(np.int32)
    u = np.minimum(e[:, 0], e[:, 1])
    v = np.maximum(e[:, 0], e[:, 1])
    pad = -(-n_edges // 128) * 128
    src = np.zeros(pad, np.int32); src[:n_edges] = u
    dst = np.zeros(pad, np.int32); dst[:n_edges] = v
    mask = np.zeros(pad, bool); mask[:n_edges] = True
    return Graph(n_experts, n_edges, jnp.asarray(src), jnp.asarray(dst),
                 jnp.asarray(mask))


@dataclasses.dataclass
class Placement:
    expert_to_shard: np.ndarray      # [E] shard id
    permutation: np.ndarray          # [E] expert order realising the placement
    shard_load: np.ndarray           # [K] expected routed-token load
    imbalance: float                 # max/mean shard load


def _loads_per_shard(assign: np.ndarray, loads: np.ndarray, k: int) -> np.ndarray:
    return np.array([loads[assign == s].sum() for s in range(k)])


def place_experts(expert_idx: np.ndarray, n_experts: int, k: int,
                  seed: int = 0, rounds_cap: int = 2000) -> Placement:
    """Run DFEP on the co-activation graph and derive an expert placement."""
    loads = np.bincount(expert_idx.reshape(-1), minlength=n_experts).astype(float)
    g = coactivation_graph(expert_idx, n_experts, seed=seed)
    owner, info = dfep.partition(g, k=k, key=seed, max_rounds=rounds_cap,
                                 stall_rounds=64)
    owner = np.asarray(owner)
    u, v = np.asarray(g.src), np.asarray(g.dst)
    m = np.asarray(g.edge_mask)
    # majority vote of incident-edge owners per expert
    votes = np.zeros((n_experts, k))
    np.add.at(votes, (u[m], owner[m]), 1.0)
    np.add.at(votes, (v[m], owner[m]), 1.0)
    assign = votes.argmax(1)
    assign[votes.sum(1) == 0] = -1

    # capacity E/K: bump overflow (lowest-vote first) to lightest shards
    cap = -(-n_experts // k)
    shard_sets: list[list[int]] = [[] for _ in range(k)]
    order = np.argsort(-loads)                     # place heavy experts first
    for e in order:
        s = assign[e]
        if s < 0 or len(shard_sets[s]) >= cap:
            s = min(range(k), key=lambda ss: (
                len(shard_sets[ss]) >= cap,
                sum(loads[x] for x in shard_sets[ss])))
        shard_sets[s].append(int(e))
    final = np.zeros(n_experts, np.int64)
    for s, es in enumerate(shard_sets):
        for e in es:
            final[e] = s
    perm = np.concatenate([np.array(sorted(es), np.int64)
                           for es in shard_sets])
    shard_load = _loads_per_shard(final, loads, k)
    imb = float(shard_load.max() / max(shard_load.mean(), 1e-9))
    return Placement(final, perm, shard_load, imb)


def naive_imbalance(loads: np.ndarray, k: int) -> float:
    """Contiguous-blocks placement baseline (the default layout)."""
    e = len(loads)
    cap = -(-e // k)
    assign = np.arange(e) // cap
    sl = _loads_per_shard(assign, loads, k)
    return float(sl.max() / max(sl.mean(), 1e-9))


def permute_expert_params(moe_params: dict, perm: np.ndarray) -> dict:
    """Apply a placement permutation to stacked MoE weights + router."""
    out = dict(moe_params)
    perm = jnp.asarray(perm)
    for name in ("w_gate", "w_up", "w_down"):
        if name in out:
            # leading dims may include the layer-stack axis: permute axis -3
            w = out[name]
            out[name] = jnp.take(w, perm, axis=w.ndim - 3)
    if "router" in out:
        r = out["router"]
        out["router"] = jnp.take(r, perm, axis=r.ndim - 1)
    return out

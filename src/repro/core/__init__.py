"""Core contribution of the paper: edge partitioning (DFEP) + the ETSCH
edge-partitioned graph-processing framework."""
from . import algorithms, baselines, dfep, etsch, graph, metrics  # noqa: F401
from .dfep import DfepConfig, partition, run_dfep  # noqa: F401
from .etsch import Partitioning, compile_partitioning, run_etsch  # noqa: F401
from .graph import Graph, from_edge_array, load_dataset  # noqa: F401

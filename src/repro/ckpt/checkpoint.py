"""Sharded checkpointing: per-leaf .npy blobs + JSON manifest, async writer,
atomic publish, resume-from-latest, and elastic re-shard on load.

Design for 1000+ nodes (DESIGN.md §6): every host writes only its local
shards (here: single-host writes all), the manifest carries the logical
spec tree so a restart onto a *different* mesh reshards transparently —
arrays are written unsharded-logical (gathered) in this reference
implementation, and re-placed through jax.device_put with the target
sharding on load.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):            # match jax.tree's dict-key sorting
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        flat = _flatten(tree)
        # snapshot to host memory first (cheap on CPU; device->host on TPU)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()                      # never two writers in flight
        if self.async_write and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: dict) -> None:
        tmp = os.path.join(self.dir, f".tmp-{step}-{threading.get_ident()}-{time.time_ns()}")
        final = os.path.join(self.dir, f"step-{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for k, v in host.items():
            fn = k.replace("/", "__") + ".npy"
            dtype_name = str(v.dtype)
            if v.dtype.kind == "V" or dtype_name == "bfloat16":
                # numpy can't round-trip ml_dtypes (bf16 etc.): store raw bits
                np.save(os.path.join(tmp, fn),
                        v.view(f"u{v.dtype.itemsize}"))
                dtype_name = "bfloat16" if v.dtype.itemsize == 2 else dtype_name
            else:
                np.save(os.path.join(tmp, fn), v)
            manifest[k] = {"file": fn, "shape": list(v.shape),
                           "dtype": dtype_name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest,
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:09d}"),
                          ignore_errors=True)

    # -- load ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step-"):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.all_steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``. With ``shardings``
        (matching pytree of jax.sharding.Sharding) arrays are placed sharded
        — this is the elastic re-shard path: the target mesh may differ from
        the one that wrote the checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        base = os.path.join(self.dir, f"step-{step:09d}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        flat_t = _flatten(template)
        flat_s = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for k, tmpl in flat_t.items():
            info = manifest[k]
            arr = np.load(os.path.join(base, info["file"]))
            if info["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            assert list(arr.shape) == list(tmpl.shape), (k, arr.shape, tmpl.shape)
            if k in flat_s and flat_s[k] is not None:
                loaded[k] = jax.device_put(arr, flat_s[k])
            else:
                loaded[k] = jnp.asarray(arr)
        # unflatten along template structure
        leaves_t, treedef = jax.tree.flatten(
            template, is_leaf=lambda x: hasattr(x, "shape"))
        keys = list(_flatten(template).keys())
        return treedef.unflatten([loaded[k] for k in keys])

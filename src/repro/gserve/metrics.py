"""Serving metrics: latency percentiles, throughput, batching efficiency.

One ``ServeMetrics`` per ``GraphServer``.  Each completed request records
its end-to-end latency (submit -> result materialised on host), the
micro-batch it rode in, and whether the epoch-keyed result cache answered
it.  ``snapshot()`` folds in the engine plan cache's hit/miss/eviction
counters (engine.plan.plan_cache_stats) so one record shows the whole
caching hierarchy: result cache (per query) -> plan cache (per graph
content) -> jit cache (per bucket shape, tracked by runtime.TRACE_COUNTER
and surfaced as ``engine.retrace`` events on the ``repro.obs`` recorder).
The ``GraphServer`` registers ``snapshot()`` as an ``obs`` provider, so
``obs.snapshot()`` shows the same record alongside the stream's health
gauges and the jit trace counters.

Latency storage: a mergeable log-bucketed ``LogHistogram`` plus a
``WindowedHistogram`` ring — fixed memory however long the server lives
(the old unbounded ``latencies`` list leaked one float per request), and
``snapshot()`` never sorts.  Percentiles are exact to one log-bucket
width (±3.7% at 32 buckets/decade) with the tails clamped to the exact
observed min/max; the regression test holds the histogram answers within
one bucket width of the old sorted-list values.  ``snapshot()`` also
reports a ``windowed`` sub-dict (trailing ~10s p50/p95/p99 + rate), which
is what the SLO monitor's burn rates are computed from.

Clock discipline: every latency/qps interval here is measured with
``time.perf_counter()`` — a monotonic clock.  The wall clock
(``time.time``) steps under NTP adjustment, which can manufacture
negative latencies or skew qps; calling it is banned from this package
and from ``repro.obs`` (CI grep guard).
"""
from __future__ import annotations

import time

import numpy as np

from ..engine.plan import plan_cache_stats
from ..obs.histogram import LogHistogram, WindowedHistogram

# Trailing window reported in snapshot()["windowed"]: long enough to be
# stable at bench qps, short enough to reflect "now" during an incident.
SNAPSHOT_WINDOW_S = 10.0

# Process-lifetime execute-span totals across every server ever created —
# benchmarks/run.py prints the per-figure delta next to wall-clock, the
# same way it attributes recorder events from lifetime counts.  The
# windowed ring gives the same spend over a trailing window, so a live
# summary can show "device time now" next to the monotone total.
_EXEC_TOTALS = {"device_s": 0.0, "executes": 0}
_EXEC_WINDOW = WindowedHistogram(slot_s=0.5, slots=60)
_EXEC_T0 = time.perf_counter()


def exec_totals() -> dict:
    """Monotone process-wide device-time spend (a copy), plus the
    trailing-window view of the same execute spans under ``"windowed"``."""
    d = dict(_EXEC_TOTALS)
    d["windowed"] = _EXEC_WINDOW.stats(SNAPSHOT_WINDOW_S,
                                       time.perf_counter() - _EXEC_T0)
    return d


def percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


class ServeMetrics:
    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.latency_hist = LogHistogram()
        self.latency_window = WindowedHistogram(slot_s=0.5, slots=60)
        self.n_completed = 0
        self.n_rejected = 0
        self.n_rejected_fair_share = 0  # subset of rejections: tenant cap
        self.n_cache_hits = 0
        self.n_batches = 0
        self.n_lanes_dispatched = 0    # padded lanes (bucket sizes summed)
        self.n_lanes_used = 0          # deduped real parameters
        self.n_lanes_warm = 0          # lanes warm-started from a prior epoch
        self.n_requests_batched = 0    # requests answered by engine runs
        self.n_swaps = 0               # plan-buffer swaps observed
        self.device_time_s = 0.0       # summed execute-span durations —
                                       #   the total the ledger's per-tenant
                                       #   device_s must reconcile against
        self.n_executes = 0
        self.t0 = time.perf_counter()

    # -- recording (called by the server) -----------------------------------
    def record_result(self, latency_s: float, from_cache: bool) -> None:
        v = float(latency_s)
        self.latency_hist.record(v)
        self.latency_window.record(v, now=time.perf_counter() - self.t0)
        self.n_completed += 1
        if from_cache:
            self.n_cache_hits += 1

    def record_batch(self, n_requests: int, n_lanes: int, bucket: int,
                     warm_lanes: int = 0) -> None:
        self.n_batches += 1
        self.n_requests_batched += n_requests
        self.n_lanes_used += n_lanes
        self.n_lanes_dispatched += bucket
        self.n_lanes_warm += warm_lanes

    def record_rejection(self, fair_share: bool = False) -> None:
        self.n_rejected += 1
        if fair_share:
            self.n_rejected_fair_share += 1

    def record_swap(self) -> None:
        self.n_swaps += 1

    def record_execute(self, dt_s: float) -> None:
        """One completed execute span: device sync wall time."""
        v = float(dt_s)
        self.device_time_s += v
        self.n_executes += 1
        _EXEC_TOTALS["device_s"] += v
        _EXEC_TOTALS["executes"] += 1
        _EXEC_WINDOW.record(v, now=time.perf_counter() - _EXEC_T0)

    # -- reporting -----------------------------------------------------------
    def snapshot(self, result_cache_stats: dict | None = None) -> dict:
        wall = max(time.perf_counter() - self.t0, 1e-9)
        occ = (self.n_requests_batched / self.n_batches
               if self.n_batches else 0.0)
        pad_waste = (1.0 - self.n_lanes_used / self.n_lanes_dispatched
                     if self.n_lanes_dispatched else 0.0)
        h = self.latency_hist
        win = self.latency_window.stats(SNAPSHOT_WINDOW_S,
                                        time.perf_counter() - self.t0)
        return {
            "completed": self.n_completed,
            "rejected": self.n_rejected,
            "rejected_fair_share": self.n_rejected_fair_share,
            "warm_started_lanes": self.n_lanes_warm,
            "qps": round(self.n_completed / wall, 2),
            "latency_p50_s": round(h.percentile(50), 6),
            "latency_p95_s": round(h.percentile(95), 6),
            "latency_p99_s": round(h.percentile(99), 6),
            "latency_mean_s": round(h.mean, 6),
            "windowed": {
                "window_s": win["window_s"],
                "n": win["n"],
                "rate_per_s": win["rate_per_s"],
                "p50_s": round(win["p50"], 6),
                "p95_s": round(win["p95"], 6),
                "p99_s": round(win["p99"], 6),
            },
            "batches": self.n_batches,
            "device_time_s": round(self.device_time_s, 6),
            "executes": self.n_executes,
            "mean_batch_occupancy": round(occ, 3),
            "pad_waste_frac": round(pad_waste, 4),
            "result_cache_hits": self.n_cache_hits,
            "plan_buffer_swaps": self.n_swaps,
            "result_cache": result_cache_stats or {},
            "plan_cache": plan_cache_stats(),
        }

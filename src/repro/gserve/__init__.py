"""repro.gserve — graph query serving subsystem.

Micro-batched multi-tenant serving over the partitioned execution engine:
typed query requests name any program registered in the engine's
``ProgramRegistry`` (``QueryRequest(kind, params={...})``); validation,
batching, caching and dispatch are all *derived* from the registry entry,
so registering a new program makes it servable with zero edits here.
Requests are coalesced into fixed-shape micro-batches (pad-to-bucket keeps
jit caches warm; a timer-based flush bounds tail latency at low load),
admitted under per-tenant fair shares, answered through the
plan-cache-backed engine with an epoch-keyed result cache plus
warm-started repair across insert-only stream patches, and kept consistent
under live ``repro.stream`` updates by a double-buffered plan swap.  See
src/repro/gserve/README.md for the design note.
"""
from .cache import ResultCache
from .metrics import ServeMetrics, percentile
from .request import AdmissionError, QueryRequest, QueryResult
from .scheduler import DEFAULT_BUCKETS, MicroBatch, MicroBatcher, bucket_for
from .server import GraphServer

__all__ = [
    "AdmissionError", "DEFAULT_BUCKETS", "GraphServer", "MicroBatch",
    "MicroBatcher", "QueryRequest", "QueryResult", "ResultCache",
    "ServeMetrics", "bucket_for", "percentile",
]

"""repro.gserve — graph query serving subsystem.

Micro-batched multi-tenant serving over the partitioned execution engine:
typed query requests (SSSP / WCC / PageRank) are coalesced into fixed-shape
micro-batches (pad-to-bucket keeps jit caches warm), answered through the
plan-cache-backed engine with an epoch-keyed result cache, and kept
consistent under live ``repro.stream`` updates by a double-buffered plan
swap.  See src/repro/gserve/README.md for the design note.
"""
from .cache import ResultCache
from .metrics import ServeMetrics, percentile
from .request import (AdmissionError, QUERY_KINDS, QueryRequest, QueryResult,
                      QuerySpec)
from .scheduler import DEFAULT_BUCKETS, MicroBatch, MicroBatcher, bucket_for
from .server import GraphServer

__all__ = [
    "AdmissionError", "DEFAULT_BUCKETS", "GraphServer", "MicroBatch",
    "MicroBatcher", "QUERY_KINDS", "QueryRequest", "QueryResult",
    "QuerySpec", "ResultCache", "ServeMetrics", "bucket_for", "percentile",
]

"""Micro-batch scheduler: coalesce compatible requests into fixed shapes.

Why fixed shapes: every distinct batch shape is its own jit cache entry, so
an arbitrary-size batch axis would retrace constantly and the serving path
would spend its life in XLA compilation.  The batcher therefore *pads to a
bucket*: a batch of S batchable requests is padded up to the smallest
configured bucket >= S (repeating the last parameter — the duplicate lanes
compute a result that is simply dropped), so after one warm-up pass per
bucket every future micro-batch of any size hits a warm cache.

Coalescing rules (request.batch_key):

  * batchable kinds (SSSP) — up to ``max(buckets)`` requests per dispatch,
    duplicate parameters deduped into one lane and fanned back out;
  * parameterless kinds (WCC, PageRank-with-same-iters) — ANY number of
    concurrent requests collapse into ONE engine run shared by every
    requesting tenant.

Queues are FIFO per batch key and keys are drained in arrival order of
their oldest request, so no tenant's query class can starve another's.
"""
from __future__ import annotations

import collections
import dataclasses

from .request import QueryRequest

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= n (buckets sorted ascending)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def pad_params(params: tuple, bucket: int) -> tuple:
    """THE padding rule: fill the bucket by repeating the last parameter
    (duplicate lanes compute a dropped result). Single-sourced here — the
    server re-pads after cache filtering with the same rule."""
    return tuple(params) + (params[-1],) * (bucket - len(params))


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """One schedulable unit: requests answerable by a single dispatch."""
    key: tuple                        # shared batch_key
    requests: tuple[QueryRequest, ...]
    params: tuple | None              # deduped batched-parameter values
    lane: tuple[int, ...] | None      # per-request index into params
    bucket: int                       # padded dispatch shape (>= len(params))

    @property
    def padded_params(self) -> tuple | None:
        if self.params is None:
            return None
        return pad_params(self.params, self.bucket)


class MicroBatcher:
    """FIFO micro-batch former over per-batch-key queues."""

    def __init__(self, buckets: tuple[int, ...] = DEFAULT_BUCKETS):
        assert buckets == tuple(sorted(buckets)) and len(buckets) >= 1
        self.buckets = tuple(int(b) for b in buckets)
        self._queues: "collections.OrderedDict[tuple, collections.deque]" = \
            collections.OrderedDict()
        self._arrival = 0
        self._order: dict[tuple, int] = {}   # key -> oldest arrival seq

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def add(self, req: QueryRequest) -> None:
        key = req.batch_key()
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = collections.deque()
        if not q:
            self._order[key] = self._arrival
        q.append(req)
        self._arrival += 1

    def _oldest_key(self) -> tuple | None:
        live = [(seq, key) for key, seq in self._order.items()
                if self._queues.get(key)]
        return min(live)[1] if live else None

    def next_batch(self) -> MicroBatch | None:
        """Form one micro-batch from the queue whose head arrived first."""
        key = self._oldest_key()
        if key is None:
            return None
        q = self._queues[key]
        head = q[0]
        if head.spec.batchable:
            take = min(len(q), self.buckets[-1])
            reqs = tuple(q.popleft() for _ in range(take))
            # dedupe identical parameters into one lane
            params: list = []
            lane: list[int] = []
            seen: dict = {}
            pname = head.spec.param
            for r in reqs:
                p = getattr(r, pname)
                if p not in seen:
                    seen[p] = len(params)
                    params.append(p)
                lane.append(seen[p])
            bucket = bucket_for(len(params), self.buckets)
            batch = MicroBatch(key, reqs, tuple(params), tuple(lane), bucket)
        else:
            # parameterless: every queued request shares one run
            reqs = tuple(q.popleft() for _ in range(len(q)))
            batch = MicroBatch(key, reqs, None, None, 1)
        if not q:
            self._order.pop(key, None)
        return batch

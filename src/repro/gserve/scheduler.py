"""Micro-batch scheduler: coalesce compatible requests into fixed shapes.

Why fixed shapes: every distinct batch shape is its own jit cache entry, so
an arbitrary-size batch axis would retrace constantly and the serving path
would spend its life in XLA compilation.  The batcher therefore *pads to a
bucket*: a batch of S batchable requests is padded up to the smallest
configured bucket >= S (repeating the last parameter — the duplicate lanes
compute a result that is simply dropped), so after one warm-up pass per
bucket every future micro-batch of any size hits a warm cache.

Coalescing rules (request.batch_key — derived from the program registry):

  * programs with a batchable parameter (SSSP, weighted SSSP, BFS, ...) —
    up to ``max(buckets)`` requests per dispatch, duplicate parameters
    deduped into one lane and fanned back out;
  * programs without one (WCC, PageRank-with-same-iters) — ANY number of
    concurrent requests collapse into ONE engine run shared by every
    requesting tenant.

Queues are FIFO per batch key and keys are drained in arrival order of
their oldest request, so no tenant's query class can starve another's.
When the server wires a usage ledger, draining becomes cost-weighted
(``cost_of``): keys whose head tenant has burned the smallest recent
device-time share flush first, so cheap tenants are not stuck behind a
heavy tenant's backlog.

Timer-based flush: ``next_batch(max_wait_s=...)`` *defers* a batchable key
that cannot yet fill the largest bucket — until its oldest request has
waited ``max_wait_s``, at which point the partial bucket dispatches
anyway.  That bounds p99 latency at low offered load while still giving
bursts time to coalesce (``GraphServer.drain`` drives the ticks).

The batcher also tracks per-tenant pending counts — the server's
fair-share admission control reads them at the door.
"""
from __future__ import annotations

import collections
import dataclasses
import time

from .request import QueryRequest

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= n (buckets sorted ascending)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def pad_params(params: tuple, bucket: int) -> tuple:
    """THE padding rule: fill the bucket by repeating the last parameter
    (duplicate lanes compute a dropped result). Single-sourced here — the
    server re-pads after cache filtering with the same rule."""
    return tuple(params) + (params[-1],) * (bucket - len(params))


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """One schedulable unit: requests answerable by a single dispatch."""
    key: tuple                        # shared batch_key
    requests: tuple[QueryRequest, ...]
    params: tuple | None              # deduped batched-parameter values
    lane: tuple[int, ...] | None      # per-request index into params
    bucket: int                       # padded dispatch shape (>= len(params))

    @property
    def padded_params(self) -> tuple | None:
        if self.params is None:
            return None
        return pad_params(self.params, self.bucket)


class MicroBatcher:
    """FIFO micro-batch former over per-batch-key queues."""

    def __init__(self, buckets: tuple[int, ...] = DEFAULT_BUCKETS):
        assert buckets == tuple(sorted(buckets)) and len(buckets) >= 1
        self.buckets = tuple(int(b) for b in buckets)
        # each queue holds (request, arrival_time) pairs; arrival times are
        # time.perf_counter() (monotonic — NTP steps must not fake waits),
        # and every ``now`` passed into next_batch/oldest_wait must come
        # from the same clock
        self._queues: "collections.OrderedDict[tuple, collections.deque]" = \
            collections.OrderedDict()
        self._arrival = 0
        self._order: dict[tuple, int] = {}   # key -> oldest arrival seq
        self._tenant = collections.Counter()  # tenant -> pending requests
        # cost-weighted flush ordering: when the server wires a usage
        # ledger, cost_of maps tenant -> recent device-time share and keys
        # drain cheapest-head-tenant first (FIFO breaks the tie), so a
        # tenant monopolizing the device queues behind everyone it starved
        self.cost_of: "collections.abc.Callable[[str], float] | None" = None

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- fair-share accounting (read by GraphServer.submit) ------------------
    def tenant_pending(self, tenant: str) -> int:
        return self._tenant.get(tenant, 0)

    def active_tenants(self) -> set[str]:
        return {t for t, n in self._tenant.items() if n > 0}

    def add(self, req: QueryRequest) -> None:
        key = req.batch_key()
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = collections.deque()
        if not q:
            self._order[key] = self._arrival
        q.append((req, time.perf_counter()))
        self._tenant[req.tenant] += 1
        self._arrival += 1

    def _live_keys(self) -> list[tuple]:
        """Keys with queued requests: oldest head first, or — with a
        ledger-backed ``cost_of`` wired — cheapest head tenant first
        (arrival order inside one tenant's cost tier)."""
        live = [(seq, key) for key, seq in self._order.items()
                if self._queues.get(key)]
        if self.cost_of is None:
            return [key for _, key in sorted(live)]
        ranked = sorted((self.cost_of(self._queues[key][0][0].tenant),
                         seq, key) for seq, key in live)
        return [key for _, _, key in ranked]

    def next_batch(self, now: float | None = None,
                   max_wait_s: float | None = None) -> MicroBatch | None:
        """Form one micro-batch from the first *ready* queue in arrival
        order of queue heads.

        Without a timer every non-empty queue is ready (greedy draining,
        the default).  With ``max_wait_s`` set, a batchable queue that
        cannot fill the largest bucket is deferred until its head request
        has waited the deadline out — the timer-based flush that bounds
        tail latency at low offered load.  Non-batchable queues dispatch
        immediately (all queued requests share one run regardless).
        """
        for key in self._live_keys():
            q = self._queues[key]
            head, t_head = q[0]
            if (max_wait_s is not None and head.entry.batchable
                    and len(q) < self.buckets[-1]
                    and (now if now is not None else time.perf_counter()) - t_head
                    < max_wait_s):
                continue                     # let the bucket fill
            return self._form(key)
        return None

    def oldest_wait(self, now: float | None = None) -> float | None:
        """Age of the oldest pending request (None when empty) — lets the
        drain loop sleep until the next deadline instead of busy-polling."""
        heads = [self._queues[k][0][1] for k in self._live_keys()]
        if not heads:
            return None
        return (now if now is not None else time.perf_counter()) - min(heads)

    def _form(self, key: tuple) -> MicroBatch:
        q = self._queues[key]
        head, _ = q[0]
        if head.entry.batchable:
            take = min(len(q), self.buckets[-1])
            reqs = tuple(q.popleft()[0] for _ in range(take))
            # dedupe identical parameters into one lane
            params: list = []
            lane: list[int] = []
            seen: dict = {}
            pname = head.entry.batch_param.name
            for r in reqs:
                p = r.params[pname]
                if p not in seen:
                    seen[p] = len(params)
                    params.append(p)
                lane.append(seen[p])
            bucket = bucket_for(len(params), self.buckets)
            batch = MicroBatch(key, reqs, tuple(params), tuple(lane), bucket)
        else:
            # parameterless: every queued request shares one run
            reqs = tuple(q.popleft()[0] for _ in range(len(q)))
            batch = MicroBatch(key, reqs, None, None, 1)
        for r in reqs:
            self._tenant[r.tenant] -= 1
            if self._tenant[r.tenant] <= 0:
                del self._tenant[r.tenant]
        if not q:
            self._order.pop(key, None)
        return batch

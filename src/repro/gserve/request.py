"""Typed query requests and results for the serving subsystem.

A request names a *program* (any entry in the engine's ``ProgramRegistry``)
plus its per-query ``params`` and the logical tenant that issued it::

    QueryRequest("sssp", tenant="alice", params={"source": 7})
    QueryRequest("pagerank", params={"iters": 20})
    QueryRequest("wcc")

Validation, dtype coercion and default-filling all happen at construction,
against the program's declarative ``ParamSpec`` schema — this module knows
no program by name.  Normalisation makes query identity canonical: two
spellings of the same logical query (e.g. pagerank with ``iters`` omitted
vs passed as its default) share one ``batch_key()``/``cache_key()``, so
they coalesce into one dispatch and share one cache entry.

Two requests are *batchable* together when they share a ``batch_key()``:
same program, same value for every non-batchable parameter — the scheduler
then answers them with one engine dispatch (the batchable parameter, e.g.
the SSSP source, carries the vmapped micro-batch axis; parameterless
programs like WCC collapse to a single run fanned out to every requester).

Results carry full provenance: the plan-buffer version and compaction epoch
they were served against, the graph fingerprint of that snapshot, whether
they came from the epoch-keyed result cache, and whether the dispatch was
warm-started from a previous epoch's result.  The consistency contract
(tests/test_gserve.py) is that ``value`` is bit-identical to the
whole-graph oracle evaluated on the snapshot named by ``fingerprint``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Mapping

import numpy as np

from ..engine.registry import DEFAULT_REGISTRY, ProgramEntry


class AdmissionError(RuntimeError):
    """Raised by ``GraphServer.submit`` when the pending queue is full or
    the tenant exceeded its fair share of it."""


_REQUEST_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    kind: str                         # a registered program name
    tenant: str = "default"
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    id: int = dataclasses.field(default_factory=lambda: next(_REQUEST_IDS))

    def __post_init__(self):
        # resolve + normalize against the registry schema NOW: every
        # constructed request is valid, canonical, and cheap to key
        entry = DEFAULT_REGISTRY.get(self.kind)
        object.__setattr__(self, "params", entry.normalize(self.params))

    @property
    def entry(self) -> ProgramEntry:
        return DEFAULT_REGISTRY.get(self.kind)

    def batch_key(self) -> tuple:
        """Requests sharing a batch key may be answered by one dispatch."""
        return self.entry.batch_key_of(self.params)

    def cache_key(self) -> tuple:
        """Identity of the *answer* (within one graph snapshot): tenant is
        deliberately excluded — tenants share cached results, that is the
        multi-tenant amortisation the layout exists for."""
        return self.entry.cache_key_of(self.params)


@dataclasses.dataclass(frozen=True)
class QueryResult:
    request: QueryRequest
    value: np.ndarray | None          # [V] final vertex state (None iff
                                      #   ``error`` is set)
    version: int                      # plan-buffer version served against
    epoch: int                        # plan compaction epoch of that buffer
    fingerprint: str                  # Graph.fingerprint() of the snapshot
    supersteps: int
    from_cache: bool
    batch_size: int                   # real requests in the micro-batch
    bucket: int                       # padded batch shape dispatched
    latency_s: float                  # submit -> result materialised
    warm_start: bool = False          # dispatched warm from a prior epoch
    error: str | None = None          # per-request failure (e.g. a channel
                                      #   plane invalidated by a plan swap
                                      #   between submit and dispatch) —
                                      #   the batch fails, the server keeps
                                      #   serving

    def row(self) -> dict[str, Any]:
        return {"id": self.request.id, "kind": self.request.kind,
                "tenant": self.request.tenant, "version": self.version,
                "epoch": self.epoch, "from_cache": self.from_cache,
                "batch_size": self.batch_size, "bucket": self.bucket,
                "latency_s": self.latency_s, "warm_start": self.warm_start,
                "error": self.error}

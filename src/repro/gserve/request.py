"""Typed query requests and results for the serving subsystem.

A request names a *program* (SSSP / WCC / PageRank / anything registered in
``QUERY_KINDS``) plus its per-query parameters and the logical tenant that
issued it.  Two requests are *batchable* when they share a ``batch_key()``:
the scheduler may then answer them with one engine dispatch (multi-source
SSSP vmaps the source axis; parameterless programs like WCC collapse to a
single run fanned out to every requester).

Results carry full provenance: the plan-buffer version and compaction epoch
they were served against, the graph fingerprint of that snapshot, and
whether they came from the epoch-keyed result cache.  The consistency
contract (tests/test_gserve.py) is that ``value`` is bit-identical to the
whole-graph oracle evaluated on the snapshot named by ``fingerprint``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import numpy as np


class AdmissionError(RuntimeError):
    """Raised by ``GraphServer.submit`` when the pending queue is full."""


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """Static description of a servable query kind."""
    kind: str
    batchable: bool          # vmap-able over a per-query parameter axis
    param: str | None        # name of the batched parameter (None: none)
    cacheable: bool = True


QUERY_KINDS: dict[str, QuerySpec] = {
    "sssp": QuerySpec("sssp", batchable=True, param="source"),
    "wcc": QuerySpec("wcc", batchable=False, param=None),
    "pagerank": QuerySpec("pagerank", batchable=False, param=None),
}

_REQUEST_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    kind: str                         # key into QUERY_KINDS
    tenant: str = "default"
    source: int | None = None         # sssp: source vertex
    iters: int | None = None          # pagerank: superstep count
    id: int = dataclasses.field(default_factory=lambda: next(_REQUEST_IDS))

    def __post_init__(self):
        spec = QUERY_KINDS.get(self.kind)
        if spec is None:
            raise ValueError(f"unknown query kind {self.kind!r}; "
                             f"known: {sorted(QUERY_KINDS)}")
        if self.kind == "sssp" and self.source is None:
            raise ValueError("sssp requires a source vertex")

    @property
    def spec(self) -> QuerySpec:
        return QUERY_KINDS[self.kind]

    def batch_key(self) -> tuple:
        """Requests sharing a batch key may be answered by one dispatch."""
        if self.kind == "pagerank":
            return ("pagerank", self.iters)
        return (self.kind,)

    def cache_key(self) -> tuple:
        """Identity of the *answer* (within one graph snapshot): tenant is
        deliberately excluded — tenants share cached results, that is the
        multi-tenant amortisation the layout exists for."""
        if self.kind == "sssp":
            return ("sssp", int(self.source))
        if self.kind == "pagerank":
            return ("pagerank", self.iters)
        return (self.kind,)


@dataclasses.dataclass(frozen=True)
class QueryResult:
    request: QueryRequest
    value: np.ndarray                 # [V] final vertex state
    version: int                      # plan-buffer version served against
    epoch: int                        # plan compaction epoch of that buffer
    fingerprint: str                  # Graph.fingerprint() of the snapshot
    supersteps: int
    from_cache: bool
    batch_size: int                   # real requests in the micro-batch
    bucket: int                       # padded batch shape dispatched
    latency_s: float                  # submit -> result materialised

    def row(self) -> dict[str, Any]:
        return {"id": self.request.id, "kind": self.request.kind,
                "tenant": self.request.tenant, "version": self.version,
                "epoch": self.epoch, "from_cache": self.from_cache,
                "batch_size": self.batch_size, "bucket": self.bucket,
                "latency_s": self.latency_s}

"""GraphServer — micro-batched multi-tenant serving over the engine.

The server pulls four pieces together:

  * a ``MicroBatcher`` (scheduler.py) that coalesces compatible requests
    from many tenants into fixed-shape micro-batches (pad-to-bucket keeps
    the engine's jit caches warm across arbitrary offered loads);
  * the partitioned engine's non-blocking dispatch: ``drain()`` is software
    pipelined — micro-batch i+1 is formed and handed to XLA while batch i's
    device arrays are still settling (``PendingResult``), so batch-formation
    overhead hides under device execution;
  * an epoch-keyed ``ResultCache`` (cache.py) keyed by graph content
    fingerprint — tenants share answers, and every plan swap drops stale
    entries;
  * a *double-buffered plan swap*: the server holds one immutable
    ``_PlanBuffer`` (engine + graph snapshot + fingerprint + version).  A
    ``repro.stream`` session publishes epoch-change hooks; on each event the
    server builds a fresh buffer and atomically swaps the front pointer.
    In-flight micro-batches captured the OLD buffer at dispatch time and
    keep draining against it (plans are immutable pytrees — there is no
    torn/half-patched state to observe); batches formed after the swap see
    the new one.  Every result is stamped with the buffer it was served
    from, so callers can check consistency against that exact snapshot.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph
from ..engine import programs
from ..engine.runtime import Engine, PendingResult
from .cache import ResultCache
from .metrics import ServeMetrics
from .request import AdmissionError, QueryRequest, QueryResult
from .scheduler import (DEFAULT_BUCKETS, MicroBatch, MicroBatcher,
                        bucket_for, pad_params)


def _frozen(a: np.ndarray) -> np.ndarray:
    """Mark an array read-only. Served values and cache entries are shared
    across tenants (and with the cache itself); a tenant mutating its
    result must fail loudly, not corrupt everyone else's answers."""
    a.flags.writeable = False
    return a


@dataclasses.dataclass(frozen=True)
class _PlanBuffer:
    """One immutable serving snapshot: everything a micro-batch needs."""
    engine: Engine
    graph: Graph
    epoch: int
    version: int

    def fingerprint(self) -> str:
        """Content hash of the snapshot — the result-cache key. Lazy and
        memoized: a stream update with no query in between never pays the
        O(E log E) hash; a queried buffer hashes exactly once."""
        cached = self.__dict__.get("_fingerprint")
        if cached is None:
            cached = self.graph.fingerprint()
            object.__setattr__(self, "_fingerprint", cached)
        return cached

    def degrees(self) -> jnp.ndarray:
        cached = self.__dict__.get("_degrees")
        if cached is None:
            cached = self.graph.degrees()
            object.__setattr__(self, "_degrees", cached)
        return cached


@dataclasses.dataclass
class _InFlight:
    """A dispatched micro-batch awaiting completion."""
    batch: MicroBatch
    buffer: _PlanBuffer
    pending: PendingResult | None     # None: fully served from cache
    lane_of: dict[int, int]           # request id -> dispatched lane
    cached: dict[int, np.ndarray]     # request id -> cache-served value
    n_lanes: int                      # deduped uncached lanes dispatched
    bucket: int                       # padded dispatch shape (0: no dispatch)
    t_dispatch: float


class GraphServer:
    """Accepts typed query requests from many logical tenants and serves
    them in micro-batches over a (possibly live/streaming) partition plan.

    Construct either over a static ``Engine`` + ``Graph``::

        server = GraphServer(engine=eng, graph=g)

    or bound to a streaming session (subscribes to its epoch-change hooks,
    double-buffering plan swaps under queries)::

        server = GraphServer.from_session(sess)
    """

    def __init__(self, engine: Engine, graph: Graph, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 max_pending: int = 1024, cache_entries: int = 512,
                 use_pallas: bool = False,
                 epoch: int = 0, version: int = 0):
        self.buckets = tuple(buckets)
        self.max_pending = int(max_pending)
        self.use_pallas = bool(use_pallas)
        self.metrics = ServeMetrics()
        self.cache = ResultCache(cache_entries)
        self._batcher = MicroBatcher(self.buckets)
        self._lock = threading.RLock()
        self._t_submit: dict[int, float] = {}
        # bounded: callers that keep ids around collect via result(); old
        # completed entries age out instead of leaking on long-lived servers
        self._results: "collections.OrderedDict[int, QueryResult]" = \
            collections.OrderedDict()
        self._results_max = max(4 * self.max_pending, 4096)
        self._session = None
        self._unsubscribe = None
        self._cache_dirty = False
        self._front = self._make_buffer(engine, graph, epoch, version)

    @classmethod
    def from_session(cls, session, **kwargs) -> "GraphServer":
        """Bind to a ``repro.stream.StreamSession``: the server snapshots
        the session's current plan and subscribes to its epoch-change hooks
        so every installed patch/recompile swaps the front buffer."""
        srv = cls(session.engine, session.graph(), epoch=session.epoch,
                  version=session.version, **kwargs)
        srv._session = session
        srv._unsubscribe = session.subscribe(srv._on_plan_change)
        return srv

    def close(self) -> None:
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None

    # -- plan double-buffering ----------------------------------------------
    def _make_buffer(self, engine: Engine, graph: Graph, epoch: int,
                     version: int) -> _PlanBuffer:
        # serving runs the XLA segment-reduce path by default: batched
        # dispatch requires it, and unbatched programs (WCC/PageRank) then
        # share one code path instead of the interpreted Pallas grid
        engine = dataclasses.replace(engine, use_pallas=self.use_pallas)
        return _PlanBuffer(engine, graph, int(epoch), int(version))

    def _on_plan_change(self, session, event: str) -> None:
        """Epoch-change hook: build the new buffer and swap the front
        pointer. In-flight batches hold the previous buffer object and
        finish against it. The result cache is marked dirty rather than
        purged here — invalidation needs the new content fingerprint, and
        hashing the edge set on the stream's update hot path would tax
        updates that no query ever observes; the purge runs on the next
        cache access instead (stale entries are unreachable in between:
        every probe is keyed by the captured buffer's fingerprint)."""
        buf = self._make_buffer(session.engine, session.graph(),
                                session.epoch, session.version)
        with self._lock:
            self._front = buf
            self._cache_dirty = True
            self.metrics.record_swap()

    def _maybe_invalidate_cache(self) -> None:
        """Deferred swap cleanup; call with the lock held, before any cache
        probe or fill."""
        if self._cache_dirty:
            self.cache.invalidate_except(self._front.fingerprint())
            self._cache_dirty = False

    @property
    def front(self) -> _PlanBuffer:
        with self._lock:
            return self._front

    # -- request intake ------------------------------------------------------
    def submit(self, req: QueryRequest) -> int:
        """Enqueue one request; returns its id. Admission control: raises
        ``AdmissionError`` when ``max_pending`` requests are already
        queued — shed load at the door rather than queue without bound."""
        with self._lock:
            if len(self._batcher) >= self.max_pending:
                self.metrics.record_rejection()
                raise AdmissionError(
                    f"pending queue full ({self.max_pending})")
            self._t_submit[req.id] = time.time()
            self._batcher.add(req)
            return req.id

    def pending(self) -> int:
        with self._lock:
            return len(self._batcher)

    # -- micro-batch execution ----------------------------------------------
    def _dispatch_batch(self, batch: MicroBatch,
                        buffer: _PlanBuffer) -> _InFlight:
        """Hand one micro-batch to the engine without syncing. Cache lookups
        happen here, at *serve* time, against the captured buffer's
        fingerprint — a request submitted before a plan swap but batched
        after it is answered (and labelled) with the post-swap snapshot."""
        kind = batch.key[0]
        eng = buffer.engine
        cached: dict[int, np.ndarray] = {}
        lane_of: dict[int, int] = {}
        pending = None
        n_lanes = 0
        bucket = 0

        if batch.params is not None:                    # batchable (sssp)
            # per-lane cache probe, then dispatch only the uncached lanes
            lane_val: dict[int, np.ndarray] = {}
            uncached: list[int] = []
            with self._lock:
                self._maybe_invalidate_cache()
                for li, p in enumerate(batch.params):
                    hit = self.cache.get(buffer.fingerprint(), (kind, int(p)))
                    if hit is not None:
                        lane_val[li] = hit
                    else:
                        uncached.append(li)
            for r, li in zip(batch.requests, batch.lane):
                if li in lane_val:
                    cached[r.id] = lane_val[li]
                else:
                    lane_of[r.id] = uncached.index(li)
            if uncached:
                n_lanes = len(uncached)
                bucket = bucket_for(n_lanes, self.buckets)
                params = pad_params(tuple(batch.params[li]
                                          for li in uncached), bucket)
                pending = eng.dispatch_batched(
                    programs.SSSP,
                    {"source": jnp.asarray(params, jnp.int32)})
        else:                                           # one shared run
            key = batch.requests[0].cache_key()
            with self._lock:
                self._maybe_invalidate_cache()
                hit = self.cache.get(buffer.fingerprint(), key)
            if hit is not None:
                for r in batch.requests:
                    cached[r.id] = hit
            else:
                n_lanes = bucket = 1
                if kind == "wcc":
                    pending = eng.dispatch(programs.WCC)
                elif kind == "pagerank":
                    iters = batch.requests[0].iters
                    pending = eng.dispatch(
                        programs.PAGERANK,
                        max_supersteps=iters,
                        degrees=buffer.degrees())
                else:
                    raise ValueError(f"unserveable kind {kind!r}")
        if pending is not None:
            self.metrics.record_batch(len(batch.requests) - len(cached),
                                      n_lanes, bucket)
        return _InFlight(batch, buffer, pending, lane_of, cached,
                         n_lanes, bucket, time.time())

    def _complete(self, fl: _InFlight) -> list[QueryResult]:
        """Sync one in-flight batch and materialise per-request results."""
        values: dict[int, np.ndarray] = dict(fl.cached)
        supersteps: dict[int, int] = {}
        if fl.pending is not None:
            res = fl.pending.result()
            state = np.asarray(res.state)
            ss = np.asarray(res.supersteps).reshape(-1)
            kind = fl.batch.key[0]
            if fl.batch.params is not None:
                # fan dispatched lanes back out + fill the cache; copy each
                # lane so neither results nor cache entries pin the whole
                # [bucket, V] batch array through a numpy view
                lane_arr = {dl: _frozen(state[dl].copy())
                            for dl in set(fl.lane_of.values())}
                for rid, dl in fl.lane_of.items():
                    values[rid] = lane_arr[dl]
                    supersteps[rid] = int(ss[min(dl, len(ss) - 1)])
                with self._lock:
                    # only fill the cache if no swap landed mid-flight: a
                    # put keyed by a dead fingerprint would re-insert a
                    # stale entry the deferred invalidation already (or
                    # will never) see
                    if (not self._cache_dirty and fl.buffer.fingerprint()
                            == self._front.fingerprint()):
                        for rid, dl in fl.lane_of.items():
                            req = next(r for r in fl.batch.requests
                                       if r.id == rid)
                            if req.spec.cacheable:
                                self.cache.put(fl.buffer.fingerprint(),
                                               req.cache_key(),
                                               lane_arr[dl])
            else:
                state = _frozen(state)
                for r in fl.batch.requests:
                    values[r.id] = state
                    supersteps[r.id] = int(ss.max())
                if fl.batch.requests[0].spec.cacheable:
                    with self._lock:
                        if (not self._cache_dirty
                                and fl.buffer.fingerprint()
                                == self._front.fingerprint()):
                            self.cache.put(fl.buffer.fingerprint(),
                                           fl.batch.requests[0].cache_key(),
                                           state)
        now = time.time()
        out = []
        with self._lock:
            for r in fl.batch.requests:
                t0 = self._t_submit.pop(r.id, now)
                qr = QueryResult(
                    request=r, value=values[r.id],
                    version=fl.buffer.version, epoch=fl.buffer.epoch,
                    fingerprint=fl.buffer.fingerprint(),
                    supersteps=supersteps.get(r.id, 0),
                    from_cache=r.id in fl.cached,
                    batch_size=len(fl.batch.requests), bucket=fl.bucket,
                    latency_s=now - t0)
                self._results[r.id] = qr
                self.metrics.record_result(qr.latency_s, qr.from_cache)
                out.append(qr)
            while len(self._results) > self._results_max:
                self._results.popitem(last=False)
        return out

    def pump(self) -> list[QueryResult]:
        """Serve exactly one micro-batch (or nothing if the queue is empty)."""
        with self._lock:
            batch = self._batcher.next_batch()
            buffer = self._front
        if batch is None:
            return []
        return self._complete(self._dispatch_batch(batch, buffer))

    def drain(self) -> list[QueryResult]:
        """Serve until the queue is empty, software-pipelined: the next
        micro-batch is formed and dispatched while the previous one's device
        computation settles."""
        done: list[QueryResult] = []
        inflight: _InFlight | None = None
        while True:
            with self._lock:
                batch = self._batcher.next_batch()
                buffer = self._front
            nxt = (self._dispatch_batch(batch, buffer)
                   if batch is not None else None)
            if inflight is not None:
                done.extend(self._complete(inflight))
            inflight = nxt
            if inflight is None:
                return done

    def serve(self, requests: list[QueryRequest]) -> list[QueryResult]:
        """Convenience: submit a burst and drain it; results in input order."""
        ids = [self.submit(r) for r in requests]
        self.drain()
        # a concurrent drainer may have coalesced some of our requests into
        # its own still-in-flight micro-batch: its queue pop beat ours, so
        # wait for those results to materialise rather than KeyError
        while any(i not in self._results for i in ids):
            self.drain()
            time.sleep(1e-3)
        return [self._results[i] for i in ids]

    def result(self, request_id: int) -> QueryResult | None:
        return self._results.get(request_id)

    def stats(self) -> dict:
        return self.metrics.snapshot(self.cache.stats())

"""GraphServer — micro-batched multi-tenant serving over the engine.

The server pulls five pieces together:

  * the engine's ``ProgramRegistry``: every servable program declared its
    schema once, and the server *derives* dispatch from the entry — the
    batch-axis name/dtype, the superstep-count parameter, derived
    per-snapshot resources (e.g. PageRank's degree vector), cacheability.
    No program is named anywhere in this package; registering a new
    program makes it servable with zero edits here;
  * a ``MicroBatcher`` (scheduler.py) that coalesces compatible requests
    from many tenants into fixed-shape micro-batches (pad-to-bucket keeps
    the engine's jit caches warm), with per-tenant pending counts feeding
    fair-share admission and a timer-based flush bounding tail latency at
    low offered load;
  * the partitioned engine's non-blocking dispatch: ``drain()`` is software
    pipelined — micro-batch i+1 is formed and handed to XLA while batch i's
    device arrays are still settling (``PendingResult``);
  * an epoch-keyed ``ResultCache`` (cache.py) keyed by graph content
    fingerprint — tenants share answers, and every plan swap drops stale
    entries.  Alongside it, a *warm-start store* keeps the last computed
    result per query key together with the fingerprint it was computed at:
    when the graph has only gained edges since (insert-only lineage,
    tracked via ``StreamSession.last_change``), a new dispatch of the same
    query warm-starts from the old result through the program's
    ``warm_init`` hook — repairing e.g. SSSP distances in one or two
    supersteps instead of recomputing from scratch;
  * a *double-buffered plan swap*: the server holds one immutable
    ``_PlanBuffer`` (engine + graph snapshot + fingerprint + version).  A
    ``repro.stream`` session publishes epoch-change hooks; on each event
    the server builds a fresh buffer and atomically swaps the front
    pointer.  In-flight micro-batches captured the OLD buffer at dispatch
    time and keep draining against it (plans are immutable pytrees — there
    is no torn/half-patched state to observe); batches formed after the
    swap see the new one.  Every result is stamped with the buffer it was
    served from, so callers can check consistency against that exact
    snapshot.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time

import jax.numpy as jnp
import numpy as np

from .. import obs as _obs
from ..obs import profile as _profile
from ..obs.ledger import CostSample
from ..core.graph import Graph
from ..engine.errors import ChannelError
from ..engine.registry import ProgramEntry
from ..engine.runtime import Engine, PendingResult
from .cache import ResultCache
from .metrics import ServeMetrics
from .request import AdmissionError, QueryRequest, QueryResult
from .scheduler import (DEFAULT_BUCKETS, MicroBatch, MicroBatcher,
                        bucket_for, pad_params)

_BATCH_DTYPES = {int: jnp.int32, float: jnp.float32}
_SERVER_IDS = itertools.count()   # obs provider names: serve0, serve1, ...


def _frozen(a: np.ndarray) -> np.ndarray:
    """Mark an array read-only. Served values and cache entries are shared
    across tenants (and with the cache itself); a tenant mutating its
    result must fail loudly, not corrupt everyone else's answers."""
    a.flags.writeable = False
    return a


@dataclasses.dataclass(frozen=True)
class _PlanBuffer:
    """One immutable serving snapshot: everything a micro-batch needs."""
    engine: Engine
    graph: Graph
    epoch: int
    version: int

    def fingerprint(self) -> str:
        """Content hash of the snapshot — the result-cache key. Lazy and
        memoized: a stream update with no query in between never pays the
        O(E log E) hash; a queried buffer hashes exactly once."""
        cached = self.__dict__.get("_fingerprint")
        if cached is None:
            cached = self.graph.fingerprint()
            object.__setattr__(self, "_fingerprint", cached)
        return cached

    def resource(self, name: str, fn) -> object:
        """Memoized registry-declared resources (e.g. pagerank's degree
        vector), derived from the graph snapshot on first use and shared
        by every micro-batch served from this buffer."""
        cache = self.__dict__.get("_resources")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_resources", cache)
        if name not in cache:
            cache[name] = fn(self.graph)
        return cache[name]


@dataclasses.dataclass
class _InFlight:
    """A dispatched micro-batch awaiting completion."""
    batch: MicroBatch
    buffer: _PlanBuffer
    pending: PendingResult | None     # None: fully served from cache
    lane_of: dict[int, int]           # request id -> dispatched lane
    cached: dict[int, np.ndarray]     # request id -> cache-served value
    n_lanes: int                      # deduped uncached lanes dispatched
    bucket: int                       # padded dispatch shape (0: no dispatch)
    t_dispatch: float                 # perf_counter at dispatch
    warm_lanes: frozenset = frozenset()
                                      # dispatched lane indices that warm-
                                      #   started from a prior epoch's
                                      #   result (others ran cold +inf rows)
    error: str | None = None          # dispatch-time failure for the whole
                                      #   batch (channel plane invalidated
                                      #   by a swap): requests get error
                                      #   results, the drain loop lives on
    span: int | None = None           # open obs "serve.batch" span id —
                                      #   execute/materialize spans attach
                                      #   to it explicitly (the pipelined
                                      #   drain interleaves batches, so
                                      #   stack nesting cannot carry it)
    cost: object = None               # per-sweep CostModel when a usage
                                      #   ledger is wired (None otherwise)


class GraphServer:
    """Accepts typed query requests from many logical tenants and serves
    them in micro-batches over a (possibly live/streaming) partition plan.

    Construct either over a static ``Engine`` + ``Graph``::

        server = GraphServer(engine=eng, graph=g)

    or bound to a streaming session (subscribes to its epoch-change hooks,
    double-buffering plan swaps under queries)::

        server = GraphServer.from_session(sess)

    ``max_wait_s`` (optional) arms the timer-based flush: ``drain()`` then
    lets partial buckets wait up to the deadline for more requests to
    coalesce before dispatching.  ``warm_entries=0`` disables warm-started
    repair dispatch.

    ``monitor`` (optional, a ``repro.obs.Monitor``) receives every
    completion (tenant, program, end-to-end latency) and every admission
    rejection (``ok=False``), and is rate-limitedly evaluated after each
    completed batch — SLO burn-rate alerts fire as ``obs.alert`` events
    without a separate polling thread.  The feed is guarded by the
    recorder's ``enabled`` flag (the observability master switch), so a
    disabled recorder keeps the serving hot path monitor-free.

    ``ledger`` (optional, a ``repro.obs.CostLedger``) turns on cost
    accounting and cost-aware scheduling: each dispatched micro-batch is
    priced by a memoized per-sweep HLO ``CostModel`` × its measured
    execute-span time and posted per request into the ledger, and both
    fair-share admission and flush ordering become device-time-weighted
    (a tenant over its windowed device-time share gets a proportionally
    smaller pending quota and drains last).  Toggle at runtime with
    ``set_ledger`` — accounting is independent of the recorder switch.
    """

    def __init__(self, engine: Engine, graph: Graph, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 max_pending: int = 1024, cache_entries: int = 512,
                 use_pallas: bool = False, max_wait_s: float | None = None,
                 warm_entries: int = 256, monitor=None, ledger=None,
                 epoch: int = 0, version: int = 0):
        self.buckets = tuple(buckets)
        self.max_pending = int(max_pending)
        self.use_pallas = bool(use_pallas)
        self.max_wait_s = max_wait_s
        self.monitor = monitor
        self.metrics = ServeMetrics()
        self.cache = ResultCache(cache_entries)
        self._batcher = MicroBatcher(self.buckets)
        self._lock = threading.RLock()
        self._t_submit: dict[int, float] = {}
        # bounded: callers that keep ids around collect via result(); old
        # completed entries age out instead of leaking on long-lived servers
        self._results: "collections.OrderedDict[int, QueryResult]" = \
            collections.OrderedDict()
        self._results_max = max(4 * self.max_pending, 4096)
        # warm-start store: cache_key -> (fingerprint, value). Entries
        # outlive plan swaps (that is their point); validity is decided at
        # dispatch time against _warm_ok, the set of fingerprints connected
        # to the front buffer by insert-only content changes.
        self._warm_max = int(warm_entries)
        self._warm: "collections.OrderedDict[tuple, tuple[str, np.ndarray]]"\
            = collections.OrderedDict()
        self._warm_ok: set[str] = set()
        self._session = None
        self._unsubscribe = None
        self._cache_dirty = False
        self._front = self._make_buffer(engine, graph, epoch, version)
        # obs: one snapshot shows the whole hierarchy — this server's
        # metrics (result cache included) join the plan-cache and jit
        # providers; stats is held by weakref, so an un-closed server that
        # gets collected drops out instead of leaking
        self._obs_unregister = _obs.get().register_provider(
            f"serve{next(_SERVER_IDS)}", self.stats)
        self.ledger = None
        # admission/flush read windowed shares at most every 50ms — one
        # ledger reduction per share-cache expiry, not per request
        self._shares_cache: tuple[float, dict] = (-1.0, {})
        self.set_ledger(ledger)

    @classmethod
    def from_session(cls, session, **kwargs) -> "GraphServer":
        """Bind to a ``repro.stream.StreamSession``: the server snapshots
        the session's current plan and subscribes to its epoch-change hooks
        so every installed patch/recompile swaps the front buffer."""
        srv = cls(session.engine, session.graph(), epoch=session.epoch,
                  version=session.version, **kwargs)
        srv._session = session
        srv._unsubscribe = session.subscribe(srv._on_plan_change)
        return srv

    def close(self) -> None:
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None
        self._obs_unregister()

    # -- cost accounting ------------------------------------------------------
    def set_ledger(self, ledger) -> None:
        """Wire (or unwire, with ``None``) a ``CostLedger``: enables batch
        cost profiling, per-request sample posting, cost-weighted
        admission quotas and cost-weighted flush ordering in one switch."""
        with self._lock:
            self.ledger = ledger
            self._shares_cache = (-1.0, {})
            self._batcher.cost_of = self._cost_of if ledger is not None \
                else None

    def _ledger_shares(self) -> dict[str, float]:
        """Windowed per-tenant device-time shares, memoized for 50ms so
        the per-request admission path never pays a ledger reduction."""
        led = self.ledger
        if led is None:
            return {}
        now = time.perf_counter()
        expires, shares = self._shares_cache
        if now >= expires:
            shares = led.tenant_shares(led.window_s)
            with self._lock:   # set_ledger swaps this tuple under the lock
                self._shares_cache = (now + 0.05, shares)
        return shares

    def _cost_of(self, tenant: str) -> float:
        return self._ledger_shares().get(tenant, 0.0)

    # -- plan double-buffering ----------------------------------------------
    def _make_buffer(self, engine: Engine, graph: Graph, epoch: int,
                     version: int) -> _PlanBuffer:
        # serving runs the XLA segment-reduce path by default: batched
        # dispatch requires it, and unbatched programs (WCC/PageRank) then
        # share one code path instead of the interpreted Pallas grid
        engine = dataclasses.replace(engine, use_pallas=self.use_pallas)
        return _PlanBuffer(engine, graph, int(epoch), int(version))

    def _on_plan_change(self, session, event: str) -> None:
        """Epoch-change hook: build the new buffer and swap the front
        pointer. In-flight batches hold the previous buffer object and
        finish against it. The result cache is marked dirty rather than
        purged here — invalidation needs the new content fingerprint, and
        hashing the edge set on the stream's update hot path would tax
        updates that no query ever observes; the purge runs on the next
        cache access instead (stale entries are unreachable in between:
        every probe is keyed by the captured buffer's fingerprint).

        Warm-start lineage: an insert-only (or content-neutral) change
        keeps previous results valid as relaxation upper bounds, so the
        outgoing buffer's fingerprint joins ``_warm_ok``; any deletion
        breaks the chain and clears the warm store wholesale."""
        buf = self._make_buffer(session.engine, session.graph(),
                                session.epoch, session.version)
        delta = getattr(session, "last_change", {}).get("content_delta",
                                                        "mixed")
        with self._lock:
            old = self._front
            self._front = buf
            self._cache_dirty = True
            if delta in ("none", "insert_only"):
                # only a *queried* buffer memoized its fingerprint; an
                # unqueried one has no warm entries keyed to it either
                old_fp = old.__dict__.get("_fingerprint")
                if old_fp is not None:
                    # prune lineage for fingerprints no warm entry holds
                    # any more (LRU-evicted): bounds _warm_ok at
                    # warm_entries + 1 on append-only streams
                    live = {fp for fp, _ in self._warm.values()}
                    self._warm_ok &= live
                    self._warm_ok.add(old_fp)
            else:
                self._warm_ok.clear()
                self._warm.clear()
            self.metrics.record_swap()
        _obs.get().event("serve.plan_swap", version=buf.version,
                         epoch=buf.epoch, content_delta=delta)

    def _maybe_invalidate_cache(self) -> None:
        """Deferred swap cleanup; call with the lock held, before any cache
        probe or fill."""
        if self._cache_dirty:
            self.cache.invalidate_except(self._front.fingerprint())
            self._cache_dirty = False

    @property
    def front(self) -> _PlanBuffer:
        with self._lock:
            return self._front

    # -- request intake ------------------------------------------------------
    def submit(self, req: QueryRequest) -> int:
        """Enqueue one request; returns its id.

        Admission control sheds load at the door rather than queue without
        bound, with a per-tenant fair share: a tenant may hold at most
        ``max_pending // active_tenants`` pending requests (active = has
        pending requests, counting the submitter).  A tenant with nothing
        pending is always allowed its first request even when the queue is
        globally full — so one tenant saturating the queue can never lock
        a quiet tenant out entirely.  The exemption is itself bounded:
        total pending never exceeds ``2 * max_pending``, so a flood of
        fresh tenant ids cannot defeat load shedding.

        Every admission decision is recorded as a ``serve.admission`` span
        tagged with the tenant and request — the root of the request's
        span tree, and the audit trail for fair-share rejections."""
        rec = _obs.get()
        sid = rec.begin("serve.admission", request=req.id,
                        tenant=req.tenant, program=req.kind) \
            if rec.enabled else None
        try:
            rid = self._submit(req)
        except AdmissionError as e:
            rec.end(sid, admitted=False, reason=str(e))
            if self.monitor is not None and rec.enabled:
                # a shed request is an availability failure for its tenant
                self.monitor.observe(req.tenant, req.kind, 0.0, ok=False)
                self.monitor.maybe_evaluate()
            raise
        rec.end(sid, admitted=True)
        return rid

    def _submit(self, req: QueryRequest) -> int:
        if req.entry.channel_params:
            # fail malformed property planes at the door (typed ChannelError
            # naming the expected shape) instead of inside a later drain —
            # shape checks only, the layout itself happens per batch
            req.entry.validate_channels(req.params, self.front.engine.plan)
        with self._lock:
            n_active = len(self._batcher.active_tenants() | {req.tenant})
            share = max(1, self.max_pending // n_active)
            # cost-weighted quota: a tenant whose windowed device-time
            # share exceeds its fair fraction has its pending quota shrunk
            # proportionally — few-but-huge queries spend quota like
            # many-but-tiny ones.  Tenants at/below fair share (and all
            # tenants when no ledger is wired) keep the count-based quota.
            shares = self._ledger_shares()
            if shares:
                used = shares.get(req.tenant, 0.0)
                fair = 1.0 / n_active
                if used > fair:
                    share = max(1, int(share * fair / used))
            mine = self._batcher.tenant_pending(req.tenant)
            total = len(self._batcher)
            if mine >= share:
                self.metrics.record_rejection(fair_share=n_active > 1)
                raise AdmissionError(
                    f"tenant {req.tenant!r} holds {mine} pending requests "
                    f">= its fair share ({share}; {self.max_pending} max "
                    f"pending / {n_active} active tenants"
                    + (f", cost-weighted by device-time share {used:.2f}"
                       if shares and used > 1.0 / n_active else "") + ")")
            if total >= self.max_pending and mine > 0:
                self.metrics.record_rejection()
                raise AdmissionError(
                    f"pending queue full ({self.max_pending})")
            if total >= 2 * self.max_pending:
                # hard wall: even the first-request exemption sheds load
                # once fresh-tenant overshoot doubles the queue
                self.metrics.record_rejection()
                raise AdmissionError(
                    f"pending queue at hard limit ({2 * self.max_pending})")
            self._t_submit[req.id] = time.perf_counter()
            self._batcher.add(req)
            return req.id

    def pending(self) -> int:
        with self._lock:
            return len(self._batcher)

    # -- micro-batch execution ----------------------------------------------
    @staticmethod
    def _warm_key(entry: ProgramEntry, key: tuple) -> tuple:
        """Warm-store key: the query key prefixed with the program's
        ``StateSpec`` identity, so a re-registered program with a different
        per-vertex rank can never warm-start from stale planes of the old
        shape (the runtime would reject them with ``WarmStateError``, but
        keying them apart means they simply miss instead of erroring)."""
        return (entry.state.key(),) + tuple(key)

    def _warm_block(self, entry: ProgramEntry, params0: dict,
                    padded_params: tuple, buffer: _PlanBuffer
                    ) -> tuple[np.ndarray | None, frozenset]:
        """([bucket, *state.shape(V)] warm-start block or None, warm lane
        indices) for a batchable dispatch.

        Lane i warm-starts from the stored result for the same query key
        when that result's snapshot is an insert-only ancestor of the
        buffer being dispatched against; lanes without one get cold rows
        from the program's ``StateSpec`` ("no prior information" — the
        warm_init contract cold-starts them) and are NOT in the returned
        index set. Call with the lock held."""
        if entry.program.warm_init is None or self._warm_max <= 0 \
                or not self._warm:
            return None, frozenset()
        fp_front = buffer.fingerprint()
        rows: list[np.ndarray | None] = []
        warm_lanes = set()
        for li, p in enumerate(padded_params):
            got = self._warm.get(
                self._warm_key(entry, entry.lane_cache_key(params0, p)))
            if got is not None and (got[0] in self._warm_ok
                                    or got[0] == fp_front):
                rows.append(got[1])
                warm_lanes.add(li)
            else:
                rows.append(None)
        if not warm_lanes:
            return None, frozenset()
        cold = entry.state.cold(buffer.graph.n_vertices)
        return (np.stack([r if r is not None else cold for r in rows]),
                frozenset(warm_lanes))

    def _store_warm(self, entry: ProgramEntry, key: tuple, fp: str,
                    value: np.ndarray) -> None:
        """Remember the latest computed result per query key (lock held)."""
        if entry.program.warm_init is None or self._warm_max <= 0:
            return
        wkey = self._warm_key(entry, key)
        self._warm[wkey] = (fp, value)
        self._warm.move_to_end(wkey)
        while len(self._warm) > self._warm_max:
            self._warm.popitem(last=False)

    def _dispatch_batch(self, batch: MicroBatch,
                        buffer: _PlanBuffer) -> _InFlight:
        """Hand one micro-batch to the engine without syncing — entirely
        derived from the program's registry entry (batch axis, superstep
        cap, snapshot resources): no program is named here. Cache lookups
        happen at *serve* time, against the captured buffer's
        fingerprint — a request submitted before a plan swap but batched
        after it is answered (and labelled) with the post-swap snapshot."""
        req0 = batch.requests[0]
        entry = req0.entry
        params0 = req0.params
        eng = buffer.engine
        rec = _obs.get()
        # per-tenant span tags: the batch span names every rider, so a
        # trace answers "whose requests shared this dispatch" directly
        bsid = rec.begin(
            "serve.batch", program=req0.kind,
            n_requests=len(batch.requests),
            requests=[r.id for r in batch.requests],
            tenants=sorted({r.tenant for r in batch.requests}),
            version=buffer.version, epoch=buffer.epoch) \
            if rec.enabled else None
        steps = entry.supersteps_of(params0)
        kw = {name: buffer.resource(name, fn) for name, fn in entry.resources}
        kw.update(entry.ctx_args(params0))
        # property channels: the registry lays the request's content-hashed
        # planes out against the captured buffer's plan (their digests are
        # already part of this batch's batch/cache keys — nothing here
        # depends on which channels, if any, the program declares). A plane
        # validated at submit can be invalidated by a plan swap landing
        # before the batch was popped (hwm grown past it / e_pad changed):
        # that fails THIS batch with per-request error results instead of
        # throwing away the drain pipeline and wedging waiting submitters.
        try:
            kw.update(entry.channel_args(params0, eng.plan))
        except ChannelError as e:
            return _InFlight(batch, buffer, None, {}, {}, 0, 0,
                             time.perf_counter(), error=str(e), span=bsid)
        cached: dict[int, np.ndarray] = {}
        lane_of: dict[int, int] = {}
        pending = None
        n_lanes = 0
        bucket = 0
        warm_lanes: frozenset = frozenset()
        cost = None

        if batch.params is not None:            # batchable program
            # per-lane cache probe, then dispatch only the uncached lanes
            lane_val: dict[int, np.ndarray] = {}
            uncached: list[int] = []
            warm_state = None
            with self._lock:
                self._maybe_invalidate_cache()
                for li, p in enumerate(batch.params):
                    hit = self.cache.get(buffer.fingerprint(),
                                         entry.lane_cache_key(params0, p))
                    if hit is not None:
                        lane_val[li] = hit
                    else:
                        uncached.append(li)
                if uncached:
                    n_lanes = len(uncached)
                    bucket = bucket_for(n_lanes, self.buckets)
                    params = pad_params(tuple(batch.params[li]
                                              for li in uncached), bucket)
                    warm_state, warm_lanes = self._warm_block(
                        entry, params0, params, buffer)
            for r, li in zip(batch.requests, batch.lane):
                if li in lane_val:
                    cached[r.id] = lane_val[li]
                else:
                    lane_of[r.id] = uncached.index(li)
            if uncached:
                # pad duplicates beyond the real lanes don't serve anyone
                warm_lanes = frozenset(li for li in warm_lanes
                                       if li < n_lanes)
                bp = entry.batch_param
                bkw = {bp.name: jnp.asarray(params,
                                            _BATCH_DTYPES[bp.dtype])}
                if self.ledger is not None:
                    # memoized per (program, plan aux, bucket, shapes) —
                    # only the first dispatch of a shape pays the AOT
                    # lowering, like the jit warm-up it rides next to
                    cost = _profile.cost_model(
                        eng, entry.program, bucket=bucket, batched_kw=bkw,
                        max_supersteps=steps, **kw)
                dsid = rec.begin("serve.dispatch", parent=bsid,
                                 bucket=bucket, lanes=n_lanes,
                                 warm_lanes=len(warm_lanes)) \
                    if rec.enabled else None
                pending = eng.dispatch_batched(
                    entry.program, bkw,
                    max_supersteps=steps, warm_state=warm_state, **kw)
                rec.end(dsid)
        else:                                   # one shared run
            key = req0.cache_key()
            with self._lock:
                self._maybe_invalidate_cache()
                hit = self.cache.get(buffer.fingerprint(), key)
            if hit is not None:
                for r in batch.requests:
                    cached[r.id] = hit
            else:
                n_lanes = bucket = 1
                if self.ledger is not None:
                    cost = _profile.cost_model(
                        eng, entry.program, bucket=None,
                        max_supersteps=steps, **kw)
                dsid = rec.begin("serve.dispatch", parent=bsid, bucket=1,
                                 lanes=1) if rec.enabled else None
                pending = eng.dispatch(entry.program, max_supersteps=steps,
                                       **kw)
                rec.end(dsid)
        if pending is not None:
            self.metrics.record_batch(len(batch.requests) - len(cached),
                                      n_lanes, bucket, len(warm_lanes))
        return _InFlight(batch, buffer, pending, lane_of, cached,
                         n_lanes, bucket, time.perf_counter(), warm_lanes,
                         span=bsid, cost=cost)

    def _complete(self, fl: _InFlight) -> list[QueryResult]:
        """Sync one in-flight batch and materialise per-request results."""
        values: dict[int, np.ndarray] = dict(fl.cached)
        supersteps: dict[int, int] = {}
        entry = fl.batch.requests[0].entry
        rec = _obs.get()
        msid = None
        exec_dt = 0.0
        sweeps = 0
        if fl.pending is not None:
            esid = rec.begin("serve.execute", parent=fl.span,
                             bucket=fl.bucket, lanes=fl.n_lanes) \
                if rec.enabled else None
            # execute time = device sync + host materialisation of the
            # state block: the denominator every ledger device_s and
            # utilization figure reconciles against (device_time_s)
            t_exec = time.perf_counter()
            res = fl.pending.result()
            state = np.asarray(res.state)
            ss = np.asarray(res.supersteps).reshape(-1)
            iters = np.asarray(res.local_iters).reshape(-1)
            exec_dt = time.perf_counter() - t_exec
            self.metrics.record_execute(exec_dt)
            # the cost model is per-sweep (every loop clamped to one
            # trip); the measured critical path scales it back up
            sweeps = max(int(ss.max()) if len(ss) else 0,
                         int(iters.max()) if len(iters) else 0, 1)
            rec.end(esid, supersteps=int(ss.max()) if len(ss) else 0)
            msid = rec.begin("serve.materialize", parent=fl.span,
                             n_requests=len(fl.batch.requests)) \
                if rec.enabled else None
            if fl.batch.params is not None:
                # fan dispatched lanes back out + fill the cache; copy each
                # lane so neither results nor cache entries pin the whole
                # [bucket, V] batch array through a numpy view
                lane_arr = {dl: _frozen(state[dl].copy())
                            for dl in set(fl.lane_of.values())}
                for rid, dl in fl.lane_of.items():
                    values[rid] = lane_arr[dl]
                    supersteps[rid] = int(ss[min(dl, len(ss) - 1)])
                with self._lock:
                    # the warm store keeps every computed result (validity
                    # is re-derived at use time from its fingerprint), but
                    # only fill the result cache if no swap landed
                    # mid-flight: a put keyed by a dead fingerprint would
                    # re-insert a stale entry the deferred invalidation
                    # already (or will never) see
                    fp = fl.buffer.fingerprint()
                    fresh = (not self._cache_dirty
                             and fp == self._front.fingerprint())
                    for rid, dl in fl.lane_of.items():
                        req = next(r for r in fl.batch.requests
                                   if r.id == rid)
                        self._store_warm(entry, req.cache_key(), fp,
                                         lane_arr[dl])
                        if fresh and entry.cacheable:
                            self.cache.put(fp, req.cache_key(),
                                           lane_arr[dl])
            else:
                state = _frozen(state)
                for r in fl.batch.requests:
                    values[r.id] = state
                    supersteps[r.id] = int(ss.max())
                if entry.cacheable:
                    with self._lock:
                        if (not self._cache_dirty
                                and fl.buffer.fingerprint()
                                == self._front.fingerprint()):
                            self.cache.put(fl.buffer.fingerprint(),
                                           fl.batch.requests[0].cache_key(),
                                           state)
        now = time.perf_counter()
        out = []
        with self._lock:
            for r in fl.batch.requests:
                t0 = self._t_submit.pop(r.id, now)
                qr = QueryResult(
                    request=r, value=values.get(r.id),
                    version=fl.buffer.version, epoch=fl.buffer.epoch,
                    fingerprint=fl.buffer.fingerprint(),
                    supersteps=supersteps.get(r.id, 0),
                    from_cache=r.id in fl.cached,
                    batch_size=len(fl.batch.requests), bucket=fl.bucket,
                    latency_s=now - t0,
                    warm_start=fl.lane_of.get(r.id, -1) in fl.warm_lanes,
                    error=fl.error)
                self._results[r.id] = qr
                self.metrics.record_result(qr.latency_s, qr.from_cache)
                out.append(qr)
            while len(self._results) > self._results_max:
                self._results.popitem(last=False)
        rec.end(msid)
        rec.end(fl.span, n_cached=len(fl.cached),
                failed=fl.error is not None)
        led = self.ledger
        if led is not None and fl.error is None:
            # post the batch's resolved cost per request: dispatched
            # requests split the measured execute time (and the model's
            # flop/byte totals) evenly; cache hits post zero-device-time
            # samples so request counts still reconcile
            fp = fl.buffer.fingerprint()
            disp = [r for r in fl.batch.requests if r.id not in fl.cached]
            if fl.pending is not None and disp:
                model = fl.cost
                n = len(disp)
                if model is not None and model.error is None:
                    b_fl, b_by, b_cb = model.cost(sweeps)
                    util = (model.attainable_s(sweeps) / exec_dt
                            if exec_dt > 0 else 0.0)
                else:
                    b_fl = b_by = b_cb = util = 0.0
                for r in disp:
                    led.post(CostSample(
                        tenant=r.tenant, program=r.kind, graph=fp,
                        epoch=fl.buffer.epoch, device_s=exec_dt / n,
                        flops=b_fl / n, hbm_bytes=b_by / n,
                        coll_bytes=b_cb / n,
                        supersteps=supersteps.get(r.id, 0),
                        utilization=util))
            for r in fl.batch.requests:
                if r.id in fl.cached:
                    led.post(CostSample(
                        tenant=r.tenant, program=r.kind, graph=fp,
                        epoch=fl.buffer.epoch, device_s=0.0,
                        from_cache=True))
        if self.monitor is not None and rec.enabled:
            # outside the lock: observe() only touches monitor-owned rings
            for qr in out:
                self.monitor.observe(qr.request.tenant, qr.request.kind,
                                     qr.latency_s, ok=qr.error is None)
            self.monitor.maybe_evaluate()
        return out

    def pump(self) -> list[QueryResult]:
        """Serve exactly one micro-batch (or nothing if the queue is empty)."""
        with self._lock:
            batch = self._batcher.next_batch()
            buffer = self._front
        if batch is None:
            return []
        return self._complete(self._dispatch_batch(batch, buffer))

    def drain(self, max_wait_s: float | None = None) -> list[QueryResult]:
        """Serve until the queue is empty, software-pipelined: the next
        micro-batch is formed and dispatched while the previous one's
        device computation settles.

        With ``max_wait_s`` (argument, or the server-level default) the
        scheduler defers partial buckets: a batchable queue that cannot
        fill the largest bucket waits — for concurrent submitters to top
        it up — until its oldest request hits the deadline, then flushes
        partial.  That bounds p99 at low offered load instead of wedging
        behind an unfillable bucket."""
        if max_wait_s is None:
            max_wait_s = self.max_wait_s
        done: list[QueryResult] = []
        inflight: _InFlight | None = None
        while True:
            now = time.perf_counter()
            with self._lock:
                batch = self._batcher.next_batch(now=now,
                                                 max_wait_s=max_wait_s)
                buffer = self._front
                waited = self._batcher.oldest_wait(now)
            if (batch is not None and inflight is not None
                    and self.ledger is not None):
                # cost-aware overlap: pipelining a heavy tenant's dispatch
                # under a cheap tenant's in-flight tail makes the cheap
                # batch contend with (or wait behind) the heavy run on the
                # same device — the starvation the ledger exists to stop.
                # Complete the in-flight batch first when the next batch's
                # cheapest rider has more than twice its share (hysteresis
                # so near-equal tenants keep the full pipeline overlap).
                shares = self._ledger_shares()
                b_cost = min(shares.get(r.tenant, 0.0)
                             for r in batch.requests)
                i_cost = min(shares.get(r.tenant, 0.0)
                             for r in inflight.batch.requests)
                if b_cost > 2.0 * i_cost:
                    done.extend(self._complete(inflight))
                    inflight = None
            nxt = (self._dispatch_batch(batch, buffer)
                   if batch is not None else None)
            if inflight is not None:
                done.extend(self._complete(inflight))
            inflight = nxt
            if inflight is None:
                if waited is None:      # queue truly empty
                    return done
                # queued work exists but is deferred to fill its bucket:
                # sleep toward the flush deadline, then re-check
                time.sleep(max(min(max_wait_s - waited, 1e-3), 1e-4))

    def serve(self, requests: list[QueryRequest]) -> list[QueryResult]:
        """Convenience: submit a burst and drain it; results in input order."""
        ids = [self.submit(r) for r in requests]
        self.drain()
        # a concurrent drainer may have coalesced some of our requests into
        # its own still-in-flight micro-batch: its queue pop beat ours, so
        # wait for those results to materialise rather than KeyError
        while any(i not in self._results for i in ids):
            self.drain()
            time.sleep(1e-3)
        return [self._results[i] for i in ids]

    def result(self, request_id: int) -> QueryResult | None:
        return self._results.get(request_id)

    def stats(self) -> dict:
        return self.metrics.snapshot(self.cache.stats())

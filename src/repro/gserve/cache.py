"""Epoch-keyed result cache for the serving layer.

Results depend only on the *graph content* (and the query parameters), not
on how the graph is partitioned — so entries are keyed by
``(Graph.fingerprint(), request.cache_key())``.  The fingerprint is the
engine plan cache's content key too (engine/plan.py), which makes the
invalidation story exact rather than heuristic:

  * every installed plan change (stream patch or compaction recompile)
    changes the edge set, hence the fingerprint, hence every key — the
    server additionally calls ``invalidate_except(new_fingerprint)`` on its
    epoch-change hook so stale entries are *dropped* (not merely
    unreachable) the moment the buffer swaps;
  * a graph that mutates and mutates back to identical content legally
    re-hits old entries (content addressing, same rationale as
    ``compile_plan_cached``).

LRU-bounded; all hit/miss/invalidation counts feed ``gserve.metrics``.
"""
from __future__ import annotations

import collections

import numpy as np


class ResultCache:
    def __init__(self, max_entries: int = 512):
        self.max_entries = int(max_entries)
        self._d: "collections.OrderedDict[tuple, np.ndarray]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidated = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, fingerprint: str, key: tuple) -> np.ndarray | None:
        full = (fingerprint, key)
        val = self._d.get(full)
        if val is None:
            self.misses += 1
            return None
        self.hits += 1
        self._d.move_to_end(full)
        return val

    def put(self, fingerprint: str, key: tuple, value: np.ndarray) -> None:
        self._d[(fingerprint, key)] = value
        self._d.move_to_end((fingerprint, key))
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)
            self.evictions += 1

    def invalidate_except(self, fingerprint: str) -> int:
        """Drop every entry not keyed by ``fingerprint``; returns the count.
        Called from the server's epoch-change hook on every buffer swap."""
        stale = [k for k in self._d if k[0] != fingerprint]
        for k in stale:
            del self._d[k]
        self.invalidated += len(stale)
        return len(stale)

    def fingerprints(self) -> set[str]:
        return {k[0] for k in self._d}

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "invalidated": self.invalidated, "evictions": self.evictions,
                "size": len(self._d), "max_entries": self.max_entries}

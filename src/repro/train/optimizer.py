"""Hand-rolled AdamW + cosine schedule + global-norm clipping.

Optimizer state shards exactly like the params (same logical specs), so
FSDP placement falls out of the param spec tree for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params) -> OptState:
    z = jax.tree.map(jnp.zeros_like, params)
    return OptState(jnp.zeros((), jnp.int32), z,
                    jax.tree.map(jnp.zeros_like, params))


def opt_state_specs(param_specs):
    """Specs for OptState mirroring the param spec tree."""
    return OptState((), param_specs, param_specs)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}

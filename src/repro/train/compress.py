"""Gradient compression with error feedback (pod-axis sync; DESIGN.md §6).

At 1000+ nodes the pod-level data-parallel all-reduce crosses DCI, the
slowest link; int8 block-quantised gradients with error feedback cut that
traffic 4× vs f32 (2× vs bf16) with no convergence loss in practice
(1-bit-Adam/EF-SGD literature). The codec is pure function + carried error
state, so it drops into the train step as a grad transform:

    g_q, err = ef_compress(g + err_prev)        # quantise what we can,
    g_synced = all_reduce(decompress(g_q))      # carry what we cannot
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # f32 per-block scales


def compress(x: jax.Array) -> Compressed:
    """Symmetric int8 block quantisation of a float array (any shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(flat / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return Compressed(q, scale[:, 0])


def decompress(c: Compressed, shape: tuple, dtype=jnp.float32) -> jax.Array:
    flat = c.q.astype(jnp.float32) * c.scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return flat.reshape(-1)[:n].reshape(shape).astype(dtype)


def ef_compress_tree(grads: Any, err: Any) -> tuple[Any, Any, Any]:
    """Error-feedback compression over a grad pytree.

    Returns (decompressed grads to feed the optimizer/all-reduce,
             new error state, compressed payloads for transport)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        c = compress(corrected)
        d = decompress(c, g.shape)
        return d, corrected - d, c

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]),
            tdef.unflatten([o[2] for o in outs]))


def init_error_state(grads_template: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_template)


def compression_ratio(grads: Any) -> float:
    """f32 bytes / compressed bytes for a grad pytree."""
    f32 = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(g.size * 1 + (g.size // BLOCK + 1) * 4
               for g in jax.tree.leaves(grads))
    return f32 / comp

"""Fault-tolerant training loop.

Production posture (DESIGN.md §6):
  * checkpoint/restart — resume-from-latest on construction, periodic async
    saves, atomic publish;
  * deterministic data skip-ahead — the pipeline is pure in (seed, step);
  * straggler/hang mitigation — per-step wall-time watchdog: steps slower
    than ``straggler_factor`` × the running median are logged and counted
    (on a real fleet this feeds the controller that evicts the slow host;
    here it is surfaced in metrics);
  * step retry — transient step failures (preempted host, flaky collective)
    retry up to ``max_retries`` from the last good state;
  * elastic re-shard — ``CheckpointManager.restore(shardings=...)`` places
    the same logical checkpoint onto whatever mesh the restart got.
"""
from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from typing import Any, Callable

import jax

from ..ckpt.checkpoint import CheckpointManager
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, SyntheticPipeline
from ..models import lm
from .optimizer import AdamWConfig, init_opt_state
from .train_step import train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro-ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    straggler_factor: float = 2.0
    max_retries: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 params=None, shardings: Any = None):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.pipeline = SyntheticPipeline(cfg, data_cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        if params is None:
            params, _ = lm.init_params(cfg, jax.random.key(0))
        self.params = params
        self.opt_state = init_opt_state(params)
        self.step = 0
        self._jit_step = jax.jit(
            lambda p, o, b: train_step(cfg, opt_cfg, p, o, b,
                                       microbatches=tcfg.microbatches))
        # resume-from-latest
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(
                {"params": self.params, "opt": self.opt_state},
                shardings=shardings)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = latest
            log.info("resumed from step %d", latest)

    def run(self) -> dict:
        times: list[float] = []
        stragglers = 0
        metrics = {}
        while self.step < self.tcfg.steps:
            batch = self.pipeline.batch_at(self.step)
            t0 = time.perf_counter()
            for attempt in range(self.tcfg.max_retries + 1):
                try:
                    self.params, self.opt_state, metrics = jax.block_until_ready(
                        self._jit_step(self.params, self.opt_state, batch))
                    break
                except Exception as e:  # pragma: no cover — transient-failure path
                    if attempt == self.tcfg.max_retries:
                        raise
                    log.warning("step %d failed (%s); retry %d",
                                self.step, e, attempt + 1)
            dt = time.perf_counter() - t0
            times.append(dt)
            if len(times) > 16:
                med = statistics.median(times[-64:])
                if dt > self.tcfg.straggler_factor * med:
                    stragglers += 1
                    log.warning("straggler step %d: %.2fs vs median %.2fs",
                                self.step, dt, med)
            self.step += 1
            if self.step % self.tcfg.log_every == 0:
                log.info("step %d loss=%.4f", self.step,
                         float(metrics.get("loss", float("nan"))))
            if self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step,
                               {"params": self.params, "opt": self.opt_state})
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       blocking=True)
        self.ckpt.wait()
        return {"final_metrics": {k: float(v) for k, v in metrics.items()},
                "stragglers": stragglers,
                "median_step_s": statistics.median(times) if times else 0.0}

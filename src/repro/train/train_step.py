"""Train step: causal-LM loss (+ MoE aux), grads, AdamW update.

The loss masks padded-vocab logits and supports an optional microbatch
(gradient-accumulation) loop for memory-bound cells (§Perf knob).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import lm
from ..sharding.env import shard
from .optimizer import AdamWConfig, OptState, apply_updates

AUX_WEIGHT = 0.01


def lm_loss(cfg: ModelConfig, params, batch: dict) -> tuple[jax.Array, dict]:
    """batch: tokens [B,S], labels [B,S] (-100 = ignore), + modality extras."""
    kw = {}
    if cfg.family == "vlm":
        kw["img_embeds"] = batch["img_embeds"]
    if cfg.family == "encdec":
        kw["enc_frames"] = batch["enc_frames"]
    logits, aux, _ = lm.forward_lm(cfg, params, batch["tokens"], **kw)
    labels = batch["labels"]
    if cfg.family == "vlm":  # image positions carry no loss
        pad = jnp.full((labels.shape[0], cfg.n_img_tokens), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)

    vp = logits.shape[-1]
    mask_v = jnp.arange(vp) < cfg.vocab
    logits = jnp.where(mask_v[None, None, :], logits.astype(jnp.float32), -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    tok_mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * tok_mask
    ntok = jnp.maximum(jnp.sum(tok_mask), 1.0)
    loss = jnp.sum(nll) / ntok
    total = loss + AUX_WEIGHT * aux
    return total, {"loss": loss, "aux": aux, "ntok": ntok}


def train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, params,
               opt_state: OptState, batch: dict, *, microbatches: int = 1):
    """One optimizer step; optionally accumulates grads over microbatches."""
    if microbatches <= 1:
        (total, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch), has_aux=True)(params)
    else:
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def acc_fn(carry, mbatch):
            g_acc, l_acc = carry
            (total, m), g = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, mbatch), has_aux=True)(params)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + total), m

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, total), ms = jax.lax.scan(acc_fn, (g0, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        total = total / microbatches
        metrics = jax.tree.map(lambda x: jnp.mean(x), ms)

    new_params, new_opt, opt_metrics = apply_updates(
        opt_cfg, params, grads, opt_state)
    metrics = dict(metrics, **opt_metrics, total=total)
    return new_params, new_opt, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    return partial(train_step, cfg, opt_cfg, microbatches=microbatches)

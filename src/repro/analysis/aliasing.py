"""Aliasing rule: owner state that gets mutated in place must be fresh.

Incident record: PR 7's ``StreamSession`` assigned the array returned by
``local_reauction`` straight to ``self.owner``.  That array is a
jax-backed, read-only view; the next in-place ``self.owner[idx] = p``
raised ``ValueError: assignment destination is read-only`` — but only on
the first *streamed* update after a re-auction, which no unit test hit.
The shipped fix wraps it in ``np.array(...)`` (a writable copy); AL001
makes the bug class unrepresentable.

Scope: classes in ``stream/`` modules.  For each ``self.<attr>`` that the
class mutates in place (``self.attr[...] = ...``, ``self.attr += ...``,
or mutating method calls), every assignment ``self.attr = <expr>`` must be
*provably fresh*: a copying constructor (``np.array``, ``np.copy``,
``np.zeros/ones/full/empty/arange/concatenate/stack``, ``.copy()``,
``list()/dict()/set()`` displays), or a local name that was itself
assigned fresh in the same function (slices of fresh stay fresh).
``np.asarray`` is *not* fresh — it is a documented no-copy passthrough,
which is exactly how the incident array sneaked in.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .base import Finding, ModuleInfo, Rule, dotted, register_rule

_FRESH_NP = {"array", "copy", "zeros", "ones", "full", "empty", "arange",
             "concatenate", "stack", "zeros_like", "ones_like",
             "full_like", "empty_like", "repeat", "tile", "where"}
_MUTATORS = {"append", "add", "update", "pop", "clear", "setdefault",
             "remove", "discard", "extend", "insert", "fill", "sort",
             "resize", "put"}


def _self_attr(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _is_fresh(expr: ast.AST, fresh_locals: set[str]) -> bool:
    """Provably returns a newly allocated, writable object."""
    if isinstance(expr, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp, ast.Constant)):
        return True
    if isinstance(expr, ast.Name):
        return expr.id in fresh_locals
    if isinstance(expr, ast.Subscript):
        # a slice of a fresh array is a view *of a writable array* — fine
        return _is_fresh(expr.value, fresh_locals)
    if isinstance(expr, ast.BinOp):
        return True               # arithmetic allocates a new array
    if isinstance(expr, ast.Call):
        # method tails are checked on the raw Attribute so chains whose
        # base is itself a call — np.asarray(x).copy() — still count
        if isinstance(expr.func, ast.Attribute):
            if expr.func.attr == "copy" and not expr.args:    # x.copy()
                return True
            if expr.func.attr in ("astype", "tolist"):        # copies
                return True
        d = dotted(expr.func) or ""
        head, _, tail = d.rpartition(".")
        if head in ("np", "numpy") and tail in _FRESH_NP:
            return True
        if d in ("list", "dict", "set", "bytearray", "sorted"):
            return True
    return False


def _function_fresh_locals(fn: ast.AST) -> set[str]:
    """Local names assigned a fresh expression anywhere in fn (single
    forward pass; sufficient for straight-line construction code)."""
    fresh: set[str] = set()
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign) and len(sub.targets) == 1 and \
                isinstance(sub.targets[0], ast.Name):
            if _is_fresh(sub.value, fresh):
                fresh.add(sub.targets[0].id)
            else:
                fresh.discard(sub.targets[0].id)
    return fresh


class StaleViewAssignment(Rule):
    id = "AL001"
    family = "aliasing"
    name = "non-fresh-assignment-to-mutated-owner-field"
    summary = ("in stream/ classes, fields mutated in place must only be "
               "assigned provably-fresh arrays (np.array/.copy()); "
               "jax-backed returns are read-only views — the PR 7 "
               "local_reauction ValueError class")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if mod.subsystem != "stream":
            return
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            mutated: set[str] = set()
            for sub in ast.walk(cls):
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    targets = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    for t in targets:
                        if isinstance(t, ast.Subscript):
                            attr = _self_attr(t.value)
                            if attr:
                                mutated.add(attr)
                        elif isinstance(sub, ast.AugAssign):
                            attr = _self_attr(t)
                            if attr:
                                mutated.add(attr)
                elif isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr in _MUTATORS:
                    attr = _self_attr(sub.func.value)
                    if attr:
                        mutated.add(attr)
            if not mutated:
                continue
            for m in cls.body:
                if not isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    continue
                fresh = _function_fresh_locals(m)
                for sub in ast.walk(m):
                    if not isinstance(sub, ast.Assign):
                        continue
                    for t in sub.targets:
                        attr = _self_attr(t)
                        if attr in mutated and \
                                not _is_fresh(sub.value, fresh):
                            yield self.finding(
                                mod, sub, f"{cls.name}.{m.name}",
                                f"self.{attr} is mutated in place "
                                f"elsewhere in {cls.name} but this "
                                "assignment is not provably fresh — a "
                                "jax-backed/read-only view here raises on "
                                "the next in-place write; wrap in "
                                "np.array(...)")


register_rule(StaleViewAssignment())

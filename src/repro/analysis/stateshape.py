"""State-shape rules: per-vertex state rank is declared, never assumed.

Incident record (the reason this family exists): before the ``StateSpec``
API, the serving warm store manufactured cold warm-start rows with
``np.full(buffer.graph.n_vertices, np.inf, np.float32)`` — hard-coding the
assumption that every program keeps exactly one float per vertex.  The
first vector-state program (``gcn_layer``, ``[V, F]`` planes) would have
warm-started from a rank-1 block and died in a reshape deep inside jit,
lanes already batched, long after the request was admitted.  The fix
routes every cold/warm allocation through ``entry.state.cold(V)`` /
``StateSpec.shape(V)`` so the program's declared rank is the only rank
decision point:

SR001  in gserve, no raw numpy allocation (``np.full``/``zeros``/``ones``/
       ``empty``) shaped directly by ``<...>.n_vertices`` — that bakes an
       implicit scalar-per-vertex rank into the serving tier; derive the
       shape from the program entry's ``StateSpec`` instead.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .base import (Finding, ImportMap, ModuleInfo, Rule, dotted,
                   qualname_at, register_rule)

_ALLOCATORS = {"numpy.full", "numpy.zeros", "numpy.ones", "numpy.empty"}


def _shape_is_n_vertices(node: ast.AST) -> bool:
    """True when a shape argument is ``<...>.n_vertices`` itself or a
    1-tuple/1-list wrapping it — both pin the per-vertex rank to scalar.
    ``(g.n_vertices, F)`` is an explicit rank-2 choice and is left alone."""
    if isinstance(node, (ast.Tuple, ast.List)):
        if len(node.elts) != 1:
            return False
        node = node.elts[0]
    return isinstance(node, ast.Attribute) and node.attr == "n_vertices"


class ImplicitScalarStateRank(Rule):
    id = "SR001"
    family = "state-shape"
    name = "implicit-scalar-state-rank"
    summary = ("gserve must not allocate per-vertex state with "
               "np.full/zeros/ones/empty shaped by .n_vertices — that "
               "hard-codes scalar rank; use the program entry's "
               "StateSpec (entry.state.cold / .shape) instead")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if mod.subsystem != "gserve":
            return
        imports = ImportMap(mod)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if not d or imports.resolve(d) not in _ALLOCATORS:
                continue
            shape = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "shape":
                    shape = kw.value
            if shape is None or not _shape_is_n_vertices(shape):
                continue
            yield self.finding(
                mod, node, qualname_at(mod.tree, node),
                f"{d}(... n_vertices ...) hard-codes one scalar per vertex "
                "in the serving tier; vector-state programs declare their "
                "rank in StateSpec — allocate via entry.state.cold(V) / "
                "entry.state.shape(V)")


register_rule(ImplicitScalarStateRank())

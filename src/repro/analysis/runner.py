"""Scan driver + CLI for ``python -m repro.analysis``.

Exit codes: 0 clean, 1 unsuppressed findings, 2 usage/config errors
(unparseable suppressions, unknown rule ids, bad paths).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Iterable

from . import rules as _rules  # noqa: F401  (imports register the catalogue)
from .base import Finding, all_rules, module_info
from .suppressions import SuppressionError, apply, discover, parse

_SKIP_DIRS = {"__pycache__", ".git"}


def iter_sources(roots: Iterable[str]) -> list[str]:
    out: list[str] = []
    for root in roots:
        if os.path.isfile(root):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return out


def scan(paths: Iterable[str],
         rule_ids: Iterable[str] | None = None) -> list[Finding]:
    """Run (a subset of) the catalogue over source files; findings sorted
    by file/line for stable output."""
    catalogue = all_rules()
    if rule_ids is not None:
        unknown = set(rule_ids) - set(catalogue)
        if unknown:
            raise SuppressionError(
                f"unknown rule id(s) in --rules: {', '.join(sorted(unknown))}")
        catalogue = {i: catalogue[i] for i in rule_ids}
    findings: list[Finding] = []
    for path in paths:
        try:
            mod = module_info(path)
        except SyntaxError as e:
            findings.append(Finding("PARSE", path, e.lineno or 0, 0,
                                    "<module>", f"syntax error: {e.msg}"))
            continue
        for rule in catalogue.values():
            findings.extend(rule.check(mod))
    findings.sort(key=lambda f: (f.file, f.line, f.col, f.rule))
    return findings


def render_text(findings: list[Finding]) -> str:
    lines = [f"{f.file}:{f.line}:{f.col}: {f.rule} [{f.symbol}] "
             f"{f.message}" for f in findings]
    return "\n".join(lines)


def report_json(unsuppressed: list[Finding], suppressed: list[Finding],
                unused: list, roots: list[str]) -> dict:
    return {
        "schema": "repro.analysis/v1",
        "roots": roots,
        "rules": {i: {"family": r.family, "name": r.name,
                      "summary": r.summary}
                  for i, r in sorted(all_rules().items())},
        "counts": {"unsuppressed": len(unsuppressed),
                   "suppressed": len(suppressed)},
        "findings": [f.to_json() for f in unsuppressed],
        "suppressed": [f.to_json() for f in suppressed],
        "unused_suppressions": [
            {"rule": s.rule, "path_glob": s.path_glob,
             "symbol_glob": s.symbol_glob, "lineno": s.lineno}
            for s in unused],
        "ok": not unsuppressed,
    }


def run_clean(root: str) -> bool:
    """True iff a default scan of ``root`` has zero unsuppressed findings.
    Used by the tier-1 test and the benchmarks footer."""
    supp_path = discover(root)
    supps = []
    if supp_path:
        with open(supp_path, encoding="utf-8") as f:
            supps = parse(f.read(), all_rules(), supp_path)
    findings = scan(iter_sources([root]))
    kept, _ = apply(findings, supps)
    return not kept


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant checker: trace-safety, retrace "
                    "hazards, lock discipline, aliasing, layering.")
    ap.add_argument("roots", nargs="*", default=None,
                    help="files or directories to scan (default: src/repro "
                         "found relative to cwd)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("-o", "--output", default=None,
                    help="write the report here as well as stdout summary")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--suppressions", default=None,
                    help=f"explicit suppressions file (default: nearest "
                         f"analysis_suppressions.txt above the scan root)")
    ap.add_argument("--no-suppressions", action="store_true",
                    help="ignore any suppressions file (show everything)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for i, r in sorted(all_rules().items()):
            print(f"{i}  {r.family:<16} {r.name}\n      {r.summary}")
        return 0

    roots = args.roots or []
    if not roots:
        default = os.path.join("src", "repro")
        if not os.path.isdir(default):
            print("error: no roots given and ./src/repro not found",
                  file=sys.stderr)
            return 2
        roots = [default]
    for r in roots:
        if not os.path.exists(r):
            print(f"error: no such path: {r}", file=sys.stderr)
            return 2

    rule_ids = args.rules.split(",") if args.rules else None

    supps = []
    supp_origin = None
    if not args.no_suppressions:
        supp_origin = args.suppressions or discover(roots[0])
        if args.suppressions and not os.path.isfile(args.suppressions):
            print(f"error: suppressions file not found: "
                  f"{args.suppressions}", file=sys.stderr)
            return 2
        if supp_origin:
            try:
                with open(supp_origin, encoding="utf-8") as f:
                    supps = parse(f.read(), all_rules(), supp_origin)
            except SuppressionError as e:
                print(f"error: {e}", file=sys.stderr)
                return 2

    try:
        findings = scan(iter_sources(roots), rule_ids)
    except SuppressionError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    kept, silenced = apply(findings, supps)
    unused = [s for s in supps if not s.used]

    if args.format == "json":
        payload = report_json(kept, silenced, unused, list(roots))
        text = json.dumps(payload, indent=2, sort_keys=True)
        if args.output:
            with open(args.output, "w", encoding="utf-8") as f:
                f.write(text + "\n")
            print(f"wrote {args.output}: {len(kept)} unsuppressed, "
                  f"{len(silenced)} suppressed")
        else:
            print(text)
    else:
        if kept:
            print(render_text(kept))
        for s in unused:
            print(f"warning: unused suppression "
                  f"{supp_origin}:{s.lineno} ({s.rule} {s.path_glob} "
                  f"{s.symbol_glob}) — matched nothing, delete it",
                  file=sys.stderr)
        print(f"repro.analysis: {len(kept)} unsuppressed finding(s), "
              f"{len(silenced)} suppressed, "
              f"{len(all_rules())} rules over {len(roots)} root(s)")
    return 1 if kept else 0

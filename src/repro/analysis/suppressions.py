"""Suppressions file: the only way to silence a finding, always justified.

Format (text, one entry per line — 3.10-compatible, no toml):

    RULE_ID  path-glob  [symbol-glob]  --  justification

* ``RULE_ID`` must name a registered rule — an unknown id is a hard error
  (exit 2), so a renamed/removed rule can't leave a stale suppression
  silently masking nothing (or worse, the wrong thing).
* ``path-glob`` matches the finding's file path with ``fnmatch`` against
  both the display path and its trailing components, so
  ``obs/recorder.py`` matches ``src/repro/obs/recorder.py``.
* ``symbol-glob`` (optional) narrows to the dotted qualname
  (``Recorder._record``); omit to match the whole file.
* the ``--  justification`` is mandatory: a suppression with no reason is
  a parse error.

The file is discovered by walking upward from the scan root looking for
``analysis_suppressions.txt`` (so the CLI works from the repo root or
anywhere inside it), or passed explicitly with ``--suppressions``.
Suppressions that matched nothing in a run are reported as warnings —
they are debt to delete.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import os
from typing import Iterable

from .base import Finding

FILENAME = "analysis_suppressions.txt"


class SuppressionError(Exception):
    """Malformed file or unknown rule id — maps to exit code 2."""


@dataclasses.dataclass
class Suppression:
    rule: str
    path_glob: str
    symbol_glob: str          # "*" when omitted
    justification: str
    lineno: int
    used: bool = False

    def matches(self, f: Finding) -> bool:
        if f.rule != self.rule:
            return False
        path = f.file.replace("\\", "/")
        ok_path = fnmatch.fnmatch(path, self.path_glob)
        if not ok_path:
            # allow repo-relative globs against absolute/prefixed paths
            parts = path.split("/")
            ok_path = any(
                fnmatch.fnmatch("/".join(parts[i:]), self.path_glob)
                for i in range(len(parts)))
        return ok_path and fnmatch.fnmatch(f.symbol, self.symbol_glob)


def parse(text: str, known_rules: Iterable[str],
          origin: str = FILENAME) -> list[Suppression]:
    known = set(known_rules)
    out: list[Suppression] = []
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if "--" not in line:
            raise SuppressionError(
                f"{origin}:{lineno}: missing ` -- justification` "
                f"(every suppression must say why): {line!r}")
        head, _, justification = line.partition("--")
        justification = justification.strip()
        if not justification:
            raise SuppressionError(
                f"{origin}:{lineno}: empty justification")
        fields = head.split()
        if len(fields) not in (2, 3):
            raise SuppressionError(
                f"{origin}:{lineno}: expected `RULE_ID path-glob "
                f"[symbol-glob] -- why`, got {len(fields)} fields")
        rule = fields[0]
        if rule not in known:
            raise SuppressionError(
                f"{origin}:{lineno}: unknown rule id {rule!r} "
                f"(known: {', '.join(sorted(known))}) — delete or fix "
                "this stale suppression")
        out.append(Suppression(rule, fields[1],
                               fields[2] if len(fields) == 3 else "*",
                               justification, lineno))
    return out


def discover(scan_root: str) -> str | None:
    """Nearest analysis_suppressions.txt at or above scan_root."""
    d = os.path.abspath(scan_root)
    if os.path.isfile(d):
        d = os.path.dirname(d)
    while True:
        cand = os.path.join(d, FILENAME)
        if os.path.isfile(cand):
            return cand
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent


def apply(findings: list[Finding],
          supps: list[Suppression]) -> tuple[list[Finding], list[Finding]]:
    """(unsuppressed, suppressed); marks each matching Suppression used."""
    kept, silenced = [], []
    for f in findings:
        hit = None
        for s in supps:
            if s.matches(f):
                hit = s
                s.used = True
                break
        (silenced if hit else kept).append(f)
    return kept, silenced

"""Import-for-effect module: pulling this in registers the full rule
catalogue.  New rule modules get one line here and nowhere else."""
from . import (aliasing, layering, locks, retrace, stateshape,  # noqa: F401
               trace_safety)

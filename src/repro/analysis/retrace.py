"""Retrace-hazard rules: jit cache keys must be stable and total.

jit's compilation cache keys on the hash of every static argument plus
the abstract shapes of the traced ones.  Anything unstable (dict order),
unhashable (lists/dicts in static aux), or *partial* (a key function that
silently skips a parameter) either crashes at dispatch, retraces on every
call, or — worst — serves a stale compiled program for a semantically
different request.

Incident record: the pagerank ``iters=None`` cache-identity bug — a cache
key built with ``params.get("iters")`` collapsed the default and an
explicit ``None`` onto the same compiled program while validation treated
them differently.  Key functions now index declared params totally
(``params[name]``), and RH003 keeps it that way.

RH001  ``tuple(d.items()/keys()/values())`` without a surrounding
       ``sorted(...)`` inside key-building code — dict iteration order is
       insertion order, so two semantically equal requests can produce
       different cache keys (scoped to registry/scheduler/cache modules);
RH002  mutable default argument values (list/dict/set displays) anywhere —
       shared across calls, and unhashable if they reach a static aux;
RH003  ``params.get(...)``/``kw.get(...)`` inside a ``*key*``-named
       function — key construction must fail loudly on a missing param,
       not silently alias requests (the pagerank incident).
"""
from __future__ import annotations

import ast
from typing import Iterator

from .base import Finding, ModuleInfo, Rule, dotted, register_rule, \
    walk_functions

_KEY_MODULES = ("registry.py", "scheduler.py", "cache.py")
_DICT_ITERS = {"items", "keys", "values"}
_PARAMS_NAMES = {"params", "kw", "kwargs"}


class UnsortedDictKey(Rule):
    id = "RH001"
    family = "retrace-hazard"
    name = "dict-order-dependent-cache-key"
    summary = ("tuple(d.items()/keys()/values()) without sorted(...) in "
               "registry/scheduler/cache key code — insertion order leaks "
               "into jit cache identity, aliasing or splitting cache "
               "entries")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.rel.endswith(_KEY_MODULES):
            return
        # parent chain so we can see whether a tuple() call sits inside a
        # sorted() call
        parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(mod.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "tuple" and node.args):
                continue
            inner = node.args[0]
            # tuple(sorted(...)) — fine, regardless of what's inside
            if isinstance(inner, ast.Call) and \
                    isinstance(inner.func, ast.Name) and \
                    inner.func.id == "sorted":
                continue
            has_dict_iter = any(
                isinstance(s, ast.Call)
                and isinstance(s.func, ast.Attribute)
                and s.func.attr in _DICT_ITERS and not s.args
                for s in ast.walk(inner))
            if not has_dict_iter:
                continue
            # sorted(tuple(d.items())) and friends — also fine
            p = parents.get(node)
            guarded = False
            while p is not None and not isinstance(
                    p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
                if isinstance(p, ast.Call) and \
                        isinstance(p.func, ast.Name) and \
                        p.func.id == "sorted":
                    guarded = True
                    break
                p = parents.get(p)
            if guarded:
                continue
            from .base import qualname_at
            yield self.finding(
                mod, node, qualname_at(mod.tree, node),
                "tuple() over dict .items()/.keys()/.values() without "
                "sorted(): insertion order becomes cache-key identity")


class MutableDefault(Rule):
    id = "RH002"
    family = "retrace-hazard"
    name = "mutable-default-argument"
    summary = ("list/dict/set literal default argument — shared across "
               "calls and unhashable if it reaches a jit static aux; "
               "default to None and construct inside")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for q, fn in walk_functions(mod.tree):
            args = fn.args
            for default in list(args.defaults) + \
                    [d for d in args.kw_defaults if d is not None]:
                if isinstance(default, (ast.List, ast.Dict, ast.Set,
                                        ast.ListComp, ast.DictComp,
                                        ast.SetComp)):
                    yield self.finding(
                        mod, default, q,
                        f"mutable default argument in {q!r}: evaluated "
                        "once, shared across calls, unhashable as a jit "
                        "static")
                elif isinstance(default, ast.Call) and \
                        isinstance(default.func, ast.Name) and \
                        default.func.id in ("list", "dict", "set"):
                    yield self.finding(
                        mod, default, q,
                        f"mutable default argument in {q!r}")


class GetInKeyFunction(Rule):
    id = "RH003"
    family = "retrace-hazard"
    name = "silent-get-in-key-function"
    summary = ("params.get()/kw.get() inside a *key*-named function — a "
               "missing param silently aliases distinct requests onto one "
               "cache entry (the pagerank iters=None incident); index "
               "declared params totally")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for q, fn in walk_functions(mod.tree):
            if "key" not in fn.name.lower():
                continue
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr == "get" and \
                        isinstance(sub.func.value, ast.Name) and \
                        sub.func.value.id in _PARAMS_NAMES:
                    yield self.finding(
                        mod, sub, q,
                        f"{sub.func.value.id}.get() inside key function "
                        f"{q!r}: missing params must raise, not default — "
                        "silent defaults alias cache identities")


register_rule(UnsortedDictKey())
register_rule(MutableDefault())
register_rule(GetInKeyFunction())

"""Layering & purity rules: the AST successors of the CI grep guards.

These three rules replace the hygiene-job ``grep -rn`` lines (and the two
tier-1 tests that mirrored them) with real parses: the greps could not see
``"sssp" == req.kind`` (reversed operands), ``from time import time as
now``, or ``import time as t`` — the AST rules can, so each invariant now
has exactly one source of truth.

LP001  no per-kind / per-channel string branching in ``gserve/`` — the
       PR 4 registry redesign exists so the serving layer never special-
       cases programs; a ``.kind == "sssp"`` comparison reintroduces the
       N-programs × M-call-sites maintenance matrix;
LP002  no wall-clock ``time.time()`` (alias-aware) anywhere in src/repro —
       measured intervals must use the monotonic ``perf_counter`` (NTP
       steps make wall-clock intervals go negative); true timestamps are
       suppressed case by case;
LP003  import layering: ``core`` must not import engine/stream/gserve/obs,
       ``engine`` must not import stream/gserve, ``stream`` must not
       import gserve, ``obs`` must not import gserve, and ``analysis``
       imports no sibling subsystem at all (it must stay runnable with
       zero heavyweight deps).  Relative imports are resolved to absolute
       ``repro.*`` names first.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .base import (Finding, ImportMap, ModuleInfo, Rule, dotted,
                   qualname_at, register_rule)

_BRANCH_ATTRS = {"kind", "channel"}

# subsystem -> subsystems it must never import
LAYERING: dict[str, tuple[str, ...]] = {
    "core": ("engine", "stream", "gserve", "obs"),
    "engine": ("stream", "gserve"),
    "stream": ("gserve",),
    "obs": ("gserve",),
    "analysis": ("core", "engine", "stream", "gserve", "obs", "ckpt",
                 "train", "launch"),
}


class KindBranching(Rule):
    id = "LP001"
    family = "layering"
    name = "kind-string-branching-in-gserve"
    summary = ("no `.kind`/`.channel` == string-constant comparisons in "
               "gserve/ — program dispatch goes through the registry "
               "(PR 4); catches reversed operand order the grep missed")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if mod.subsystem != "gserve":
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left] + list(node.comparators)
            has_attr = any(
                isinstance(s, ast.Attribute) and s.attr in _BRANCH_ATTRS
                for s in sides)
            has_str = any(
                isinstance(s, ast.Constant) and isinstance(s.value, str)
                for s in sides)
            if has_attr and has_str:
                yield self.finding(
                    mod, node, qualname_at(mod.tree, node),
                    "per-kind/per-channel string comparison in the "
                    "serving layer: dispatch must go through the program "
                    "registry, not string branching")


class WallClock(Rule):
    id = "LP002"
    family = "layering"
    name = "wall-clock-time"
    summary = ("no time.time() in src/repro (alias-aware: catches `from "
               "time import time as now`) — intervals use the monotonic "
               "time.perf_counter(); genuine timestamps get a suppression")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        imports = ImportMap(mod)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if not d:
                continue
            if imports.resolve(d) == "time.time" or d == "time.time":
                yield self.finding(
                    mod, node, qualname_at(mod.tree, node),
                    f"wall-clock time.time() (written `{d}()`): intervals "
                    "must use time.perf_counter(); if this is a genuine "
                    "timestamp, suppress with a justification")


class ImportLayering(Rule):
    id = "LP003"
    family = "layering"
    name = "import-layering"
    summary = ("core never imports engine/stream/gserve/obs; engine never "
               "imports stream/gserve; stream/obs never import gserve; "
               "analysis imports no repro sibling (relative imports "
               "resolved first)")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        forbidden = LAYERING.get(mod.subsystem)
        if not forbidden:
            return
        pkg = mod.rel.rsplit("/", 1)[0].replace("/", ".") \
            if "/" in mod.rel else ""
        pkg = f"repro.{pkg}" if pkg else "repro"
        for node in ast.walk(mod.tree):
            targets: list[str] = []
            if isinstance(node, ast.Import):
                targets = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                base = ImportMap.resolve_from(node, pkg)
                targets = [f"{base}.{a.name}" if base else a.name
                           for a in node.names]
            for t in targets:
                parts = t.split(".")
                if "repro" not in parts:
                    continue
                after = parts[parts.index("repro") + 1:]
                if after and after[0] in forbidden and \
                        after[0] != mod.subsystem:
                    yield self.finding(
                        mod, node, "<module>",
                        f"{mod.subsystem!r} must not import "
                        f"repro.{after[0]} (layering: "
                        f"{mod.subsystem} forbids {', '.join(forbidden)})")
                    break


register_rule(KindBranching())
register_rule(WallClock())
register_rule(ImportLayering())

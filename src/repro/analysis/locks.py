"""Lock-discipline rule: guarded state is written under the lock, always.

Incident record: PR 8's ``GraphServer._ledger_shares`` refreshed
``self._shares_cache`` without holding ``self._lock`` while ``set_ledger``
wrote the same attribute under it — a torn-read window on the drain path
that this rule now catches (and whose fix shipped with this PR).

LD001 applies to every class that creates a ``self._lock`` (``Lock`` /
``RLock``) in ``__init__``.  The guarded attribute set is inferred, not
declared: an attribute is *guarded* if any method mutates it lexically
inside ``with self._lock:`` — or inside a method that is itself only ever
called with the lock held (computed as a fixpoint over intra-class call
sites; ``__init__`` counts as a locked context since no other thread can
hold a reference yet).  Any other mutation of a guarded attribute —
assignment, augmented assignment, ``del``, or a mutating method call
(``.append``/``.pop``/``.update``/...) — is flagged.

Deliberately lock-free fast paths (the Recorder's GIL-atomic record path)
are real designs; they are expressed as suppressions with their
justification, not by weakening the rule.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .base import Finding, ModuleInfo, Rule, dotted, register_rule

_MUTATORS = {"append", "add", "update", "pop", "popitem", "clear",
             "move_to_end", "setdefault", "remove", "discard", "extend",
             "insert", "appendleft", "popleft"}
_LOCK_CTORS = {"Lock", "RLock"}


def _creates_lock(cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Attribute) and t.attr == "_lock" and \
                        isinstance(t.value, ast.Name) and t.value.id == "self":
                    return True
    return False


def _is_self_lock(node: ast.AST) -> bool:
    """True for a ``with self._lock`` context expression (not
    ``other._lock`` — CostLedger.merge locks the *other* ledger to read it,
    which guards nothing on self)."""
    return (isinstance(node, ast.Attribute) and node.attr == "_lock"
            and isinstance(node.value, ast.Name) and node.value.id == "self")


def _self_attr_writes(node: ast.AST) -> Iterator[tuple[str, ast.AST]]:
    """(attr, node) for every mutation of ``self.<attr>`` in the subtree,
    excluding nested with-self._lock bodies (handled by the caller's
    lexical walk)."""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = sub.targets if isinstance(sub, ast.Assign) \
                else [sub.target]
            for t in targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Attribute) and \
                            isinstance(leaf.value, ast.Name) and \
                            leaf.value.id == "self":
                        yield leaf.attr, sub
                        break
        elif isinstance(sub, ast.Delete):
            for t in sub.targets:
                base = t
                while isinstance(base, ast.Subscript):
                    base = base.value
                if isinstance(base, ast.Attribute) and \
                        isinstance(base.value, ast.Name) and \
                        base.value.id == "self":
                    yield base.attr, sub
        elif isinstance(sub, ast.Call) and \
                isinstance(sub.func, ast.Attribute) and \
                sub.func.attr in _MUTATORS:
            recv = sub.func.value
            while isinstance(recv, ast.Subscript):
                recv = recv.value
            if isinstance(recv, ast.Attribute) and \
                    isinstance(recv.value, ast.Name) and \
                    recv.value.id == "self":
                yield recv.attr, sub


def _split_writes(method: ast.AST) -> tuple[list, list]:
    """(locked_writes, bare_writes) for one method body, where each entry
    is (attr, node).  A write is *locked* if any enclosing ``with``
    statement in the method uses ``self._lock``."""
    locked_spans: list[tuple[int, int]] = []
    for sub in ast.walk(method):
        if isinstance(sub, ast.With):
            if any(_is_self_lock(item.context_expr)
                   for item in sub.items):
                locked_spans.append(
                    (sub.lineno, getattr(sub, "end_lineno", sub.lineno)))
    locked, bare = [], []
    for attr, node in _self_attr_writes(method):
        line = node.lineno
        if any(lo <= line <= hi for lo, hi in locked_spans):
            locked.append((attr, node))
        else:
            bare.append((attr, node))
    return locked, bare


class UnguardedWrite(Rule):
    id = "LD001"
    family = "lock-discipline"
    name = "guarded-attr-written-without-lock"
    summary = ("in classes owning self._lock, attributes ever mutated "
               "under the lock must always be mutated under it (the "
               "GraphServer._shares_cache torn-write class); deliberate "
               "lock-free paths need a suppression with justification")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef) or not _creates_lock(cls):
                continue
            methods = {n.name: n for n in cls.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            splits = {name: _split_writes(m) for name, m in methods.items()}

            # intra-class call sites: method -> set of (caller, locked?)
            call_sites: dict[str, set[tuple[str, bool]]] = {}
            for caller, m in methods.items():
                locked_spans = [
                    (w.lineno, getattr(w, "end_lineno", w.lineno))
                    for w in ast.walk(m) if isinstance(w, ast.With)
                    and any(_is_self_lock(i.context_expr) for i in w.items)]
                for sub in ast.walk(m):
                    if isinstance(sub, ast.Call) and \
                            isinstance(sub.func, ast.Attribute) and \
                            isinstance(sub.func.value, ast.Name) and \
                            sub.func.value.id == "self" and \
                            sub.func.attr in methods:
                        in_lock = any(lo <= sub.lineno <= hi
                                      for lo, hi in locked_spans)
                        call_sites.setdefault(sub.func.attr, set()).add(
                            (caller, in_lock))

            # fixpoint: a method runs in a locked context if it is
            # __init__, or every intra-class call site is locked or comes
            # from a locked-context method.
            locked_ctx = {"__init__"}
            changed = True
            while changed:
                changed = False
                for name in methods:
                    if name in locked_ctx:
                        continue
                    sites = call_sites.get(name)
                    if sites and all(locked or caller in locked_ctx
                                     for caller, locked in sites):
                        locked_ctx.add(name)
                        changed = True

            guarded: set[str] = set()
            for name, (locked, _bare) in splits.items():
                for attr, _ in locked:
                    guarded.add(attr)
                if name in locked_ctx and name != "__init__":
                    for attr, _ in _bare_of(splits, name):
                        guarded.add(attr)

            for name, (_locked, bare) in splits.items():
                if name == "__init__" or name in locked_ctx:
                    continue
                for attr, node in bare:
                    if attr in guarded:
                        yield self.finding(
                            mod, node, f"{cls.name}.{name}",
                            f"write to self.{attr} outside `with "
                            f"self._lock` but {cls.name} also mutates it "
                            "under the lock — torn-write/torn-read hazard")


def _bare_of(splits, name):
    return splits[name][1]


register_rule(UnguardedWrite())

"""repro.analysis — AST invariant checker for the repro codebase.

Stdlib-only static analysis enforcing the invariants the test suite can't
see until they bite at runtime: jit-trace purity (TS*), retrace/cache-key
hazards (RH*), lock discipline (LD*), view-aliasing freshness (AL*), and
layering/purity (LP*).  Replaces the CI grep guards.

CLI:   python -m repro.analysis [roots...] [--format json] [-o report.json]
Test:  repro.analysis.run_clean("src/repro") — the tier-1 gate.
Docs:  src/repro/analysis/README.md — rule catalogue with the incident
       motivating each rule.
"""
from . import rules as _rules  # noqa: F401  (registers the catalogue)
from .base import Finding, all_rules, module_info
from .runner import main, run_clean, scan
from .suppressions import Suppression, SuppressionError, parse

__all__ = ["Finding", "Suppression", "SuppressionError", "all_rules",
           "main", "module_info", "parse", "run_clean", "scan"]

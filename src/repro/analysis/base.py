"""Shared infrastructure for the AST invariant checker.

Every rule operates on a ``ModuleInfo`` — one parsed source file plus the
context the rules scope on: the *repro-relative* path (``engine/runtime.py``)
and the subsystem (``engine``).  Fixtures outside the package tree declare a
virtual path in a leading comment (``# analysis-virtual-path: engine/x.py``)
so the same scoping logic exercises them.

Rules subclass ``Rule`` and register themselves via ``register_rule`` at
import time; ``all_rules()`` is the single catalogue the runner, the CLI
``--rules`` filter, and the suppressions validator share — an unknown rule
id can exist nowhere.

Everything here is stdlib-only on purpose: the analyzer runs in CI's
hygiene job before any heavyweight dependency is installed.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterable, Iterator

_VIRTUAL_PATH_RE = re.compile(
    r"^#\s*analysis-virtual-path:\s*(\S+)\s*$", re.MULTILINE)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str                 # rule id, e.g. "LD001"
    file: str                 # display path (as scanned, relative to cwd)
    line: int
    col: int
    symbol: str               # dotted qualname context, e.g. "Recorder.disable"
    message: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ModuleInfo:
    """A parsed source file plus the context rules scope on."""
    path: str                 # display path of the file on disk
    rel: str                  # repro-relative path, e.g. "engine/runtime.py"
    subsystem: str            # first component of rel ("" for top-level)
    tree: ast.Module
    source: str


def module_info(path: str, display: str | None = None) -> ModuleInfo:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    tree = ast.parse(source, filename=path)
    rel = _repro_relative(path)
    m = _VIRTUAL_PATH_RE.search(source[:400])
    if m:                     # fixtures pin their scoping path explicitly
        rel = m.group(1)
    subsystem = rel.split("/", 1)[0] if "/" in rel else ""
    return ModuleInfo(display or path, rel, subsystem, tree, source)


def _repro_relative(path: str) -> str:
    parts = path.replace("\\", "/").split("/")
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i + 1:])
    return parts[-1]


class Rule:
    """One invariant. Subclasses set the class attributes and implement
    ``check``; ``finding`` builds a ``Finding`` with the rule id filled."""

    id: str = ""
    family: str = ""          # "trace-safety" | "retrace-hazard" | ...
    name: str = ""
    summary: str = ""         # one line; ``--list-rules`` and the README

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, mod: ModuleInfo, node: ast.AST, symbol: str,
                message: str) -> Finding:
        return Finding(self.id, mod.path, getattr(node, "lineno", 0),
                       getattr(node, "col_offset", 0), symbol, message)


_RULES: dict[str, Rule] = {}


def register_rule(rule: Rule) -> Rule:
    assert rule.id and rule.id not in _RULES, rule.id
    _RULES[rule.id] = rule
    return rule


def all_rules() -> dict[str, Rule]:
    """id -> rule, every registered rule (importing repro.analysis registers
    the full catalogue)."""
    return dict(_RULES)


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def walk_functions(tree: ast.Module) -> Iterator[tuple[str, ast.AST]]:
    """Yield (qualname, node) for every function/method, depth-first.
    Qualnames are dotted through classes and enclosing functions:
    ``Recorder.disable``, ``GraphServer.drain.<locals>.body``-style nesting
    collapses to plain dots (``drain.body``) for readable suppressions."""

    def rec(node: ast.AST, prefix: str) -> Iterator[tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from rec(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from rec(child, f"{prefix}{child.name}.")
            else:
                yield from rec(child, prefix)

    yield from rec(tree, "")


def qualname_at(tree: ast.Module, target: ast.AST) -> str:
    """Dotted qualname of the innermost function/class containing target
    (best effort; "<module>" at top level)."""
    best = "<module>"
    best_span = None
    t_line = getattr(target, "lineno", None)
    if t_line is None:
        return best
    for q, fn in walk_functions(tree):
        end = getattr(fn, "end_lineno", fn.lineno)
        if fn.lineno <= t_line <= end:
            span = end - fn.lineno
            if best_span is None or span <= best_span:
                best, best_span = q, span
    return best


class ImportMap:
    """Alias-aware import resolution for one module.

    ``resolve_call(node)`` maps a Call's func back to a canonical dotted
    name: ``from time import time as now; now()`` resolves to
    ``time.time`` — the aliasing the grep guards could never see.
    """

    def __init__(self, mod: ModuleInfo):
        self.aliases: dict[str, str] = {}       # local name -> dotted origin
        pkg = mod.rel.rsplit("/", 1)[0].replace("/", ".") \
            if "/" in mod.rel else ""
        pkg = f"repro.{pkg}" if pkg else "repro"
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
                    if a.asname:
                        self.aliases[a.asname] = a.name
            elif isinstance(node, ast.ImportFrom):
                base = self.resolve_from(node, pkg)
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = f"{base}.{a.name}"

    @staticmethod
    def resolve_from(node: ast.ImportFrom, pkg: str) -> str:
        """Absolute dotted base of a ``from X import ...`` given the
        importing module's package (``repro.stream``)."""
        if node.level == 0:
            return node.module or ""
        parts = pkg.split(".")
        # level=1: current package; each extra level strips one component
        base_parts = parts[: len(parts) - (node.level - 1)]
        base = ".".join(p for p in base_parts if p)
        if node.module:
            base = f"{base}.{node.module}" if base else node.module
        return base

    def resolve(self, name: str) -> str:
        """Canonical dotted origin of a dotted local name."""
        head, _, rest = name.partition(".")
        origin = self.aliases.get(head, head)
        return f"{origin}.{rest}" if rest else origin

"""Trace-safety rules: keep the jit-traced hot path pure and cheap.

Incident record (the reason this family exists): PR 6's first cut of the
engine instrumentation computed ``jnp.max``/``jnp.all`` reductions while
building recorder event arguments.  Each served result then dispatched a
fresh single-op XLA computation on the host-sync path and the observability
overhead benchmark blew its 3% budget.  The fix (numpy on already-synced
host arrays) is now enforced mechanically:

TS001  no ``jnp.*`` calls inside recorder event/span/counter arguments;
TS002  no host syncs (``np.asarray``/``np.array``/``.item()``/``.tolist()``/
       ``jax.device_get``/``float(jnp...)``) inside functions reachable from
       a ``jit``/``shard_map``/``pallas_call`` trace;
TS003  no Python ``if``/``while``/``assert``/ternary on a traced value
       (a ``jnp.*`` expression) inside those same functions — data-dependent
       Python control flow either crashes under jit or silently retraces.

"Reachable from a trace" is computed per module: roots are functions
decorated with (or passed to) ``jax.jit``/``shard_map``/``pl.pallas_call``/
``jax.vmap``, or passed as the body/cond of ``lax.while_loop``/``scan``/
``cond``/``fori_loop`` — plus every module-local function they call,
transitively.  A host driver that merely *calls* ``lax.scan(step, ...)``
is not traced; ``step`` is.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .base import (Finding, ImportMap, ModuleInfo, Rule, dotted,
                   qualname_at, register_rule, walk_functions)

# subsystems whose modules run (partly) under jax tracing
TRACED_SUBSYSTEMS = ("engine", "kernels", "core")

_RECORDER_METHODS = {"event", "gauge", "counter", "begin", "end"}
_TRACER_HEADS = {"jax.jit", "jit", "jax.experimental.shard_map.shard_map",
                 "shard_map", "pl.pallas_call", "pallas_call",
                 "jax.experimental.pallas.pallas_call", "jax.vmap", "vmap"}
_TRACER_CALL_TAILS = {"jit", "pallas_call", "shard_map", "vmap",
                      "while_loop", "scan", "fori_loop", "cond"}
_SYNC_ATTRS = {"item", "tolist"}
_JNP_MODULES = {"jnp", "jax.numpy"}


def _is_jnp_call(node: ast.AST, imports: ImportMap) -> bool:
    """True for any ``jnp.<op>(...)`` (alias-aware) in the subtree."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            d = dotted(sub.func)
            if d and imports.resolve(d.split(".")[0]) == "jax.numpy":
                return True
            if d and d.rsplit(".", 1)[0] in _JNP_MODULES:
                return True
    return False


def _callee_names(call: ast.Call) -> Iterator[str]:
    """Bare function names referenced anywhere in a call's arguments
    (covers ``jit(f)``, ``partial(jit, f)``, ``pallas_call(partial(k))``)."""
    for sub in ast.walk(call):
        if isinstance(sub, ast.Name):
            yield sub.id


def traced_functions(mod: ModuleInfo) -> dict[str, ast.AST]:
    """qualname -> node for every function reachable from a trace root."""
    imports = ImportMap(mod)
    funcs = dict(walk_functions(mod.tree))
    by_name: dict[str, list[str]] = {}
    for q, fn in funcs.items():
        by_name.setdefault(q.rsplit(".", 1)[-1], []).append(q)

    roots: set[str] = set()
    for q, fn in funcs.items():
        for dec in fn.decorator_list:
            flat = ast.unparse(dec)
            if any(h.split(".")[-1] in flat.split("(")[0].replace(
                    ")", "").split(",")[-1] or h in flat
                   for h in ("jit", "pallas_call", "shard_map")) and \
                    ("jit" in flat or "pallas_call" in flat
                     or "shard_map" in flat):
                roots.add(q)
    # functions *passed to* a tracer anywhere in the module become roots.
    # Note the enclosing function is deliberately NOT a root: a host
    # driver that calls jax.lax.scan(step, ...) runs eagerly — only
    # ``step`` is traced.  (The PR 6-era grep could not make this
    # distinction; the first cut of this rule couldn't either and flagged
    # every reference oracle that orchestrates a scan.)
    for sub in ast.walk(mod.tree):
        if isinstance(sub, ast.Call):
            d = dotted(sub.func) or ""
            if d.split(".")[-1] in _TRACER_CALL_TAILS:
                for name in _callee_names(sub):
                    for cand in by_name.get(name, ()):
                        roots.add(cand)

    # nested functions inherit their parent's traced-ness; plus fixpoint
    # over module-local calls by bare name
    traced = set(roots)
    changed = True
    while changed:
        changed = False
        for q, fn in funcs.items():
            if q in traced:
                continue
            parent = q.rsplit(".", 1)[0] if "." in q else None
            if parent in traced:
                traced.add(q)
                changed = True
                continue
        for q in list(traced):
            fn = funcs[q]
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Name):
                    for cand in by_name.get(sub.func.id, ()):
                        if cand not in traced:
                            traced.add(cand)
                            changed = True
    return {q: funcs[q] for q in traced}


class JnpInRecorderArgs(Rule):
    id = "TS001"
    family = "trace-safety"
    name = "jnp-in-recorder-args"
    summary = ("recorder event/span/counter arguments must not call jnp.* "
               "(each call dispatches a fresh XLA computation per event — "
               "the PR 6 overhead regression); use numpy on synced values")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        imports = ImportMap(mod)
        # local names bound to the process recorder: ``rec = _obs.get()``
        rec_names: set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                d = dotted(node.value.func) or ""
                if d.endswith(".get") and ("obs" in d or "rec" in d):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            rec_names.add(t.id)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _RECORDER_METHODS):
                continue
            recv = node.func.value
            is_rec = (isinstance(recv, ast.Name) and recv.id in rec_names)
            if not is_rec and isinstance(recv, ast.Call):
                d = dotted(recv.func) or ""
                is_rec = d.endswith(".get") and ("obs" in d or "rec" in d)
            if not is_rec:
                continue
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if _is_jnp_call(arg, imports):
                    yield self.finding(
                        mod, arg, qualname_at(mod.tree, node),
                        f"jnp.* call inside recorder .{node.func.attr}() "
                        "arguments dispatches an XLA computation per "
                        "recorded event; reduce with numpy on synced host "
                        "arrays instead")
                    break


class HostSyncInTrace(Rule):
    id = "TS002"
    family = "trace-safety"
    name = "host-sync-in-traced-function"
    summary = ("no np.asarray/np.array/.item()/.tolist()/jax.device_get/"
               "float(jnp...) inside functions reachable from jit/"
               "shard_map/pallas traces — host syncs break or serialize "
               "the trace")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if mod.subsystem not in TRACED_SUBSYSTEMS:
            return
        imports = ImportMap(mod)
        for q, fn in traced_functions(mod).items():
            for sub in ast.walk(fn):
                if not isinstance(sub, ast.Call):
                    continue
                d = dotted(sub.func) or ""
                resolved = imports.resolve(d) if d else ""
                if resolved in ("numpy.asarray", "numpy.array") or \
                        d in ("np.asarray", "np.array"):
                    yield self.finding(
                        mod, sub, q,
                        f"{d}() inside traced function {q!r} forces a "
                        "device->host sync at runtime (or freezes a traced "
                        "value at trace time); use jnp")
                elif resolved == "jax.device_get" or d == "jax.device_get":
                    yield self.finding(
                        mod, sub, q,
                        f"jax.device_get inside traced function {q!r}")
                elif isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr in _SYNC_ATTRS and not sub.args:
                    yield self.finding(
                        mod, sub, q,
                        f".{sub.func.attr}() inside traced function {q!r} "
                        "forces a host sync")
                elif isinstance(sub.func, ast.Name) and \
                        sub.func.id in ("float", "int", "bool") and \
                        sub.args and _is_jnp_call(sub.args[0], imports):
                    yield self.finding(
                        mod, sub, q,
                        f"{sub.func.id}(jnp...) inside traced function "
                        f"{q!r} concretizes a traced value (host sync / "
                        "TracerConversionError)")


class TracedBranch(Rule):
    id = "TS003"
    family = "trace-safety"
    name = "python-branch-on-traced-value"
    summary = ("no Python if/while/assert/ternary on a jnp.* expression "
               "inside traced functions — use lax.cond/while_loop/select "
               "(data-dependent Python control flow retraces or crashes)")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if mod.subsystem not in TRACED_SUBSYSTEMS:
            return
        imports = ImportMap(mod)
        for q, fn in traced_functions(mod).items():
            for sub in ast.walk(fn):
                test = None
                kind = None
                if isinstance(sub, (ast.If, ast.While)):
                    test, kind = sub.test, type(sub).__name__.lower()
                elif isinstance(sub, ast.IfExp):
                    test, kind = sub.test, "ternary"
                elif isinstance(sub, ast.Assert):
                    test, kind = sub.test, "assert"
                if test is None or not _is_jnp_call(test, imports):
                    continue
                yield self.finding(
                    mod, sub, q,
                    f"Python {kind} on a jnp.* expression inside traced "
                    f"function {q!r}: data-dependent control flow must go "
                    "through lax.cond/lax.while_loop/jnp.where")


register_rule(JnpInRecorderArgs())
register_rule(HostSyncInTrace())
register_rule(TracedBranch())

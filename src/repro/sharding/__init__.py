from .env import MeshEnv, env_from_mesh, get_env, logical_spec, set_env, shard, use_mesh  # noqa: F401

"""Mesh environment: logical-axis helpers shared by model code.

Model code never hard-codes mesh axis names; it asks the active ``MeshEnv``
for constraint specs. With no env set (CPU smoke tests) every helper is a
no-op, so the same model code runs on 1 device and on the 512-chip mesh.

Physical mesh (launch/mesh.py):
    single-pod  (data=16, model=16)            axes ("data", "model")
    multi-pod   (pod=2, data=16, model=16)     axes ("pod", "data", "model")

Logical mapping:
    batch / sequence-shards -> ("pod", "data")   ["dp"]
    heads / d_ff / experts  -> "model"           ["tp"]
    fsdp param dim          -> "data"            (replicated across pods;
                                                  grads all-reduce over pod)
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshEnv:
    mesh: Mesh | None = None
    dp: tuple[str, ...] = ()     # batch axes (pod, data)
    fsdp: str | None = None      # param-shard axis (data)
    tp: str | None = None        # tensor axis (model)

    @property
    def active(self) -> bool:
        return self.mesh is not None

    def dp_size(self) -> int:
        if not self.active:
            return 1
        import math
        return math.prod(self.mesh.shape[a] for a in self.dp)

    def tp_size(self) -> int:
        return self.mesh.shape[self.tp] if self.active and self.tp else 1


_local = threading.local()


def set_env(env: MeshEnv) -> None:
    _local.env = env


def get_env() -> MeshEnv:
    return getattr(_local, "env", MeshEnv())


def env_from_mesh(mesh: Mesh | None) -> MeshEnv:
    if mesh is None:
        return MeshEnv()
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    return MeshEnv(mesh=mesh,
                   dp=dp,
                   fsdp="data" if "data" in names else None,
                   tp="model" if "model" in names else None)


class use_mesh:
    """Context manager: activate a MeshEnv (and the mesh itself)."""

    def __init__(self, mesh: Mesh | None):
        self.env = env_from_mesh(mesh)
        self._prev: MeshEnv | None = None

    def __enter__(self):
        self._prev = get_env()
        set_env(self.env)
        return self.env

    def __exit__(self, *exc):
        set_env(self._prev or MeshEnv())
        return False


def shard(x: jax.Array, *spec: Any) -> jax.Array:
    """Apply a sharding constraint if a mesh env is active, else no-op.

    Spec entries use LOGICAL names: "dp" (batch axes), "tp" (model axis),
    "fsdp" (data axis), None, or tuples thereof.
    """
    env = get_env()
    if not env.active:
        return x
    phys = []
    for s in spec:
        phys.append(_resolve(env, s))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(env.mesh, P(*phys)))


def _resolve(env: MeshEnv, s):
    if s is None:
        return None
    if isinstance(s, tuple):
        out: list[str] = []
        for part in s:
            r = _resolve(env, part)
            if r is None:
                continue
            out.extend(r if isinstance(r, tuple) else (r,))
        return tuple(out) if out else None
    if s == "dp":
        return env.dp if env.dp else None
    if s == "tp":
        return env.tp
    if s == "fsdp":
        return env.fsdp
    return s  # literal mesh axis name


def logical_spec(*spec: Any) -> P:
    """Resolve a logical spec to a physical PartitionSpec for the active env
    (used for in_shardings/out_shardings at jit boundaries)."""
    env = get_env()
    if not env.active:
        return P()
    return P(*[_resolve(env, s) for s in spec])

"""Serving: prefill + single-token decode steps (the shapes the assigned
``decode_*``/``long_*`` cells lower), plus a tiny batched engine.

Decode attention with a sequence-sharded cache is the cross-chip
flash-decoding split-K pattern (softmax max/sum lower to psums over the
"tp"/"dp" axes holding the cache sequence — DESIGN.md §6).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import lm


def prefill(cfg: ModelConfig, params, tokens: jax.Array, **modality):
    """Full-sequence forward collecting KV caches. Returns (logits, caches)."""
    logits, _, caches = lm.forward_lm(cfg, params, tokens, remat=False,
                                      collect_cache=True, **modality)
    return logits, caches


def decode(cfg: ModelConfig, params, token: jax.Array, caches,
           cache_len: jax.Array, cross_kvs=None):
    """One token for every sequence in the batch. token [B, 1]."""
    logits, new_caches = lm.decode_step(cfg, params, token, caches,
                                        cache_len, cross_kvs=cross_kvs)
    return logits, new_caches


def greedy_token(logits: jax.Array, vocab: int) -> jax.Array:
    masked = jnp.where(jnp.arange(logits.shape[-1]) < vocab,
                       logits, -jnp.inf)
    return jnp.argmax(masked, axis=-1).astype(jnp.int32)


class Engine:
    """Minimal batched serving loop (example/driver use): prefill a batch of
    prompts, then greedy-decode step by step with a shared jitted decode."""

    def __init__(self, cfg: ModelConfig, params, s_max: int):
        self.cfg, self.params, self.s_max = cfg, params, s_max
        self._decode = jax.jit(
            lambda p, t, c, n, x: decode(cfg, p, t, c, n, cross_kvs=x))

    def generate(self, tokens: jax.Array, n_new: int,
                 **modality) -> jax.Array:
        cfg = self.cfg
        b, s0 = tokens.shape
        logits, caches = jax.jit(
            partial(prefill, cfg))(self.params, tokens, **modality)
        # grow prefill caches into s_max-capacity buffers
        def grow(x):
            if x.ndim >= 3 and x.shape[2] == s0:          # [R,B,S,...]
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, self.s_max - s0)
                return jnp.pad(x, pad)
            return x
        caches = jax.tree.map(grow, caches)
        cross_kvs = None
        if cfg.family == "encdec":
            memory = lm._encode(cfg, self.params, modality["enc_frames"])
            cross_kvs = lm.cross_kvs_from_memory(cfg, self.params, memory)

        tok = greedy_token(logits[:, -1:, :], cfg.vocab)
        out = [tok]
        n = jnp.int32(s0)
        for _ in range(n_new - 1):
            logits, caches = self._decode(self.params, tok, caches, n, cross_kvs)
            tok = greedy_token(logits[:, -1:, :], cfg.vocab)
            out.append(tok)
            n = n + 1
        return jnp.concatenate(out, axis=1)

"""Mamba-1 selective-SSM block (falcon-mamba / jamba mixers).

TPU adaptation (DESIGN.md §3/§7): the CUDA selective-scan kernel becomes a
chunked associative scan — ``lax.associative_scan`` inside fixed-size chunks
(materialising [B, chunk, d_inner, N] tiles that fit VMEM-scale buffers) with
a ``lax.scan`` carrying the inter-chunk state. Decode is the O(1) recurrent
update. d_inner is tensor-sharded ("tp"); the scan state [B, d_inner, N]
shards the same way, so the recurrence needs no collectives.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SsmConfig
from .layers import COMPUTE_DTYPE, PARAM_DTYPE, _init


def _ssm_dims(cfg: ModelConfig):
    s = cfg.ssm or SsmConfig()
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return s, d_in, dt_rank


def init_ssm(cfg: ModelConfig, key: jax.Array):
    s, d_in, dt_rank = _ssm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    # S4D-real initialisation for A
    a_init = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=PARAM_DTYPE)[None, :],
                      (d_in, 1))
    p: dict[str, Any] = {
        "in_proj": _init(ks[0], (d, 2 * d_in)),            # x and gate z
        "conv_w": _init(ks[1], (s.d_conv, d_in), scale=0.2),
        "conv_b": jnp.zeros((d_in,), PARAM_DTYPE),
        "x_proj": _init(ks[2], (d_in, dt_rank + 2 * s.d_state)),
        "dt_proj": _init(ks[3], (dt_rank, d_in), scale=dt_rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (d_in,), PARAM_DTYPE)
                             * (math.log(0.1) - math.log(0.001))
                             + math.log(0.001)), 1e-4, None))),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((d_in,), PARAM_DTYPE),
        "out_proj": _init(ks[5], (d_in, d),
                          scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    specs = {
        "in_proj": ("fsdp", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "x_proj": ("tp", None),
        "dt_proj": (None, "tp"),
        "dt_bias": ("tp",),
        "a_log": ("tp", None),
        "d_skip": ("tp",),
        "out_proj": ("tp", "fsdp"),
    }
    return p, specs


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv over [B,S,C] with kernel [K,C]. If ``state``
    ([B, K-1, C], the trailing inputs) is given, runs in streaming mode and
    returns the updated state."""
    k = w.shape[0]
    if state is not None:
        xin = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xin[:, -(k - 1):, :]
    else:
        xin = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = xin[:, -(k - 1):, :]
    out = sum(xin[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :], new_state


def _selective_scan_chunked(x, dt, b_t, c_t, a, d_skip, h0, chunk: int):
    """h_t = exp(dt_t ⊙ A) h_{t-1} + dt_t ⊙ (B_t ⊗ x_t);  y_t = C_t·h_t + D x_t.

    x/dt [B,S,Di]; b_t/c_t [B,S,N]; a [Di,N]; h0 [B,Di,N].
    Chunked: associative scan inside chunks, lax.scan across chunks.
    """
    bsz, s, d_in = x.shape
    n = a.shape[1]
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    xr = x.reshape(bsz, n_chunks, chunk, d_in)
    dtr = dt.reshape(bsz, n_chunks, chunk, d_in)
    br = b_t.reshape(bsz, n_chunks, chunk, n)
    cr = c_t.reshape(bsz, n_chunks, chunk, n)

    from .perf import get_perf
    scan_dtype = jnp.bfloat16 if get_perf().ssm_bf16 else jnp.float32

    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp                               # [B,chunk,...]
        decay = jnp.exp(-dtc[..., None] * a[None, None])    # [B,c,Di,N]
        inject = (dtc * xc)[..., None] * bc[:, :, None, :]  # [B,c,Di,N]
        decay = decay.astype(scan_dtype)
        inject = inject.astype(scan_dtype)

        def comb(l, r):
            al, bl = l
            ar, br_ = r
            return al * ar, bl * ar + br_

        a_cum, b_cum = jax.lax.associative_scan(comb, (decay, inject), axis=1)
        h_all = (a_cum.astype(jnp.float32) * h[:, None]
                 + b_cum.astype(jnp.float32))               # [B,c,Di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h_all.astype(scan_dtype),
                       cc.astype(scan_dtype),
                       preferred_element_type=jnp.float32)
        return h_all[:, -1], y

    h, ys = jax.lax.scan(
        lambda h, i: chunk_step(h, jax.tree.map(lambda t: t[:, i], (xr, dtr, br, cr))),
        h0, jnp.arange(n_chunks))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, d_in)
    return y + x * d_skip[None, None, :], h


def ssm_block(cfg: ModelConfig, p: dict, x: jax.Array, *,
              state: tuple[jax.Array, jax.Array] | None = None,
              chunk: int | None = None):
    """Mamba block. x [B,S,D].

    state = (conv_state [B,K-1,Di], h [B,Di,N]) for streaming decode; None
    for full-sequence (train/prefill) mode. Returns (y, new_state).
    """
    from .perf import get_perf
    if chunk is None:
        chunk = get_perf().ssm_chunk
    s_cfg, d_in, dt_rank = _ssm_dims(cfg)
    xc = x.astype(COMPUTE_DTYPE)
    xz = xc @ p["in_proj"].astype(COMPUTE_DTYPE)            # [B,S,2Di]
    xi, z = jnp.split(xz, 2, axis=-1)

    conv_state = state[0] if state is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"].astype(COMPUTE_DTYPE),
                                p["conv_b"].astype(COMPUTE_DTYPE), conv_state)
    xi = jax.nn.silu(xi)

    proj = xi @ p["x_proj"].astype(COMPUTE_DTYPE)           # [B,S,R+2N]
    dt_r = proj[..., :dt_rank]
    b_t = proj[..., dt_rank:dt_rank + s_cfg.d_state].astype(jnp.float32)
    c_t = proj[..., dt_rank + s_cfg.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
        + p["dt_bias"][None, None, :])                      # [B,S,Di]
    a = jnp.exp(p["a_log"])                                 # [Di,N] (positive)

    bsz = x.shape[0]
    if state is not None:
        h0 = state[1]
    else:
        h0 = jnp.zeros((bsz, d_in, s_cfg.d_state), jnp.float32)

    if x.shape[1] == 1 and state is not None:
        # O(1) decode update
        decay = jnp.exp(-dt[:, 0, :, None] * a[None])       # [B,Di,N]
        inject = (dt[:, 0] * xi[:, 0].astype(jnp.float32))[..., None] \
            * b_t[:, 0, None, :]
        h = decay * h0 + inject
        y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])[:, None, :]
        y = y + xi.astype(jnp.float32) * p["d_skip"][None, None, :]
        new_h = h
    else:
        y, new_h = _selective_scan_chunked(
            xi.astype(jnp.float32), dt, b_t, c_t, a, p["d_skip"], h0, chunk)

    y = (y.astype(COMPUTE_DTYPE) * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(COMPUTE_DTYPE)
    return out.astype(x.dtype), (new_conv, new_h)

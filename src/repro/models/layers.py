"""Transformer building blocks: RMSNorm, RoPE, GQA attention (flash-scan),
MLA attention, SwiGLU MLP, and expert-parallel MoE.

Conventions
-----------
* every ``init_*`` returns ``(params, specs)`` — two parallel pytrees; specs
  use LOGICAL axis names resolved by ``repro.sharding.env`` ("tp" = model,
  "fsdp" = data, "dp" = (pod, data), None = replicated);
* compute runs in bf16, params are stored f32 (cast at use);
* head counts are padded up to the tensor-parallel degree at init time
  (``pad_heads``) — the padding overhead is accounted in the roofline's
  MODEL_FLOPS/HLO_FLOPS ratio (DESIGN.md §5);
* attention over long sequences uses a lax.scan flash pattern (online
  softmax over KV blocks) so no [S, S] score tensor is ever materialised.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..configs.base import MlaConfig, ModelConfig, MoeConfig
from ..sharding.env import get_env, shard

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def _init(key, shape, scale=None):
    scale = scale if scale is not None else 0.02
    return (jax.random.normal(key, shape, PARAM_DTYPE) * scale)


def pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_heads(h: int, kv: int, tp: int) -> tuple[int, int]:
    """Pad (q-heads, kv-heads) so q-heads shard over tp and group evenly."""
    h_pad = pad_to(h, tp)
    if kv >= h_pad:
        return h_pad, h_pad
    kv_pad = kv
    while h_pad % kv_pad != 0:
        kv_pad += 1
    return h_pad, kv_pad


# ---------------------------------------------------------------------------
# Norm + RoPE
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, dh] (dh even), positions [S] or broadcastable."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [S, dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key: jax.Array, cross: bool = False):
    env = get_env()
    tp = env.tp_size()
    h, kv = pad_heads(cfg.n_heads, cfg.n_kv, tp)
    dh, d = cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "wq": _init(ks[0], (d, h, dh)),
        "wk": _init(ks[1], (d, kv, dh)),
        "wv": _init(ks[2], (d, kv, dh)),
        "wo": _init(ks[3], (h, dh, d), scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    s: dict[str, Any] = {
        "wq": ("fsdp", "tp", None),
        "wk": ("fsdp", None, None),   # kv heads replicated across tp
        "wv": ("fsdp", None, None),
        "wo": ("tp", None, "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), PARAM_DTYPE)
        p["bk"] = jnp.zeros((kv, dh), PARAM_DTYPE)
        p["bv"] = jnp.zeros((kv, dh), PARAM_DTYPE)
        s["bq"], s["bk"], s["bv"] = ("tp", None), (None, None), (None, None)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), PARAM_DTYPE)
        p["k_norm"] = jnp.ones((dh,), PARAM_DTYPE)
        s["q_norm"], s["k_norm"] = (None,), (None,)
    return p, s


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool, q_offset: jax.Array | int = 0,
                    block: int = 1024) -> jax.Array:
    """Online-softmax attention. q [B,H,Sq,dh]; k/v [B,KV,Sk,dh]; returns
    [B,H,Sq,dh]. Never materialises the [Sq,Sk] score matrix — scans KV
    blocks carrying the running (max, sum, acc)."""
    from .perf import get_perf
    if get_perf().flash_custom_vjp and q_offset == 0:
        from .flash_vjp import flash_fa2
        return flash_fa2(q, k, v, causal, block if k.shape[2] % block == 0
                         else k.shape[2])

    b, hq, sq, dh = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]                                # may differ from dh (MLA)
    g = hq // kvh
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, kvh, g, sq, dh)

    n_blk = max(sk // block, 1)
    block = sk // n_blk
    kb = k.astype(jnp.float32).reshape(b, kvh, n_blk, block, dh)
    vb = v.astype(jnp.float32).reshape(b, kvh, n_blk, block, dv)
    kb = jnp.moveaxis(kb, 2, 0)                     # [n, B, KV, blk, dh]
    vb = jnp.moveaxis(vb, 2, 0)

    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)

    from .perf import get_perf
    pv_bf16 = get_perf().pv_bf16
    additive_mask = get_perf().additive_mask

    def step(carry, xs):
        m, l, acc, blk_i = carry
        kblk, vblk = xs
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, kblk)     # [B,KV,G,Sq,blk]
        if causal:
            k_pos = blk_i * block + jnp.arange(block)
            if additive_mask:
                # §Perf: [Sq,blk] additive bias broadcast fuses into the dot
                # epilogue; no [B,H,Sq,blk] select tensor is materialised
                bias = jnp.where(q_pos[:, None] >= k_pos[None, :],
                                 0.0, -jnp.inf).astype(s.dtype)
                s = s + bias[None, None, None]
            else:
                mask = q_pos[:, None] >= k_pos[None, :]    # [Sq, blk]
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        if pv_bf16:    # §Perf: halve probs HBM traffic; accum stays f32
            pv = jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(jnp.bfloat16),
                            vblk.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bkgqc,bkcd->bkgqd", p, vblk)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc, blk_i + 1), None

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array) -> jax.Array:
    """Single-token attention against a (possibly sequence-sharded) cache.

    q [B,H,dh]; k_cache/v_cache [B,S,KV,dh]; length: valid prefix length.
    Softmax reductions over the sharded S axis lower to psums (the
    cross-chip flash-decoding split-K pattern — DESIGN.md §6).
    """
    b, hq, dh = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = hq // kvh
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, kvh, g, dh)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, kf)        # [B,KV,G,S]
    valid = jnp.arange(s)[None, None, None, :] < length
    logits = jnp.where(valid, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30), vf)
    return out.reshape(b, hq, dh).astype(q.dtype)


def attention(cfg: ModelConfig, p: dict, x: jax.Array, *,
              positions: jax.Array, causal: bool = True,
              cache: tuple[jax.Array, jax.Array] | None = None,
              cache_len: jax.Array | None = None,
              kv_input: jax.Array | None = None,
              use_rope: bool = True):
    """GQA attention, all modes.

    train/prefill: x [B,S,D] -> (out [B,S,D], new_kv)
    decode:        x [B,1,D] + cache -> (out, updated cache slice at cache_len)
    cross-attn:    kv_input [B,S_enc,D] (whisper decoder), cache unused.
    """
    b, sq, d = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    kv_src = (kv_input if kv_input is not None else x).astype(COMPUTE_DTYPE)

    q = jnp.einsum("bsd,dhk->bhsk", xc, p["wq"].astype(COMPUTE_DTYPE))
    k = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wv"].astype(COMPUTE_DTYPE))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(COMPUTE_DTYPE)[None, :, None, :]
        k = k + p["bk"].astype(COMPUTE_DTYPE)[None, :, None, :]
        v = v + p["bv"].astype(COMPUTE_DTYPE)[None, :, None, :]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    if use_rope:
        kv_positions = positions if kv_input is None else jnp.arange(k.shape[2])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)

    if cache is not None:
        k_cache, v_cache = cache
        # write new k/v at cache_len (sq == 1 decode step)
        k_new = jnp.moveaxis(k, 1, 2)                     # [B,Sq,KV,dh]
        v_new = jnp.moveaxis(v, 1, 2)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, cache_len, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, cache_len, 0, 0))
        out = decode_attention(q[:, :, 0, :], k_cache, v_cache,
                               cache_len + 1)
        out = out[:, :, None, :]                          # [B,H,1,dh]
        new_cache = (k_cache, v_cache)
    else:
        out = flash_attention(q, k, v, causal=causal)
        new_cache = (jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2))

    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(COMPUTE_DTYPE))
    return y.astype(x.dtype), new_cache


def attention_fixed_kv(cfg: ModelConfig, p: dict, x: jax.Array,
                       k_cache: jax.Array, v_cache: jax.Array) -> jax.Array:
    """Cross-attention against precomputed K/V (whisper decode): x [B,1,D],
    caches [B,S_enc,KV,dh]. No RoPE, no cache update."""
    xc = x.astype(COMPUTE_DTYPE)
    q = jnp.einsum("bsd,dhk->bhsk", xc, p["wq"].astype(COMPUTE_DTYPE))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(COMPUTE_DTYPE)[None, :, None, :]
    s_enc = k_cache.shape[1]
    out = decode_attention(q[:, :, 0, :], k_cache, v_cache,
                           jnp.int32(s_enc))
    y = jnp.einsum("bhsk,hkd->bsd", out[:, :, None, :],
                   p["wo"].astype(COMPUTE_DTYPE))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key: jax.Array):
    m = cfg.mla
    assert m is not None
    env = get_env()
    tp = env.tp_size()
    h = pad_to(cfg.n_heads, tp)
    d = cfg.d_model
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    q_in = m.q_lora or d
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": _init(ks[0], (d, m.kv_lora)),            # compress KV
        "w_kr": _init(ks[1], (d, dr)),                    # decoupled rope key
        "w_uk": _init(ks[2], (m.kv_lora, h, dn)),         # up-proj keys
        "w_uv": _init(ks[3], (m.kv_lora, h, dv)),         # up-proj values
        "w_uq": _init(ks[4], (q_in, h, dn + dr)),         # queries
        "wo": _init(ks[5], (h, dv, d), scale=0.02 / math.sqrt(2 * cfg.n_layers)),
        "kv_norm": jnp.ones((m.kv_lora,), PARAM_DTYPE),
    }
    s = {
        "w_dkv": ("fsdp", None),
        "w_kr": ("fsdp", None),
        "w_uk": (None, "tp", None),
        "w_uv": (None, "tp", None),
        "w_uq": ("fsdp", "tp", None),
        "wo": ("tp", None, "fsdp"),
        "kv_norm": (None,),
    }
    if m.q_lora:
        p["w_dq"] = _init(ks[6], (d, m.q_lora))
        p["q_norm"] = jnp.ones((m.q_lora,), PARAM_DTYPE)
        s["w_dq"] = ("fsdp", None)
        s["q_norm"] = (None,)
    return p, s


def mla_attention(cfg: ModelConfig, p: dict, x: jax.Array, *,
                  positions: jax.Array,
                  cache: tuple[jax.Array, jax.Array] | None = None,
                  cache_len: jax.Array | None = None):
    """Multi-head Latent Attention.

    Cache holds only (c_kv [B,S,kv_lora], k_rope [B,S,dr]) — the compressed
    latent — and decode uses the absorbed form (w_uk folded into the query,
    w_uv folded into the output projection), so per-step decode reads
    O(S·kv_lora) bytes instead of O(S·H·dh).
    """
    m = cfg.mla
    b, sq, d = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    h = p["w_uq"].shape[1]
    dn, dr = m.nope_head_dim, m.rope_head_dim

    if m.q_lora:
        q_in = rms_norm(xc @ p["w_dq"].astype(COMPUTE_DTYPE), p["q_norm"],
                        cfg.rms_eps)
    else:
        q_in = xc
    q = jnp.einsum("bsd,dhk->bhsk", q_in, p["w_uq"].astype(COMPUTE_DTYPE))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rms_norm(xc @ p["w_dkv"].astype(COMPUTE_DTYPE), p["kv_norm"],
                    cfg.rms_eps)                           # [B,S,kv_lora]
    k_rope = apply_rope(xc @ p["w_kr"].astype(COMPUTE_DTYPE),
                        positions, cfg.rope_theta)         # [B,S,dr]

    if cache is not None:
        ckv_cache, kr_cache = cache
        ckv_cache = jax.lax.dynamic_update_slice(
            ckv_cache, c_kv.astype(ckv_cache.dtype), (0, cache_len, 0))
        kr_cache = jax.lax.dynamic_update_slice(
            kr_cache, k_rope.astype(kr_cache.dtype), (0, cache_len, 0))
        s_len = ckv_cache.shape[1]
        # absorbed decode: fold w_uk into the query -> score in latent space
        q_c = jnp.einsum("bhsk,lhk->bhsl", q_nope.astype(jnp.float32),
                         p["w_uk"].astype(jnp.float32))    # [B,H,1,kv_lora]
        scale = 1.0 / math.sqrt(dn + dr)
        lat = ckv_cache.astype(jnp.float32)                # [B,S,L]
        krc = kr_cache.astype(jnp.float32)                 # [B,S,dr]
        logits = (jnp.einsum("bhsl,btl->bhst", q_c, lat)
                  + jnp.einsum("bhsk,btk->bhst",
                               q_rope.astype(jnp.float32), krc)) * scale
        valid = jnp.arange(s_len)[None, None, None, :] < (cache_len + sq)
        logits = jnp.where(valid, logits, -jnp.inf)
        mx = jnp.max(logits, axis=-1, keepdims=True)
        pr = jnp.exp(logits - mx)
        pr = pr / jnp.maximum(jnp.sum(pr, axis=-1, keepdims=True), 1e-30)
        o_lat = jnp.einsum("bhst,btl->bhsl", pr, lat)      # [B,H,1,L]
        out = jnp.einsum("bhsl,lhv->bhsv", o_lat,
                         p["w_uv"].astype(jnp.float32))    # absorbed w_uv
        new_cache = (ckv_cache, kr_cache)
    else:
        # train/prefill: materialise per-head keys/values, flash-scan
        k_nope = jnp.einsum("bsl,lhk->bhsk", c_kv, p["w_uk"].astype(COMPUTE_DTYPE))
        vfull = jnp.einsum("bsl,lhv->bhsv", c_kv, p["w_uv"].astype(COMPUTE_DTYPE))
        kr = jnp.broadcast_to(k_rope[:, None, :, :], (b, h, sq, dr))
        k = jnp.concatenate([k_nope, kr.astype(k_nope.dtype)], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(qq, k, vfull, causal=True)
        new_cache = (c_kv, k_rope)

    y = jnp.einsum("bhsv,hvd->bsd", out.astype(COMPUTE_DTYPE),
                   p["wo"].astype(COMPUTE_DTYPE))
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key: jax.Array, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_gate": _init(ks[0], (d, f)),
         "w_up": _init(ks[1], (d, f)),
         "w_down": _init(ks[2], (f, d), scale=0.02 / math.sqrt(2 * cfg.n_layers))}
    s = {"w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
         "w_down": ("tp", "fsdp")}
    return p, s


def mlp(p: dict, x: jax.Array) -> jax.Array:
    xc = x.astype(COMPUTE_DTYPE)
    g = jax.nn.silu(xc @ p["w_gate"].astype(COMPUTE_DTYPE))
    u = xc @ p["w_up"].astype(COMPUTE_DTYPE)
    return ((g * u) @ p["w_down"].astype(COMPUTE_DTYPE)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (expert-parallel over "tp"; see DESIGN.md §4 for the
# DFEP-balanced placement variant)
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key: jax.Array):
    mo = cfg.moe
    env = get_env()
    tp = env.tp_size()
    e_pad = pad_to(mo.n_experts, tp)
    d = cfg.d_model
    fe = mo.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": _init(ks[0], (d, e_pad), scale=0.006),
        "w_gate": _init(ks[1], (e_pad, d, fe)),
        "w_up": _init(ks[2], (e_pad, d, fe)),
        "w_down": _init(ks[3], (e_pad, fe, d),
                        scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    s = {
        "router": (None, None),
        "w_gate": ("tp", "fsdp", None),
        "w_up": ("tp", "fsdp", None),
        "w_down": ("tp", None, "fsdp"),
    }
    if mo.n_shared:
        sh, shs = init_mlp(cfg, ks[4], d_ff=mo.n_shared * fe)
        p["shared"], s["shared"] = sh, shs
    return p, s


def _moe_worker(x, router, w_gate, w_up, w_down, *,
                n_real: int, top_k: int, capacity: int,
                e_lo: jax.Array, tp_axis: str | None, norm_topk: bool):
    """Per-device MoE: local tokens x [T,D] × this shard's experts.

    Tokens are replicated over the tp axis (activations are batch-sharded
    only), so expert-parallelism needs no all-to-all: every shard computes
    its experts' contribution for its tokens and a psum over tp combines.
    """
    t, d = x.shape
    e_pad = router.shape[1]
    e_loc = w_gate.shape[0]
    xc = x.astype(COMPUTE_DTYPE)

    logits = (xc @ router.astype(COMPUTE_DTYPE)).astype(jnp.float32)
    logits = jnp.where(jnp.arange(e_pad)[None, :] < n_real, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)              # [T,k]
    if norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    fe_idx = eidx.reshape(-1)                              # [T*k]
    fg = gates.reshape(-1)
    tok = jnp.arange(t * top_k, dtype=jnp.int32) // top_k
    order = jnp.argsort(fe_idx)
    se, stok, sg = fe_idx[order], tok[order], fg[order]
    starts = jnp.searchsorted(se, jnp.arange(e_pad), side="left")
    pos = jnp.arange(t * top_k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < capacity
    local = (se >= e_lo) & (se < e_lo + e_loc) & keep
    b_e = jnp.where(local, se - e_lo, 0)
    b_p = jnp.where(local, pos, capacity)                  # overflow slot
    buf = jnp.zeros((e_loc, capacity + 1, d), COMPUTE_DTYPE)
    buf = buf.at[b_e, b_p].add(xc[stok] * local[:, None].astype(COMPUTE_DTYPE))
    buf = buf[:, :capacity]

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(COMPUTE_DTYPE)))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(COMPUTE_DTYPE))
    o = jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(COMPUTE_DTYPE))

    o_pad = jnp.concatenate([o, jnp.zeros((e_loc, 1, d), o.dtype)], axis=1)
    contrib = o_pad[b_e, b_p] * (sg * local)[:, None].astype(o.dtype)
    y = jnp.zeros((t, d), jnp.float32).at[stok].add(contrib.astype(jnp.float32))
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)

    # Switch-style load-balance aux loss over the real experts
    me = jnp.mean(probs[:, :n_real], axis=0)
    onehot = jax.nn.one_hot(eidx, e_pad, dtype=jnp.float32)[..., :n_real]
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    aux = n_real * jnp.sum(me * ce)
    return y, aux


def moe(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [B,S,D] -> (y [B,S,D], aux_loss scalar)."""
    mo = cfg.moe
    env = get_env()
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e_pad = p["router"].shape[1]

    if env.active and env.tp is not None:
        tp = env.tp_size()
        dp_ok = t % max(env.dp_size(), 1) == 0 and t >= env.dp_size()
        dp_spec = env.dp if (env.dp and dp_ok) else None
        t_loc = t // env.dp_size() if dp_spec else t
        cap = max(8, int(mo.capacity_factor * t_loc * mo.top_k / mo.n_experts))
        worker = partial(_moe_worker, n_real=mo.n_experts, top_k=mo.top_k,
                         capacity=cap, tp_axis=env.tp, norm_topk=True)

        def wrapped(xt_, router_, wg_, wu_, wd_):
            e_loc = e_pad // tp
            e_lo = jax.lax.axis_index(env.tp) * e_loc
            return worker(xt_, router_, wg_, wu_, wd_, e_lo=e_lo)

        y, aux = shard_map(
            wrapped, mesh=env.mesh,
            in_specs=(P(dp_spec, None), P(None, None),
                      P(env.tp, None, None), P(env.tp, None, None),
                      P(env.tp, None, None)),
            out_specs=(P(dp_spec, None), P()),
            check_rep=False,
        )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
        aux = jnp.mean(aux)
    else:
        cap = max(8, int(mo.capacity_factor * t * mo.top_k / mo.n_experts))
        y, aux = _moe_worker(xt, p["router"], p["w_gate"], p["w_up"],
                             p["w_down"], n_real=mo.n_experts, top_k=mo.top_k,
                             capacity=cap, e_lo=jnp.int32(0), tp_axis=None,
                             norm_topk=True)

    y = y.reshape(b, s, d).astype(x.dtype)
    if mo.n_shared:
        y = y + mlp(p["shared"], x)
    return y, aux

"""Flash attention with a FlashAttention-2-style custom VJP.

JAX's reverse-through-scan of the online-softmax forward stores per-block
residuals (the [B,H,Sq,blk] probability tiles) on the linearization tape —
measured at ~40% of deepseek-v2 train HBM traffic. The FA-2 backward
instead saves only (out, logsumexp) per query and *recomputes* each block's
probabilities from q,k on the fly: traffic ≈ 2× forward instead of ~4×.

Layout matches ``layers.flash_attention``: q [B,H,Sq,dh], k/v [B,KV,Sk,dh*].
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def _blocks(x, n_blk, block):
    b, kvh, sk, d = x.shape
    return jnp.moveaxis(x.reshape(b, kvh, n_blk, block, d), 2, 0)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_fa2(q, k, v, causal: bool, block: int):
    out, _ = _fwd_core(q, k, v, causal, block)
    return out


def _fwd_core(q, k, v, causal: bool, block: int):
    b, hq, sq, dh = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // kvh
    scale = 1.0 / math.sqrt(dh)
    qf = q.astype(jnp.float32).reshape(b, kvh, g, sq, dh)
    n_blk = max(sk // block, 1)
    block = sk // n_blk
    kb = _blocks(k.astype(jnp.float32), n_blk, block)
    vb = _blocks(v.astype(jnp.float32), n_blk, block)
    q_pos = jnp.arange(sq)

    def step(carry, xs):
        m, l, acc, i = carry
        kblk, vblk = xs
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, kblk) * scale
        if causal:
            k_pos = i * block + jnp.arange(block)
            bias = jnp.where(q_pos[:, None] >= k_pos[None, :], 0.0,
                             -jnp.inf).astype(s.dtype)
            s = s + bias[None, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgqc,bkcd->bkgqd", p, vblk)
        return (m_new, l, acc, i + 1), None

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kb, vb))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))                # [B,KV,G,Sq]
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(b, hq, sq, dv)
    return out.astype(q.dtype), lse


def _fwd(q, k, v, causal, block):
    out, lse = _fwd_core(q, k, v, causal, block)
    return out, (q, k, v, out, lse)


def _bwd(causal, block, res, dout):
    q, k, v, out, lse = res
    b, hq, sq, dh = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // kvh
    scale = 1.0 / math.sqrt(dh)
    qf = q.astype(jnp.float32).reshape(b, kvh, g, sq, dh)
    do = dout.astype(jnp.float32).reshape(b, kvh, g, sq, dv)
    of = out.astype(jnp.float32).reshape(b, kvh, g, sq, dv)
    delta = jnp.sum(do * of, axis=-1)                       # [B,KV,G,Sq]
    n_blk = max(sk // block, 1)
    block = sk // n_blk
    kb = _blocks(k.astype(jnp.float32), n_blk, block)
    vb = _blocks(v.astype(jnp.float32), n_blk, block)
    q_pos = jnp.arange(sq)

    def step(dq, xs):
        kblk, vblk, i = xs
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, kblk) * scale
        if causal:
            k_pos = i * block + jnp.arange(block)
            bias = jnp.where(q_pos[:, None] >= k_pos[None, :], 0.0,
                             -jnp.inf).astype(s.dtype)
            s = s + bias[None, None, None]
        p = jnp.exp(s - lse[..., None])                     # recomputed probs
        dv_blk = jnp.einsum("bkgqc,bkgqd->bkcd", p, do)
        dp = jnp.einsum("bkgqd,bkcd->bkgqc", do, vblk)
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bkgqc,bkcd->bkgqd", ds, kblk) * scale
        dk_blk = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qf) * scale
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros_like(qf)
    dq, (dk_b, dv_b) = jax.lax.scan(
        step, dq0, (kb, vb, jnp.arange(n_blk, dtype=jnp.int32)))
    dk = jnp.moveaxis(dk_b, 0, 2).reshape(b, kvh, sk, dh)
    dv_ = jnp.moveaxis(dv_b, 0, 2).reshape(b, kvh, sk, dv)
    return (dq.reshape(b, hq, sq, dh).astype(q.dtype),
            dk.astype(k.dtype), dv_.astype(v.dtype))


flash_fa2.defvjp(_fwd, _bwd)

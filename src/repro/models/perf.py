"""Performance profile — the §Perf hillclimb knobs.

``BASELINE`` is the paper-faithful-substrate configuration the first
roofline table was measured with; ``TUNED`` holds the accepted iterations.
Each knob maps to one hypothesis→change→measure entry in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass(frozen=True)
class PerfConfig:
    # flash attention: keep probs in bf16 for the PV matmul (f32 accum)
    pv_bf16: bool = False
    # flash attention: additive causal bias instead of a [B,H,Sq,blk] select
    additive_mask: bool = False
    # flash attention: FA2-style custom VJP (recompute probs in bwd instead
    # of storing per-block scan residuals)
    flash_custom_vjp: bool = False
    # remat: "block" = full-block checkpoint; "dots" = save matmul outputs
    remat_policy: str = "block"
    # selective scan: intermediate dtype + chunk length
    ssm_bf16: bool = False
    ssm_chunk: int = 256
    # sequence-parallel activation constraints at block boundaries (train)
    sp_activations: bool = False
    # serving: params in bf16, replicated over dp (sharded over tp only)
    # when the per-device footprint fits — kills FSDP weight all-gathers
    serve_bf16: bool = False
    serve_replicate_dp_below_gb: float = 0.0   # 0 = off


BASELINE = PerfConfig()

# Accepted §Perf iterations (EXPERIMENTS.md logs the full
# hypothesis→measure trail, including the refuted knobs):
#  * flash_custom_vjp (FA2 bwd): deepseek train mem 112.9s -> 74.7s
#  * additive_mask: -7% standalone (built into the FA2 path)
#  * ssm_chunk 4096 (kill outer chunk loop): falcon-mamba 148.1s -> 60.2s
#  * serve_bf16 + dp-replication: jamba long_500k collective 0.2255s -> ~0
# Refuted (kept off): pv_bf16 (+7% mem), remat "dots" (+26% mem),
#  ssm_bf16 (-10% alone but negligible at chunk 4096), ssm_chunk 128 (+50%).
TUNED = PerfConfig(pv_bf16=False, additive_mask=True, flash_custom_vjp=True,
                   remat_policy="block", ssm_bf16=False, ssm_chunk=4096,
                   sp_activations=False,
                   serve_bf16=True, serve_replicate_dp_below_gb=10.0)

_local = threading.local()


def set_perf(cfg: PerfConfig) -> None:
    _local.cfg = cfg


def get_perf() -> PerfConfig:
    return getattr(_local, "cfg", BASELINE)

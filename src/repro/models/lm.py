"""Model assembly: decoder-only LM (dense/MoE/MLA/SSM/hybrid), enc-dec
(whisper), and VLM-stub (llava) — all built from one layer vocabulary and
executed as ``lax.scan`` over repeated blocks (keeps lowered HLO size
independent of depth; DESIGN.md §6).

Layout of ``params``:
  embed      [V_pad, D]
  blocks     {"l0": ..., "l{P-1}": ...}  — each leaf stacked [R, ...]
  enc_blocks (encdec only) — same scheme, pattern ("attn",)
  final_norm [D];  lm_head [V_pad, D] (absent if tied)

Caches (decode): per pattern position, stacked [R, ...]:
  attn  -> {"k": [R,B,S,KV,dh], "v": ...}
  ssm   -> {"conv": [R,B,K-1,Di], "h": [R,B,Di,N]}
  cross (whisper) -> precomputed {"k": [R,B,S_enc,KV,dh], "v": ...}
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.env import get_env, shard
from . import layers as L
from . import ssm as S

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def vocab_pad(cfg: ModelConfig) -> int:
    return L.pad_to(cfg.vocab, 128)


def _init_layer(cfg: ModelConfig, kind: str, pos: int, key: jax.Array,
                cross: bool = False, encoder: bool = False):
    """One layer's params/specs: mixer + optional FFN (+ cross-attn)."""
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), L.PARAM_DTYPE)}
    s: dict[str, Any] = {"norm1": (None,)}
    if kind == "ssm":
        p["mixer"], s["mixer"] = S.init_ssm(cfg, ks[0])
    elif cfg.mla is not None:
        p["mixer"], s["mixer"] = L.init_mla(cfg, ks[0])
    else:
        p["mixer"], s["mixer"] = L.init_attention(cfg, ks[0])
    if cross:
        p["norm_x"] = jnp.ones((cfg.d_model,), L.PARAM_DTYPE)
        s["norm_x"] = (None,)
        p["cross"], s["cross"] = L.init_attention(cfg, ks[1])
    fk = "dense" if encoder else cfg.ffn_kind(pos)
    if fk == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), L.PARAM_DTYPE)
        s["norm2"] = (None,)
        p["ffn"], s["ffn"] = L.init_moe(cfg, ks[2])
    elif fk == "dense":
        p["norm2"] = jnp.ones((cfg.d_model,), L.PARAM_DTYPE)
        s["norm2"] = (None,)
        p["ffn"], s["ffn"] = L.init_mlp(cfg, ks[2])
    return p, s


def _stack_init(fn, repeats: int, key: jax.Array):
    """vmap an init over R block repeats -> leaves [R, ...]; specs get a
    leading None (the scan axis is never sharded)."""
    keys = jax.random.split(key, repeats)
    p0, s0 = fn(keys[0])
    p = jax.vmap(lambda k: fn(k)[0])(keys)
    s = jax.tree.map(lambda spec: (None,) + tuple(spec), s0,
                     is_leaf=lambda x: isinstance(x, tuple))
    return p, s


def init_params(cfg: ModelConfig, key: jax.Array):
    ks = jax.random.split(key, 8)
    vp = vocab_pad(cfg)
    pattern = cfg.layer_pattern
    repeats = cfg.block_repeats
    cross = cfg.family == "encdec"

    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"] = L._init(ks[0], (vp, cfg.d_model))
    specs["embed"] = ("tp", "fsdp")

    blocks_p, blocks_s = {}, {}
    for i, kind in enumerate(pattern):
        fn = partial(_init_layer, cfg, kind, i, cross=cross)
        blocks_p[f"l{i}"], blocks_s[f"l{i}"] = _stack_init(
            fn, repeats, jax.random.fold_in(ks[1], i))
    params["blocks"], specs["blocks"] = blocks_p, blocks_s

    if cfg.family == "encdec":
        fn = partial(_init_layer, cfg, "attn", 0, encoder=True)
        params["enc_blocks"], specs["enc_blocks"] = {}, {}
        ep, es = _stack_init(fn, cfg.n_enc_layers, ks[2])
        params["enc_blocks"]["l0"], specs["enc_blocks"]["l0"] = ep, es
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), L.PARAM_DTYPE)
        specs["enc_final_norm"] = (None,)

    params["final_norm"] = jnp.ones((cfg.d_model,), L.PARAM_DTYPE)
    specs["final_norm"] = (None,)
    if not cfg.tie_embeddings:
        params["lm_head"] = L._init(ks[3], (vp, cfg.d_model))
        specs["lm_head"] = ("tp", "fsdp")
    return params, specs


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, kind: str, pos: int, p: dict,
                 x: jax.Array, *, positions, cache=None, cache_len=None,
                 memory: jax.Array | None = None,
                 cross_kv: tuple | None = None, causal: bool = True,
                 encoder: bool = False):
    """Pre-norm residual layer. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["norm1"], cfg.rms_eps)
    if kind == "ssm":
        y, new_cache = S.ssm_block(cfg, p["mixer"], h, state=cache)
    elif cfg.mla is not None:
        y, new_cache = L.mla_attention(cfg, p["mixer"], h, positions=positions,
                                       cache=cache, cache_len=cache_len)
    else:
        y, new_cache = L.attention(cfg, p["mixer"], h, positions=positions,
                                   causal=causal, cache=cache,
                                   cache_len=cache_len)
    x = x + y
    if "cross" in p:
        hx = L.rms_norm(x, p["norm_x"], cfg.rms_eps)
        if memory is not None:         # train/prefill: attend to encoder output
            y, _ = L.attention(cfg, p["cross"], hx, positions=positions,
                               causal=False, kv_input=memory, use_rope=False)
        else:                          # decode: precomputed cross K/V
            y = L.attention_fixed_kv(cfg, p["cross"], hx, *cross_kv)
        x = x + y
    if "ffn" in p:
        h2 = L.rms_norm(x, p["norm2"], cfg.rms_eps)
        if cfg.moe is not None and (not encoder) and cfg.moe_at(pos):
            y2, aux = L.moe(cfg, p["ffn"], h2)
        else:
            y2 = L.mlp(p["ffn"], h2)
        x = x + y2
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Block scan
# ---------------------------------------------------------------------------

def _scan_blocks(cfg: ModelConfig, blocks: dict, x: jax.Array, *,
                 positions, caches=None, cache_len=None, memory=None,
                 cross_kvs=None, causal=True, encoder=False, remat=False,
                 pattern=None, collect_cache=False):
    """Scan over R repeated blocks. Returns (x, new_caches | None, aux)."""
    pattern = pattern or (("attn",) if encoder else cfg.layer_pattern)
    has_cache = caches is not None
    has_cross = cross_kvs is not None

    def body(carry, xs):
        x, aux = carry
        bp = xs["p"]
        cs = xs.get("c")
        xkv = xs.get("x")
        new_cs = {}
        for i, kind in enumerate(pattern):
            c = cs[f"l{i}"] if cs is not None else None
            ck = xkv[f"l{i}"] if xkv is not None else None
            x, nc, a = _apply_layer(
                cfg, kind, i, bp[f"l{i}"], x, positions=positions,
                cache=c, cache_len=cache_len, memory=memory,
                cross_kv=ck, causal=causal, encoder=encoder)
            aux = aux + a
            if has_cache or collect_cache:
                new_cs[f"l{i}"] = nc
        return (x, aux), (new_cs if (has_cache or collect_cache) else 0)

    if remat:
        from .perf import get_perf
        if get_perf().remat_policy == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)

    xs = {"p": blocks}
    if has_cache:
        xs["c"] = caches
    if has_cross:
        xs["x"] = cross_kvs
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    new_caches = ys if (has_cache or collect_cache) else None
    return x, new_caches, aux


def _encode(cfg: ModelConfig, params: dict, enc_frames: jax.Array):
    """Whisper encoder over stub frame embeddings [B, S_enc, D]."""
    x = enc_frames
    pos = jnp.arange(x.shape[1])
    x, _, _ = _scan_blocks(cfg, params["enc_blocks"], x, positions=pos,
                           causal=False, encoder=True, pattern=("attn",))
    return L.rms_norm(x, params["enc_final_norm"], cfg.rms_eps)


def _logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(L.COMPUTE_DTYPE),
                        head.astype(L.COMPUTE_DTYPE))
    return shard(logits, "dp", None, "tp")


def forward_lm(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
               img_embeds: jax.Array | None = None,
               enc_frames: jax.Array | None = None,
               remat: bool = True, collect_cache: bool = False):
    """Full-sequence forward (train / prefill).

    tokens [B, S_text]; vlm: img_embeds [B, N_img, D] prepended;
    encdec: enc_frames [B, S_enc, D] through the encoder as cross memory.
    Returns (logits [B, S, V_pad], aux, caches | None).
    """
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    x = shard(x, "dp", None, None)
    memory = None
    if enc_frames is not None:
        memory = _encode(cfg, params, enc_frames)
    positions = jnp.arange(x.shape[1])
    x, caches, aux = _scan_blocks(
        cfg, params["blocks"], x, positions=positions, memory=memory,
        causal=True, remat=remat, collect_cache=collect_cache)
    return _logits(cfg, params, x), aux, caches


def cross_kvs_from_memory(cfg: ModelConfig, params: dict, memory: jax.Array):
    """Precompute every decoder layer's cross K/V from encoder output
    (whisper decode; [R, B, S_enc, KV, dh] each)."""
    bp = params["blocks"]["l0"]["cross"]
    mc = memory.astype(L.COMPUTE_DTYPE)
    k = jnp.einsum("bsd,rdhk->rbshk", mc, bp["wk"].astype(L.COMPUTE_DTYPE))
    v = jnp.einsum("bsd,rdhk->rbshk", mc, bp["wv"].astype(L.COMPUTE_DTYPE))
    if cfg.qkv_bias:
        k = k + bp["bk"].astype(L.COMPUTE_DTYPE)[:, None, None]
        v = v + bp["bv"].astype(L.COMPUTE_DTYPE)[:, None, None]
    return {"l0": (jnp.moveaxis(k, 2, 3) if False else k, v)}


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array,
                caches, cache_len: jax.Array, cross_kvs=None):
    """One decode step. token [B, 1] int32; cache_len: current prefix length.
    Returns (logits [B, 1, V_pad], new_caches)."""
    x = params["embed"].astype(L.COMPUTE_DTYPE)[token]
    positions = jnp.full((1,), cache_len, jnp.int32)
    x, new_caches, _ = _scan_blocks(
        cfg, params["blocks"], x, positions=positions, caches=caches,
        cache_len=cache_len, cross_kvs=cross_kvs, causal=True)
    return _logits(cfg, params, x), new_caches


# ---------------------------------------------------------------------------
# Cache construction (shapes + logical partition specs)
# ---------------------------------------------------------------------------

def cache_struct(cfg: ModelConfig, batch: int, s_max: int):
    """Returns (pytree of ShapeDtypeStruct, pytree of logical specs) for the
    decode caches. Spec policy (DESIGN.md §6): batch over dp when it shards
    evenly, KV sequence over tp (cross-chip flash-decode); B==1 long-context
    shards the sequence over (dp+tp)."""
    env = get_env()
    dp = env.dp_size()
    r = cfg.block_repeats
    tp = env.tp_size()
    h, kv = L.pad_heads(cfg.n_heads, cfg.n_kv, tp)
    dh = cfg.head_dim
    b_shardable = batch % dp == 0 and batch >= dp and dp > 1
    if b_shardable:
        b_spec, s_spec = "dp", "tp"
    elif dp > 1:
        b_spec, s_spec = None, ("dp", "tp")
    else:
        b_spec, s_spec = None, "tp"

    structs: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    sd = jax.ShapeDtypeStruct
    for i, kind in enumerate(cfg.layer_pattern):
        name = f"l{i}"
        if kind == "ssm":
            s_cfg, d_in, _ = S._ssm_dims(cfg)
            structs[name] = (
                sd((r, batch, s_cfg.d_conv - 1, d_in), jnp.bfloat16),
                sd((r, batch, d_in, s_cfg.d_state), jnp.float32))
            specs[name] = ((None, b_spec if b_shardable else None, None, "tp"),
                           (None, b_spec if b_shardable else None, "tp", None))
        elif cfg.mla is not None:
            m = cfg.mla
            structs[name] = (
                sd((r, batch, s_max, m.kv_lora), jnp.bfloat16),
                sd((r, batch, s_max, m.rope_head_dim), jnp.bfloat16))
            specs[name] = ((None, b_spec, s_spec, None),
                           (None, b_spec, s_spec, None))
        else:
            structs[name] = (
                sd((r, batch, s_max, kv, dh), jnp.bfloat16),
                sd((r, batch, s_max, kv, dh), jnp.bfloat16))
            specs[name] = ((None, b_spec, s_spec, None, None),
                           (None, b_spec, s_spec, None, None))
    return structs, specs


def cross_kv_struct(cfg: ModelConfig, batch: int):
    env = get_env()
    tp = env.tp_size()
    h, kv = L.pad_heads(cfg.n_heads, cfg.n_kv, tp)
    dh = cfg.head_dim
    dp = env.dp_size()
    b_spec = "dp" if (batch % dp == 0 and batch >= dp and dp > 1) else None
    sd = jax.ShapeDtypeStruct
    structs = {"l0": (sd((cfg.block_repeats, batch, cfg.enc_seq, kv, dh), jnp.bfloat16),
                      sd((cfg.block_repeats, batch, cfg.enc_seq, kv, dh), jnp.bfloat16))}
    specs = {"l0": ((None, b_spec, None, None, None),
                    (None, b_spec, None, None, None))}
    return structs, specs

"""Deterministic synthetic data pipeline with skip-ahead resume.

Produces tokenised LM batches (plus stub modality inputs for vlm/encdec)
from a seeded generator. ``state = (seed, step)`` is all a restart needs:
``batch_at(step)`` is pure, so resuming after a failure replays nothing and
skips nothing (DESIGN.md §6 fault tolerance).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


class SyntheticPipeline:
    """Zipf-distributed token stream — cheap, deterministic, vocab-shaped."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg, self.data = cfg, data

    def batch_at(self, step: int) -> dict:
        cfg, d = self.cfg, self.data
        rng = np.random.default_rng((d.seed << 20) ^ step)
        # zipf-ish: sample from a power-law over the vocab
        u = rng.random((d.batch, d.seq_len + 1))
        toks = np.minimum((cfg.vocab * u ** 3).astype(np.int64),
                          cfg.vocab - 1)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if cfg.family == "vlm":
            img = rng.standard_normal(
                (d.batch, cfg.n_img_tokens, cfg.d_model)).astype(np.float32)
            batch["img_embeds"] = jnp.asarray(0.02 * img, jnp.bfloat16)
        if cfg.family == "encdec":
            fr = rng.standard_normal(
                (d.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
            batch["enc_frames"] = jnp.asarray(0.02 * fr, jnp.bfloat16)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

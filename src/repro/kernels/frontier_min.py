"""Pallas TPU kernel: ETSCH frontier aggregation (masked min over replicas).

Aggregation phase of the paper's framework (§III step 3): every frontier
vertex appears in several partitions; its replicas' states are reconciled
with a min reduce. State is [K, V] (partition-major); output [V].

TPU mapping: V is blocked into lane-aligned [BLK_V] tiles; each grid step
loads a [K, BLK_V] state tile + member-mask tile into VMEM and the VPU
reduces over the K sublane axis. K is padded to the 8-sublane multiple.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(state_ref, member_ref, o_ref):
    s = state_ref[...]                              # [K, BLK_V]
    m = member_ref[...]
    big = jnp.asarray(jnp.inf, s.dtype)
    o_ref[...] = jnp.min(jnp.where(m, s, big), axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def frontier_min(state: jax.Array, member: jax.Array, block_v: int = 2048,
                 interpret: bool = True) -> jax.Array:
    """Masked min over axis 0: state [K, V] float, member [K, V] bool -> [V]."""
    k, v = state.shape
    k_pad = -(-k // 8) * 8
    v_pad = -(-v // block_v) * block_v
    sp = jnp.full((k_pad, v_pad), jnp.inf, state.dtype).at[:k, :v].set(state)
    mp = jnp.zeros((k_pad, v_pad), jnp.bool_).at[:k, :v].set(member)
    out = pl.pallas_call(
        _kernel,
        grid=(v_pad // block_v,),
        in_specs=[pl.BlockSpec((k_pad, block_v), lambda i: (0, i)),
                  pl.BlockSpec((k_pad, block_v), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_v), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, v_pad), state.dtype),
        interpret=interpret,
    )(sp, mp)
    return out[0, :v]

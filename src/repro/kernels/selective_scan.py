"""Pallas TPU kernel: Mamba-1 selective scan (inference/prefill path).

The §Roofline analysis flags the SSM training/prefill cells as memory-bound:
the XLA associative-scan materialises O(S·d_inner·N·log S) bytes of
intermediate state in HBM. This kernel is the TPU adaptation of the CUDA
selective-scan: the recurrent state h [B, D_blk, N] lives in a VMEM scratch
across sequence chunks, so HBM traffic drops to the O(S·(d_inner + N))
inputs/outputs — the ~200× reduction quoted in EXPERIMENTS.md §Perf.

Grid: (d_inner blocks, sequence chunks) — the chunk axis iterates
sequentially (last grid dim), carrying h in scratch; each chunk is processed
with an in-VMEM fori_loop over its timesteps (elementwise VPU work on
[B, D_blk, N] tiles).

Forward-only (used for prefill/serving; training keeps the differentiable
associative-scan path — see ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, o_ref, h_ref,
            *, chunk: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...]          # [B, chunk, D_blk]
    dt = dt_ref[...]        # [B, chunk, D_blk]
    bc = b_ref[...]         # [B, chunk, N]
    cc = c_ref[...]         # [B, chunk, N]
    a = a_ref[...]          # [D_blk, N]
    d_skip = d_ref[...]     # [1, D_blk]

    def step(t, carry):
        h, y = carry
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 1)[:, 0]   # [B, D_blk]
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 1)[:, 0]
        b_t = jax.lax.dynamic_slice_in_dim(bc, t, 1, 1)[:, 0]    # [B, N]
        c_t = jax.lax.dynamic_slice_in_dim(cc, t, 1, 1)[:, 0]
        decay = jnp.exp(-dt_t[:, :, None] * a[None])             # [B,D,N]
        inject = (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        h = decay * h + inject
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1)              # [B, D_blk]
        y_t = y_t + x_t * d_skip
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[:, None], t, 1)
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros_like(x)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_ref[...] = h
    o_ref[...] = y


@functools.partial(jax.jit,
                   static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(x: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
                   a: jax.Array, d_skip: jax.Array, *,
                   block_d: int = 128, chunk: int = 128,
                   interpret: bool = True) -> jax.Array:
    """h_t = exp(-dt_t ⊙ A) h_{t-1} + dt_t ⊙ (B_t ⊗ x_t);  y_t = C_t·h_t + D x_t.

    x/dt [B,S,Di] f32; b/c [B,S,N] f32; a [Di,N] (positive); d_skip [Di].
    Returns y [B,S,Di].
    """
    bsz, s, d_in = x.shape
    n = a.shape[1]
    d_pad = -(-d_in // block_d) * block_d
    s_pad = -(-s // chunk) * chunk

    def padx(t, dval=0.0):
        out = jnp.full((bsz, s_pad, d_pad), dval, t.dtype)
        return out.at[:, :s, :d_in].set(t)

    xp, dtp = padx(x), padx(dt)
    bp = jnp.zeros((bsz, s_pad, n), b.dtype).at[:, :s].set(b)
    cp = jnp.zeros((bsz, s_pad, n), c.dtype).at[:, :s].set(c)
    ap = jnp.zeros((d_pad, n), a.dtype).at[:d_in].set(a)
    dp = jnp.zeros((1, d_pad), d_skip.dtype).at[0, :d_in].set(d_skip)

    grid = (d_pad // block_d, s_pad // chunk)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, chunk, block_d), lambda i, j: (0, j, i)),
            pl.BlockSpec((bsz, chunk, block_d), lambda i, j: (0, j, i)),
            pl.BlockSpec((bsz, chunk, n), lambda i, j: (0, j, 0)),
            pl.BlockSpec((bsz, chunk, n), lambda i, j: (0, j, 0)),
            pl.BlockSpec((block_d, n), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_d), lambda i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec((bsz, chunk, block_d), lambda i, j: (0, j, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, s_pad, d_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((bsz, block_d, n), jnp.float32)],
        interpret=interpret,
    )(xp, dtp, bp, cp, ap, dp)
    return out[:, :s, :d_in]

"""Pallas TPU kernel: multi-lane inclusive cumsum along the slot axis.

This is the compute hot-spot of DFEP step 1: ranking every funding slot
among its vertex's eligible slots requires a [2E, K] cumsum (K = number of
partitions = lane dim). Profiling the jnp implementation showed this cumsum
dominating the round cost.

TPU mapping: the slot axis is blocked into [BLK_S]-row tiles kept in VMEM
([BLK_S, K] per tile); the grid walks tiles sequentially ("arbitrary"
dimension semantics) carrying the running per-lane total in a VMEM scratch
tile. Inside a tile the VPU computes the local cumsum; K is padded to the
128-lane width for full-width vector ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...]                          # [BLK_S, K]
    local = jnp.cumsum(x, axis=0)
    o_ref[...] = local + carry_ref[...]
    carry_ref[...] = carry_ref[...] + local[-1:, :]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def lane_cumsum(x: jax.Array, block_s: int = 1024,
                interpret: bool = True) -> jax.Array:
    """Inclusive cumsum along axis 0 of [S, K]. Pads S to the block size and
    K to the 128-lane width; the caller sees the original shape."""
    s, k = x.shape
    s_pad = -(-s // block_s) * block_s
    k_pad = -(-k // 128) * 128
    xp = jnp.zeros((s_pad, k_pad), x.dtype).at[:s, :k].set(x)
    out = pl.pallas_call(
        _kernel,
        grid=(s_pad // block_s,),
        in_specs=[pl.BlockSpec((block_s, k_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_s, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, k_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, k_pad), x.dtype)],
        interpret=interpret,
    )(xp)
    return out[:s, :k]

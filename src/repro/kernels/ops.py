"""Jit'd public wrappers for the Pallas kernels.

``interpret=True`` (default here) executes the kernel bodies in Python on
CPU — bit-correct validation of the TPU kernels in this container. On a
real TPU runtime set ``interpret=False`` (the wrappers are jit'd either
way and the BlockSpecs are the TPU tiling).
"""
from __future__ import annotations

import jax

from .frontier_min import frontier_min
from .lane_cumsum import lane_cumsum
from .minplus_sweep import minplus_sweep

__all__ = ["lane_cumsum", "frontier_min", "minplus_sweep"]

from .selective_scan import selective_scan  # noqa: E402,F401

__all__.append("selective_scan")

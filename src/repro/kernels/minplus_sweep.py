"""Pallas TPU kernel: one min-plus relaxation sweep (ETSCH local phase).

The paper's local computation runs Dijkstra with a heap; the TPU adaptation
(DESIGN.md §3) is a data-parallel relaxation sweep with the same fixed
point. A sweep is a scatter-min — irregular on its face, so the kernel
re-expresses it densely, the TPU-native way:

  grid = (vertex_blocks, edge_blocks); each instance loads an edge tile
  (src, dst, mask) plus the full dist vector tile-gathered candidate
  values, builds the [BLK_E, BLK_V] one-hot compare mask against the
  vertex tile's iota (VPU broadcast-compare — no scatter), and min-reduces
  over the edge axis into the output vertex tile. The edge axis is the
  revisiting reduction dimension (init on first visit).

Candidate values dist[src]+cost are gathered OUTSIDE the kernel (XLA gather
is already optimal for this) — the kernel's job is the scatter-min, which
is the part XLA lowers poorly on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(dst_ref, cand_ref, dist_ref, o_ref, *, block_v: int):
    vb = pl.program_id(0)
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        o_ref[...] = dist_ref[...]                  # start from current dist

    dst = dst_ref[...]                              # [1, BLK_E] int32
    cand = cand_ref[...]                            # [1, BLK_E] float
    v0 = vb * block_v
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_v, dst.shape[1]), 0) + v0
    hit = iota == dst                               # [BLK_V, BLK_E]
    big = jnp.asarray(jnp.inf, cand.dtype)
    contrib = jnp.where(hit, cand, big)             # broadcast row of cands
    upd = jnp.min(contrib, axis=1, keepdims=True).T  # [1, BLK_V]
    o_ref[...] = jnp.minimum(o_ref[...], upd)


@functools.partial(jax.jit, static_argnames=("block_v", "block_e", "interpret"))
def minplus_sweep(dist: jax.Array, src: jax.Array, dst: jax.Array,
                  mask: jax.Array, cost: float = 1.0,
                  block_v: int = 512, block_e: int = 512,
                  interpret: bool = True) -> jax.Array:
    """One undirected relaxation sweep. dist [V]; src/dst [E]; mask [E]."""
    v, e = dist.shape[0], src.shape[0]
    big = jnp.asarray(jnp.inf, dist.dtype)
    # undirected: relax both directions -> 2E directed candidates
    d_dst = jnp.concatenate([dst, src]).astype(jnp.int32)
    d_cand = jnp.concatenate([
        jnp.where(mask, dist[src] + cost, big),
        jnp.where(mask, dist[dst] + cost, big)])
    e2 = 2 * e
    e_pad = -(-e2 // block_e) * block_e
    v_pad = -(-v // block_v) * block_v
    dstp = jnp.full((1, e_pad), jnp.int32(-1)).at[0, :e2].set(d_dst)
    candp = jnp.full((1, e_pad), big).at[0, :e2].set(d_cand)
    distp = jnp.full((1, v_pad), big).at[0, :v].set(dist)

    out = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid=(v_pad // block_v, e_pad // block_e),
        in_specs=[pl.BlockSpec((1, block_e), lambda i, j: (0, j)),
                  pl.BlockSpec((1, block_e), lambda i, j: (0, j)),
                  pl.BlockSpec((1, block_v), lambda i, j: (0, i))],
        out_specs=pl.BlockSpec((1, block_v), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, v_pad), dist.dtype),
        interpret=interpret,
    )(dstp, candp, distp)
    return out[0, :v]

"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp


def cumsum_lanes(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive cumsum along axis 0 of a [S, K] array (any int/float dtype).

    Oracle for ``lane_cumsum`` — the DFEP step-1 rank hotspot (the segmented
    rank is this cumsum followed by a gather-subtract at segment starts).
    """
    return jnp.cumsum(x, axis=0)


def kreduce_min(state: jnp.ndarray, member: jnp.ndarray) -> jnp.ndarray:
    """Masked min over axis 0: [K, V] x [K, V] bool -> [V].

    Oracle for ``frontier_min`` — the ETSCH aggregation phase (reconcile
    frontier-vertex replicas with a min reduce).
    """
    big = jnp.asarray(jnp.inf, state.dtype)
    return jnp.min(jnp.where(member, state, big), axis=0)


def minplus_relax(dist: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                  mask: jnp.ndarray, cost: float = 1.0) -> jnp.ndarray:
    """One undirected min-plus relaxation sweep: for each edge (u, v),
    out[v] = min(out[v], dist[u]+cost) and out[u] = min(out[u], dist[v]+cost).

    Oracle for ``minplus_sweep`` — the ETSCH local-computation phase.
    dist [V] float; src/dst [E] int32; mask [E] bool.
    """
    big = jnp.asarray(jnp.inf, dist.dtype)
    cu = jnp.where(mask, dist[src] + cost, big)
    cv = jnp.where(mask, dist[dst] + cost, big)
    out = dist.at[dst].min(cu)
    out = out.at[src].min(cv)
    return out


def selective_scan_ref(x, dt, b, c, a, d_skip):
    """Sequential selective-scan oracle (same recurrence as ssm.py).

    x/dt [B,S,Di]; b/c [B,S,N]; a [Di,N]; d_skip [Di] -> y [B,S,Di].
    """
    import jax

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(-dt_t[:, :, None] * a[None])
        inject = (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        h = decay * h + inject
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1) + x_t * d_skip[None]
        return h, y_t

    bsz, s, d_in = x.shape
    h0 = jnp.zeros((bsz, d_in, a.shape[1]), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)

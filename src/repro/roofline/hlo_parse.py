"""A small but real post-optimization-HLO text parser for roofline terms.

``compiled.cost_analysis()`` visits every while-loop body exactly once, so
scanned layer stacks / flash-scan loops are undercounted; and it reports no
collective traffic at all. This parser recovers:

  * matmul FLOPs        — every ``dot`` op: 2 · |out| · K, K from the lhs
    contracting dims, multiplied through nested while-loop trip counts;
  * elementwise FLOPs   — the graph engine's executables contain *zero*
    ``dot`` ops (its compute is gather → segment-reduce → elementwise
    apply), so arithmetic elementwise ops count one flop per output
    element and reductions (``reduce`` / ``reduce-window`` / ``scatter``)
    one per *input* element, fusion bodies included;
  * HBM byte traffic    — Σ (operand + output bytes) of every instruction
    (an upper bound proxy for memory traffic: assumes no fusion reuse
    between instructions; fusions are single instructions so intra-fusion
    temporaries are correctly NOT counted);
  * collective bytes    — all-gather (output), all-reduce (2 × operand),
    reduce-scatter / all-to-all / collective-permute (operand), again
    trip-multiplied.

Loop trip counts come from XLA's ``known_trip_count`` backend config, with
the largest s32 constant in the loop's condition computation as fallback
(canonical form: ``compare(iv, constant(N)), direction=LT``).  For the
engine's data-dependent fixpoint loops the recovered trips are the loop
*caps* (worst case); ``analyze_hlo(..., trip_clamp=1)`` clamps every loop
to one trip, yielding a *per-sweep* cost that callers scale by measured
superstep/local-iteration counts (``repro.obs.profile`` does exactly
that).

Robustness contract: profiling must never break a compile.  Instructions
whose opcode the model does not know — and instructions whose text this
parser chokes on — degrade into the counted ``unmodeled_ops`` field of
``HloCosts`` instead of raising mid-analysis.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# `%name = TYPE op-name(...)` — TYPE may be a tuple; layout {..} may follow
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->\s*(.*?)\s*\{\s*$")


def _dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _TYPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((dt, dims))
    return out


def tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    args: str


@dataclasses.dataclass
class Computation:
    name: str
    entry: bool
    instrs: list
    sym: dict          # instr name -> type_str (incl. parameters)


def parse(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(2), bool(m.group(1)), [], {})
            comps[cur.name] = cur
            # parameter types from the signature
            for pm in re.finditer(r"%?([\w\.\-]+)\s*:\s*"
                                  r"(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)",
                                  m.group(3)):
                cur.sym[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3), im.group(4))
            cur.instrs.append(ins)
            cur.sym[ins.name] = ins.type_str
    return comps


def _callees(ins: Instr) -> list[str]:
    out = []
    for key in ("to_apply=", "body=", "condition=", "calls="):
        for m in re.finditer(key + r"%?([\w\.\-]+)", ins.args):
            out.append(m.group(1))
    m = re.search(r"called_computations=\{([^}]*)\}", ins.args)
    if m:
        out.extend(c.strip().lstrip("%") for c in m.group(1).split(","))
    return out


def _loop_trips(comps: dict) -> dict:
    """body computation name -> trip count.

    Primary source: XLA's own ``backend_config={"known_trip_count":{"n":N}}``
    on the while instruction. Fallback: the largest s32 constant in the
    loop's condition computation."""
    trips: dict[str, int] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op != "while":
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", ins.args)
            body = mb.group(1) if mb else None
            trip = 0
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.args)
            if mt:
                trip = int(mt.group(1))
            if trip <= 0:
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.args)
                cond = mc.group(1) if mc else None
                if cond and cond in comps:
                    consts = [int(m.group(1)) for ci in comps[cond].instrs
                              for m in [re.search(r"constant\((\d+)\)",
                                                  ci.args + " " + ci.type_str)]
                              if m]
                    trip = max(consts) if consts else 1
            if body:
                trips[body] = max(trip, 1)
    return trips


def _first_operands(ins: Instr, sym: dict, n: int = 2) -> list[str]:
    """Types of the first n operands (by %name lookup)."""
    depth = 0
    args = []
    cur = ""
    for ch in ins.args:
        # commas inside shapes/layouts (f32[128,128]{1,0}) are not separators
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                break
            depth = max(depth - 1, 0)
        if ch == "," and depth == 0:
            args.append(cur)
            cur = ""
        else:
            cur += ch
    if cur:
        args.append(cur)
    types = []
    for a in args[:n]:
        m = re.search(r"%?([\w\.\-]+)\s*$", a.strip())
        if m and m.group(1) in sym:
            types.append(sym[m.group(1)])
        else:
            # inline-typed operand e.g. "f32[8,16]{1,0} %x"
            tm = _TYPE_RE.search(a)
            types.append(tm.group(0) if tm else "")
    return types


def _dot_flops(ins: Instr, sym: dict) -> float:
    out_elems = 1
    dims_list = _dims(ins.type_str)
    if not dims_list:
        return 0.0
    for d in dims_list[0][1]:
        out_elems *= d
    lhs_types = _first_operands(ins, sym, 1)
    if not lhs_types or not lhs_types[0]:
        return 0.0
    lhs_dims = _dims(lhs_types[0])
    if not lhs_dims:
        return 0.0
    ldims = lhs_dims[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.args)
    k = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(ldims):
                k *= ldims[idx]
    return 2.0 * out_elems * k


_COLL_OPS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute", "all-gather-start", "all-reduce-start",
             "collective-permute-start", "all-to-all-start",
             "reduce-scatter-start"}

# Arithmetic elementwise ops: one flop per OUTPUT element.  This is the
# whole compute model for the graph engine's executables (gather →
# segment-reduce → apply lowers to compare/select/min/add chains — no dot
# ops anywhere), observed by opcode census of the compiled SSSP/PageRank
# superstep loops.
_EW_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "remainder", "power",
    "maximum", "minimum", "compare", "select", "clamp", "and", "or",
    "xor", "not", "negate", "abs", "sign", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "sqrt", "rsqrt",
    "cbrt", "tanh", "logistic", "sine", "cosine", "tan", "atan2",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "is-finite", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "popcnt", "count-leading-zeros",
}
# Reductions: one flop per INPUT element of the reduced operand (each
# input element passes through the combiner once, to first order).
_REDUCE_FLOP_OPS = {"reduce", "reduce-window", "scatter",
                    "select-and-scatter"}
# Known zero-flop ops: data movement, layout, and control structure.  The
# bytes proxy still charges their traffic; they are *modeled*, just free.
_MOVEMENT_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "broadcast", "copy", "copy-start", "copy-done",
    "reshape", "transpose", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "gather", "iota", "convert",
    "pad", "reverse", "rng", "rng-bit-generator", "while", "conditional",
    "call", "fusion", "map", "sort", "after-all", "partition-id",
    "replica-id", "domain", "optimization-barrier", "add-dependency",
    "get-dimension-size", "real", "imag", "complex", "send", "send-done",
    "recv", "recv-done", "infeed", "outfeed", "all-reduce-done",
    "all-gather-done", "collective-permute-done", "all-to-all-done",
    "reduce-scatter-done",
}


def tensor_elems(type_str: str) -> int:
    total = 0
    for _, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _elementwise_flops(ins: Instr, sym: dict) -> float:
    """Non-dot flop model: |out| for arithmetic elementwise ops, |input|
    for reductions, 0 for known movement/structure.  Raises KeyError for
    an opcode it does not know — the caller counts it as unmodeled."""
    if ins.op in _EW_FLOP_OPS:
        return float(tensor_elems(ins.type_str))
    if ins.op in _REDUCE_FLOP_OPS:
        ops = _first_operands(ins, sym, 1)
        return float(tensor_elems(ops[0])) if ops and ops[0] else 0.0
    if ins.op in _MOVEMENT_OPS or ins.op == "dot" or ins.op in _COLL_OPS \
            or ins.op.endswith("-done"):
        return 0.0
    raise KeyError(ins.op)


def _coll_bytes(ins: Instr, sym: dict) -> float:
    base = ins.op.replace("-start", "")
    out_b = tensor_bytes(ins.type_str)
    op_types = _first_operands(ins, sym, 4)
    in_b = sum(tensor_bytes(t) for t in op_types if t)
    if base == "all-gather":
        return float(out_b)
    if base == "all-reduce":
        return float(2 * in_b)
    return float(max(in_b, out_b) if base == "all-to-all" else in_b)


@dataclasses.dataclass
class HloCosts:
    flops: float
    bytes_traffic: float
    coll_bytes: float
    coll_breakdown: dict
    loop_trips: dict
    unmodeled_ops: int = 0          # instructions the flop model does not
                                    #   know (or whose text choked the
                                    #   parser), trip-multiplied — counted,
                                    #   never raised

    @property
    def arithmetic_intensity(self) -> float:
        """flops per HBM byte — the roofline x-axis."""
        return self.flops / max(self.bytes_traffic, 1.0)


def analyze_hlo(hlo: str, trip_clamp: int | None = None) -> HloCosts:
    """Walk the computation graph and accumulate roofline terms.

    ``trip_clamp`` clamps every while-loop trip count (the recovered trips
    are loop *caps* for data-dependent fixpoint loops); ``trip_clamp=1``
    yields the cost of one sweep through every loop body, which callers
    scale by measured iteration counts."""
    comps = parse(hlo)
    trips = _loop_trips(comps)
    if trip_clamp is not None:
        trips = {k: min(v, max(int(trip_clamp), 1)) for k, v in trips.items()}

    memo: dict[str, tuple] = {}

    def walk(name: str) -> tuple:
        if name in memo:
            return memo[name]
        memo[name] = (0.0, 0.0, 0.0, defaultdict(float), 0)  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        fl = by = cb = 0.0
        unmod = 0
        breakdown: dict = defaultdict(float)
        for ins in comp.instrs:
            # a single opaque instruction must degrade, not abort: the
            # analyzer runs against whatever HLO the compiler emitted
            try:
                if ins.op == "dot":
                    fl += _dot_flops(ins, comp.sym)
                else:
                    fl += _elementwise_flops(ins, comp.sym)
                if ins.op in _COLL_OPS and not ins.op.endswith("-done"):
                    b = _coll_bytes(ins, comp.sym)
                    cb += b
                    breakdown[ins.op.replace("-start", "")] += b
                # bytes proxy: operands + output of every instruction
                if ins.op not in ("parameter", "constant", "tuple",
                                  "get-tuple-element", "bitcast"):
                    by += tensor_bytes(ins.type_str)
                    for t in _first_operands(ins, comp.sym, 3):
                        by += tensor_bytes(t)
            except Exception:
                unmod += 1
            is_fusion = ins.op == "fusion"
            for callee in _callees(ins):
                cf, cby, ccb, cbrk, cum = walk(callee)
                mult = trips.get(callee, 1) if callee in trips else 1
                fl += cf * mult
                # fusion bodies execute in registers/VMEM: their internal
                # tensors are NOT HBM traffic (the fusion instruction's own
                # operands/output were already counted above)
                if not is_fusion:
                    by += cby * mult
                cb += ccb * mult
                unmod += cum * mult
                for k, v in cbrk.items():
                    breakdown[k] += v * mult
        memo[name] = (fl, by, cb, breakdown, unmod)
        return memo[name]

    entry = next((c.name for c in comps.values() if c.entry), None)
    if entry is None:
        return HloCosts(0.0, 0.0, 0.0, {}, trips, 0)
    fl, by, cb, brk, unmod = walk(entry)
    return HloCosts(fl, by, cb, dict(brk), trips, unmod)

"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch × shape × mesh) — EXPERIMENTS.md §Roofline:

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = collective_bytes / link_bw       (per chip link)

``compiled.cost_analysis()`` supplies flops/bytes **but visits every
while-loop body exactly once** — scanned layer stacks and flash-scan loops
would be undercounted. We therefore walk the HLO text, multiply each
while-body's ops by its static trip count (recovered from the loop-bound
constant in the condition computation), and sum collective operand bytes
the same way. MODEL_FLOPS (6·N·D analytic) is reported alongside as the
useful-compute yardstick.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

from ..launch import mesh as M


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device, loop-corrected
    bytes_hbm: float             # per device, loop-corrected
    coll_bytes: float            # per device, loop-corrected
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float    # 6·N·D (or analytic serve flops)
    useful_ratio: float          # model_flops_per_dev / hlo flops
    raw_cost_analysis: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _tensor_bytes(type_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[8,128,4096]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo(hlo_text: str) -> dict:
    """Walk HLO computations; per computation, collect collective operand
    bytes, dot/convolution FLOPs (approx from output+contraction — we rely
    on cost_analysis for flops instead), and while-loop trip counts.

    Returns {"coll_bytes_flat": bytes ignoring loops,
             "loops": [(body_name, trip_count)],
             "coll_by_comp": {comp: bytes}, "calls": {comp: [callee...]}}
    """
    comp_name = None
    coll_by_comp: dict[str, float] = {}
    calls: dict[str, list[str]] = {}
    loop_trips: dict[str, int] = {}          # body computation -> trip count
    const_ints: dict[str, int] = {}          # per-comp constants (loop bounds)
    comp_of_line: dict[str, str] = {}

    # pass 1: computations, collectives, calls
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(%?[\w\.\-]+)\s*(\([^)]*\))?\s*->.*{$", s)
        if s.endswith("{") and ("(" in s):
            name = s.split()[0].lstrip("%")
            comp_name = name
            coll_by_comp.setdefault(comp_name, 0.0)
            calls.setdefault(comp_name, [])
            continue
        if s == "}":
            continue
        if comp_name is None:
            continue
        # collective ops: count operand bytes (result side for all-gather)
        for op in _COLLECTIVES:
            if f" {op}(" in s or f"= {op}" in s.replace("-start", ""):
                # result type is at '= TYPE op(...)'
                mm = re.search(r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\s+" +
                               op.replace("-", r"\-"), s)
                if mm:
                    coll_by_comp[comp_name] = (coll_by_comp.get(comp_name, 0.0)
                                               + _tensor_bytes(mm.group(1)))
                break
        # nested calls: to_apply=, body=, condition=, branch_computations
        for key in ("to_apply=", "body=", "condition=", "called_computations="):
            for mm in re.finditer(key + r"%?([\w\.\-]+)", s):
                calls[comp_name].append(mm.group(1))
        # while loops: remember body name; trip count resolved in pass 2
        mm = re.search(r"while\(.*body=%?([\w\.\-]+)", s)
        if mm:
            loop_trips.setdefault(mm.group(1), -1)
        # constants (potential loop bounds)
        mm = re.search(r"=\s+s32\[\]\s+constant\((\d+)\)", s)
        if mm and comp_name:
            const_ints.setdefault(comp_name, 0)
            const_ints[comp_name] = max(const_ints[comp_name], int(mm.group(1)))

    # pass 2: resolve trip counts — take the max s32 constant in the loop's
    # condition computation (XLA emits `compare(iter, constant(N))`)
    cond_of_body: dict[str, str] = {}
    for line in hlo_text.splitlines():
        mm = re.search(r"while\(.*condition=%?([\w\.\-]+),.*body=%?([\w\.\-]+)",
                       line)
        if not mm:
            mm2 = re.search(
                r"while\(.*body=%?([\w\.\-]+),.*condition=%?([\w\.\-]+)", line)
            if mm2:
                cond_of_body[mm2.group(1)] = mm2.group(2)
            continue
        cond_of_body[mm.group(2)] = mm.group(1)
    for body, cond in cond_of_body.items():
        loop_trips[body] = max(const_ints.get(cond, 1), 1)

    return {"coll_by_comp": coll_by_comp, "calls": calls,
            "loops": loop_trips}


def _weight_of_comp(comp: str, parsed: dict, cache: dict) -> float:
    """Total collective bytes reachable from ``comp``, multiplying nested
    while bodies by their trip counts."""
    if comp in cache:
        return cache[comp]
    cache[comp] = 0.0  # cycle guard
    total = parsed["coll_by_comp"].get(comp, 0.0)
    for callee in parsed["calls"].get(comp, []):
        sub = _weight_of_comp(callee, parsed, cache)
        trip = parsed["loops"].get(callee, 0)
        total += sub * (trip if trip and trip > 0 else 1)
    cache[comp] = total
    return total


def collective_bytes(hlo_text: str) -> float:
    parsed = parse_hlo(hlo_text)
    roots = [c for c in parsed["coll_by_comp"]
             if c.startswith("main") or c == "main"]
    root = roots[0] if roots else next(iter(parsed["coll_by_comp"]), None)
    if root is None:
        return 0.0
    return _weight_of_comp(root, parsed, {})


def loop_corrected_costs(hlo_text: str, cost: dict) -> tuple[float, float]:
    """Approximate loop correction for cost_analysis flops/bytes: scale them
    by (Σ body_ops × trips) / (Σ body_ops) using op counts per computation
    as the weight proxy. Conservative but catches the scan-over-layers
    factor exactly when the loop body dominates (it does here)."""
    parsed = parse_hlo(hlo_text)
    # count "heavy" ops (dot/convolution/cumsum-scatter) per computation
    weights: dict[str, float] = {}
    comp = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "(" in s:
            comp = s.split()[0].lstrip("%")
            weights.setdefault(comp, 0.0)
            continue
        if comp is None:
            continue
        if " dot(" in s or " convolution(" in s:
            mm = re.search(r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\s", s)
            if mm:
                weights[comp] += _tensor_bytes(mm.group(1))

    def reach(comp, cache):
        if comp in cache:
            return cache[comp]
        cache[comp] = 0.0
        total = weights.get(comp, 0.0)
        for callee in parsed["calls"].get(comp, []):
            sub = reach(callee, cache)
            trip = parsed["loops"].get(callee, 0)
            total += sub * (trip if trip and trip > 0 else 1)
        cache[comp] = total
        return total

    flat = sum(weights.values())
    roots = [c for c in weights if c.startswith("main")]
    root = roots[0] if roots else None
    if root is None or flat <= 0:
        return cost.get("flops", 0.0), cost.get("bytes accessed", 0.0)
    corrected = reach(root, {})
    factor = max(corrected / flat, 1.0)
    return (cost.get("flops", 0.0) * factor,
            cost.get("bytes accessed", 0.0) * factor)


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for the whole step (all chips)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = shape.global_batch
    flops = 2.0 * n_active * tokens
    # attention reads over cache: 2·2·S·(kv heads·dh)·layers per sequence
    kv_bytes_flops = 0.0
    for li in range(cfg.n_layers):
        kind = cfg.layer_pattern[li % len(cfg.layer_pattern)]
        if kind == "ssm":
            continue
        if cfg.mla is not None:
            width = cfg.mla.kv_lora
            heads = cfg.n_heads
            kv_bytes_flops += 2 * 2 * shape.seq_len * width * heads
        else:
            kv_bytes_flops += (2 * 2 * shape.seq_len
                               * cfg.n_kv * cfg.head_dim
                               * (cfg.n_heads // cfg.n_kv))
    return flops + kv_bytes_flops * tokens


def analyze(hlo_text: str, cost: dict, cfg, shape, n_chips: int) -> Roofline:
    from .hlo_parse import analyze_hlo
    h = analyze_hlo(hlo_text)
    flops = h.flops
    # memory term: prefer cost_analysis 'bytes accessed' corrected by the
    # parser's loop-aware proxy ratio (cost_analysis visits loop bodies once)
    cost_bytes = float(cost.get("bytes accessed", 0.0))
    hbm = max(h.bytes_traffic, cost_bytes)
    coll = h.coll_bytes
    compute_s = flops / M.PEAK_FLOPS_BF16
    memory_s = hbm / M.HBM_BW
    coll_s = coll / M.ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    useful = (mf / n_chips) / flops if flops else 0.0
    r = Roofline(flops=flops, bytes_hbm=hbm, coll_bytes=coll,
                 compute_s=compute_s, memory_s=memory_s,
                 collective_s=coll_s, dominant=dom,
                 model_flops_global=mf, useful_ratio=useful,
                 raw_cost_analysis={k: float(v) for k, v in cost.items()
                                    if isinstance(v, (int, float))})
    r.raw_cost_analysis["coll_breakdown"] = {k: float(v)
                                             for k, v in h.coll_breakdown.items()}
    return r

"""Render the §Dry-run / §Roofline tables for EXPERIMENTS.md from the
per-cell JSON records written by repro.launch.dryrun.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs: list[dict], mesh: str) -> str:
    lines = ["| arch | shape | status | params | per-dev bytes (arg+tmp) | "
             "compile s |",
             "|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "ok":
            ma = r.get("memory_analysis", {})
            dev_bytes = (ma.get("argument_size_in_bytes", 0)
                         + ma.get("temp_size_in_bytes", 0))
            lines.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{r.get('params', 0)/1e9:.2f}B | {fmt_bytes(dev_bytes)} | "
                f"{r.get('compile_s', '?')} |")
        elif r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — |")
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL_FLOPS | useful ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh or r["status"] != "ok":
            continue
        ro = r["roofline"]
        bound = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        # roofline fraction: useful-compute time vs the binding term
        useful_s = (ro["model_flops_global"] / r["chips"]) / 197e12
        frac = useful_s / bound if bound else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"{ro['dominant']} | {ro['model_flops_global']:.2e} | "
            f"{ro['useful_ratio']:.2f} | {frac:.3f} |")
    return "\n".join(lines)


def pick_hillclimb(recs: list[dict]) -> list[dict]:
    """Worst roofline fraction, most collective-bound, most MoE/EP-relevant."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"]

    def frac(r):
        ro = r["roofline"]
        bound = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        return ((ro["model_flops_global"] / r["chips"]) / 197e12) / bound

    picks: list[dict] = []

    def add(r):
        if all(p["arch"] != r["arch"] or p["shape"] != r["shape"]
               for p in picks):
            picks.append(r)

    add(max(ok, key=lambda r: r["roofline"]["collective_s"]
            / max(r["roofline"]["compute_s"], 1e-9)))
    for r in sorted(ok, key=frac):
        if len(picks) < 2:
            add(r)
    for r in sorted((r for r in ok if "moe" in r["arch"]
                     or "deepseek" in r["arch"] or "jamba" in r["arch"]),
                    key=lambda r: -r["roofline"]["model_flops_global"]):
        if len(picks) < 3:
            add(r)
    return picks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run (16x16, 256 chips)\n")
    print(dryrun_table(recs, "16x16"))
    print("\n## Dry-run (2x16x16, 512 chips)\n")
    print(dryrun_table(recs, "2x16x16"))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs))
    print("\n## Hillclimb candidates\n")
    for r in pick_hillclimb(recs):
        print(f"- {r['arch']} × {r['shape']} (dominant: "
              f"{r['roofline']['dominant']})")


if __name__ == "__main__":
    main()

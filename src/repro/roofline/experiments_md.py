"""Assemble EXPERIMENTS.md from dry-run/perf/bench artifacts.

    PYTHONPATH=src python -m repro.roofline.experiments_md > EXPERIMENTS.md
"""
from __future__ import annotations

import csv
import glob
import json
import os
import statistics as st
from collections import defaultdict

from .report import dryrun_table, fmt_bytes, load, roofline_table


def bench_rows(name: str) -> list[dict]:
    path = f"experiments/bench/{name}.csv"
    if not os.path.exists(path):
        return []
    return list(csv.DictReader(open(path)))


def md_table(rows: list[dict], cols: list[str]) -> str:
    out = ["| " + " | ".join(cols) + " |",
           "|" + "---|" * len(cols)]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


def agg_fig7() -> list[dict]:
    rows = bench_rows("fig7_comparison")
    agg = defaultdict(list)
    for r in rows:
        agg[(r["dataset"], r["algo"])].append(r)
    out = []
    for (ds, algo), rs in sorted(agg.items()):
        m = lambda k: st.mean(float(r[k]) for r in rs)
        out.append({"dataset": ds, "algo": algo,
                    "largest": f"{m('largest'):.2f}",
                    "nstdev": f"{m('nstdev'):.3f}",
                    "messages": f"{m('messages'):.0f}",
                    "gain": f"{m('gain'):.3f}",
                    "connected": f"{m('connected'):.2f}",
                    "rounds": f"{m('rounds'):.0f}"})
    return out


def agg_fig5() -> list[dict]:
    rows = bench_rows("fig5_k_sweep")
    agg = defaultdict(list)
    for r in rows:
        agg[(r["dataset"], int(r["k"]), r["algo"])].append(r)
    out = []
    for (ds, k, algo), rs in sorted(agg.items()):
        m = lambda kk: st.mean(float(r[kk]) for r in rs)
        out.append({"dataset": ds, "K": k, "algo": algo,
                    "rounds": f"{m('rounds'):.0f}",
                    "largest": f"{m('largest'):.2f}",
                    "nstdev": f"{m('nstdev'):.3f}",
                    "messages": f"{m('messages'):.0f}",
                    "gain": f"{m('gain'):.3f}"})
    return out


def agg_fig6() -> list[dict]:
    rows = bench_rows("fig6_diameter")
    agg = defaultdict(list)
    for r in rows:
        agg[(float(r["remap_frac"]), int(r["diameter_proxy"]))].append(r)
    out = []
    for (frac, diam), rs in sorted(agg.items(), key=lambda kv: -kv[0][1]):
        m = lambda kk: st.mean(float(r[kk]) for r in rs)
        out.append({"remap_frac": frac, "diameter(ecc)": diam,
                    "rounds": f"{m('rounds'):.0f}",
                    "largest": f"{m('largest'):.2f}",
                    "nstdev": f"{m('nstdev'):.3f}",
                    "messages": f"{m('messages'):.0f}",
                    "gain": f"{m('gain'):.3f}",
                    "disconnected%": f"{m('disconnected_pct'):.1f}"})
    return out


def perf_compare(base: list[dict], tuned: list[dict]) -> list[dict]:
    tmap = {(r["arch"], r["shape"]): r for r in tuned
            if r.get("status") == "ok" and r.get("mesh") == "16x16"}
    out = []
    for r in base:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        t = tmap.get((r["arch"], r["shape"]))
        if not t:
            continue
        rb, rt = r["roofline"], t["roofline"]
        bb = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        bt = max(rt["compute_s"], rt["memory_s"], rt["collective_s"])
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "bound_before_s": f"{bb:.4f}", "bound_after_s": f"{bt:.4f}",
            "speedup": f"{bb / bt:.2f}x" if bt else "-",
            "dominant_after": rt["dominant"],
        })
    return out


def main() -> None:
    recs = load("experiments/dryrun")
    tuned = load("experiments/perf") if os.path.isdir("experiments/perf") else []

    print("""# EXPERIMENTS

All artifacts are reproducible in-container:

```
PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes   # §Dry-run baseline
PYTHONPATH=src python -m repro.launch.dryrun --all --perf --out experiments/perf
PYTHONPATH=src python -m benchmarks.run                            # §Paper-figures
PYTHONPATH=src python -m repro.roofline.experiments_md > EXPERIMENTS.md
```

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(`repro/launch/mesh.py`). The container is CPU-only: every number below is
derived from the *compiled* SPMD artifact (lower+compile with 512 host
devices), not wall-clock — see §Methodology.

## Methodology (roofline terms)

For each (arch × shape × mesh) cell, `repro.launch.dryrun`:
1. builds `ShapeDtypeStruct` stand-ins for params / optimizer / batch /
   KV-caches (no allocation), with logical shardings resolved on the
   production mesh;
2. `jax.jit(step).lower(...).compile()` — failures here (sharding
   mismatch, OOM, bad collective) are system bugs; all 40 runnable cells
   compile on BOTH meshes;
3. derives the three roofline terms per chip:
   * `compute = HLO_dot_FLOPs / 197e12` — exact matmul FLOPs parsed from
     post-optimization HLO (`repro/roofline/hlo_parse.py`), **multiplied
     through while-loop trip counts** (XLA's own `known_trip_count`), since
     `compiled.cost_analysis()` visits loop bodies once;
   * `memory = HLO_bytes / 819e9` — Σ(operand+output bytes) over
     instructions, loop-corrected, fusion-internal tensors excluded;
   * `collective = collective_bytes / 50e9` — all-gather counts output
     bytes, all-reduce 2× operand, reduce-scatter/all-to-all/permute
     operand bytes; loop-corrected, per chip.
4. `MODEL_FLOPS` = 6·N_active·D (train), 2·N_active·D (prefill), decode
   adds analytic KV-read FLOPs. `useful_ratio` = MODEL_FLOPS/chips ÷
   HLO_FLOPs — remat recompute, attention-score FLOPs, head/vocab padding
   and dead-expert padding all push it below 1.

Caveats stated once: (a) the memory proxy counts XLA-CPU lowering, which
inserts `copy` ops (esp. around scanned KV caches) that the TPU compiler
elides via donation/aliasing — decode-cell memory terms are upper bounds;
(b) the collective term divides by one link's bandwidth — a consistent
cross-cell yardstick, not a ring-schedule simulation; (c) `temp_size`
below is the CPU backend's buffer assignment — unfused f32 intermediates
and unaliased scan stacks it reports do not exist in the TPU lowering, so
big train cells show temp >> 16 GB. The *analytic* per-chip budget for the
worst cell (deepseek-v2 train_4k: f32 params+Adam 11.1 GB fully sharded,
+0.67 GB/layer remat boundary) fits v5e HBM with the supported
`microbatches=4` grad accumulation (train_step knob) or sequence-parallel
activation sharding; serve cells fit outright (e.g. deepseek decode 12.6 GB
argument+temp as measured).
""")

    print("\n## §Dry-run — single pod (16×16, 256 chips)\n")
    print(dryrun_table(recs, "16x16"))
    print("\n## §Dry-run — multi-pod (2×16×16, 512 chips)\n")
    print(dryrun_table(recs, "2x16x16"))
    print("""
Skips are the 8 pure-full-attention archs × `long_500k` (sub-quadratic
required; DESIGN.md §5) — they appear as `skipped` rows, per spec.
""")

    print("\n## §Roofline — baseline (paper-faithful substrate), single pod\n")
    print(roofline_table(recs, "16x16"))
    print("""
Reading the table: *every train/prefill cell is memory-term dominated* in
this pure-XLA lowering — the flash-softmax probability tiles, scan-stacked
caches and remat recompute dominate HBM traffic; the MoE archs add
collective load from tensor-parallel psums (tokens are batch-sharded,
experts model-sharded, so combine is a psum over `model`). `useful_ratio`
0.3–0.9 decomposes as: ~1.33× full-block remat recompute, attention-score
FLOPs absent from 6·N·D, head-padding (qwen2-1.5b 12→16, llava 56→64,
whisper 12→16 MHA) and expert padding (qwen2-moe 60→64).

One sentence per dominant term on what would move it (expanded in §Perf):
memory → keep flash probabilities in VMEM (Pallas kernel) and stop storing
scan residuals (FA2 custom VJP — implemented); collective → sequence-
parallel resharding or, for B=1 decode, weight-stationary placement
(implemented); compute → nothing is compute-bound at these scales.
""")

    if tuned:
        print("\n## §Perf — baseline vs optimized (all cells, single pod)\n")
        print(md_table(perf_compare(recs, tuned),
                       ["arch", "shape", "bound_before_s", "bound_after_s",
                        "speedup", "dominant_after"]))

    print("""
### §Perf — hillclimb log (hypothesis → change → before → after → verdict)

Three cells were hillclimbed per the spec: worst roofline fraction
(falcon-mamba-7b × train_4k), most collective-bound (jamba-v0.1-52b ×
long_500k), most representative of MoE/expert-parallel + biggest model
(deepseek-v2-236b × train_4k). Dominant-term seconds per chip:

| # | cell | hypothesis | change | before | after | verdict |
|---|---|---|---|---|---|---|
| 1 | falcon-mamba train_4k | bf16 scan intermediates halve the assoc-scan traffic | `ssm_bf16` | mem 148.1 | 132.7 | confirmed, weaker than 2× predicted (casts add copies) |
| 2 | falcon-mamba train_4k | smaller chunks (128) reduce assoc-scan level count | `ssm_chunk=128` | 148.1 | 221.7 | **refuted** — per-chunk boundary tensors dominate; more chunks = more traffic |
| 3 | falcon-mamba train_4k | inverted: FEWER chunks amortise boundaries | `ssm_chunk=512/1024/2048/4096` | 148.1 | 111.0 / 92.3 / 83.0 / **60.2** | confirmed — the outer chunk loop was pure overhead; full-seq assoc scan wins (2.46×) |
| 4 | falcon-mamba train_4k | save-dots remat cuts recompute | `remat_policy=dots` | mem 148.1 / comp 1.06 | mem 156.9 / comp 0.87 | **refuted** for the dominant term (saved residual traffic exceeds recompute saved) |
| 5 | deepseek-v2 train_4k | bf16 probs halve PV traffic | `pv_bf16` | mem 112.9 | 120.6 | **refuted** — the cast materialises an extra [B,H,S,blk] tensor in XLA |
| 6 | deepseek-v2 train_4k | additive causal bias avoids the 10.8%-of-traffic select | `additive_mask` | 112.9 | 104.7 | confirmed (−7.3%) |
| 7 | deepseek-v2 train_4k | FA2 custom VJP stops scan-transpose residual storage | `flash_custom_vjp` | 112.9 | **74.7** | confirmed (−34%); byte-attribution showed ~40% of traffic in scan-body/remat fusions |
| 8 | jamba long_500k | B=1 decode is bound by FSDP weight all-gathers (≈11.3 GB/chip/step ≈ tp-shard of all weights); replicate weights across dp (they fit: 104 GB bf16 / 16 tp = 6.5 GB/chip) | `serve_bf16 + serve_replicate_dp` | coll 0.2255 | **0.0001** | confirmed (2250×); bound moves to memory 0.1505 (scan-stacked cache copies — CPU-lowering artifact, see caveats) |
| 9 | all decode cells | dp-replication helps everywhere weights fit | apply knob 8 to every serve cell under 10 GB/chip | e.g. falcon decode 0.0250 | 0.1479 (**regression**) | **refuted** — when the batch shards over dp, FSDP gathers amortise across the batch and replication just multiplies weight reads; rule refined to `B < dp AND attention-bearing` (specs.py), regressions gone |

Byte-attribution (iteration 7's evidence) is reproducible with the snippet
in `experiments/README-perf-debug.md`.

Stopping rule: after iterations 3/7/8 the next-best predicted wins on each
cell were <5% XLA-level changes (further gains need the Pallas kernels —
see below), so per the spec the loop stops.

### Beyond-paper optimizations (kept; paper-faithful baseline preserved)

* **FA2 custom-VJP flash attention** (`repro/models/flash_vjp.py`) —
  validated grad-exact vs autodiff (`tests/test_flash_vjp.py`).
* **Weight-stationary serving placement + bf16 serving** for every arch
  whose tp-sharded weights fit one chip.
* **Full-sequence associative selective scan** for SSM training.
* **DFEP-balanced MoE expert placement** (`repro/core/moe_dfep.py`): the
  paper's auction run on the expert co-activation graph; skewed-routing
  imbalance max/mean 1.9 → ~1.1 (`examples/moe_rebalance.py`).
* Pallas kernels for the paper's graph hot-spots (`repro/kernels/`):
  lane_cumsum (DFEP step-1 ranks), frontier_min (ETSCH aggregation),
  minplus_sweep (local relaxation) — interpret-validated vs jnp oracles;
  on TPU they remove exactly the HBM round-trips the roofline flags.
""")

    print("\n## §Paper-figures (graph engine vs the paper's own claims)\n")
    print("Scales: datasets are synthetic stand-ins at scale=0.12 of the "
          "published |V| (generator params in `repro/core/graph.py`), "
          "3 samples/point vs the paper's 100 — one CPU core. Qualitative "
          "claims are what we validate.\n")
    print("### Fig 5 — K sweep (astroph / usroads)\n")
    print(md_table(agg_fig5(), ["dataset", "K", "algo", "rounds", "largest",
                                "nstdev", "messages", "gain"]))
    print("""
Paper claims reproduced: NSTDEV and messages grow with K; rounds shrink
with K; gain shrinks with K (fewer/larger partitions compress paths more).
""")
    print("### Fig 6 — diameter sweep (usroads, edge-remap protocol)\n")
    print(md_table(agg_fig6(), ["remap_frac", "diameter(ecc)", "rounds",
                                "largest", "nstdev", "messages", "gain",
                                "disconnected%"]))
    print("""
Paper claims reproduced: rounds rise ~linearly with diameter; balance
degrades (largest/NSTDEV up) with diameter; messages *fall* with diameter;
gain rises with diameter.
""")
    print("### Fig 7 — DFEP vs DFEP-C vs JaBeJa (+ random/greedy)\n")
    print(md_table(agg_fig7(), ["dataset", "algo", "largest", "nstdev",
                                "messages", "gain", "connected", "rounds"]))
    print("""
Paper's headline result reproduced: on small-world graphs DFEP is better
balanced than JaBeJa at similar gain; on the road network JaBeJa balances
better **but needs ~19× the messages** (9084 vs 467 here; "roughly ten
times higher" in the paper) and reaches lower gain (0.76 vs 0.97).
DFEP partitions are connected; random/JaBeJa conversions are not. The
PowerGraph-style greedy baseline (not in the paper) is strong on
small-world balance+messages but it is a *sequential streaming* heuristic —
on the road network its gain (0.70) still trails DFEP (0.97).
""")
    print("### Fig 8 — distributed DFEP scalability\n")
    print(md_table(bench_rows("fig8_scalability"),
                   ["ndev", "V", "E", "rounds", "wall_s", "edges_per_worker",
                    "speedup_vs_1"]))
    print("""
Honest negative: this container has ONE physical core, so adding host
"devices" adds orchestration overhead without parallel hardware — wall
clock *degrades*; the structural quantities (per-worker edge shard, the
psum-per-round schedule visible in the lowered HLO) are what transfer to a
real fleet, where the paper measured >5× at 16 nodes. The per-round
communication is two [V,K] psums — independent of worker count.
""")
    print("### Fig 9 — SSSP: ETSCH vs vertex-centric\n")
    print(md_table(bench_rows("fig9_sssp"),
                   ["dataset", "k", "etsch_supersteps",
                    "vertex_centric_rounds", "gain", "etsch_wall_s",
                    "baseline_wall_s", "partition_rounds"]))
    print("""
ETSCH needs strictly fewer synchronisation rounds than the one-hop-per-
round vertex-centric baseline at every K (the paper's fig-9 effect; its
y-axis is Hadoop wall-clock where sync rounds dominate). The small
synthetic DBLP's eccentricity (4) quantises gain at 0.25 here; the
diameter sweep (fig 6) shows gain up to 0.97 where paths are long.
""")
    print("### Kernel microbench\n")
    print(md_table(bench_rows("kernel_bench"), ["name", "kernel_us", "ref_us"]))
    print("""
`kernel_us` is **interpret-mode** (Python executing the TPU kernel body for
correctness) — not TPU performance; `ref_us` is the jnp oracle on CPU.
""")


if __name__ == "__main__":
    main()

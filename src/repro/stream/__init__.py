"""repro.stream — streaming graph subsystem: incremental DFEP maintenance
and engine plan patching for a live (mutating) edge set.

Pipeline: StreamingGraph chunked ingest → online HDRF assignment seeded
from DFEP owner state → in-place PartitionPlan patching (jit caches stay
warm) → drift-triggered bounded local re-auction (DFEP steps 1–2 on the
h-hop region).  See src/repro/stream/README.md for the design note.
"""
from .assign import hdrf_assign, seed_state
from .ingest import ApplyResult, StreamingGraph, iter_chunks
from .patch import EdgeChange, SlackExhausted, patch_plan
from .policy import (AdaptiveCompactionPolicy, CompactionPolicy,
                     ReactiveCompactionPolicy)
from .reauction import h_hop_vertices, local_reauction
from .session import StreamConfig, StreamSession

__all__ = [
    "AdaptiveCompactionPolicy", "ApplyResult", "CompactionPolicy",
    "EdgeChange", "ReactiveCompactionPolicy", "SlackExhausted",
    "StreamConfig", "StreamSession", "StreamingGraph", "h_hop_vertices",
    "hdrf_assign", "iter_chunks", "local_reauction", "patch_plan",
    "seed_state",
]

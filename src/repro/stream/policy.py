"""Compaction policies: when the streaming session compacts + how much
slack it reserves.

The session's default behaviour is **reactive**: it compacts only when
forced — an insert batch finds the graph out of spare padded slots, or a
partition's reserved slack is exhausted mid-patch (``SlackExhausted``).
Either way the recompile (and the jit retrace behind it) lands *inside*
the update burst that triggered it, exactly where latency hurts most.

``CompactionPolicy`` makes that decision pluggable.  The session feeds
the policy its update telemetry (``on_apply``), asks it during idle gaps
whether to compact proactively (``should_compact`` — driven by
``StreamSession.idle_tick()``), and consults it for slack sizing on every
recompile (``recommend_slack``).

``AdaptiveCompactionPolicy`` closes the loop through the observability
layer: it forwards each apply into a ``repro.obs.Monitor``'s stream
telemetry (``observe_update_batch``) and reads back the observed update
rate, slack-burn rate and peak per-batch slack consumption.  From those
it (a) triggers compaction during idle gaps whenever the remaining
graph-slot or partition-slack headroom could not absorb
``headroom_batches`` more bursts of the observed peak magnitude, and
(b) recommends per-partition edge slack sized to the same burst headroom
— so the forced recompile either never happens or is paid in the idle
gap instead of mid-burst.  ``benchmarks/fig_stream.py`` measures the two
policies head-to-head on a bursty workload (apply-latency p99 + forced
recompile count).
"""
from __future__ import annotations

import math

from ..obs.health import plan_health
from ..obs.monitor import Monitor


class CompactionPolicy:
    """Base policy = the session's historical reactive behaviour: never
    compact proactively, never override the config's slack sizing."""

    name = "reactive"

    def on_attach(self, session) -> None:
        """Called once when the session binds this policy."""

    def on_apply(self, session, n_updates: int, n_inserted: int,
                 dt_s: float) -> None:
        """Called after every ``apply()`` with the batch's total update
        count, its inserted-edge count (the slack it may have consumed)
        and its wall duration."""

    def on_compact(self, session) -> None:
        """Called after every compaction epoch (forced or idle)."""

    def should_compact(self, session) -> bool:
        """Consulted by ``session.idle_tick()``: compact now, in the idle
        gap, instead of waiting to be forced mid-burst?"""
        return False

    def recommend_slack(self, session) -> tuple[int | None, int | None]:
        """(edge_slack, vertex_slack) recommendation for the next compile;
        ``None`` keeps the session's default sizing for that axis."""
        return None, None


class ReactiveCompactionPolicy(CompactionPolicy):
    """Explicit name for the default: compaction only when forced."""


class AdaptiveCompactionPolicy(CompactionPolicy):
    """Telemetry-driven proactive compaction + slack sizing.

    ``monitor``: the ``repro.obs.Monitor`` to feed/read; omitted, the
    policy owns a private one.  ``headroom_batches``: how many bursts of
    the observed peak magnitude the session must be able to absorb
    without a forced recompile — the knob trading memory (bigger slack)
    against retraces.
    """

    name = "adaptive"

    def __init__(self, monitor: Monitor | None = None, *,
                 headroom_batches: float = 3.0):
        if headroom_batches <= 0:
            raise ValueError("headroom_batches must be > 0")
        self._owns_monitor = monitor is None
        self.monitor = Monitor() if monitor is None else monitor
        self.headroom_batches = float(headroom_batches)
        self._inserted_since_compact = 0

    def close(self) -> None:
        if self._owns_monitor:
            self.monitor.close()

    # -- telemetry feed ------------------------------------------------------
    def on_apply(self, session, n_updates: int, n_inserted: int,
                 dt_s: float) -> None:
        self.monitor.observe_update_batch(n_updates, n_inserted, dt_s)
        self._inserted_since_compact += int(n_inserted)

    def on_compact(self, session) -> None:
        self._inserted_since_compact = 0

    # -- control -------------------------------------------------------------
    def _headroom_edges(self) -> int:
        """Slot headroom the next bursts need: ``headroom_batches`` times
        the largest single-apply insert burst observed in the window."""
        return int(math.ceil(self.headroom_batches
                             * self.monitor.peak_batch_slack()))

    def should_compact(self, session) -> bool:
        if self._inserted_since_compact <= 0:
            return False          # nothing ingested: compaction buys nothing
        need = self._headroom_edges()
        if need <= 0:
            return False          # no telemetry yet: stay reactive
        free_graph = session.sg.free_slots()
        # partition slack is in CSR half-edge slots; one inserted edge can
        # put both its half-edges in the same partition, hence the 2x
        free_plan = plan_health(session.plan)["min_free_edge_slots"]
        return free_graph < need or free_plan < 2 * need

    def recommend_slack(self, session) -> tuple[int | None, int | None]:
        need = self._headroom_edges()
        return (need, None) if need > 0 else (None, None)

"""Online greedy assignment of arriving edges — HDRF-style heuristic.

New edges cannot wait for a full DFEP auction, so they are placed by the
streaming rule of Petroni et al.'s HDRF (the high-degree-replicated-first
scoring used by the streaming partitioners in PAPERS.md), *seeded from the
current DFEP owner state*: partition presence sets and sizes are initialised
from the edges DFEP already assigned, so arriving edges are attracted to the
partitions that already hold their endpoints and the DFEP territories grow
contiguously instead of being diluted by hash placement.

Score for edge (u, v) and partition p:

    C_rep(p) = g(u, p) + g(v, p),  g(x, p) = 1 + (1 - theta_x) if x ∈ A(p)
    C_bal(p) = lam * (maxsize - size_p) / (eps + maxsize - minsize)
    place at argmax C_rep + C_bal

where theta_x = d(x) / (d(u) + d(v)) uses the *partial* degrees seen so far,
so the lower-degree endpoint dominates the replica-affinity term (replicate
the high-degree vertex, keep the low-degree one intact — the HDRF insight
that bounds replication on power-law graphs).

The loop is sequential by construction (each placement updates the presence
sets the next decision reads); chunks are small and host-side numpy is the
honest cost model here, matching the greedy baseline in core/baselines.py.
"""
from __future__ import annotations

import numpy as np


def seed_state(u: np.ndarray, v: np.ndarray, owner: np.ndarray, n_vertices: int,
               k: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(presence [V, K], sizes [K], degrees [V]) from a live edge list with
    its current DFEP assignment (owner >= 0 for every live edge)."""
    presence = np.zeros((n_vertices, k), bool)
    presence[u, owner] = True
    presence[v, owner] = True
    sizes = np.bincount(owner, minlength=k).astype(np.int64)
    degrees = (np.bincount(u, minlength=n_vertices)
               + np.bincount(v, minlength=n_vertices)).astype(np.int64)
    return presence, sizes, degrees


def hdrf_assign(edges_u: np.ndarray, edges_v: np.ndarray,
                presence: np.ndarray, sizes: np.ndarray,
                degrees: np.ndarray, lam: float = 1.1,
                eps: float = 1.0) -> np.ndarray:
    """Assign each (u, v) in order; ``presence``/``sizes``/``degrees`` are
    updated in place so a session carries one state across chunks."""
    k = sizes.shape[0]
    out = np.empty(len(edges_u), np.int32)
    for m, (a, b) in enumerate(zip(edges_u.tolist(), edges_v.tolist())):
        degrees[a] += 1
        degrees[b] += 1
        theta_a = degrees[a] / (degrees[a] + degrees[b])
        c_rep = (presence[a] * (2.0 - theta_a)          # 1 + (1 - theta_a)
                 + presence[b] * (1.0 + theta_a))       # 1 + (1 - theta_b)
        mx = sizes.max()
        c_bal = lam * (mx - sizes) / (eps + mx - sizes.min())
        p = int(np.argmax(c_rep + c_bal))
        out[m] = p
        presence[a, p] = presence[b, p] = True
        sizes[p] += 1
    return out

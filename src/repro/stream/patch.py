"""Incremental ``PartitionPlan`` patching — the piece that keeps jit caches
warm across graph updates.

A compiled plan is a set of static-shape arrays; recompiling it on every
update batch would both redo the O(|E|) host compaction *and* hand jax a new
pytree, and the first query after each batch would pay a retrace.  Instead,
``patch_plan`` edits the plan arrays in place (numpy, then re-wrapped):

  * **deletion** — the edge's two half-edge slots have their ``emask`` bit
    cleared.  Masked slots are pinned to the combine identity inside
    ``segment_reduce`` (both the Pallas segmented-scan path and the scatter
    reference), so a cleared slot is inert for min and add alike — the CSR
    prefix keeps its sorted order with holes;
  * **insertion** — two half-edges are appended into the partition's slack
    region ``[csr_fill, e_max-1)``.  Appended slots are each their own
    segment (order-free), combined by masked scatter on top of the scanned
    prefix; freed slack slots are reused, freed *prefix* slots are not
    (reuse there would corrupt the sorted-run invariant);
  * **vertex arrival/departure** — arriving vertices claim a cleared or
    virgin ``vmask`` slot (its ``last_slot`` is pointed at the identity pad
    slot — the vertex's edges live only in slack); vertices whose last local
    edge disappeared have their ``vmask`` bit cleared;
  * the replica-exchange masks (``replicated`` / ``is_master``) and the
    per-partition counts are recomputed exactly — these are pytree
    *children*, so changing them does not retrace anything.

The patched plan has the identical treedef + avals as its parent (``epoch``
unchanged), so ``Engine`` superstep loops hit their existing compilation
cache — asserted by the TRACE_COUNTER test.  When a partition's slack runs
out, ``SlackExhausted`` tells the session to recompile (a compaction epoch).
"""
from __future__ import annotations

from typing import Iterable, NamedTuple

import numpy as np

import jax.numpy as jnp

from ..core.graph import edge_weights
from ..engine.plan import PartitionPlan, replica_masks


class SlackExhausted(RuntimeError):
    """A partition ran out of reserved CSR or vertex slack — recompile."""


class EdgeChange(NamedTuple):
    """One edge-level ownership delta. ``old == -1``: pure insert;
    ``new == -1``: pure delete; both >= 0: a re-auction move.

    ``slot`` is the edge's graph slot (StreamingGraph slot id) — the row
    external edge property channels are keyed by.  The session always
    provides it; callers constructing raw changes may leave the default
    -1, in which case the patched half-edges read the channel *fill*
    value instead of a feature row (plan.edge_slot stays -1 there).
    """
    u: int
    v: int
    old: int
    new: int
    slot: int = -1


def patch_plan(plan: PartitionPlan, changes: Iterable[EdgeChange]
               ) -> PartitionPlan:
    """Apply edge inserts/deletes/moves to a plan without recompiling.

    Raises SlackExhausted (leaving the input plan untouched) when any
    partition lacks slack; the caller falls back to compile_plan with a
    bumped epoch.
    """
    changes = [EdgeChange(*c) for c in changes]
    if not changes:
        return plan

    k, v_cap, e_cap = plan.k, plan.v_max, plan.e_max
    n_vertices = plan.n_vertices
    l2g = np.array(plan.local2global)
    vmask = np.array(plan.vmask)
    tgt = np.array(plan.edge_tgt)
    nbr = np.array(plan.edge_nbr)
    em = np.array(plan.emask)
    seg = np.array(plan.seg_start)
    last_slot = np.array(plan.last_slot)
    csr_fill = np.array(plan.csr_fill)
    v_fill = np.array(plan.v_fill)
    ew = np.array(plan.edge_w)
    eslot = np.array(plan.edge_slot)

    touched: set[int] = set()
    g2l: dict[int, np.ndarray] = {}
    edge_slots: dict[int, dict] = {}
    free_edge: dict[int, list] = {}
    free_vert: dict[int, list] = {}

    def _g2l(p: int) -> np.ndarray:
        if p not in g2l:
            a = np.full(n_vertices, -1, np.int64)
            used = np.flatnonzero(vmask[p])
            a[l2g[p, used]] = used
            g2l[p] = a
        return g2l[p]

    def _edge_slots(p: int) -> dict:
        if p not in edge_slots:
            d: dict = {}
            for s in np.flatnonzero(em[p]).tolist():
                a = int(l2g[p, tgt[p, s]])
                b = int(l2g[p, nbr[p, s]])
                d.setdefault((min(a, b), max(a, b)), []).append(s)
            edge_slots[p] = d
        return edge_slots[p]

    def _free_edge_slots(p: int) -> list:
        if p not in free_edge:
            # slack region only, excluding the guaranteed identity pad slot
            sl = np.flatnonzero(~em[p, csr_fill[p]:e_cap - 1]) + csr_fill[p]
            free_edge[p] = sl.tolist()[::-1]
        return free_edge[p]

    def _free_vert_slots(p: int) -> list:
        if p not in free_vert:
            free_vert[p] = np.flatnonzero(~vmask[p]).tolist()[::-1]
        return free_vert[p]

    # deletes first so a move's freed slack can be reused by later inserts
    for c in changes:
        if c.old < 0:
            continue
        p = c.old
        key = (min(c.u, c.v), max(c.u, c.v))   # global ids, like _edge_slots
        slots = _edge_slots(p).pop(key, None)
        if slots is None:
            raise KeyError(f"edge {key} not present in partition {p}")
        for s in slots:
            em[p, s] = False
        # freed slack slots become reusable: the free lists are built lazily
        # in the insert pass below, from the post-delete emask (deletes all
        # precede inserts, so no slot is ever listed twice)
        touched.add(p)  # presence is finalised by the degree sweep below

    for c in changes:
        if c.new < 0:
            continue
        p = c.new
        gl = _g2l(p)

        def ensure_vertex(x: int) -> int:
            if gl[x] >= 0:
                return int(gl[x])
            fv = _free_vert_slots(p)
            if not fv:
                raise SlackExhausted(f"partition {p}: no vertex slack")
            s = fv.pop()
            l2g[p, s] = x
            vmask[p, s] = True
            last_slot[p, s] = e_cap - 1   # edges live in slack; base agg
            gl[x] = s                     # is the identity pad slot
            v_fill[p] = max(v_fill[p], s + 1)
            return s

        fe = _free_edge_slots(p)
        if len(fe) < 2:
            raise SlackExhausted(f"partition {p}: no CSR slack")
        lu = ensure_vertex(int(c.u))
        lv = ensure_vertex(int(c.v))
        s0, s1 = fe.pop(), fe.pop()
        # same content hash compile_plan uses: patched == recompiled weights
        w_uv = float(edge_weights(np.asarray([c.u]), np.asarray([c.v]))[0])
        for s, t_, n_ in ((s0, lu, lv), (s1, lv, lu)):
            tgt[p, s] = t_
            nbr[p, s] = n_
            em[p, s] = True
            seg[p, s] = True              # every appended slot: own segment
            ew[p, s] = w_uv
            # scatter the inserted edge's graph slot so external edge
            # channel planes stay aligned: patched == recompiled layout
            eslot[p, s] = c.slot
        _edge_slots(p).setdefault((min(c.u, c.v), max(c.u, c.v)),
                                  []).extend([s0, s1])
        touched.add(p)

    # finalise touched partitions: vertex departures + exact counts
    n_local = np.array(plan.n_local)
    n_edges_local = np.array(plan.n_edges_local)
    for p in touched:
        deg = np.zeros(v_cap, np.int64)
        np.add.at(deg, tgt[p, em[p]], 1)
        vmask[p] &= deg > 0
        n_local[p] = int(vmask[p].sum())
        n_edges_local[p] = int(em[p].sum()) // 2

    replicated, is_master = replica_masks(l2g, vmask, n_vertices, k)

    return PartitionPlan(
        k=k, n_vertices=n_vertices, v_max=v_cap, e_max=e_cap,
        epoch=plan.epoch, e_slots=plan.e_slots,
        local2global=jnp.asarray(l2g), vmask=jnp.asarray(vmask),
        edge_tgt=jnp.asarray(tgt), edge_nbr=jnp.asarray(nbr),
        emask=jnp.asarray(em), seg_start=jnp.asarray(seg),
        last_slot=jnp.asarray(last_slot),
        replicated=jnp.asarray(replicated), is_master=jnp.asarray(is_master),
        n_local=jnp.asarray(n_local), n_edges_local=jnp.asarray(n_edges_local),
        n_replicated=jnp.asarray(replicated.sum(1).astype(np.int32)),
        csr_fill=jnp.asarray(csr_fill), v_fill=jnp.asarray(v_fill),
        edge_w=jnp.asarray(ew),
        edge_slot=jnp.asarray(eslot),
    )

"""StreamSession — the streaming subsystem's front door.

Owns the full pipeline state: a ``StreamingGraph`` (chunked slot-level
ingest), the slot-parallel DFEP ``owner`` array, the slack-compiled
``PartitionPlan``, and the ``Engine`` bound to it.  One ``apply()`` call
takes a batch of insertions + deletions and leaves the session queryable
again:

  1. updates are ingested chunk by chunk (``chunk_size`` fixed);
  2. arriving edges are placed online by the HDRF rule seeded from the
     current owner state (assign.py);
  3. the plan is *patched* in place (patch.py) — jit caches stay warm;
  4. if the replication factor has drifted past ``drift_threshold`` above
     its post-correction baseline, a bounded local re-auction
     (reauction.py) re-sells the h-hop region around touched vertices and
     the resulting moves are patched in too;
  5. only two events recompile: a partition exhausting its reserved slack,
     or the graph itself running out of spare padded slots (a compaction
     epoch — ``epoch`` bumps and the next query retraces once).

A pluggable ``CompactionPolicy`` (policy.py) decides *when* beyond the
forced cases: ``idle_tick()`` lets the policy compact proactively during
idle gaps, and ``recommend_slack`` lets it size the reserved slack from
observed update telemetry on every recompile — the adaptive policy moves
the retrace out of the burst and into the gap.

Engine results over the session plan stay exactly consistent with the
whole-graph oracles on ``session.graph()`` (tests/test_stream.py).
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Callable

import numpy as np

from .. import obs as _obs
from ..core import dfep
from ..engine import registry as _registry
from ..engine.plan import compile_plan
from ..engine.runtime import Engine
from . import assign, reauction
from .ingest import StreamingGraph, iter_chunks
from .patch import EdgeChange, SlackExhausted, patch_plan
from .policy import CompactionPolicy, ReactiveCompactionPolicy


@dataclasses.dataclass
class _BoundChannel:
    """One session-maintained property plane (see bind_channel)."""
    program: str
    param: str
    channel: str                      # "vertex" | "edge"
    features: int
    values: np.ndarray                # working copy, [V,F] or [e_pad,F]
    fill: Callable | None             # (u, v) -> feature row for inserts


# registry bindings are process-global (they resolve at QueryRequest
# construction), so two sessions maintaining the same (program, param)
# would silently clobber each other's planes. This ownership map turns
# that into a loud error: a session may only (re)bind a slot that is
# free, or that it already owns. A weakref.finalize per bind releases
# BOTH the slot and the registry binding when a session is dropped
# without unbind_channel — a garbage-collected maintainer must not leave
# its last (now unmaintained) plane silently live for normalize().
_BINDING_OWNERS: dict[tuple[str, str], "weakref.ref"] = {}


def _release_binding(key: tuple[str, str], ref, entry) -> None:
    """Session finalizer: drop the ownership slot and the registry binding
    iff they still belong to the dead session (identity-checked via the
    exact ref object — a successor's rebind installs a different ref and
    must survive this)."""
    if _BINDING_OWNERS.get(key) is ref:
        _BINDING_OWNERS.pop(key, None)
        entry.unbind_channel(key[1])


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    k: int
    chunk_size: int = 256
    edge_slack: int | None = None     # per-partition undirected-edge slack
    vertex_slack: int | None = None   # per-partition local-vertex slack
    drift_threshold: float = 0.10     # RF drift triggering local re-auction
    hops: int = 2                     # re-auction region radius
    reauction_max_rounds: int = 400
    compaction_headroom: float = 0.5
    hdrf_lambda: float = 1.1


class StreamSession:
    """Live-graph serving session: ingest updates, keep the partition and
    the compiled plan maintained, answer engine queries in between."""

    def __init__(self, g, cfg: StreamConfig, key: int = 0,
                 owner: np.ndarray | None = None,
                 policy: CompactionPolicy | None = None):
        self.cfg = cfg
        self.k = cfg.k
        self.policy = policy if policy is not None \
            else ReactiveCompactionPolicy()
        self.sg = StreamingGraph(g, chunk_size=cfg.chunk_size)
        if owner is None:
            owner, _ = dfep.partition(g, k=cfg.k, key=key)
        self.owner = np.asarray(owner).copy()          # [e_pad], -2 at pads
        self.touched = np.zeros(g.n_vertices, bool)
        self.epoch = 0
        self.n_ingested = 0
        self.n_patches = 0
        self.n_recompiles = 0
        self.n_forced_recompiles = 0   # recompiles paid mid-apply (slack or
                                       #   slot exhaustion) — what the
                                       #   adaptive policy tries to avoid
        self.n_idle_compactions = 0    # proactive compactions via idle_tick
        self.n_reauctions = 0
        # monotone plan-version token: bumps on EVERY installed plan (patch,
        # re-auction patch, or compaction recompile) — the serving layer's
        # epoch-change signal. ``epoch`` only tracks compactions (retraces).
        self.version = 0
        # what the most recent installed plan changed about the graph
        # *content* — the serving layer's warm-start lineage signal:
        # "insert_only" / "none" hops keep previous-epoch results valid as
        # relaxation upper bounds, "mixed" (any deletion) breaks the chain.
        self.last_change: dict = {"event": "init", "content_delta": "none",
                                  "inserts": 0, "deletes": 0, "moves": 0}
        self._subscribers: list[Callable[["StreamSession", str], None]] = []
        self._channels: dict[tuple[str, str], _BoundChannel] = {}
        self.policy.on_attach(self)
        self._compile()
        self.rf_base = self.plan.replication_factor()

    # -- epoch-change hooks (the serving layer subscribes) -------------------
    def subscribe(self, fn: Callable[["StreamSession", str], None]):
        """Register ``fn(session, event)`` to run after every installed plan
        change, with ``event`` in {"patch", "recompile"}. By the time the
        hook fires, ``self.plan`` / ``self.engine`` / ``self.version`` are
        the NEW state; the previous plan object is untouched (plans are
        immutable pytrees), so in-flight consumers of it keep draining
        against a consistent snapshot. Returns an unsubscribe callable."""
        self._subscribers.append(fn)

        def unsubscribe() -> None:
            if fn in self._subscribers:
                self._subscribers.remove(fn)
        return unsubscribe

    def _notify(self, event: str) -> None:
        self.version += 1
        rec = _obs.get()
        if rec.enabled:
            # stamp every installed plan mutation with the paper's health
            # gauges (replication factor, balance, slack remaining) — the
            # numbers the partitioning is judged on, live instead of
            # post-hoc; plan_health is memoized per plan instance
            health = _obs.plan_health(self.plan)
            rec.event("stream.plan_swap", event=event,
                      version=self.version, epoch=self.epoch,
                      content_delta=self.last_change.get("content_delta"),
                      inserts=self.last_change.get("inserts", 0),
                      deletes=self.last_change.get("deletes", 0),
                      moves=self.last_change.get("moves", 0), **health)
            for name, value in health.items():
                rec.gauge(f"stream.{name}", value)
        for fn in list(self._subscribers):
            fn(self, event)

    # -- plan lifecycle -----------------------------------------------------
    def _slack(self) -> tuple[int, int]:
        """Default slack is sized from the update granularity (a few chunks
        per partition) with a small |E|-proportional floor — enough for
        several patch batches between compactions without inflating the
        per-superstep scan over [K, e_max] at steady state.  When the
        config leaves an axis unset, the compaction policy may raise (never
        shrink) the default from observed update telemetry — slack sized to
        the measured burst instead of to a static guess."""
        e = max(self.sg.n_edges, 1)
        rec_edge, rec_vertex = self.policy.recommend_slack(self)
        edge_slack = self.cfg.edge_slack
        if edge_slack is None:
            edge_slack = max(2 * self.cfg.chunk_size, e // (4 * self.k))
            if rec_edge is not None:
                edge_slack = max(edge_slack, int(rec_edge))
        vertex_slack = self.cfg.vertex_slack
        if vertex_slack is None:
            vertex_slack = max(self.cfg.chunk_size,
                               self.sg.n_vertices // (2 * self.k))
            if rec_vertex is not None:
                vertex_slack = max(vertex_slack, int(rec_vertex))
        return int(edge_slack), int(vertex_slack)

    def _compile(self) -> None:
        g = self.sg.graph()
        edge_slack, vertex_slack = self._slack()
        self.plan = compile_plan(g, self.owner, self.k,
                                 edge_slack=edge_slack,
                                 vertex_slack=vertex_slack, epoch=self.epoch)
        self.engine = Engine(self.plan)

    @staticmethod
    def _delta_of(changes: list[EdgeChange]) -> dict:
        """Summarise the graph-content delta of a change batch. Re-auction
        moves (old >= 0 and new >= 0) relocate edges between partitions
        without touching content, so a move-only batch is "none"."""
        ins = sum(c.old < 0 for c in changes)
        dels = sum(c.new < 0 for c in changes)
        moves = len(changes) - ins - dels
        delta = "mixed" if dels else ("insert_only" if ins else "none")
        return {"content_delta": delta, "inserts": ins, "deletes": dels,
                "moves": moves}

    def _recompile(self, delta: dict | None = None,
                   reason: str = "forced") -> None:
        """Compaction epoch: full plan rebuild; the next query retraces.
        ``delta`` describes the content change the rebuild absorbs (a pure
        compaction changes no content).  ``reason`` is "forced" when the
        rebuild landed mid-apply (slack/slot exhaustion) and "idle" when a
        policy scheduled it into an idle gap."""
        self.epoch += 1
        self.n_recompiles += 1
        if reason == "forced":
            self.n_forced_recompiles += 1
        self._compile()
        self.last_change = {"event": "recompile",
                            **(delta or self._delta_of([]))}
        self.policy.on_compact(self)
        self._notify("recompile")

    # -- session-bound property channels ------------------------------------
    def bind_channel(self, program: str, param: str, values,
                     fill: Callable | None = None) -> None:
        """Bind an external property plane "once per epoch" and keep it
        valid across the session's own mutations.

        ``values``: ``[V, F]`` for vertex channels, ``[n<=e_pad, F]`` in
        graph edge-slot order for edge channels (zero-padded to e_pad
        here).  Edge planes are *maintained*: every inserted edge's row is
        scattered in (``fill(u, v)`` — default zeros) before the plan is
        patched, and a compaction remaps rows by the same slot gather the
        owner array uses.  After each maintenance step the plane is
        re-bound on the registry entry, so new queries pick up a fresh
        content digest — results computed from the old plane are never
        aliased with the new one.  Vertex planes need no maintenance
        (|V| is static); binding them here is pure convenience.
        """
        entry = _registry.get_program(program)
        spec = entry.spec(param)
        if spec.role != "channel":
            raise _registry.ChannelError(
                f"{program}.{param} has role={spec.role!r}, not 'channel' "
                "— only property channels can be bound")
        # validate EVERYTHING before touching the registry: a failed bind
        # must not leave a half-installed plane live for normalize()
        cv = spec.coerce(program, values)
        vals = np.array(cv.values, np.float32)        # mutable working copy
        if spec.channel == "edge":
            if vals.shape[0] > self.sg.e_pad:
                raise _registry.ChannelError(
                    f"{program}.{param}: edge plane has {vals.shape[0]} "
                    f"rows but the streaming graph holds {self.sg.e_pad} "
                    "edge slots")
            if vals.shape[0] < self.sg.e_pad:
                vals = np.concatenate(
                    [vals, np.zeros((self.sg.e_pad - vals.shape[0],
                                     vals.shape[1]), np.float32)])
        owner = _BINDING_OWNERS.get((program, param))
        owner = owner() if owner is not None else None
        if owner is not None and owner is not self:
            raise _registry.ChannelError(
                f"{program}.{param} is already bound and maintained by "
                "another live StreamSession — unbind it there first (one "
                "maintained binding per program param per process)")
        # reuse the already-coerced ChannelValue when padding didn't change
        # the bytes (coercion short-circuits on it: no second copy/hash);
        # the maintenance rebinds below pass raw arrays — ChannelValue
        # always takes a private copy, so the working array is safe as-is
        entry.bind_channel(
            param, cv if vals.shape == cv.values.shape else vals)
        ref = weakref.ref(self)
        _BINDING_OWNERS[(program, param)] = ref
        weakref.finalize(self, _release_binding, (program, param), ref,
                         entry)
        self._channels[(program, param)] = _BoundChannel(
            program, param, spec.channel, spec.features, vals, fill)
        _obs.get().event("stream.channel_bind", program=program,
                         param=param, channel=spec.channel,
                         features=spec.features, rows=vals.shape[0])

    def unbind_channel(self, program: str, param: str) -> None:
        """Release a maintained binding. Owner-checked: a session may only
        release a slot it owns (or a dead/free one) — otherwise one session
        could drop another's live binding and re-open the silent-clobber
        window the ownership map closes."""
        key = (program, param)
        owner = _BINDING_OWNERS.get(key)
        owner = owner() if owner is not None else None
        if owner is not None and owner is not self:
            raise _registry.ChannelError(
                f"{program}.{param} is bound and maintained by another "
                "live StreamSession — only its owner may unbind it")
        self._channels.pop(key, None)
        _BINDING_OWNERS.pop(key, None)
        _registry.get_program(program).unbind_channel(param)

    def _channel_scatter(self, changes: list[EdgeChange]) -> None:
        """Scatter inserted edges' feature rows into every bound edge
        plane (and re-bind, bumping the content digest). Runs before the
        plan is installed so patch and recompile paths see identical
        planes — patched == recompiled."""
        inserts = [c for c in changes if c.old < 0 and c.slot >= 0]
        if not inserts:
            return
        for bc in self._channels.values():
            if bc.channel != "edge":
                continue
            for c in inserts:
                row = (np.zeros(bc.features, np.float32) if bc.fill is None
                       else np.asarray(bc.fill(c.u, c.v),
                                       np.float32).reshape(bc.features))
                bc.values[c.slot] = row
            _registry.get_program(bc.program).bind_channel(
                bc.param, bc.values)
            _obs.get().event("stream.channel_rebind", program=bc.program,
                             param=bc.param, reason="insert_scatter",
                             rows=len(inserts))

    def _channel_remap(self, keep: np.ndarray) -> None:
        """Compaction epoch: remap every bound edge plane by the same slot
        gather the owner array uses, re-padded to the fresh e_pad."""
        for bc in self._channels.values():
            if bc.channel != "edge":
                continue
            vals = np.zeros((self.sg.e_pad, bc.features), np.float32)
            vals[:len(keep)] = bc.values[keep]
            bc.values = vals
            _registry.get_program(bc.program).bind_channel(
                bc.param, vals)
            _obs.get().event("stream.channel_rebind", program=bc.program,
                             param=bc.param, reason="compaction_remap",
                             rows=len(keep))

    def _patch(self, changes: list[EdgeChange]) -> None:
        if not changes:
            return
        self._channel_scatter(changes)
        delta = self._delta_of(changes)
        try:
            self.plan = patch_plan(self.plan, changes)
            self.engine = self.engine.with_plan(self.plan)
            self.n_patches += 1
            self.last_change = {"event": "patch", **delta}
            self._notify("patch")
        except SlackExhausted:
            self._recompile(delta)

    # -- update ingestion ---------------------------------------------------
    def apply(self, inserts=None, deletes=None) -> dict:
        """Ingest a batch of edge updates; returns maintenance stats."""
        inserts = np.zeros((0, 2), np.int64) if inserts is None else inserts
        deletes = np.zeros((0, 2), np.int64) if deletes is None else deletes
        with _obs.get().span("stream.apply", inserts=len(inserts),
                             deletes=len(deletes)):
            return self._apply(inserts, deletes)

    def _apply(self, inserts, deletes) -> dict:
        cfg = self.cfg
        t_apply = time.perf_counter()
        n_inserts_req = len(inserts)
        n_updates_req = n_inserts_req + len(deletes)
        changes: list[EdgeChange] = []

        u_live, v_live = self.sg.graph().as_numpy()
        own_live = self.owner[np.asarray(self.sg.graph().edge_mask)]
        presence, sizes, degrees = assign.seed_state(
            u_live, v_live, own_live, self.sg.n_vertices, self.k)

        for chunk in iter_chunks(deletes, cfg.chunk_size):
            res = self.sg.delete_chunk(chunk)
            for s, a, b in zip(res.slots.tolist(), res.u.tolist(),
                               res.v.tolist()):
                changes.append(EdgeChange(a, b, int(self.owner[s]), -1, s))
                self.owner[s] = -2
                self.touched[a] = self.touched[b] = True
            self.n_ingested += len(res.slots)

        for chunk in iter_chunks(inserts, cfg.chunk_size):
            if self.sg.free_slots() < len(chunk):
                # graph out of spare slots: compaction epoch (owner remaps
                # by the slot gather compact() returns, plan rebuilds)
                self._flush_via_compaction(changes)
                changes = []
            res = self.sg.insert_chunk(chunk)
            owners = assign.hdrf_assign(res.u, res.v, presence, sizes,
                                        degrees, lam=cfg.hdrf_lambda)
            for s, a, b, p in zip(res.slots.tolist(), res.u.tolist(),
                                  res.v.tolist(), owners.tolist()):
                self.owner[s] = p
                changes.append(EdgeChange(a, b, -1, int(p), s))
                self.touched[a] = self.touched[b] = True
            self.n_ingested += len(res.slots)

        self._patch(changes)

        reauction_info = self._reauction() if self._drifted() else None
        # feed the policy's telemetry: requested counts (dedup/no-op skips
        # included — they are offered load) + the batch's wall duration
        self.policy.on_apply(self, n_updates_req, n_inserts_req,
                             time.perf_counter() - t_apply)
        return {"epoch": self.epoch, "patches": self.n_patches,
                "recompiles": self.n_recompiles,
                "forced_recompiles": self.n_forced_recompiles,
                "idle_compactions": self.n_idle_compactions,
                "reauctions": self.n_reauctions,
                "rf": self.plan.replication_factor(),
                "rf_base": self.rf_base, "reauction": reauction_info}

    def _flush_via_compaction(self, pending: list[EdgeChange],
                              reason: str = "forced") -> None:
        """Compact the graph's slot space; pending patch changes are
        absorbed by the recompile (owner already reflects them)."""
        self._channel_scatter(pending)   # pending inserts' rows, old space
        delta = self._delta_of(pending)
        keep = self.sg.compact(headroom_frac=self.cfg.compaction_headroom)
        _obs.get().event("stream.compaction", kept=len(keep),
                         e_pad=self.sg.e_pad, epoch=self.epoch + 1,
                         reason=reason)
        owner = np.full(self.sg.e_pad, -2, np.int32)
        owner[:len(keep)] = self.owner[keep]
        self.owner = owner
        self._channel_remap(keep)
        self._recompile(delta, reason=reason)

    def idle_tick(self) -> bool:
        """Give the compaction policy an idle gap: compacts (and recompiles
        with policy-recommended slack) when the policy says the remaining
        headroom could not absorb the observed burst pattern.  Returns
        whether a compaction ran — the retrace it implies is paid HERE, in
        the gap, pre-empting a forced one mid-burst.  Serving layers call
        this between drains; it is cheap when the policy declines."""
        if not self.policy.should_compact(self):
            return False
        self.n_idle_compactions += 1
        with _obs.get().span("stream.idle_compaction"):
            self._flush_via_compaction([], reason="idle")
        return True

    # -- drift-triggered local re-auction -----------------------------------
    def _drifted(self) -> bool:
        rf_now = self.plan.replication_factor()
        return (bool(self.touched.any())
                and rf_now > (1.0 + self.cfg.drift_threshold) * self.rf_base)

    def _reauction(self) -> dict:
        g = self.sg.graph()
        new_owner, info = reauction.local_reauction(
            g, self.owner, self.touched, self.k, hops=self.cfg.hops,
            max_rounds=self.cfg.reauction_max_rounds)
        mask = np.asarray(g.edge_mask)
        moved = np.flatnonzero((new_owner != self.owner) & mask)
        u = np.asarray(g.src)
        v = np.asarray(g.dst)
        changes = [EdgeChange(int(u[s]), int(v[s]), int(self.owner[s]),
                              int(new_owner[s]), int(s)) for s in moved]
        # writable copy: local_reauction hands back a read-only jax-backed
        # view, and the next insert chunk assigns into this array in place
        self.owner = np.array(new_owner)
        _obs.get().event(
            "stream.reauction", moves=len(changes),
            **{k: v for k, v in info.items()
               if isinstance(v, (int, float, bool, str))})
        self._patch(changes)
        self.n_reauctions += 1
        self.touched[:] = False
        # re-baseline: drift is measured against the last correction point
        self.rf_base = self.plan.replication_factor()
        return info

    # -- queries ------------------------------------------------------------
    def graph(self):
        return self.sg.graph()

    def replication_factor(self) -> float:
        return self.plan.replication_factor()

"""Chunked edge-update ingestion into the static-shape ``Graph``.

``StreamingGraph`` is the mutable host-side front of the streaming
subsystem: it mirrors the Graph's flat slot arrays in numpy, keeps an exact
(u, v) -> slot index, and applies updates in fixed-size chunks:

  * insertions claim spare (masked) padded slots — the padding every Graph
    already carries for lane alignment becomes ingest headroom, and plans
    compiled against the padded shape stay shape-stable;
  * deletions clear ``edge_mask`` in place and return the slot to the free
    list;
  * when a chunk of insertions cannot fit in the remaining spare slots the
    graph is *compacted*: real edges are repacked into a prefix (keeping
    their relative slot order so parallel per-slot state like the owner
    array remaps with one gather) and re-padded with fresh headroom.  Each
    compaction bumps ``epoch`` — downstream, a compaction is the only event
    that recompiles plans / retraces jitted supersteps.

Updates are canonicalised exactly like ``graph.from_edge_array``: u < v,
self-loops dropped, duplicates (against the live edge set and within the
chunk) ignored.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from ..core.graph import Graph, apply_edge_updates


def _align(x: int, to: int = 128) -> int:
    return max(to, -(-x // to) * to)


@dataclasses.dataclass
class ApplyResult:
    """Slots touched by one chunk application (parallel arrays)."""
    slots: np.ndarray   # [M] int64 slot indices
    u: np.ndarray       # [M] int32 canonical endpoints (u < v)
    v: np.ndarray       # [M] int32


class StreamingGraph:
    """Mutable slot-level view over a static-shape Graph."""

    def __init__(self, g: Graph, chunk_size: int = 256):
        assert chunk_size > 0
        self.n_vertices = g.n_vertices
        self.chunk_size = int(chunk_size)
        self.epoch = 0
        self._u = np.asarray(g.src).copy()
        self._v = np.asarray(g.dst).copy()
        self._mask = np.asarray(g.edge_mask).copy()
        self._rebuild_index()
        self._graph_cache: Graph | None = g
        self._dirty: set[int] = set()

    # -- bookkeeping --------------------------------------------------------
    def _rebuild_index(self) -> None:
        live = np.flatnonzero(self._mask)
        keys = (self._u[live].astype(np.int64) * self.n_vertices
                + self._v[live])
        self._index = dict(zip(keys.tolist(), live.tolist()))
        self._free = np.flatnonzero(~self._mask).tolist()[::-1]  # stack

    @property
    def n_edges(self) -> int:
        return len(self._index)

    @property
    def e_pad(self) -> int:
        return len(self._u)

    def free_slots(self) -> int:
        return len(self._free)

    def _canon(self, edges) -> tuple[np.ndarray, np.ndarray]:
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        assert edges.size == 0 or (edges.min() >= 0
                                   and edges.max() < self.n_vertices), \
            "streamed endpoints must be existing vertex ids (|V| is static)"
        u = np.minimum(edges[:, 0], edges[:, 1])
        v = np.maximum(edges[:, 0], edges[:, 1])
        keep = u != v
        return u[keep].astype(np.int32), v[keep].astype(np.int32)

    # -- chunk application --------------------------------------------------
    def insert_chunk(self, edges) -> ApplyResult:
        """Insert up to ``chunk_size`` canonicalised edges into spare slots.
        Already-present edges are skipped. Raises if slots run out — callers
        check ``free_slots()`` and compact first."""
        u, v = self._canon(edges)
        assert len(u) <= self.chunk_size, "chunk exceeds the fixed chunk size"
        slots, au, av = [], [], []
        for a, b in zip(u.tolist(), v.tolist()):
            key = a * self.n_vertices + b
            if key in self._index:
                continue
            if not self._free:
                raise RuntimeError("no spare edge slots; compact() first")
            s = self._free.pop()
            self._u[s], self._v[s], self._mask[s] = a, b, True
            self._index[key] = s
            slots.append(s), au.append(a), av.append(b)
        self._dirty.update(slots)
        return ApplyResult(np.asarray(slots, np.int64),
                           np.asarray(au, np.int32), np.asarray(av, np.int32))

    def delete_chunk(self, edges) -> ApplyResult:
        """Delete up to ``chunk_size`` edges (unknown edges are skipped)."""
        u, v = self._canon(edges)
        assert len(u) <= self.chunk_size, "chunk exceeds the fixed chunk size"
        slots, au, av = [], [], []
        for a, b in zip(u.tolist(), v.tolist()):
            s = self._index.pop(a * self.n_vertices + b, None)
            if s is None:
                continue
            self._mask[s] = False
            self._free.append(s)
            slots.append(s), au.append(a), av.append(b)
        self._dirty.update(slots)
        return ApplyResult(np.asarray(slots, np.int64),
                           np.asarray(au, np.int32), np.asarray(av, np.int32))

    # -- compaction epoch ---------------------------------------------------
    def compact(self, headroom_frac: float = 0.5) -> np.ndarray:
        """Repack live edges into a prefix (relative order preserved) and
        re-pad with ``headroom_frac * |E|`` fresh spare slots. Bumps
        ``epoch``. Returns the old slot index of each new prefix slot so
        per-slot companion state (the owner array) remaps with one gather."""
        live = np.flatnonzero(self._mask)
        e = len(live)
        pad = _align(e + max(self.chunk_size, int(headroom_frac * e)))
        nu = np.zeros(pad, np.int32)
        nv = np.zeros(pad, np.int32)
        nm = np.zeros(pad, bool)
        nu[:e], nv[:e], nm[:e] = self._u[live], self._v[live], True
        self._u, self._v, self._mask = nu, nv, nm
        self._rebuild_index()
        self._graph_cache = None      # shape changed: full rebuild
        self._dirty.clear()
        self.epoch += 1
        return live

    # -- materialisation ----------------------------------------------------
    def graph(self) -> Graph:
        """Static-shape Graph over the current slot arrays. Incremental:
        only the slots dirtied since the last materialisation are rewritten
        (core.graph.apply_edge_updates); a compaction forces a full
        rebuild."""
        if self._graph_cache is None:
            self._graph_cache = Graph(
                self.n_vertices, self.n_edges, jnp.asarray(self._u),
                jnp.asarray(self._v), jnp.asarray(self._mask))
        elif self._dirty:
            s = np.fromiter(self._dirty, np.int64, len(self._dirty))
            self._graph_cache = apply_edge_updates(
                self._graph_cache, s, self._u[s], self._v[s], self._mask[s])
            self._dirty.clear()
        return self._graph_cache


def iter_chunks(edges, chunk_size: int):
    """Split an [M, 2] update list into fixed-size chunks (last one ragged)."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    for i in range(0, len(edges), chunk_size):
        yield edges[i:i + chunk_size]

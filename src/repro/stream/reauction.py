"""Bounded local re-auction: DFEP steps 1–2 on the h-hop region around
touched vertices.

Online HDRF placement (assign.py) is greedy and order-dependent; as updates
accumulate, its decisions drift away from what a fresh DFEP auction would
choose and the replication factor creeps up.  Instead of re-running the
full market, the session releases only the edges inside the h-hop
neighbourhood of the vertices touched since the last correction and lets
the paper's funding auction (core/dfep.py, ``run_dfep_region``) re-sell
them, with step-3 grants restricted to region vertices so the correction
cannot leak funding into untouched territory.  Ownership outside the region
is frozen; partitions anchor their bids on the presence they already hold
at the region boundary, so re-auctioned edges rejoin coherent territories.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..core import dfep
from ..core.graph import Graph


def h_hop_vertices(u: np.ndarray, v: np.ndarray, mask: np.ndarray,
                   n_vertices: int, seeds: np.ndarray, hops: int) -> np.ndarray:
    """Grow a vertex set by ``hops`` BFS levels over the live edges."""
    reach = seeds.copy()
    for _ in range(max(hops, 0)):
        hit = (reach[u] | reach[v]) & mask
        nxt = reach.copy()
        nxt[u[hit]] = True
        nxt[v[hit]] = True
        if np.array_equal(nxt, reach):
            break
        reach = nxt
    return reach


def local_reauction(g: Graph, owner: np.ndarray, touched: np.ndarray, k: int,
                    hops: int = 2, max_rounds: int = 400,
                    stall_rounds: int = 32, cap: int = 10
                    ) -> tuple[np.ndarray, dict]:
    """Re-auction the edges whose endpoints both lie in the h-hop region
    around ``touched`` vertices. Returns (new owner [E_pad], info).

    ``owner`` is the slot-parallel assignment (-2 at masked slots); only
    region edges can change hands. Slots are rebuilt here because ingestion
    mutates slot endpoints, staleing any cached sort.
    """
    u = np.asarray(g.src)
    v = np.asarray(g.dst)
    mask = np.asarray(g.edge_mask)
    region_v = h_hop_vertices(u, v, mask, g.n_vertices, touched, hops)
    active = mask & region_v[u] & region_v[v]
    n_active = int(active.sum())
    info = {"region_vertices": int(region_v.sum()), "active_edges": n_active,
            "rounds": 0}
    if n_active == 0:
        return owner.copy(), info

    slots = dfep.build_slots(g)
    cfg = dfep.DfepConfig(k=k, cap=cap, max_rounds=max_rounds,
                          stall_rounds=stall_rounds)
    st = dfep.run_dfep_region(g, slots, cfg, jnp.asarray(owner),
                              jnp.asarray(active), jnp.asarray(region_v))
    new_owner = st.owner
    unsold = int(jnp.sum(jnp.where(new_owner == dfep.FREE, 1, 0)))
    if unsold:
        new_owner = dfep.finalize(g, new_owner, k)
    new_owner = np.asarray(jnp.where(g.edge_mask, new_owner, -2))
    info["rounds"] = int(st.rounds)
    info["unsold_at_stop"] = unsold
    info["moved_edges"] = int(((new_owner != owner) & mask).sum())
    return new_owner, info

"""Trace exporters: JSONL and Chrome trace-event format (Perfetto-loadable).

Both exporters serialise the recorder's ring contents (oldest first).  The
JSONL export is the machine-diffable artifact CI uploads from the bench
smoke run; the Chrome trace loads directly in https://ui.perfetto.dev or
``chrome://tracing`` so a served request's span tree (admission -> batch ->
dispatch -> execute -> materialize) can be walked visually.

Chrome trace-event mapping (the subset we emit):

  * spans   -> complete events, ``ph: "X"`` with ``ts``/``dur`` in
    microseconds; ``args.span_id`` / ``args.parent_id`` carry the explicit
    tree (the serving drain interleaves batches, so stack-based nesting on
    one tid is not enough to reconstruct parenthood);
  * instants -> ``ph: "i"`` with thread scope (``s: "t"``);
  * every event gets ``pid`` 0 and the recording thread's ident as ``tid``.
"""
from __future__ import annotations

import json
from typing import Any

from .recorder import Recorder, get


def _chrome_event(e: dict) -> dict[str, Any]:
    out = {"name": e["name"], "ph": e["ph"], "ts": e["ts"],
           "pid": 0, "tid": e["tid"], "args": e["args"]}
    if e["ph"] == "X":
        out["dur"] = e["dur"]
    else:
        out["s"] = "t"
    return out


def export_jsonl(path: str, recorder: Recorder | None = None) -> int:
    """One JSON object per line per recorded event; returns the count."""
    rec = recorder if recorder is not None else get()
    events = rec.events()
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e, sort_keys=True, default=str) + "\n")
    return len(events)


def export_chrome_trace(path: str, recorder: Recorder | None = None) -> int:
    """Chrome trace-event JSON (``{"traceEvents": [...]}``); returns the
    event count.  Load in Perfetto / chrome://tracing."""
    rec = recorder if recorder is not None else get()
    events = [_chrome_event(e) for e in rec.events()]
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f,
                  sort_keys=True, default=str)
    return len(events)

"""Trace exporters: JSONL and Chrome trace-event format (Perfetto-loadable).

Both exporters serialise the recorder's ring contents (oldest first).  The
JSONL export is the machine-diffable artifact CI uploads from the bench
smoke run; the Chrome trace loads directly in https://ui.perfetto.dev or
``chrome://tracing`` so a served request's span tree (admission -> batch ->
dispatch -> execute -> materialize) can be walked visually.

Chrome trace-event mapping (the subset we emit):

  * spans   -> complete events, ``ph: "X"`` with ``ts``/``dur`` in
    microseconds; ``args.span_id`` / ``args.parent_id`` carry the explicit
    tree (the serving drain interleaves batches, so stack-based nesting on
    one tid is not enough to reconstruct parenthood);
  * instants -> ``ph: "i"`` with thread scope (``s: "t"``);
  * every event gets ``pid`` 0 and the recording thread's ident as ``tid``.

Dangling parents: the ring buffer overwrites oldest-first, so a long-lived
trace can keep a child span whose parent was already evicted.  The Chrome
exporter re-parents such spans to the root — ``parent_id`` is replaced by
``dangling_parent_id`` so the tree stays connected (Perfetto renders a
disconnected id as a silently separate track) while the original id stays
auditable; the bundle-level count lands in ``otherData.dangling_parents``.
The JSONL export stays verbatim (it is the machine-diffable artifact).
"""
from __future__ import annotations

import json
from typing import Any

from .recorder import Recorder, get


def _chrome_event(e: dict, span_ids: set | None = None) -> dict[str, Any]:
    args = e["args"]
    if span_ids is not None and args.get("parent_id") is not None \
            and args["parent_id"] not in span_ids:
        # parent span overwritten by ring wraparound: re-parent to root,
        # keep the original id for the audit trail (copy — never mutate
        # the recorder's live ring entries)
        args = dict(args)
        args["dangling_parent_id"] = args.pop("parent_id")
    out = {"name": e["name"], "ph": e["ph"], "ts": e["ts"],
           "pid": 0, "tid": e["tid"], "args": args}
    if e["ph"] == "X":
        out["dur"] = e["dur"]
    else:
        out["s"] = "t"
    return out


def export_jsonl(path: str, recorder: Recorder | None = None) -> int:
    """One JSON object per line per recorded event; returns the count."""
    rec = recorder if recorder is not None else get()
    events = rec.events()
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e, sort_keys=True, default=str) + "\n")
    return len(events)


def export_chrome_trace(path: str, recorder: Recorder | None = None) -> int:
    """Chrome trace-event JSON (``{"traceEvents": [...]}``); returns the
    event count.  Load in Perfetto / chrome://tracing."""
    rec = recorder if recorder is not None else get()
    raw = rec.events()
    span_ids = {e["args"]["span_id"] for e in raw
                if "span_id" in e["args"]}
    events = [_chrome_event(e, span_ids) for e in raw]
    n_dangling = sum("dangling_parent_id" in e["args"] for e in events)
    doc: dict[str, Any] = {"traceEvents": events, "displayTimeUnit": "ms"}
    if n_dangling:
        doc["otherData"] = {"dangling_parents": n_dangling}
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, default=str)
    return len(events)

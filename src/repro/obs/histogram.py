"""Mergeable log-bucketed streaming histograms with fixed memory.

``LogHistogram`` is the aggregation primitive the active observability
layer is built on: latencies (and any other positive quantity spanning
orders of magnitude) are counted into geometrically spaced buckets —
``buckets_per_decade`` per factor of 10 between ``lo`` and ``hi`` — so a
recorded stream of any length costs one fixed int64 array, one increment
per sample, and percentile queries never sort anything.  The price is
resolution: a percentile is exact only up to one log-bucket width
(``width_factor`` = 10^(1/buckets_per_decade), ±3.7% at the default 32
buckets per decade), which is the contract ``ServeMetrics`` is
regression-tested against and ``tolerances.json`` gates with.

Histograms with the same bucketing **merge associatively** (count arrays
add), so per-slot sub-histograms compose into any window — that is what
``WindowedHistogram`` does: a ring of time-sliced sub-histograms rotated
by a monotonic clock, answering windowed p50/p95/p99, event rates and
failure counts over "the last W seconds" for the burn-rate monitor
without ever growing memory.

Nothing here reads the wall clock: callers pass ``now`` from
``time.perf_counter()`` (or any monotonic source — tests inject a fake
clock), keeping the package's clock discipline.
"""
from __future__ import annotations

import math

import numpy as np


class LogHistogram:
    """Fixed-memory histogram over geometric buckets of a positive value.

    Values below ``lo`` clamp into the first bucket, above ``hi`` into the
    last — nothing is ever dropped, only blurred.  Exact ``min``/``max``
    are tracked on the side so the tails never leave the observed range.
    """

    __slots__ = ("lo", "hi", "buckets_per_decade", "_log_lo", "_scale",
                 "counts", "n", "total", "vmin", "vmax")

    def __init__(self, lo: float = 1e-7, hi: float = 1e4,
                 buckets_per_decade: int = 32):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        if buckets_per_decade < 1:
            raise ValueError("buckets_per_decade must be >= 1")
        self.lo = float(lo)
        self.hi = float(hi)
        self.buckets_per_decade = int(buckets_per_decade)
        self._log_lo = math.log10(self.lo)
        self._scale = float(buckets_per_decade)
        n_buckets = int(math.ceil(
            (math.log10(self.hi) - self._log_lo) * self._scale)) + 1
        self.counts = np.zeros(n_buckets, np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # -- geometry ------------------------------------------------------------
    @property
    def width_factor(self) -> float:
        """Multiplicative width of one bucket: the resolution contract."""
        return 10.0 ** (1.0 / self.buckets_per_decade)

    def same_buckets(self, other: "LogHistogram") -> bool:
        return (self.lo == other.lo and self.hi == other.hi
                and self.buckets_per_decade == other.buckets_per_decade)

    def _index(self, value: float) -> int:
        if value <= self.lo:
            return 0
        i = int((math.log10(value) - self._log_lo) * self._scale)
        return min(i, len(self.counts) - 1)

    def edge(self, i: int) -> float:
        """Lower edge of bucket ``i``."""
        return 10.0 ** (self._log_lo + i / self._scale)

    # -- recording -----------------------------------------------------------
    def record(self, value: float) -> None:
        v = float(value)
        self.counts[self._index(v)] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def record_many(self, values) -> None:
        vs = np.asarray(values, np.float64).reshape(-1)
        if vs.size == 0:
            return
        idx = np.clip(((np.log10(np.maximum(vs, self.lo)) - self._log_lo)
                       * self._scale).astype(np.int64),
                      0, len(self.counts) - 1)
        np.add.at(self.counts, idx, 1)
        self.n += int(vs.size)
        self.total += float(vs.sum())
        self.vmin = min(self.vmin, float(vs.min()))
        self.vmax = max(self.vmax, float(vs.max()))

    # -- merging (associative + commutative) ---------------------------------
    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Fold ``other`` into this histogram in place; returns self."""
        if not self.same_buckets(other):
            raise ValueError("cannot merge histograms with different buckets")
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def copy(self) -> "LogHistogram":
        h = LogHistogram(self.lo, self.hi, self.buckets_per_decade)
        h.counts = self.counts.copy()
        h.n, h.total, h.vmin, h.vmax = self.n, self.total, self.vmin, \
            self.vmax
        return h

    def clear(self) -> None:
        self.counts[:] = 0
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # -- queries -------------------------------------------------------------
    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q: float) -> float:
        """Approximate percentile: the geometric midpoint of the bucket
        holding the q-th sample, clamped to the exact observed [min, max]
        — within one log-bucket width of the sorted-array answer."""
        if self.n == 0:
            return 0.0
        target = max(1, int(math.ceil(q / 100.0 * self.n)))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += int(c)
            if cum >= target:
                mid = math.sqrt(self.edge(i) * self.edge(i + 1))
                return float(min(max(mid, self.vmin), self.vmax))
        return float(self.vmax)                    # not reachable

    def count_above(self, threshold: float) -> int:
        """Samples strictly above ``threshold``, at bucket resolution:
        counts whole buckets whose lower edge is >= the threshold's bucket
        upper edge (a value sharing the threshold's bucket counts as NOT
        above — the blur errs toward fewer violations)."""
        if self.n == 0:
            return 0
        i = self._index(float(threshold))
        return int(self.counts[i + 1:].sum())

    def stats(self) -> dict:
        return {"n": self.n, "mean": round(self.mean, 9),
                "min": 0.0 if self.n == 0 else self.vmin,
                "max": 0.0 if self.n == 0 else self.vmax,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class _Slot:
    """One time slice of a WindowedHistogram."""

    __slots__ = ("hist", "n_fail")

    def __init__(self, lo, hi, bpd):
        self.hist = LogHistogram(lo, hi, bpd)
        self.n_fail = 0

    def clear(self):
        self.hist.clear()
        self.n_fail = 0


class WindowedHistogram:
    """A ring of per-time-slot sub-histograms: windowed percentiles/rates.

    ``slots`` slices of ``slot_s`` seconds each — the longest answerable
    window is ``slots * slot_s``.  Recording advances the ring by the
    caller-supplied monotonic ``now`` (slices that time skipped over are
    zeroed); ``window(W, now)`` merges the slices covering the last ``W``
    seconds into one ``LogHistogram`` plus a failure count, so one ring
    serves every window the multi-window burn-rate monitor asks for.
    """

    def __init__(self, slot_s: float = 1.0, slots: int = 120,
                 lo: float = 1e-7, hi: float = 1e4,
                 buckets_per_decade: int = 32):
        if slot_s <= 0 or slots < 1:
            raise ValueError("need slot_s > 0 and slots >= 1")
        self.slot_s = float(slot_s)
        self.slots = int(slots)
        self._ring = [_Slot(lo, hi, buckets_per_decade)
                      for _ in range(self.slots)]
        self._cur: int | None = None       # absolute slot index of newest
        self._lo, self._hi, self._bpd = lo, hi, buckets_per_decade
        self.lifetime_n = 0
        self.lifetime_fail = 0

    @property
    def max_window_s(self) -> float:
        return self.slot_s * self.slots

    def _advance(self, now: float) -> _Slot:
        idx = int(now // self.slot_s)
        if self._cur is None:
            self._cur = idx
            self._ring[idx % self.slots].clear()
        elif idx > self._cur:
            # zero every slice time skipped over (cap one full revolution)
            for j in range(self._cur + 1,
                           min(idx, self._cur + self.slots) + 1):
                self._ring[j % self.slots].clear()
            self._cur = idx
        # idx < self._cur (a clock running backwards) clamps to the newest
        # slice rather than resurrecting an expired one
        return self._ring[self._cur % self.slots]

    def record(self, value: float, ok: bool = True, now: float = 0.0) -> None:
        """Record one sample at monotonic time ``now``.  ``ok=False`` marks
        a failure (rejection/error) — counted for availability, with the
        value still recorded (0-latency failures land in the lo bucket)."""
        slot = self._advance(float(now))
        slot.hist.record(value)
        if not ok:
            slot.n_fail += 1
            self.lifetime_fail += 1
        self.lifetime_n += 1

    def window(self, window_s: float, now: float
               ) -> tuple[LogHistogram, int]:
        """(merged histogram, failure count) over ``[now - window_s, now]``,
        at slot granularity (a partial oldest slot is included whole)."""
        out = LogHistogram(self._lo, self._hi, self._bpd)
        n_fail = 0
        if self._cur is None:
            return out, 0
        self._advance(float(now))          # expire slices time skipped over
        k = min(self.slots, max(1, int(math.ceil(window_s / self.slot_s))))
        for j in range(self._cur, self._cur - k, -1):
            if j < 0:
                break
            slot = self._ring[j % self.slots]
            out.merge(slot.hist)
            n_fail += slot.n_fail
        return out, n_fail

    def rate(self, window_s: float, now: float) -> float:
        """Events per second over the trailing window."""
        hist, _ = self.window(window_s, now)
        w = min(float(window_s), self.max_window_s)
        return hist.n / w if w > 0 else 0.0

    def stats(self, window_s: float, now: float) -> dict:
        hist, n_fail = self.window(window_s, now)
        out = hist.stats()
        out["window_s"] = min(float(window_s), self.max_window_s)
        out["n_fail"] = n_fail
        out["rate_per_s"] = round(hist.n / out["window_s"], 3) \
            if out["window_s"] > 0 else 0.0
        return out
